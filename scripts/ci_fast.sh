#!/usr/bin/env bash
# Fast tier-1 test run, exactly as CI executes it: fully offline, no
# network, no hypothesis required, slow integration tests excluded.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
# Capture/replay fast path first: a focused signal before the full sweep
# (these also run as part of the suite below).
python -m pytest -q tests/test_capture.py
# Multi-tenant QoS smoke: tiny contention scenario, priority weighting on
# vs off, plus the thread-safe submission pipeline tests.
python -m benchmarks.bench_multitenant --smoke
python -m pytest -q tests/test_multitenant.py
exec python -m pytest -q -m "not slow" "$@"
