#!/usr/bin/env bash
# Fast tier-1 test run, exactly as CI executes it: fully offline, no
# network, no hypothesis required, slow integration tests excluded.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
exec python -m pytest -q -m "not slow" "$@"
