#!/usr/bin/env bash
# Fast tier-1 test run, exactly as CI executes it: fully offline, no
# network, no hypothesis required, slow integration tests excluded.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
# Lint first — blocking (scope and rule families in ruff.toml: E9/F plus
# bugbear and pyupgrade).  Hosts without ruff fall through so the test
# tiers still run offline; CI always installs ruff and enforces the gate.
if command -v ruff >/dev/null 2>&1; then
  ruff check .
else
  echo "[ci_fast] ruff not installed; lint enforced by CI only"
fi
# Access-mode lint: every registered GrFunction's declared const/out/inout
# modes checked against its traced jaxpr (plus the examples' declarations).
# Exit 1 on any under-/over-declaration.
python -m repro.analysis lint \
  --file examples/quickstart.py \
  --file examples/serve_lm.py \
  --file examples/train_lm.py
# Capture/replay fast path first: a focused signal before the full sweep
# (these also run as part of the suite below).
python -m pytest -q tests/test_capture.py
# Frontend API overhead smoke: asserts GrFunction stays near the legacy
# shim's cost and captured replay collapses per-launch overhead.
python -m benchmarks.bench_api_overhead --smoke
# Multi-tenant QoS smoke: tiny contention scenario, priority weighting on
# vs off, plus the thread-safe submission pipeline tests.
python -m benchmarks.bench_multitenant --smoke
python -m pytest -q tests/test_multitenant.py
# Memory-budget smoke: tiny out-of-core scenario on sim + real executors;
# fails fast when it records zero spills (spill path not exercised) or the
# budgeted makespan exceeds 2x the unlimited run.
python -m benchmarks.bench_memory --smoke
# Plan-time optimizer smoke: locality-heavy and out-of-core captures run
# greedy vs optimized; fails fast when the optimized makespan or the
# spill/D2D traffic exceeds greedy, or the optimizer never fired.
python -m benchmarks.bench_planopt --smoke
# Deadline/SLO smoke: bulk-vs-latency contention with and without
# deadlines; fails fast when the p99 improvement drops under the floor,
# the makespan regresses >10%, or EDF/preemption never engaged.
python -m benchmarks.bench_slo --smoke
python -m pytest -q tests/test_slo.py
# Runtime-daemon smoke: IPC overhead gate vs in-process execution plus the
# spike-and-cooldown admission scenario (sheds under overload, admits 100%
# when calm); the socket round-trip itself is covered by
# tests/test_daemon.py::test_cli_socket_roundtrip_smoke in the sweep below.
python -m benchmarks.bench_daemon --smoke
# Static-analysis smoke: lint wall-time ceiling, happens-before verifier
# over a captured benchsuite plan, and the sanitizer-mode overhead gate
# (sanitize=True must stay within 2x of the plain eager sim run).
python -m benchmarks.bench_analysis --smoke
exec python -m pytest -q -m "not slow" "$@"
