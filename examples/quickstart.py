"""Quickstart: the GrJAX polyglot frontend in 30 lines.

Declare each kernel ONCE with its access modes (`gr.function`), enter an
ambient runtime, and write plain sequential host code: the runtime infers
the dependency DAG, assigns lanes (streams), inserts events, prefetches
host-resident inputs, allocates declared outputs, and overlaps everything
it can — exactly the paper's programming model (Fig. 4), with JAX kernels.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np
import jax

import repro.api as gr

# Declare once: access modes, output specs — never re-annotated at calls.
square = gr.function(jax.jit(lambda x, _out: x * x),
                     modes=("const", "out"), outputs=0, name="square")
reduce_diff = gr.function(jax.jit(lambda a, b, _out: (a - b).sum()[None]),
                          modes=("const", "const", "out"),
                          outputs=((1,), np.float32), name="RED")

with gr.runtime(policy="parallel") as sched:
    # managed arrays (the UM-backed polyglot arrays of the paper)
    x1 = gr.array(np.random.rand(1 << 16).astype(np.float32), name="x1")
    x2 = gr.array(np.random.rand(1 << 16).astype(np.float32), name="x2")

    # Plain function calls — the scheduler runs the two squares on separate
    # lanes, prefetches x1/x2 asynchronously, serializes RED behind both,
    # and allocates y1/y2/z from the declared output specs.
    y1 = square(x1)
    y2 = square(x2)
    z = reduce_diff(y1, y2)

    print("z =", float(z[0]))           # host read -> syncs only RED's lane
    print("scheduler stats:", sched.stats())
    assert np.isclose(float(z[0]),
                      float((np.asarray(y1) - np.asarray(y2)).sum()),
                      rtol=1e-4)
    kernels = [e for e in sched._elements if e.kind.value == "kernel"]
    print("OK: two branches ran on",
          len({e.stream for e in kernels}), "lanes")
    sched.shutdown()
