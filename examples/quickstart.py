"""Quickstart: the GrJAX runtime scheduler in 30 lines.

Write plain sequential host code against managed arrays; the runtime infers
the dependency DAG, assigns lanes (streams), inserts events, prefetches
host-resident inputs, and overlaps everything it can — exactly the paper's
programming model (Fig. 4), with JAX kernels.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np
import jax

from repro.core import make_scheduler, const, inout, out

sched = make_scheduler("parallel")

# managed arrays (the UM-backed polyglot arrays of the paper)
x1 = sched.array(np.random.rand(1 << 16).astype(np.float32), name="x1")
x2 = sched.array(np.random.rand(1 << 16).astype(np.float32), name="x2")
y1 = sched.array(shape=(1 << 16,), dtype=np.float32, name="y1")
y2 = sched.array(shape=(1 << 16,), dtype=np.float32, name="y2")
z = sched.array(shape=(1,), dtype=np.float32, name="z")

square = jax.jit(lambda x, _out: x * x)
reduce_diff = jax.jit(lambda a, b, _out: (a - b).sum()[None])

# Plain sequential issue order — the scheduler runs SQ1 ∥ SQ2 on separate
# lanes, prefetches x1/x2 asynchronously, and serializes RED behind both.
sched.launch(square, [const(x1), out(y1)], name="SQ1")
sched.launch(square, [const(x2), out(y2)], name="SQ2")
sched.launch(reduce_diff, [const(y1), const(y2), out(z)], name="RED")

print("z =", float(z[0]))               # host read -> syncs only RED's lane
print("scheduler stats:", sched.stats())
assert np.isclose(float(z[0]),
                  float((np.asarray(y1) - np.asarray(y2)).sum()), rtol=1e-4)
print("OK: two branches ran on",
      len({e.stream for e in sched._elements if e.kind.value == 'kernel'}),
      "lanes")
sched.shutdown()
