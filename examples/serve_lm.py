"""Serving driver: batched autoregressive decoding with prefill + KV cache,
with *space-sharing* across concurrent request batches via the GrJAX
scheduler (independent batches land on separate lanes — the paper's
multi-task overlap applied to inference).

    PYTHONPATH=src python examples/serve_lm.py --requests 4 --new-tokens 16
"""
import argparse
import time

import numpy as np
import jax
import jax.numpy as jnp

import repro.api as gr
from repro.configs import get_config
from repro.core.managed import ManagedValue
from repro.models import init_cache, init_lm
from repro.runtime import make_decode_step, make_prefill_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3_12b")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=True)
    params = init_lm(jax.random.PRNGKey(0), cfg)
    prefill = jax.jit(make_prefill_step(cfg))
    decode = jax.jit(make_decode_step(cfg))

    sched = gr.make_scheduler("parallel")
    params_v = ManagedValue(sched, params, name="weights")
    rng = np.random.RandomState(0)
    max_len = args.prompt_len + args.new_tokens

    def kernel(p, toks, _out):
        """One request batch: prefill then greedy decode (device kernel)."""
        cache = init_cache(cfg, toks.shape[0], max_len)
        logits, cache = prefill(p, {"tokens": toks}, cache)
        nxt = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        outs = [nxt]
        pos = toks.shape[1]
        for i in range(args.new_tokens - 1):
            nxt, _, cache = decode(p, nxt, cache, jnp.int32(pos + i))
            outs.append(nxt)
        return jnp.concatenate(outs, axis=1)

    # Declared once: const weights, const prompts, out generated tokens.
    serve = gr.function(kernel, modes=("const", "const", "out"),
                        name="serve", scheduler=sched)

    t0 = time.time()
    results = []
    for r in range(args.requests):
        toks = sched.array(
            rng.randint(0, cfg.vocab,
                        (args.batch, args.prompt_len)).astype(np.int32),
            name=f"req{r}")
        out_toks = sched.array(
            np.zeros((args.batch, args.new_tokens), np.int32),
            name=f"gen{r}")
        # independent requests share read-only weights -> separate lanes
        serve.with_options(name=f"serve_req{r}")(params_v, toks, out_toks)
        results.append(out_toks)

    texts = [np.asarray(r) for r in results]     # host reads sync per-lane
    dt = time.time() - t0
    total = args.requests * args.batch * args.new_tokens
    print(f"served {args.requests} request batches "
          f"({total} tokens) in {dt:.2f}s -> {total/dt:.1f} tok/s")
    print("lanes used:", sched.streams.lanes_created,
          "| events:", sched.streams.events_created)
    for r, t in enumerate(texts[:2]):
        print(f"req{r} sample tokens:", t[0][:8], "...")
    sched.shutdown()


if __name__ == "__main__":
    main()
