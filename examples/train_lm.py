"""End-to-end training driver: train an LM through the TaskGraphTrainer —
the paper's scheduler overlapping data loading / H2D / compute / metrics /
checkpointing at step granularity.

Default is a ~20M-param qwen3-family config sized for this CPU container;
pass ``--arch qwen3_32b --full --steps 300`` on a real pod for the 100M+
regime (the same code path lowers to the production mesh via
repro.launch.train).

    PYTHONPATH=src python examples/train_lm.py --steps 30
"""
import argparse
import dataclasses
import time

import numpy as np

from repro.configs import get_config
from repro.optim import AdamW
from repro.runtime import TaskGraphTrainer


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3_32b")
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--full", action="store_true",
                    help="use the full published config (pod-scale!)")
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--d-model", type=int, default=256,
                    help="width of the reduced config (~20M params at 256)")
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=not args.full)
    if not args.full:
        cfg = dataclasses.replace(cfg, d_model=args.d_model,
                                  n_heads=8, n_kv_heads=4, head_dim=32,
                                  d_ff=args.d_model * 4, n_layers=4,
                                  vocab=8192)
    n_params = cfg.param_count()
    print(f"arch={cfg.name} params={n_params/1e6:.1f}M seq={args.seq} "
          f"batch={args.batch} accum={args.accum}")

    trainer = TaskGraphTrainer(
        cfg, seq_len=args.seq, global_batch=args.batch, accum=args.accum,
        optimizer=AdamW(lr=3e-4, warmup=20, total_steps=max(100, args.steps)),
        ckpt_dir=args.ckpt, ckpt_every=20)
    t0 = time.time()
    report = trainer.run(args.steps, metrics_every=5)
    dt = time.time() - t0
    toks = args.steps * args.batch * args.seq
    print(f"steps={report.steps_run} wall={dt:.1f}s "
          f"tokens/s={toks/dt:.0f} stragglers={report.stragglers}")
    print("losses:", [round(l, 3) for l in report.losses])
    print("scheduler:", trainer.sched.stats())
    trainer.sched.shutdown()


if __name__ == "__main__":
    main()
