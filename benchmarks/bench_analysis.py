"""Static-analysis benchmark: lint wall time, verifier coverage, and the
sanitizer runtime-mode overhead.

Three measurements, each with fail-fast gates (``BENCH_analysis.json``):

* **lint** — the access-mode checker over every in-repo ``GrFunction``
  declaration (same module set as ``python -m repro.analysis lint``).
  Gates: zero issues on the shipped declarations and wall time <= 10 s —
  the lint runs in ci_fast.sh on every push, so it has to stay cheap.

* **verify** — capture a benchsuite episode on the simulator and run the
  happens-before verifier over the live window and the cached plan.
  Gates: zero violations, and at least one plan with a non-trivial
  element count actually verified (an empty sweep proves nothing).

* **sanitizer** — the same eager multi-branch scenario on the simulator
  with ``sanitize=False`` vs ``sanitize=True`` (per-element version-vector
  checks on every start/finish).  Gates: sanitized wall time <= 2x plain
  (3x in smoke — tiny runs amortize less), every element checked, zero
  races on a race-free workload.
"""
from __future__ import annotations

import importlib
import json
import time

from repro.analysis.cli import _LINT_MODULES
from repro.analysis.modes import lint_functions
from repro.analysis.verifier import verify_scheduler
from repro.benchsuite import BENCHMARKS, build_task_parallel
from repro.benchsuite.costmodel import P100, sim_hardware
from repro.core import make_scheduler

from .common import emit


# ----------------------------------------------------------------------
def bench_lint() -> dict:
    t0 = time.perf_counter()
    for mod in _LINT_MODULES:
        importlib.import_module(mod)
    from repro.daemon import jobs as _jobs
    _jobs._jax_chain_fns()          # job kernels declare lazily; poke them
    reports = lint_functions()
    wall_s = time.perf_counter() - t0
    issues = [str(i) for r in reports for i in r.issues]
    return {"functions": len(reports),
            "skipped": sum(1 for r in reports if r.skipped),
            "issues": issues, "wall_s": wall_s}


# ----------------------------------------------------------------------
def bench_verify(smoke: bool) -> dict:
    bench = BENCHMARKS["HITS"]
    data = bench.make_data(0.001 if smoke else 0.01)
    s = make_scheduler("parallel", simulate=True,
                       hw=sim_hardware(P100, "parallel", True))
    try:
        with s.capture("bench_verify"):
            bench.build(s, data, gpu=P100, iters=2)
        t0 = time.perf_counter()
        violations = [str(v) for v in verify_scheduler(s)]
        wall_s = time.perf_counter() - t0
        plans = s.plan_cache.all_plans()
        plan_elements = sum(len(p.elements) for p in plans)
        s.sync()
    finally:
        s.shutdown()
    return {"plans": len(plans), "plan_elements": plan_elements,
            "violations": violations, "wall_s": wall_s}


# ----------------------------------------------------------------------
def _eager_scenario(sanitize: bool, *, branches: int, chain: int,
                    reps: int) -> dict:
    walls = []
    checked = races = 0
    for _ in range(reps):
        s = make_scheduler("parallel", simulate=True, sanitize=sanitize)
        try:
            t0 = time.perf_counter()
            build_task_parallel(s, branches=branches, chain=chain, n=1 << 10)
            s.sync()
            walls.append(time.perf_counter() - t0)
            if sanitize:
                st = s.stats()
                checked = st["sanitizer_elements_checked"]
                races = st["sanitizer_races_detected"]
        finally:
            s.shutdown()
    return {"wall_s": min(walls), "elements_checked": checked,
            "races": races}


def bench_sanitizer(smoke: bool) -> dict:
    branches, chain = (3, 4) if smoke else (6, 12)
    reps = 3 if smoke else 5
    plain = _eager_scenario(False, branches=branches, chain=chain, reps=reps)
    sane = _eager_scenario(True, branches=branches, chain=chain, reps=reps)
    return {"branches": branches, "chain": chain,
            "plain_wall_s": plain["wall_s"],
            "sanitize_wall_s": sane["wall_s"],
            "ratio": sane["wall_s"] / max(plain["wall_s"], 1e-9),
            "elements_checked": sane["elements_checked"],
            "races": sane["races"]}


# ----------------------------------------------------------------------
def main(smoke: bool = False) -> list:
    max_lint_s = 10.0
    max_ratio = 3.0 if smoke else 2.0
    lint = bench_lint()
    verify = bench_verify(smoke)
    sani = bench_sanitizer(smoke)
    result = {"lint": lint, "verify": verify, "sanitizer": sani,
              "max_lint_s": max_lint_s, "max_sanitizer_ratio": max_ratio}
    rows = [
        ("analysis/lint", lint["wall_s"] * 1e6,
         f"functions={lint['functions']} skipped={lint['skipped']} "
         f"issues={len(lint['issues'])} (gate <= {max_lint_s:.0f}s)"),
        ("analysis/verify", verify["wall_s"] * 1e6,
         f"plans={verify['plans']} elements={verify['plan_elements']} "
         f"violations={len(verify['violations'])}"),
        ("analysis/sanitizer", sani["sanitize_wall_s"] * 1e6,
         f"plain_us={sani['plain_wall_s'] * 1e6:.0f} "
         f"ratio={sani['ratio']:.2f} checked={sani['elements_checked']} "
         f"races={sani['races']} (gate <= {max_ratio}x)"),
    ]
    if not smoke:
        with open("BENCH_analysis.json", "w") as f:
            json.dump(result, f, indent=2, sort_keys=True)
    emit(rows)
    # Fail-fast gates: the analysis passes run in CI on every push, so
    # they must stay cheap, quiet on shipped code, and actually engaged.
    assert not lint["issues"], f"shipped declarations mis-declared: {lint}"
    assert lint["wall_s"] <= max_lint_s, (
        f"lint took {lint['wall_s']:.1f}s > {max_lint_s:.0f}s budget")
    assert not verify["violations"], verify["violations"]
    assert verify["plans"] >= 1 and verify["plan_elements"] >= 10, (
        f"verifier swept a trivial plan set: {verify}")
    assert sani["elements_checked"] > 0, "sanitizer hooks never fired"
    assert sani["races"] == 0, f"false-positive races: {sani}"
    assert sani["ratio"] <= max_ratio, (
        f"sanitize=True cost {sani['ratio']:.2f}x > {max_ratio}x eager sim")
    return rows


if __name__ == "__main__":
    import sys
    main(smoke="--smoke" in sys.argv)
