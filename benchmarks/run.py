"""Benchmark harness: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV (see DESIGN.md §7 for the mapping
to the paper's tables/figures).
"""
from __future__ import annotations

import sys
import traceback


def main() -> None:
    from . import (bench_api_overhead, bench_capture, bench_contention,
                   bench_daemon, bench_hwmetrics, bench_memory,
                   bench_multidevice, bench_multitenant, bench_oracle,
                   bench_overlap, bench_planopt, bench_roofline, bench_slo,
                   bench_speedup)

    suites = [
        ("API overhead: legacy vs GrFunction vs replay "
         "(BENCH_api_overhead.json)", bench_api_overhead),
        ("Fig.7 speedup-vs-serial", bench_speedup),
        ("Fig.8 vs-hand-optimized", bench_oracle),
        ("Capture/replay vs eager vs oracle (BENCH_capture.json)",
         bench_capture),
        ("Fig.9 contention", bench_contention),
        ("Fig.11 overlap", bench_overlap),
        ("Fig.12 hw-metrics", bench_hwmetrics),
        ("Table.I memory + out-of-core spill (BENCH_memory.json)",
         bench_memory),
        ("Plan-time optimizer: min-cut placement + Belady memory "
         "(BENCH_planopt.json)", bench_planopt),
        ("Roofline (dry-run)", bench_roofline),
        ("Multi-device scaling", bench_multidevice),
        ("Multi-tenant QoS (BENCH_multitenant.json)", bench_multitenant),
        ("Deadline/SLO: EDF + boundary preemption (BENCH_slo.json)",
         bench_slo),
        ("Runtime daemon: IPC overhead + admission control "
         "(BENCH_daemon.json)", bench_daemon),
    ]
    failed = []
    for title, mod in suites:
        print(f"# === {title} ===")
        try:
            mod.main()
        except Exception:
            traceback.print_exc()
            failed.append(title)
    if failed:
        print(f"# FAILED: {failed}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
