"""Memory benchmarks: Table I footprints + the out-of-core spill scenario.

Two parts:

* **Table I** — benchmark memory footprints across input scales/GPUs
  (which testbeds each workload fits in, unchanged from earlier PRs);
* **Out-of-core** — the budgeted-memory acceptance run (ISSUE 5): the
  benchsuite two-pass streaming scenario with working set ≈ 2× the device
  budget, on the simulator (makespan vs the unlimited run) and on the real
  executor (end-to-end correctness through spill + reload).  Results land
  in ``BENCH_memory.json``.

The run **fails fast** when the budgeted scenario records zero spills —
that would mean the benchmark stopped exercising the spill path and the
acceptance numbers are vacuous.
"""
from __future__ import annotations

import json

from repro.benchsuite import BENCHMARKS, GPUS
from repro.benchsuite.outofcore import (build_outofcore, verify_outofcore,
                                        working_set_bytes)
from repro.core import make_scheduler

from .common import emit

# Acceptance: budgeted makespan <= RATIO_LIMIT x unlimited, >= 1 spill.
RATIO_LIMIT = 2.0


def _mem_stats(sched) -> dict:
    return {k: v for k, v in sched.stats().items()
            if k.startswith("mem_") and not isinstance(v, dict)}


def run_outofcore(budget, *, simulate: bool, chunks: int, n: int) -> dict:
    s = make_scheduler("parallel", simulate=simulate, memory_budget=budget)
    try:
        arrays = build_outofcore(s, chunks=chunks, n=n)
        ok = True if simulate else verify_outofcore(arrays)
        s.sync()
        return {"makespan_s": s.timeline.makespan, "correct": bool(ok),
                **_mem_stats(s)}
    finally:
        s.shutdown()


def table1_rows() -> list:
    rows = []
    for bname, bench in BENCHMARKS.items():
        for scale in (0.02, 0.1, 0.5, 1.0):
            fb = bench.footprint_bytes(scale)
            fits = ",".join(g for g, spec in GPUS.items()
                            if fb <= spec.mem_gb * 0.9 * 2 ** 30)
            rows.append((f"table1/{bname}/scale{scale}", 0.0,
                         f"footprint_gb={fb / 2 ** 30:.2f};fits=[{fits}]"))
    return rows


def main(smoke: bool = False) -> list:
    chunks, n = (6, 1 << 10) if smoke else (8, 1 << 16)
    budget = working_set_bytes(chunks, n) // 2    # working set = 2x budget

    unlimited = run_outofcore(None, simulate=True, chunks=chunks, n=n)
    budgeted = run_outofcore(budget, simulate=True, chunks=chunks, n=n)
    # The real-executor correctness pass runs on smaller chunks (it moves
    # actual bytes); its budget scales with its own working set.
    real_n = min(n, 1 << 12)
    real = run_outofcore(working_set_bytes(chunks, real_n) // 2,
                         simulate=False, chunks=chunks, n=real_n)
    ratio = budgeted["makespan_s"] / max(unlimited["makespan_s"], 1e-12)

    rows = [] if smoke else table1_rows()
    rows.append(("outofcore/sim/unlimited", unlimited["makespan_s"] * 1e6,
                 f"spills={unlimited['mem_spills']}"))
    rows.append(("outofcore/sim/budgeted", budgeted["makespan_s"] * 1e6,
                 f"spills={budgeted['mem_spills']} "
                 f"spill_mb={budgeted['mem_spill_bytes'] / 2 ** 20:.2f} "
                 f"makespan_ratio={ratio:.3f}"))
    rows.append(("outofcore/real/budgeted", real["makespan_s"] * 1e6,
                 f"spills={real['mem_spills']} correct={real['correct']}"))

    result = {"budget_bytes": budget,
              "working_set_bytes": working_set_bytes(chunks, n),
              "sim_unlimited": unlimited, "sim_budgeted": budgeted,
              "real_budgeted": real, "makespan_ratio": ratio}
    if not smoke:
        with open("BENCH_memory.json", "w") as f:
            json.dump(result, f, indent=2, sort_keys=True)
    emit(rows)

    # Fail-fast gates: the whole point of the scenario is to exercise the
    # spill path within the acceptance envelope.
    if budgeted["mem_spills"] < 1 or real["mem_spills"] < 1:
        raise SystemExit("bench_memory: out-of-core scenario recorded zero "
                         "spills — the spill path is not being exercised")
    if unlimited["mem_spills"] != 0 or unlimited["mem_evict_blocks"] != 0:
        raise SystemExit("bench_memory: unlimited-budget run spilled — "
                         "budget accounting is broken")
    if not real["correct"]:
        raise SystemExit("bench_memory: out-of-core results diverge from "
                         "the reference on the real executor")
    if ratio > RATIO_LIMIT:
        raise SystemExit(f"bench_memory: budgeted makespan is {ratio:.2f}x "
                         f"the unlimited run (limit {RATIO_LIMIT}x)")
    return rows


if __name__ == "__main__":
    import sys
    main(smoke="--smoke" in sys.argv)
