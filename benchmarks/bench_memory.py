"""Memory benchmarks: Table I footprints + the out-of-core spill scenarios.

Three parts:

* **Table I** — benchmark memory footprints across input scales/GPUs
  (which testbeds each workload fits in, unchanged from earlier PRs);
* **Out-of-core** — the budgeted-memory acceptance run (ISSUE 5): the
  benchsuite two-pass streaming scenario with working set ≈ 2× the device
  budget, on the simulator (makespan vs the unlimited run) and on the real
  executor (end-to-end correctness through spill + reload);
* **Tiered spill** (ISSUE 6) — the same scenario pinned to a budgeted
  device on two-device hardware with large, transfer-bound chunks, under
  three spill policies: flat D2H (the PR 5 baseline), a peer-device tier
  (spill over the fast D2D link to the idle device) and a lossy
  compressed-host tier (half wire volume).  Per-tier spill/reload bytes
  land in ``BENCH_memory.json``.

The run **fails fast** when the budgeted scenario records zero spills,
when a tiered run stops using its tier, or when a tiered makespan is
*slower* than flat D2H (peer must be strictly faster) — that would mean
the tier stack stopped doing its job and the acceptance numbers are
vacuous.
"""
from __future__ import annotations

import json

from repro.benchsuite import BENCHMARKS, GPUS
from repro.benchsuite.outofcore import (build_outofcore, verify_outofcore,
                                        working_set_bytes)
from repro.core import CompressedHostTier, PeerDeviceTier, make_scheduler

from .common import emit

# Acceptance: budgeted makespan <= RATIO_LIMIT x unlimited, >= 1 spill.
RATIO_LIMIT = 2.0


def _mem_stats(sched) -> dict:
    return {k: v for k, v in sched.stats().items()
            if k.startswith("mem_") and not isinstance(v, dict)}


def run_outofcore(budget, *, simulate: bool, chunks: int, n: int) -> dict:
    s = make_scheduler("parallel", simulate=simulate, memory_budget=budget)
    try:
        arrays = build_outofcore(s, chunks=chunks, n=n)
        ok = True if simulate else verify_outofcore(arrays)
        s.sync()
        return {"makespan_s": s.timeline.makespan, "correct": bool(ok),
                "reload_stall_s": s.timeline.reload_stall_s(),
                **_mem_stats(s)}
    finally:
        s.shutdown()


def run_tiered(tiers, *, chunks: int, n: int, cost_s: float = 1e-5) -> dict:
    """One tiered-spill simulation: two devices, the compute pinned to a
    budgeted device 0 (budget = half the working set) with device 1 idle
    and unbounded, so the tier stack competes on *spill placement* alone.
    ``tiers=None`` is the flat-D2H baseline on identical hardware."""
    budget = {0: working_set_bytes(chunks, n) // 2, 1: None}
    s = make_scheduler("parallel", simulate=True, num_devices=2,
                       memory_budget=budget, spill_tiers=tiers)
    try:
        build_outofcore(s, chunks=chunks, n=n, cost_s=cost_s, device=0)
        s.sync()
        tier_stats = s.stats().get("mem_tiers", {})
        return {"makespan_s": s.timeline.makespan,
                "reload_stall_s": s.timeline.reload_stall_s(),
                **_mem_stats(s), "tiers": tier_stats}
    finally:
        s.shutdown()


def table1_rows() -> list:
    rows = []
    for bname, bench in BENCHMARKS.items():
        for scale in (0.02, 0.1, 0.5, 1.0):
            fb = bench.footprint_bytes(scale)
            fits = ",".join(g for g, spec in GPUS.items()
                            if fb <= spec.mem_gb * 0.9 * 2 ** 30)
            rows.append((f"table1/{bname}/scale{scale}", 0.0,
                         f"footprint_gb={fb / 2 ** 30:.2f};fits=[{fits}]"))
    return rows


def main(smoke: bool = False) -> list:
    chunks, n = (6, 1 << 10) if smoke else (8, 1 << 16)
    budget = working_set_bytes(chunks, n) // 2    # working set = 2x budget

    unlimited = run_outofcore(None, simulate=True, chunks=chunks, n=n)
    budgeted = run_outofcore(budget, simulate=True, chunks=chunks, n=n)
    # The real-executor correctness pass runs on smaller chunks (it moves
    # actual bytes); its budget scales with its own working set.
    real_n = min(n, 1 << 12)
    real = run_outofcore(working_set_bytes(chunks, real_n) // 2,
                         simulate=False, chunks=chunks, n=real_n)
    ratio = budgeted["makespan_s"] / max(unlimited["makespan_s"], 1e-12)

    rows = [] if smoke else table1_rows()
    rows.append(("outofcore/sim/unlimited", unlimited["makespan_s"] * 1e6,
                 f"spills={unlimited['mem_spills']}"))
    rows.append(("outofcore/sim/budgeted", budgeted["makespan_s"] * 1e6,
                 f"spills={budgeted['mem_spills']} "
                 f"spill_mb={budgeted['mem_spill_bytes'] / 2 ** 20:.2f} "
                 f"reload_mb={budgeted['mem_reload_bytes'] / 2 ** 20:.2f} "
                 f"reload_stall_us={budgeted['reload_stall_s'] * 1e6:.1f} "
                 f"makespan_ratio={ratio:.3f}"))
    rows.append(("outofcore/real/budgeted", real["makespan_s"] * 1e6,
                 f"spills={real['mem_spills']} "
                 f"reload_mb={real['mem_reload_bytes'] / 2 ** 20:.2f} "
                 f"correct={real['correct']}"))

    # Tiered-spill comparison: transfer-bound chunks (a 4 MiB chunk costs
    # ~350 us over PCIe vs ~84 us over the D2D link) so spill *placement*
    # is what the makespan measures.
    t_chunks, t_n = (6, 1 << 16) if smoke else (8, 1 << 20)
    flat = run_tiered(None, chunks=t_chunks, n=t_n)
    peer = run_tiered([PeerDeviceTier()], chunks=t_chunks, n=t_n)
    comp = run_tiered([CompressedHostTier(lossy=True)],
                      chunks=t_chunks, n=t_n)
    peer_ratio = flat["makespan_s"] / max(peer["makespan_s"], 1e-12)
    comp_ratio = flat["makespan_s"] / max(comp["makespan_s"], 1e-12)
    rows.append(("outofcore/tiered/flat-d2h", flat["makespan_s"] * 1e6,
                 f"spills={flat['mem_spills']}"))
    rows.append(("outofcore/tiered/peer-device", peer["makespan_s"] * 1e6,
                 f"spills={peer['mem_spills']} speedup={peer_ratio:.2f}x"))
    rows.append(("outofcore/tiered/compressed-host", comp["makespan_s"] * 1e6,
                 f"spills={comp['mem_spills']} speedup={comp_ratio:.2f}x"))

    result = {"budget_bytes": budget,
              "working_set_bytes": working_set_bytes(chunks, n),
              "sim_unlimited": unlimited, "sim_budgeted": budgeted,
              "real_budgeted": real, "makespan_ratio": ratio,
              "tiered": {"flat_d2h": flat, "peer_device": peer,
                         "compressed_host": comp,
                         "peer_speedup": peer_ratio,
                         "compressed_speedup": comp_ratio}}
    if not smoke:
        with open("BENCH_memory.json", "w") as f:
            json.dump(result, f, indent=2, sort_keys=True)
    emit(rows)

    # Fail-fast gates: the whole point of the scenario is to exercise the
    # spill path within the acceptance envelope.
    if budgeted["mem_spills"] < 1 or real["mem_spills"] < 1:
        raise SystemExit("bench_memory: out-of-core scenario recorded zero "
                         "spills — the spill path is not being exercised")
    if unlimited["mem_spills"] != 0 or unlimited["mem_evict_blocks"] != 0:
        raise SystemExit("bench_memory: unlimited-budget run spilled — "
                         "budget accounting is broken")
    if not real["correct"]:
        raise SystemExit("bench_memory: out-of-core results diverge from "
                         "the reference on the real executor")
    if ratio > RATIO_LIMIT:
        raise SystemExit(f"bench_memory: budgeted makespan is {ratio:.2f}x "
                         f"the unlimited run (limit {RATIO_LIMIT}x)")
    # Tiered gates: each tier must actually take the spills routed at it,
    # the peer tier must strictly beat flat D2H, and no tier may be slower
    # than the flat baseline it is supposed to improve on.
    peer_t = peer["tiers"].get("peer-device", {})
    comp_t = comp["tiers"].get("compressed-host", {})
    if peer_t.get("spills", 0) < 1 or comp_t.get("spills", 0) < 1:
        raise SystemExit("bench_memory: a tiered run recorded zero tier "
                         "spills — victims are bypassing the stack")
    if peer["makespan_s"] >= flat["makespan_s"]:
        raise SystemExit(
            f"bench_memory: peer-device tier ({peer['makespan_s']*1e3:.3f} "
            f"ms) is not faster than flat D2H "
            f"({flat['makespan_s']*1e3:.3f} ms)")
    if comp["makespan_s"] > flat["makespan_s"] * (1 + 1e-9):
        raise SystemExit(
            f"bench_memory: compressed-host tier "
            f"({comp['makespan_s']*1e3:.3f} ms) is slower than flat D2H "
            f"({flat['makespan_s']*1e3:.3f} ms)")
    return rows


if __name__ == "__main__":
    import sys
    main(smoke="--smoke" in sys.argv)
