"""Table I: benchmark memory footprints across input scales/GPUs."""
from __future__ import annotations

from repro.benchsuite import BENCHMARKS, GPUS

from .common import emit


def main() -> list:
    rows = []
    for bname, bench in BENCHMARKS.items():
        for scale in (0.02, 0.1, 0.5, 1.0):
            fb = bench.footprint_bytes(scale)
            fits = ",".join(g for g, spec in GPUS.items()
                            if fb <= spec.mem_gb * 0.9 * 2 ** 30)
            rows.append((f"table1/{bname}/scale{scale}", 0.0,
                         f"footprint_gb={fb / 2 ** 30:.2f};fits=[{fits}]"))
    emit(rows)
    return rows


if __name__ == "__main__":
    main()
