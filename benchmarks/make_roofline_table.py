"""Generate the §Roofline markdown table from results/dryrun and append it
to EXPERIMENTS.md (idempotent: replaces the generated block)."""
from __future__ import annotations

import glob
import json
import os

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
RESULTS = os.path.join(ROOT, "results", "dryrun")
EXP = os.path.join(ROOT, "EXPERIMENTS.md")
BEGIN = "<!-- ROOFLINE-TABLE:BEGIN -->"
END = "<!-- ROOFLINE-TABLE:END -->"


def build_table() -> str:
    lines = [
        BEGIN,
        "",
        "### Single-pod (16x16) baseline table — all 40 cells",
        "",
        "| arch | shape | fits HBM | compute (ms) | memory (ms) |"
        " collective (ms) | dominant | roofline frac |"
        " useful-FLOPs ratio |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    multi_rows = []
    for path in sorted(glob.glob(os.path.join(RESULTS, "*.json"))):
        res = json.load(open(path))
        tag = os.path.basename(path)[:-5]
        arch, shape, meshk = tag.rsplit("__", 2)
        if "skipped" in res:
            row = (f"| {arch} | {shape} | — | — | — | — | skip | — | — |"
                   f" <!-- {res['skipped'][:60]} -->")
            (lines if meshk == "single" else multi_rows).append(row)
            continue
        if "error" in res:
            row = f"| {arch} | {shape} | ERROR | | | | | | |"
            (lines if meshk == "single" else multi_rows).append(row)
            continue
        r, m = res["roofline"], res["memory"]
        if m["fits_hbm"]:
            fits = "yes"
        else:
            fits = f"**no** ({m['hbm_fraction']:.1f}x)"
        row = (f"| {arch} | {shape} | {fits} | "
               f"{r['compute_s']*1e3:.1f} | {r['memory_s']*1e3:.1f} | "
               f"{r['collective_s']*1e3:.1f} | {r['dominant'].replace('_s','')} | "
               f"{r['roofline_fraction']:.3f} | {r['useful_flops_ratio']:.2f} |")
        (lines if meshk == "single" else multi_rows).append(row)

    lines += ["", "### Multi-pod (2x16x16) — pod-axis sharding proof", "",
              "| arch | shape | fits HBM | compute (ms) | memory (ms) |"
              " collective (ms) | dominant | roofline frac |"
              " useful-FLOPs ratio |",
              "|---|---|---|---|---|---|---|---|---|"]
    lines += multi_rows
    lines += ["", END]
    return "\n".join(lines)


def main() -> None:
    table = build_table()
    text = open(EXP).read()
    if BEGIN in text:
        pre = text[:text.index(BEGIN)]
        post = text[text.index(END) + len(END):]
        text = pre + table + post
    else:
        marker = "## §Perf — hillclimbing log"
        idx = text.index(marker)
        text = text[:idx] + table + "\n\n" + text[idx:]
    open(EXP, "w").write(text)
    n = table.count("\n| ")
    print(f"wrote roofline table ({n} rows)")


if __name__ == "__main__":
    main()
