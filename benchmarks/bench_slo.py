"""Deadline/SLO benchmark (simulated): EDF ordering + element-boundary
preemption vs the deadline-blind scheduler under bulk-vs-latency contention.

Runs the benchsuite SLO scenario twice on one simulated device with the
bulk tenant quota-folded onto 4 lanes — ``baseline`` (no deadlines; the
PR 7 scheduler, both tenants priority 0) and ``deadline`` (every latency
launch carries ``deadline_s``) — and reports the latency tenant's p99
completion latency, SLO attainment, the aggregate makespan and the EDF
engagement counters.

Acceptance targets (ISSUE 8), enforced as fail-fast gates: deadline'd p99
for the latency tenant improves >= 2x over the baseline, aggregate makespan
regresses <= 10%, and the EDF machinery actually engaged (deadlines
stamped, EDF fill rounds taken, preemption fired).  Smoke mode shrinks the
workload but keeps the same gates with a relaxed improvement floor.
Results land in ``BENCH_slo.json``.
"""
from __future__ import annotations

import json

from repro.benchsuite.slo import (BULK_TENANT, LATENCY_TENANT,
                                  build_slo_workload)
from repro.core import make_scheduler

from .common import emit

BULK_QUOTA = 4


def run_slo(use_deadlines: bool, **kw):
    s = make_scheduler(simulate=True, num_devices=1,
                       tenant_quotas={BULK_TENANT: BULK_QUOTA})
    build_slo_workload(s, use_deadlines=use_deadlines, **kw)
    s.sync()
    ts = s.tenant_stats()
    st = s.stats()
    lat = ts[LATENCY_TENANT]
    out = {
        "makespan_s": s.timeline.makespan,
        "latency_p99_s": lat["latency_p99_s"],
        "latency_p50_s": lat["latency_p50_s"],
        "bulk_makespan_s": ts[BULK_TENANT]["makespan_s"],
        "slo_attainment": lat.get("slo_attainment"),
        "deadline_elements": st.get("deadline_elements", 0),
        "edf_fill_rounds": st.get("edf_fill_rounds", 0),
        "edf_preemptions": st.get("edf_preemptions", 0),
        "edf_preempt_events": st.get("edf_preempt_events", 0),
    }
    s.shutdown()
    return out


def main(smoke: bool = False) -> list:
    # Smoke keeps two latency chains (the second chain's refill pressure is
    # what trips preemption) and halves the bulk flood.
    kw = ({"bulk_units": 16, "latency_chains": 2, "per_chain": 4}
          if smoke else {})
    min_improvement = 1.3 if smoke else 2.0
    base = run_slo(use_deadlines=False, **kw)
    dl = run_slo(use_deadlines=True, **kw)
    improvement = base["latency_p99_s"] / dl["latency_p99_s"]
    mk_ratio = dl["makespan_s"] / base["makespan_s"]
    result = {"baseline": base, "deadline": dl,
              "latency_p99_improvement": improvement,
              "makespan_ratio": mk_ratio}
    rows = [
        ("slo/baseline", base["latency_p99_s"] * 1e6,
         f"makespan_us={base['makespan_s'] * 1e6:.1f}"),
        ("slo/deadline", dl["latency_p99_s"] * 1e6,
         f"p99_improvement={improvement:.2f} "
         f"makespan_ratio={mk_ratio:.3f} "
         f"slo_attainment={dl['slo_attainment']} "
         f"preemptions={dl['edf_preemptions']}"),
    ]
    if not smoke:
        with open("BENCH_slo.json", "w") as f:
            json.dump(result, f, indent=2, sort_keys=True)
    emit(rows)
    # Fail-fast gates: a silent regression here is a broken tentpole.
    assert improvement >= min_improvement, (
        f"SLO p99 improvement {improvement:.2f}x < {min_improvement}x")
    assert mk_ratio <= 1.10, f"makespan regression {mk_ratio:.3f} > 1.10"
    assert dl["deadline_elements"] > 0, "no deadlines were stamped"
    assert dl["edf_fill_rounds"] > 0, "EDF capacity fill never engaged"
    assert dl["edf_preemptions"] > 0, "element-boundary preemption never fired"
    assert base["deadline_elements"] == 0, "baseline run saw deadlines"
    return rows


if __name__ == "__main__":
    import sys
    main(smoke="--smoke" in sys.argv)
