"""Fig. 8: runtime scheduler vs hand-optimized scheduling (CUDA-Graphs
analogue = oracle with the full DAG known in advance, zero overhead)."""
from __future__ import annotations

from repro.benchsuite import BENCHMARKS, GPUS

from .common import emit, run_sim


def main() -> list:
    rows = []
    for gname, gpu in GPUS.items():
        for bname, bench in BENCHMARKS.items():
            tp, _, _ = run_sim(bench, gpu, "parallel")
            to, _, _ = run_sim(bench, gpu, "parallel", oracle=True)
            rows.append((f"fig8/{gname}/{bname}", tp * 1e6,
                         f"oracle_over_runtime={to / tp:.4f}"))
    emit(rows)
    return rows


if __name__ == "__main__":
    main()
