"""Fig. 9: slowdown vs the contention-free bound (every kernel at solo
speed even when overlapped) — how much space-sharing contention costs."""
from __future__ import annotations

from repro.benchsuite import BENCHMARKS, GTX1660S
from repro.benchsuite import costmodel

from .common import emit, run_sim


def main() -> list:
    rows = []
    gpu = GTX1660S
    for bname, bench in BENCHMARKS.items():
        tp, _, _ = run_sim(bench, gpu, "parallel")
        costmodel.OCCUPANCY_SCALE = 0.0          # contention-free bound
        try:
            tfree, _, _ = run_sim(bench, gpu, "parallel")
        finally:
            costmodel.OCCUPANCY_SCALE = 1.0
        rows.append((f"fig9/{bname}", tp * 1e6,
                     f"relative_to_contention_free={tfree / tp:.3f}"))
    emit(rows)
    return rows


if __name__ == "__main__":
    main()
