"""Per-launch API overhead: legacy ``s.launch`` vs ``GrFunction.__call__``
vs captured replay.

The frontend adds one Python layer (mode zipping, option resolution) on top
of the submission engine; capture/replay removes the whole per-launch
scheduling path.  This benchmark measures the *host-side wall-clock* cost
per issued kernel for all three surfaces against the discrete-event
simulator (so device time never pollutes the measurement), and writes
``BENCH_api_overhead.json`` so the overhead trajectory is machine-readable
across PRs.

    python -m benchmarks.bench_api_overhead [--smoke]
"""
from __future__ import annotations

import argparse
import json
import statistics
import time

import numpy as np

from repro.core import const, make_scheduler, out
from repro.core.frontend import function

from .common import emit

N_ARRAYS = 4
KERNELS_PER_EPISODE = 8     # chain pairs + reduce, see _issue_*
COST_S = 1e-5

STAGE = function(None, modes=("const", "out"), name="api_k", cost_s=COST_S)


def _arrays(s, tag):
    return [s.array(np.zeros(64, np.float32), name=f"{tag}_a{i}")
            for i in range(N_ARRAYS)]


def _issue_legacy(s, xs):
    for k in range(KERNELS_PER_EPISODE):
        src, dst = xs[k % 2], xs[2 + k % 2]
        s.launch(None, [const(src), out(dst)], name=f"api_k{k}",
                 cost_s=COST_S)


def _issue_frontend(s, xs, fns):
    for k in range(KERNELS_PER_EPISODE):
        fns[k](xs[k % 2], xs[2 + k % 2], scheduler=s)


def _episode_fns():
    """One with_options variant per kernel position, resolved once (the
    declare-once idiom: per-call rebinding is what legacy launch pays)."""
    return [STAGE.with_options(name=f"api_k{k}")
            for k in range(KERNELS_PER_EPISODE)]


def run_mode(mode: str, episodes: int, warmup: int):
    """Median (wall_us, sim_us) per issued kernel.

    ``wall_us`` is host Python time spent in the call surface; ``sim_us``
    is the simulated per-launch scheduling overhead the executor charged
    (``launch_overhead_s`` eagerly, one plan-launch overhead per replayed
    episode) — the deterministic cudaGraphLaunch-analogue saving."""
    s = make_scheduler("parallel", simulate=True)
    xs = _arrays(s, mode)
    fns = _episode_fns()
    wall, sim = [], []
    for _ep in range(warmup + episodes):
        t0 = time.perf_counter()
        t0s = s.executor.host_time
        if mode == "legacy":
            _issue_legacy(s, xs)
        elif mode == "grfunction":
            _issue_frontend(s, xs, fns)
        else:                                   # captured replay
            with s.capture("api_episode"):
                _issue_frontend(s, xs, fns)
        wall.append((time.perf_counter() - t0) / KERNELS_PER_EPISODE)
        sim.append((s.executor.host_time - t0s) / KERNELS_PER_EPISODE)
        s.sync()
    if mode == "replay":
        assert s.stats()["plan_replays"] >= episodes - 2, \
            "capture stopped replaying: the fast path regressed"
    return (statistics.median(wall[warmup:]) * 1e6,
            statistics.median(sim[warmup:]) * 1e6)


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny run for CI (asserts the replay fast path)")
    ap.add_argument("--episodes", type=int, default=None)
    args = ap.parse_args(argv)
    episodes = args.episodes or (20 if args.smoke else 200)
    warmup = 3

    result = {"kernels_per_episode": KERNELS_PER_EPISODE,
              "episodes": episodes}
    for mode in ("legacy", "grfunction", "replay"):
        w, m = run_mode(mode, episodes, warmup)
        result[f"{mode}_wall_us_per_launch"] = w
        result[f"{mode}_sim_overhead_us_per_launch"] = m
    result["grfunction_over_legacy_wall"] = (
        result["grfunction_wall_us_per_launch"]
        / result["legacy_wall_us_per_launch"])
    result["replay_sim_overhead_speedup"] = (
        result["grfunction_sim_overhead_us_per_launch"]
        / result["replay_sim_overhead_us_per_launch"])
    with open("BENCH_api_overhead.json", "w") as f:
        json.dump(result, f, indent=2, sort_keys=True)
    emit([(f"api_overhead/{m}", result[f"{m}_wall_us_per_launch"],
           f"sim_overhead_us={result[f'{m}_sim_overhead_us_per_launch']:.2f}")
          for m in ("legacy", "grfunction", "replay")])
    emit([("api_overhead/ratios", 0.0,
           f"grfunction_over_legacy_wall="
           f"{result['grfunction_over_legacy_wall']:.2f},"
           f"replay_sim_overhead_speedup="
           f"{result['replay_sim_overhead_speedup']:.2f}")])
    if args.smoke:
        # The declared frontend must stay within a small constant factor of
        # the legacy shim, and steady-state replay must collapse per-launch
        # scheduling overhead (the deterministic, simulated metric).
        assert result["grfunction_over_legacy_wall"] < 3.0, result
        assert result["replay_sim_overhead_speedup"] > 4.0, result
    return result


if __name__ == "__main__":
    main()
