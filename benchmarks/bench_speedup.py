"""Fig. 7: parallel-scheduler speedup over the serial GrCUDA scheduler,
per benchmark x GPU (simulated on the calibrated cost model)."""
from __future__ import annotations

from repro.benchsuite import BENCHMARKS, GPUS

from .common import emit, geomean, run_sim


def main() -> list:
    rows = []
    per_gpu = {}
    for gname, gpu in GPUS.items():
        speedups = []
        for bname, bench in BENCHMARKS.items():
            ts, _, _ = run_sim(bench, gpu, "serial")
            tp, _, _ = run_sim(bench, gpu, "parallel")
            sp = ts / tp
            speedups.append(sp)
            rows.append((f"fig7/{gname}/{bname}", tp * 1e6,
                         f"speedup_vs_serial={sp:.3f}"))
        per_gpu[gname] = geomean(speedups)
        rows.append((f"fig7/{gname}/geomean", 0.0,
                     f"geomean_speedup={per_gpu[gname]:.3f}"))
    overall = geomean(list(per_gpu.values()))
    rows.append(("fig7/overall", 0.0,
                 f"geomean_speedup={overall:.3f} (paper: 1.44)"))
    emit(rows)
    return rows


if __name__ == "__main__":
    main()
