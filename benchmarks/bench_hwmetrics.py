"""Fig. 12: hardware-utilization metrics per policy — aggregate device
memory throughput and GFLOPS rise under parallel scheduling for benchmarks
with computation overlap."""
from __future__ import annotations

from collections import defaultdict

from repro.benchsuite import BENCHMARKS, GTX1660S
from repro.core import make_scheduler
from repro.benchsuite.costmodel import sim_hardware

from .common import ITERS, SCALE, emit


def main() -> list:
    rows = []
    gpu = GTX1660S
    for bname, bench in BENCHMARKS.items():
        for policy in ("serial", "parallel"):
            s = make_scheduler(policy, simulate=True,
                               hw=sim_hardware(gpu, policy))
            # intercept launches to accumulate flops/bytes
            totals = defaultdict(float)
            orig = s.launch

            def launch(fn, args, name="", cost_s=0.0, **cfg):
                totals["flops"] += cfg.pop("_flops", 0.0)
                totals["bytes"] += cfg.pop("_bytes", 0.0)
                return orig(fn, args, name=name, cost_s=cost_s, **cfg)

            # benchsuite doesn't pass _flops; recompute from cost model:
            # reuse the kernel launch records via history after the run.
            bench.build(s, bench.make_data(SCALE), gpu=gpu, iters=ITERS)
            mk = s.timeline.makespan
            comp_busy = s.timeline.busy_time("compute")
            # throughput proxies: busy-compute fraction scales the device's
            # peak rates (Fig. 12's "higher utilization under overlap")
            util = comp_busy / mk if mk else 0.0
            rows.append((f"fig12/{bname}/{policy}", mk * 1e6,
                         f"mem_tput={util * gpu.mem_gbps:.0f}GBps;"
                         f"gflops={util * gpu.fp32_tflops * 1e3:.0f};"
                         f"busy_frac={util:.2f}"))
    emit(rows)
    return rows


if __name__ == "__main__":
    main()
