"""Plan-time optimizer benchmarks (planopt.py) -> BENCH_planopt.json.

Two gate scenarios, each run greedy (``plan_optimize=False``) vs optimized
under capture/replay on the simulator:

* **locality-heavy / 2 devices / round-robin** — the worst case for
  location-blind placement: every scattered hop drags a persistent array
  across the D2D link.  The min-cut refinement must coalesce each group's
  chain onto one device (>= 20% D2D-byte reduction; in practice ~100%)
  without hurting makespan.
* **out-of-core / 1 device / budget = working set / 2** — the reactive-LRU
  thrash case: LRU spills the intermediates pass 2 is about to read and
  reloads them on demand.  The Belady rewrite must spill no more bytes
  than LRU and strictly reduce the re-upload (reload) traffic.

The run **fails fast** when the optimized plan loses any gate — slower
makespan, insufficient D2D reduction, more spill/reload bytes, or plans
that never actually replayed (a vacuous comparison).
"""
from __future__ import annotations

import json

from repro.benchsuite.multidevice import build_locality_heavy
from repro.benchsuite.outofcore import build_outofcore, working_set_bytes
from repro.core import make_scheduler
from repro.core.element import ElementKind

from .common import emit

EPISODES = 3            # 1 record + 2 replays
D2D_REDUCTION = 0.20    # locality-heavy gate: >= 20% fewer D2D bytes


def _plan_bytes(sched, name: str, kind: ElementKind) -> int:
    return sum(pe.transfer_bytes
               for plan in sched.plan_cache.candidates(name)
               for pe in plan.elements if pe.kind is kind)


def run_locality(optimize: bool, *, groups: int, iters: int, n: int) -> dict:
    s = make_scheduler("parallel", simulate=True, num_devices=2,
                       placement="round-robin", plan_optimize=optimize)
    try:
        for _ in range(EPISODES):
            with s.capture("planopt_loc"):
                build_locality_heavy(s, groups=groups, iters=iters, n=n)
            s.sync()
        st = s.stats()
        plans = s.plan_cache.candidates("planopt_loc")
        return {"makespan_s": s.timeline.makespan,
                "plan_d2d_bytes": _plan_bytes(s, "planopt_loc",
                                              ElementKind.D2D),
                "d2d_transfers": st["d2d_transfers"],
                "plan_replays": st["plan_replays"],
                "optimized": any(p.optimized for p in plans)}
    finally:
        s.shutdown()


def run_outofcore_opt(optimize: bool, *, chunks: int, n: int) -> dict:
    budget = working_set_bytes(chunks, n) // 2
    s = make_scheduler("parallel", simulate=True,
                       memory_budget=budget, plan_optimize=optimize)
    try:
        for _ in range(EPISODES):
            with s.capture("planopt_ooc"):
                build_outofcore(s, chunks=chunks, n=n)
            s.sync()
        st = s.stats()
        plans = s.plan_cache.candidates("planopt_ooc")
        return {"makespan_s": s.timeline.makespan,
                "spill_bytes": st["mem_spill_bytes"],
                "reload_bytes": st["mem_reload_bytes"],
                "evicts_scheduled": st["mem_evicts_scheduled"],
                "reload_stall_s": s.timeline.reload_stall_s(),
                "plan_replays": st["plan_replays"],
                "optimized": any(p.optimized for p in plans),
                "mem_scheduled": any(p.mem_scheduled for p in plans)}
    finally:
        s.shutdown()


def main(smoke: bool = False) -> list:
    groups, iters, n = (2, 3, 1 << 12) if smoke else (4, 6, 1 << 20)
    loc_greedy = run_locality(False, groups=groups, iters=iters, n=n)
    loc_opt = run_locality(True, groups=groups, iters=iters, n=n)

    o_chunks, o_n = (6, 1 << 10) if smoke else (8, 1 << 16)
    ooc_greedy = run_outofcore_opt(False, chunks=o_chunks, n=o_n)
    ooc_opt = run_outofcore_opt(True, chunks=o_chunks, n=o_n)

    d2d_cut = 1.0 - (loc_opt["plan_d2d_bytes"]
                     / max(loc_greedy["plan_d2d_bytes"], 1))
    rows = [
        ("planopt/locality/greedy", loc_greedy["makespan_s"] * 1e6,
         f"plan_d2d_mb={loc_greedy['plan_d2d_bytes'] / 2 ** 20:.2f}"),
        ("planopt/locality/optimized", loc_opt["makespan_s"] * 1e6,
         f"plan_d2d_mb={loc_opt['plan_d2d_bytes'] / 2 ** 20:.2f} "
         f"d2d_reduction={d2d_cut:.0%}"),
        ("planopt/outofcore/greedy-lru", ooc_greedy["makespan_s"] * 1e6,
         f"spill_mb={ooc_greedy['spill_bytes'] / 2 ** 20:.2f} "
         f"reload_mb={ooc_greedy['reload_bytes'] / 2 ** 20:.2f}"),
        ("planopt/outofcore/belady", ooc_opt["makespan_s"] * 1e6,
         f"spill_mb={ooc_opt['spill_bytes'] / 2 ** 20:.2f} "
         f"reload_mb={ooc_opt['reload_bytes'] / 2 ** 20:.2f} "
         f"evicts_scheduled={ooc_opt['evicts_scheduled']}"),
    ]
    result = {"locality": {"greedy": loc_greedy, "optimized": loc_opt,
                           "d2d_reduction": d2d_cut},
              "outofcore": {"greedy_lru": ooc_greedy, "belady": ooc_opt}}
    if not smoke:
        with open("BENCH_planopt.json", "w") as f:
            json.dump(result, f, indent=2, sort_keys=True)
    emit(rows)

    # Fail-fast gates (the ISSUE's acceptance criteria).
    eps = 1e-9
    for tag, g, o in (("locality", loc_greedy, loc_opt),
                      ("outofcore", ooc_greedy, ooc_opt)):
        if o["plan_replays"] < EPISODES - 1 or g["plan_replays"] < EPISODES - 1:
            raise SystemExit(f"bench_planopt: {tag} plans did not replay "
                             f"— the comparison is vacuous")
        if not o["optimized"]:
            raise SystemExit(f"bench_planopt: {tag} optimizer never fired")
        if o["makespan_s"] > g["makespan_s"] * (1 + eps):
            raise SystemExit(
                f"bench_planopt: optimized {tag} makespan "
                f"({o['makespan_s'] * 1e3:.3f} ms) exceeds greedy "
                f"({g['makespan_s'] * 1e3:.3f} ms)")
    if loc_opt["plan_d2d_bytes"] > loc_greedy["plan_d2d_bytes"] \
            * (1 - D2D_REDUCTION):
        raise SystemExit(
            f"bench_planopt: locality-heavy D2D reduction {d2d_cut:.0%} "
            f"is below the {D2D_REDUCTION:.0%} gate")
    if ooc_opt["spill_bytes"] > ooc_greedy["spill_bytes"]:
        raise SystemExit(
            f"bench_planopt: Belady spill bytes ({ooc_opt['spill_bytes']}) "
            f"exceed LRU ({ooc_greedy['spill_bytes']})")
    if ooc_opt["spill_bytes"] + ooc_opt["reload_bytes"] \
            >= ooc_greedy["spill_bytes"] + ooc_greedy["reload_bytes"]:
        raise SystemExit(
            "bench_planopt: Belady spill+reload traffic is not strictly "
            "below LRU — the memory schedule is not paying for itself")
    if not ooc_opt["mem_scheduled"]:
        raise SystemExit("bench_planopt: the out-of-core plan carries no "
                         "Belady schedule (mem_scheduled=False)")
    return rows


if __name__ == "__main__":
    import sys
    main(smoke="--smoke" in sys.argv)
