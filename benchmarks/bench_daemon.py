"""Runtime-daemon benchmark: IPC submission overhead + admission control
under an open-loop spike-and-cooldown arrival scenario.

Three measurements, each with fail-fast gates (``BENCH_daemon.json``):

* **ipc** — identical warmed ``chain`` jobs executed in-process
  (``run_job`` on a local scheduler) vs through the daemon socket
  (submit + wait round trips, lifecycle journaling, admission sampling).
  Gate: daemon wall time per job <= 2x in-process (3x in smoke — tiny jobs
  amortize less).

* **spike** — open-loop arrivals: a calm trickle, then burst waves faster
  than the single worker drains.  The monitor's depth/rate detectors open a
  cooldown window and the policy sheds low-priority work and defers
  dispatch.  Gates: sheds > 0, defer events > 0, >=1 spike detected, and
  every shed journaled with a ``shed:`` reason.

* **calm control** — the same daemon configuration fed only the trickle:
  zero sheds, 100% admission.  (Admission control that sheds without a
  spike is just broken admission.)
"""
from __future__ import annotations

import json
import os
import tempfile
import time

from repro.core import make_scheduler
from repro.daemon import (AdmissionPolicy, DaemonClient, DaemonServer,
                          RuntimeMonitor)
from repro.daemon.jobs import run_job
from repro.daemon.lifecycle import JobState, validate_history

from .common import emit


def _percentile(xs, q):
    ys = sorted(xs)
    if not ys:
        return 0.0
    k = (len(ys) - 1) * q
    lo = int(k)
    hi = min(lo + 1, len(ys) - 1)
    return ys[lo] + (ys[hi] - ys[lo]) * (k - lo)


# ----------------------------------------------------------------------
def bench_ipc_overhead(smoke: bool) -> dict:
    jobs = 8 if smoke else 20
    params = {"n": 4 if smoke else 8,
              "size": 2048 if smoke else 65536, "digest": True}

    # In-process reference: same handler, same scheduler machinery, no
    # socket / journal / lifecycle.  Warm jit first on both paths' shapes.
    sched = make_scheduler("parallel")
    run_job(sched, "chain", dict(params, seed=999))
    t0 = time.perf_counter()
    for i in range(jobs):
        run_job(sched, "chain", dict(params, seed=i))
    in_proc_s = (time.perf_counter() - t0) / jobs
    sched.close()

    # Daemon path: in-process server (same interpreter => same warm jit
    # cache), persistent client connection, full submit->wait round trip.
    tmp = tempfile.mkdtemp(prefix="bench_daemon_")
    srv = DaemonServer(os.path.join(tmp, "d.sock"),
                       store_path=os.path.join(tmp, "jobs.jsonl"),
                       workers=1, monitor_interval_s=0.05).start()
    try:
        with DaemonClient(srv.socket_path) as c:
            c.result(c.submit("chain",
                              dict(params, seed=999))["job_id"],
                     timeout=300)          # warm the daemon's scheduler
            t0 = time.perf_counter()
            for i in range(jobs):
                jid = c.submit("chain", dict(params, seed=i))["job_id"]
                c.result(jid, timeout=300)
            daemon_s = (time.perf_counter() - t0) / jobs
    finally:
        srv.stop()
    return {"jobs": jobs, "in_process_us": in_proc_s * 1e6,
            "daemon_us": daemon_s * 1e6, "ratio": daemon_s / in_proc_s}


# ----------------------------------------------------------------------
def _spike_daemon(tmp: str) -> DaemonServer:
    return DaemonServer(
        os.path.join(tmp, "d.sock"),
        store_path=os.path.join(tmp, "jobs.jsonl"),
        sched_kw={"simulate": True}, workers=1,
        policy=AdmissionPolicy(max_queue_depth=24, spike_shed_depth=4,
                               shed_below_priority=1, max_running=1,
                               defer_backoff_s=0.01),
        monitor=RuntimeMonitor(interval_s=0.02, spike_factor=3.0,
                               spike_floor=2.0, rate_floor=50.0,
                               cooldown_s=1.0),
        monitor_interval_s=0.02).start()


def _drive(c: DaemonClient, *, trickle: int, waves: int, wave_size: int,
           service_s: float) -> dict:
    """Open-loop arrival schedule; returns per-phase submit outcomes."""
    calm, stormy = [], []
    for _ in range(trickle):               # calm: slower than service rate
        calm.append(c.submit("sleep", {"total_s": service_s, "steps": 2}))
        time.sleep(service_s * 1.5)
    for _ in range(waves):                 # storm: bursts faster than drain
        for _ in range(wave_size):
            stormy.append(c.submit("sleep", {"total_s": service_s,
                                             "steps": 2}))
        time.sleep(0.08)                   # a beat: the monitor sees depth
    return {"calm": calm, "stormy": stormy}


def bench_admission(smoke: bool) -> dict:
    service_s = 0.02
    waves, wave_size = (2, 8) if smoke else (4, 12)

    # Spike run: trickle then burst waves.
    tmp = tempfile.mkdtemp(prefix="bench_daemon_spike_")
    srv = _spike_daemon(tmp)
    try:
        with DaemonClient(srv.socket_path) as c:
            phases = _drive(c, trickle=4, waves=waves, wave_size=wave_size,
                            service_s=service_s)
            srv.wait_idle(timeout=120)
            pol, mon = srv.policy.stats(), srv.monitor.stats()
            jobs = srv.store.jobs()
    finally:
        srv.stop()
    admitted_ids = {r["job_id"] for r in phases["stormy"] if r.get("ok")}
    sheds = [r for r in phases["stormy"] if r.get("shed")]
    lat = [j.transitions[-1][2] - j.submit_t for j in jobs
           if j.job_id in admitted_ids and j.state is JobState.FINISHED]
    bad_histories = [p for j in jobs for p in validate_history(j.transitions)]
    spike = {
        "submitted": len(phases["calm"]) + len(phases["stormy"]),
        "calm_admitted": sum(bool(r.get("ok")) for r in phases["calm"]),
        "storm_admitted": len(admitted_ids),
        "shed": len(sheds),
        "shed_rate": len(sheds) / max(1, len(phases["stormy"])),
        "defer_events": pol["policy_defer_events"],
        "monitor_spikes": mon["monitor_spikes"],
        "p99_latency_s": _percentile(lat, 0.99),
        "p50_latency_s": _percentile(lat, 0.50),
        "bad_histories": bad_histories,
        "shed_reasons_ok": all(r.get("reason", "").startswith("shed:")
                               for r in sheds),
    }

    # Calm control: same configuration, trickle only.
    tmp2 = tempfile.mkdtemp(prefix="bench_daemon_calm_")
    srv2 = _spike_daemon(tmp2)
    try:
        with DaemonClient(srv2.socket_path) as c:
            outcomes = []
            for _ in range(8 if smoke else 16):
                outcomes.append(c.submit("sleep", {"total_s": service_s,
                                                   "steps": 2}))
                time.sleep(service_s * 1.5)
            srv2.wait_idle(timeout=120)
            pol2 = srv2.policy.stats()
            finished = len(srv2.store.by_state(JobState.FINISHED))
    finally:
        srv2.stop()
    calm = {"submitted": len(outcomes),
            "admitted": sum(bool(r.get("ok")) for r in outcomes),
            "shed": pol2["policy_shed"], "finished": finished}
    return {"spike": spike, "calm": calm}


# ----------------------------------------------------------------------
def main(smoke: bool = False) -> list:
    max_ratio = 3.0 if smoke else 2.0
    ipc = bench_ipc_overhead(smoke)
    adm = bench_admission(smoke)
    spike, calm = adm["spike"], adm["calm"]
    result = {"ipc": ipc, "spike": spike, "calm": calm,
              "max_ipc_ratio": max_ratio}
    rows = [
        ("daemon/ipc", ipc["daemon_us"],
         f"in_process_us={ipc['in_process_us']:.1f} "
         f"ratio={ipc['ratio']:.2f} (gate <= {max_ratio}x)"),
        ("daemon/spike", spike["p99_latency_s"] * 1e6,
         f"shed={spike['shed']}/{spike['submitted']} "
         f"shed_rate={spike['shed_rate']:.2f} "
         f"defers={spike['defer_events']} "
         f"spikes={spike['monitor_spikes']} "
         f"p50_us={spike['p50_latency_s'] * 1e6:.0f}"),
        ("daemon/calm", 0.0,
         f"admitted={calm['admitted']}/{calm['submitted']} "
         f"shed={calm['shed']}"),
    ]
    if not smoke:
        with open("BENCH_daemon.json", "w") as f:
            json.dump(result, f, indent=2, sort_keys=True)
    emit(rows)
    # Fail-fast gates: a daemon that is slow, blind or trigger-happy is a
    # broken tentpole.
    assert ipc["ratio"] <= max_ratio, (
        f"daemon IPC overhead {ipc['ratio']:.2f}x > {max_ratio}x in-process")
    assert spike["monitor_spikes"] >= 1, "overload never detected as a spike"
    assert spike["shed"] > 0, "admission control never shed under overload"
    assert spike["defer_events"] > 0, "dispatch never deferred in cooldown"
    assert spike["shed_reasons_ok"], "shed without a shed: reason"
    assert 0.0 < spike["shed_rate"] < 1.0, (
        f"shed rate {spike['shed_rate']:.2f} must be partial, not all-or-none")
    assert spike["p99_latency_s"] > 0.0, "no admitted storm job finished"
    assert not spike["bad_histories"], spike["bad_histories"]
    assert spike["calm_admitted"] == 4, "trickle phase must admit everything"
    assert calm["shed"] == 0 and calm["admitted"] == calm["submitted"], (
        f"calm control shed work: {calm}")
    assert calm["finished"] == calm["submitted"]
    return rows


if __name__ == "__main__":
    import sys
    main(smoke="--smoke" in sys.argv)
