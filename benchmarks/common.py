"""Shared helpers for the per-figure benchmarks."""
from __future__ import annotations

import numpy as np

from repro.benchsuite.costmodel import sim_hardware
from repro.core import make_scheduler

SCALE = 0.05
ITERS = 6


def run_sim(bench, gpu, policy, *, oracle=False, prefetch=True,
            scale=SCALE, iters=ITERS):
    """One simulated run; returns (makespan_s, overlap_metrics, sched)."""
    s = make_scheduler(policy, simulate=True,
                       hw=sim_hardware(gpu, policy, prefetch), oracle=oracle)
    bench.build(s, bench.make_data(scale), gpu=gpu, iters=iters)
    return s.timeline.makespan, s.timeline.overlap_metrics(), s


def geomean(vals):
    return float(np.exp(np.mean(np.log(np.asarray(vals)))))


def emit(rows):
    """Print ``name,us_per_call,derived`` CSV rows."""
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
