"""§Roofline: read the dry-run JSONs and print the per-(arch x shape x mesh)
three-term roofline table."""
from __future__ import annotations

import glob
import json
import os

from .common import emit

RESULTS = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "results", "dryrun")


def main() -> list:
    rows = []
    for path in sorted(glob.glob(os.path.join(RESULTS, "*.json"))):
        with open(path) as f:
            res = json.load(f)
        tag = os.path.basename(path)[:-5]
        if "skipped" in res:
            rows.append((f"roofline/{tag}", 0.0, f"skipped={res['skipped'][:40]}"))
            continue
        if "error" in res:
            rows.append((f"roofline/{tag}", 0.0, "ERROR"))
            continue
        r = res["roofline"]
        m = res["memory"]
        rows.append((
            f"roofline/{tag}", r["step_time_bound_s"] * 1e6,
            f"compute_ms={r['compute_s'] * 1e3:.1f};"
            f"memory_ms={r['memory_s'] * 1e3:.1f};"
            f"collective_ms={r['collective_s'] * 1e3:.1f};"
            f"dominant={r['dominant']};"
            f"roofline_frac={r['roofline_fraction']:.3f};"
            f"useful_flops_ratio={r['useful_flops_ratio']:.2f};"
            f"hbm_frac={m['hbm_fraction']:.2f}"))
    if not rows:
        rows.append(("roofline/none", 0.0,
                     "no dry-run results; run python -m repro.launch.dryrun"))
    emit(rows)
    return rows


if __name__ == "__main__":
    main()
