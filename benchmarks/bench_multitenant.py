"""Multi-tenant QoS benchmark (simulated): priority-weighted space-sharing
vs the priority-blind baseline under latency-vs-bulk contention.

For 1-3 simulated GPUs, runs the benchsuite contention scenario twice —
``blind`` (every element priority 0) and ``weighted`` (latency tenant at
priority 3 = 8x capacity weight) — and reports the latency tenant's p99
completion latency, the bulk tenant's makespan and the aggregate makespan.

Acceptance targets (ISSUE 3): weighted p99 for the latency tenant improves
>= 2x over blind while aggregate makespan regresses <= 10%.  Results land in
``BENCH_multitenant.json`` so the trajectory is machine-readable across PRs.
"""
from __future__ import annotations

import json

from repro.benchsuite.multitenant import (BULK_TENANT, LATENCY_TENANT,
                                          build_contention)
from repro.core import make_scheduler

from .common import emit

DEVICES = (1, 2, 3)


def run_contention(num_devices: int, weighted: bool, **kw):
    s = make_scheduler("parallel", simulate=True, num_devices=num_devices,
                       placement="min-load")
    build_contention(s, use_priority=weighted, **kw)
    s.sync()
    ts = s.tenant_stats()
    return {
        "makespan_s": s.timeline.makespan,
        "latency_p99_s": ts[LATENCY_TENANT]["latency_p99_s"],
        "latency_p50_s": ts[LATENCY_TENANT]["latency_p50_s"],
        "latency_queue_p99_s": ts[LATENCY_TENANT]["queue_delay_p99_s"],
        "bulk_makespan_s": ts[BULK_TENANT]["makespan_s"],
        "priority_bypasses": s.stats().get("priority_bypasses", 0),
    }


def main(smoke: bool = False) -> list:
    kw = ({"bulk_kernels": 3, "latency_streams": 1, "per_stream": 3,
           "n": 1 << 10} if smoke else {})
    rows, result = [], {}
    for nd in DEVICES if not smoke else (1,):
        blind = run_contention(nd, weighted=False, **kw)
        wtd = run_contention(nd, weighted=True, **kw)
        improvement = blind["latency_p99_s"] / wtd["latency_p99_s"]
        mk_ratio = wtd["makespan_s"] / blind["makespan_s"]
        result[f"{nd}dev"] = {"blind": blind, "weighted": wtd,
                              "latency_p99_improvement": improvement,
                              "makespan_ratio": mk_ratio}
        rows.append((f"multitenant/{nd}dev/blind",
                     blind["latency_p99_s"] * 1e6,
                     f"makespan_us={blind['makespan_s'] * 1e6:.1f}"))
        rows.append((f"multitenant/{nd}dev/weighted",
                     wtd["latency_p99_s"] * 1e6,
                     f"p99_improvement={improvement:.2f} "
                     f"makespan_ratio={mk_ratio:.3f}"))
    if not smoke:
        with open("BENCH_multitenant.json", "w") as f:
            json.dump(result, f, indent=2, sort_keys=True)
    emit(rows)
    return rows


if __name__ == "__main__":
    import sys
    main(smoke="--smoke" in sys.argv)
