"""Fig. 10/11: overlap decomposition (CT / TC / CC / TOT) per benchmark,
serial vs parallel scheduling."""
from __future__ import annotations

from repro.benchsuite import BENCHMARKS, GTX1660S

from .common import emit, run_sim


def main() -> list:
    rows = []
    for bname, bench in BENCHMARKS.items():
        for policy in ("serial", "parallel"):
            t, m, _ = run_sim(bench, GTX1660S, policy)
            rows.append((f"fig11/{bname}/{policy}", t * 1e6,
                         f"CT={m['CT']:.2f};TC={m['TC']:.2f};"
                         f"CC={m['CC']:.2f};TOT={m['TOT']:.2f}"))
    emit(rows)
    return rows


if __name__ == "__main__":
    main()
