"""Multi-device scaling + placement-policy benchmark (simulated).

Two questions, two tables:

* **speedup** — makespan of the task-parallel scenario (independent kernel
  chains) on 1/2/4 simulated devices.  With full-occupancy kernels a single
  device serializes everything; N devices should approach N×.
* **placement** — D2D transfer counts and makespan of the locality-heavy
  scenario under round-robin vs min-load vs data-affinity placement on 2
  devices.  Affinity should insert (near) zero D2D copies.
"""
from __future__ import annotations

from repro.benchsuite.multidevice import (build_locality_heavy,
                                          build_task_parallel)
from repro.core import make_scheduler

from .common import emit

BRANCHES = 4
CHAIN = 4


def run_task_parallel(num_devices: int, placement: str = "affinity"):
    s = make_scheduler("parallel", simulate=True, num_devices=num_devices,
                       placement=placement)
    build_task_parallel(s, branches=BRANCHES, chain=CHAIN)
    s.sync()
    return s.timeline.makespan, s.stats()


def run_locality(num_devices: int, placement: str):
    s = make_scheduler("parallel", simulate=True, num_devices=num_devices,
                       placement=placement)
    build_locality_heavy(s, groups=BRANCHES)
    s.sync()
    return s.timeline.makespan, s.stats()


def main() -> list:
    rows = []
    t1, _ = run_task_parallel(1)
    for nd in (1, 2, 4):
        t, st = run_task_parallel(nd)
        rows.append((f"multidev/speedup/{nd}dev", t * 1e6,
                     f"speedup_vs_1dev={t1 / t:.3f} "
                     f"d2d={st['d2d_transfers']}"))
    for pl in ("round-robin", "min-load", "affinity"):
        t, st = run_locality(2, pl)
        rows.append((f"multidev/placement/{pl}", t * 1e6,
                     f"d2d={st['d2d_transfers']} "
                     f"lanes={st['lanes_created']}"))
    emit(rows)
    return rows


if __name__ == "__main__":
    main()
