"""Capture/replay vs eager vs the CUDA-Graphs oracle (§V-D) on the paper's
6 benchmarks: repeated identical episodes, steady-state medians.

Also writes ``BENCH_capture.json`` (eager/replay/oracle medians per
GPU x benchmark) so the perf trajectory is machine-readable across PRs.
"""
from __future__ import annotations

import json
import statistics

from repro.benchsuite import BENCHMARKS, GPUS
from repro.benchsuite.costmodel import sim_hardware
from repro.core import make_scheduler

from .common import emit, geomean

SCALE = 0.02
EPISODES = 6
WARMUP = 2          # capture/re-record warm-up excluded from the median
OVERHEAD = 2e-4     # high per-launch overhead: the regime replay targets


def run_episodes(bench, gpu, mode: str) -> float:
    """Median steady-state episode time under one launch mode."""
    kw = {} if mode == "oracle" else {"launch_overhead_s": OVERHEAD}
    s = make_scheduler("parallel", simulate=True,
                       hw=sim_hardware(gpu, "parallel", True),
                       oracle=(mode == "oracle"), **kw)
    data = bench.make_data(SCALE)
    times = []
    for _ in range(WARMUP + EPISODES):
        t0 = s.executor.host_time
        if mode == "replay":
            with s.capture(bench.name):
                bench.build(s, data, gpu=gpu, iters=1)
        else:
            bench.build(s, data, gpu=gpu, iters=1)
        times.append(s.executor.host_time - t0)
    return statistics.median(times[WARMUP:])


def main() -> list:
    rows, result = [], {}
    speedups, ratios = [], []
    for gname, gpu in GPUS.items():
        for bname, bench in BENCHMARKS.items():
            te = run_episodes(bench, gpu, "eager")
            tr = run_episodes(bench, gpu, "replay")
            to = run_episodes(bench, gpu, "oracle")
            result[f"{gname}/{bname}"] = {
                "eager_s": te, "replay_s": tr, "oracle_s": to,
                "replay_speedup_vs_eager": te / tr,
                "replay_over_oracle": tr / to,
            }
            speedups.append(te / tr)
            ratios.append(tr / to)
            rows.append((f"capture/{gname}/{bname}", tr * 1e6,
                         f"speedup_vs_eager={te / tr:.3f},"
                         f"over_oracle={tr / to:.4f}"))
    result["geomean"] = {"replay_speedup_vs_eager": geomean(speedups),
                         "replay_over_oracle": geomean(ratios)}
    rows.append(("capture/geomean", 0.0,
                 f"speedup_vs_eager={geomean(speedups):.3f},"
                 f"over_oracle={geomean(ratios):.4f}"))
    with open("BENCH_capture.json", "w") as f:
        json.dump(result, f, indent=2, sort_keys=True)
    emit(rows)
    return rows


if __name__ == "__main__":
    main()
