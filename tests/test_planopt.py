"""Plan-time global optimizer (core/planopt.py): identity guarantees,
DAG-equivalence of rewritten plans, bit-identical results on the real
executor, Belady-vs-LRU traffic, and the satellite signature memoization."""
import numpy as np
import pytest

from _hypothesis_fallback import given, settings, st
from repro.benchsuite.multidevice import build_locality_heavy
from repro.benchsuite.outofcore import (build_outofcore, verify_outofcore,
                                        working_set_bytes)
from repro.core import const, inout, make_scheduler, out
from repro.core.frontend import function
from repro.core.planopt import optimize_plan


def _capture_plan(s, name, builder):
    with s.capture(name):
        builder(s)
    s.sync()
    return s.plan_cache.candidates(name)[0]


def _span_key(s):
    return tuple((sp.name, sp.kind, sp.lane, sp.t0, sp.t1)
                 for sp in s.timeline.spans)


# ----------------------------------------------------------------------
# Identity guarantees: no rewrite -> the same plan object, and disabled /
# eager paths produce bit-identical timelines.
# ----------------------------------------------------------------------

def test_identity_when_nothing_to_improve():
    """Single device, unlimited memory: there is no cut to reduce and no
    schedule to rewrite — the optimizer must return the *same object*."""
    s = make_scheduler("parallel", simulate=True, plan_optimize=False)
    try:
        def build(sc):
            x = sc.array(np.ones(256, np.float32), name="ix")
            y = sc.array(shape=(256,), dtype=np.float32, name="iy")
            sc.launch(None, [const(x), out(y)], name="IK1", cost_s=1e-4)
            sc.launch(None, [inout(y)], name="IK2", cost_s=1e-4)
        plan = _capture_plan(s, "ident", build)
        assert optimize_plan(s, plan) is plan
        assert not plan.optimized and not plan.mem_scheduled
    finally:
        s.shutdown()


def test_eager_timeline_identical_with_optimizer_enabled():
    """The optimizer only runs at capture finalization: plain eager
    execution must be bit-identical whether the flag is on or off."""
    def run(opt):
        s = make_scheduler("parallel", simulate=True, num_devices=2,
                           plan_optimize=opt)
        try:
            build_locality_heavy(s, groups=2, iters=3, n=1 << 10)
            s.sync()
            return _span_key(s)
        finally:
            s.shutdown()
    assert run(True) == run(False)


def test_disabled_optimizer_is_pure_passthrough(monkeypatch):
    """``plan_optimize=False`` must equal an optimizer that returns its
    input unchanged — same spans, same plan flags — proving the capture
    hook itself adds nothing when disabled."""
    def run(opt):
        s = make_scheduler("parallel", simulate=True, num_devices=2,
                           plan_optimize=opt)
        try:
            for _ in range(3):
                with s.capture("pass"):
                    build_locality_heavy(s, groups=2, iters=3, n=1 << 10)
                s.sync()
            plans = s.plan_cache.candidates("pass")
            assert s.stats()["plan_replays"] == 2
            return _span_key(s), [p.optimized for p in plans]
        finally:
            s.shutdown()

    base = run(False)
    import repro.core.planopt as planopt
    monkeypatch.setattr(planopt, "optimize_plan",
                        lambda sched, plan: plan)
    neutered = run(True)
    assert base == neutered


# ----------------------------------------------------------------------
# Property: the optimized plan is DAG-equivalent to the greedy one —
# every true data dependency (RAW/WAR/WAW) between original kernels is
# still ordered after the rewrite.
# ----------------------------------------------------------------------

def _order_pairs(plan):
    """(ancestor_name, descendant_name) for every ordered kernel pair."""
    anc = [set() for _ in plan.elements]
    for i, pe in enumerate(plan.elements):
        for p in pe.parents:
            anc[i].add(p)
            anc[i] |= anc[p]
    names = {i: plan.elements[i].name for i in plan.kernel_positions}
    return {(names[i], names[j])
            for j in plan.kernel_positions for i in anc[j] & names.keys()}


def _data_dep_pairs(plan):
    """The pairs that MUST stay ordered: per-slot RAW/WAR/WAW between the
    plan's kernels, derived from access modes alone (movement-element
    artifacts like read-read migration ordering are excluded — they are
    placement-dependent, not semantic)."""
    pairs, lw, readers = set(), {}, {}
    for i in plan.kernel_positions:
        pe = plan.elements[i]
        merged = {}
        for slot, mode in pe.arg_slots:
            prev = merged.get(slot)
            if prev is None or (mode.writes and not prev.writes):
                merged[slot] = mode
        for slot, mode in merged.items():
            if slot in lw and lw[slot] != pe.name:
                pairs.add((lw[slot], pe.name))      # RAW / WAW
            if mode.writes:
                for r in readers.get(slot, ()):
                    if r != pe.name:
                        pairs.add((r, pe.name))     # WAR
        for slot, mode in merged.items():
            if mode.writes:
                lw[slot] = pe.name
                readers[slot] = []
            else:
                readers.setdefault(slot, []).append(pe.name)
    return pairs


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 10 ** 6))
def test_optimized_plan_is_dag_equivalent(seed):
    rng = np.random.RandomState(seed)
    narr = int(rng.randint(3, 7))
    ops = []
    for k in range(int(rng.randint(4, 12))):
        w = int(rng.randint(narr))
        nread = int(rng.randint(0, 3))
        reads = [int(x) for x in rng.choice(narr, size=nread, replace=False)]
        ops.append((k, [r for r in reads if r != w], w))

    s = make_scheduler("parallel", simulate=True, num_devices=2,
                       plan_optimize=False)
    try:
        def build(sc):
            arrs = [sc.array(np.zeros(256, np.float32), name=f"pa{i}")
                    for i in range(narr)]
            for k, reads, w in ops:
                args = [const(arrs[r]) for r in reads] + [inout(arrs[w])]
                sc.launch(None, args, name=f"pk{k}", cost_s=1e-4)
        plan = _capture_plan(s, f"prop{seed}", build)
        new = optimize_plan(s, plan)
        required = _data_dep_pairs(plan)
        assert required <= _order_pairs(plan)       # sanity: greedy has them
        assert required <= _order_pairs(new)        # the rewrite keeps them
        # Kernel sequence itself is preserved verbatim.
        assert [new.elements[i].name for i in new.kernel_positions] \
            == [plan.elements[i].name for i in plan.kernel_positions]
    finally:
        s.shutdown()


# ----------------------------------------------------------------------
# Real executor: optimized replays produce bit-identical results on 1-
# and 2-device configs, including budgeted (Belady) replays.
# ----------------------------------------------------------------------

@pytest.mark.parametrize("ndev", [1, 2])
def test_optimized_replay_bit_identical_on_real_executor(ndev):
    import jax
    sq = jax.jit(lambda a, _o: a * a + 1.0)
    mix = jax.jit(lambda a, b, _o: a * 0.5 + b)

    def run(opt):
        s = make_scheduler("parallel", num_devices=ndev, plan_optimize=opt)
        try:
            outs = []
            for _ in range(3):
                rng = np.random.RandomState(11)
                x = s.array(rng.randn(256).astype(np.float32), name="bx")
                y = s.array(rng.randn(256).astype(np.float32), name="by")
                u = s.array(shape=(256,), dtype=np.float32, name="bu")
                v = s.array(shape=(256,), dtype=np.float32, name="bv")
                w = s.array(shape=(256,), dtype=np.float32, name="bw")
                with s.capture("bit"):
                    s.launch(sq, [const(x), out(u)], name="SQ1", cost_s=1e-4)
                    s.launch(sq, [const(y), out(v)], name="SQ2", cost_s=1e-4)
                    s.launch(mix, [const(u), const(v), out(w)], name="MIX",
                             cost_s=1e-4)
                outs.append(np.asarray(w).copy())
            assert s.stats()["plan_replays"] >= 1
            return outs
        finally:
            s.shutdown()

    for b, o in zip(run(False), run(True)):
        assert np.array_equal(b, o)


def test_optimized_budgeted_replay_correct_on_real_executor():
    ws = working_set_bytes(6, 1 << 10)
    s = make_scheduler("parallel", memory_budget=ws // 2, plan_optimize=True)
    try:
        for _ in range(3):
            with s.capture("oocr"):
                arrs = build_outofcore(s, chunks=6, n=1 << 10)
            s.sync()
        st = s.stats()
        assert st["plan_replays"] == 2
        plans = s.plan_cache.candidates("oocr")
        assert plans and plans[0].optimized and plans[0].mem_scheduled
        assert st["mem_evicts_scheduled"] > 0
        assert verify_outofcore(arrs)
        assert s.memory.verify().ok
    finally:
        s.shutdown()


# ----------------------------------------------------------------------
# Belady vs reactive LRU on the out-of-core scenario (sim)
# ----------------------------------------------------------------------

def test_belady_reduces_spill_plus_reload_traffic():
    def run(opt):
        ws = working_set_bytes(6, 1 << 10)
        s = make_scheduler("parallel", simulate=True,
                           memory_budget=ws // 2, plan_optimize=opt)
        try:
            for _ in range(3):
                with s.capture("ooc"):
                    build_outofcore(s, chunks=6, n=1 << 10)
                s.sync()
            st = s.stats()
            assert st["plan_replays"] == 2      # the rewritten plan sticks
            return st
        finally:
            s.shutdown()

    lru = run(False)
    bel = run(True)
    assert bel["mem_spill_bytes"] <= lru["mem_spill_bytes"]
    assert (bel["mem_spill_bytes"] + bel["mem_reload_bytes"]
            < lru["mem_spill_bytes"] + lru["mem_reload_bytes"])
    assert bel["mem_evicts_scheduled"] > 0


# ----------------------------------------------------------------------
# Min-cut placement: D2D bytes drop, user pins are immovable
# ----------------------------------------------------------------------

def test_mincut_placement_cuts_d2d_bytes_and_keeps_results():
    from repro.core.element import ElementKind

    def run(opt):
        s = make_scheduler("parallel", simulate=True, num_devices=2,
                           placement="round-robin", plan_optimize=opt)
        try:
            for _ in range(3):
                with s.capture("loc"):
                    build_locality_heavy(s, groups=2, iters=4, n=1 << 12)
                s.sync()
            plans = s.plan_cache.candidates("loc")
            d2d = sum(pe.transfer_bytes for p in plans for pe in p.elements
                      if pe.kind is ElementKind.D2D)
            return d2d, s.timeline.makespan, s.stats()["plan_replays"]
        finally:
            s.shutdown()

    d2d_g, mk_g, rep_g = run(False)
    d2d_o, mk_o, rep_o = run(True)
    assert rep_g == rep_o == 2
    assert d2d_g > 0                    # round-robin bounces the arrays
    assert d2d_o <= d2d_g * 0.8         # the ISSUE's >= 20% reduction gate
    assert mk_o <= mk_g * (1 + 1e-9)


def test_user_pinned_kernels_never_move():
    stage = function(None, modes=("inout",), name="pin_k",
                     parallel_fraction=1.0)
    s = make_scheduler("parallel", simulate=True, num_devices=2,
                       placement="round-robin", plan_optimize=True)
    try:
        fn = stage.with_options(scheduler=s, cost_s=1e-4)
        with s.capture("pin"):
            x = s.array(np.zeros(1 << 12, np.float32), name="pinx")
            y = s.array(np.zeros(1 << 12, np.float32), name="piny")
            for i in range(4):
                fn.with_options(name=f"pinned_{i}", device=1)(x)
                fn.with_options(name=f"free_{i}")(y)
        s.sync()
        plan = s.plan_cache.candidates("pin")[0]
        for i in plan.kernel_positions:
            pe = plan.elements[i]
            if pe.name.startswith("pinned_"):
                assert pe.pinned and pe.device == 1
    finally:
        s.shutdown()


# ----------------------------------------------------------------------
# Satellite: memoized structural signature
# ----------------------------------------------------------------------

def test_plan_signature_memoized_and_stable():
    s = make_scheduler("parallel", simulate=True, plan_optimize=False)
    try:
        def build(sc):
            x = sc.array(np.ones(128, np.float32), name="sx")
            sc.launch(None, [inout(x)], name="SK", cost_s=1e-4)
        plan = _capture_plan(s, "sig", build)
        sig = plan.signature
        assert plan.signature is sig            # memoized, not re-walked
        assert hash(sig) == hash(plan.signature)
        # The raw tuple still compares equal (cache probes mix both forms).
        assert sig == (plan.elements, plan.slots, plan.device_mem)
    finally:
        s.shutdown()
