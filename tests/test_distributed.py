"""Distributed-correctness tests on a fake multi-device mesh.

These run in a subprocess so the 8 fake CPU devices never leak into the
other tests (jax pins the device count at first init).
"""
import json
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_sub(code: str) -> dict:
    prog = ("import os\n"
            "os.environ['XLA_FLAGS'] = "
            "'--xla_force_host_platform_device_count=8'\n"
            + textwrap.dedent(code))
    env = dict(os.environ,
               PYTHONPATH=os.path.join(REPO, "src"))
    out = subprocess.run([sys.executable, "-c", prog], capture_output=True,
                         text=True, env=env, timeout=900)
    assert out.returncode == 0, f"stderr:\n{out.stderr[-4000:]}"
    return json.loads(out.stdout.strip().splitlines()[-1])


@pytest.mark.slow
def test_sharded_train_step_matches_single_device():
    """One train step on a (2, 4) mesh must equal the unsharded step."""
    res = run_sub("""
        import json
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs import get_config
        from repro.models import init_lm
        from repro.optim import AdamW
        from repro.runtime.steps import TrainState, make_train_step
        from repro.launch.specs import build_cell, _with_rules
        from repro.sharding.rules import param_sharding, batch_spec
        from repro.models.config import ShapeCell

        cfg = get_config("qwen3_32b", reduced=True)
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        opt = AdamW(lr=1e-3)
        params = init_lm(jax.random.PRNGKey(0), cfg)
        state = TrainState(params, opt.init(params))
        rng = np.random.RandomState(0)
        batch = {"tokens": rng.randint(0, cfg.vocab, (2, 4, 32)).astype(np.int32),
                 "labels": rng.randint(0, cfg.vocab, (2, 4, 32)).astype(np.int32)}

        # single-device reference
        step_ref = jax.jit(make_train_step(cfg, opt))
        st_ref, m_ref = step_ref(state, batch)

        # sharded
        ps = param_sharding(params, mesh)
        bs = batch_spec(mesh)
        b_sh = {k: NamedSharding(mesh, P(*((None,) + tuple(bs[k]))))
                for k in batch}
        state2 = TrainState(jax.device_put(params, ps), opt.init(params))
        with mesh:
            step_sh = jax.jit(_with_rules(make_train_step(cfg, opt), mesh),
                              in_shardings=(None, b_sh))
            st_got, m_got = step_sh(state2, batch)

        d = float(max(abs(float(m_got["loss"]) - float(m_ref["loss"])), 0))
        # parameter agreement after one update
        diffs = jax.tree_util.tree_map(
            lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                               - b.astype(jnp.float32)))),
            st_got.params, st_ref.params)
        mx = max(jax.tree_util.tree_leaves(diffs))
        print(json.dumps({"loss_diff": d, "param_diff": mx}))
    """)
    assert res["loss_diff"] < 1e-3, res
    assert res["param_diff"] < 1e-3, res


def test_compressed_psum_error_feedback():
    """Int8 error-feedback gradient compression: mean over replicas is
    recovered to within quantization error, and the error feedback keeps the
    long-run average unbiased."""
    res = run_sub("""
        import json
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.optim import compressed_psum, init_error_feedback

        mesh = jax.make_mesh((8,), ("data",))
        x = np.random.RandomState(0).randn(8, 64, 256).astype(np.float32)

        from jax.experimental.shard_map import shard_map
        def body(xs, errs):
            g, e = compressed_psum({"g": xs}, {"g": errs}, "data")
            return g["g"], e["g"]

        f = jax.jit(shard_map(body, mesh=mesh,
                              in_specs=(P("data"), P("data")),
                              out_specs=(P("data"), P("data"))))
        errs = jnp.zeros_like(x)
        red, errs = f(x, errs)
        true_mean = np.mean(x, axis=0, keepdims=True)
        err1 = float(np.max(np.abs(np.asarray(red)[0] - true_mean[0])))

        # steady-state: same gradients repeatedly, EF should correct bias
        acc = np.zeros_like(true_mean[0])
        e = jnp.zeros_like(x)
        for _ in range(20):
            r, e = f(x, e)
            acc += np.asarray(r)[0]
        err_avg = float(np.max(np.abs(acc / 20 - true_mean[0])))
        print(json.dumps({"err1": err1, "err_avg": err_avg}))
    """)
    assert res["err1"] < 0.05, res          # single-shot quantization error
    assert res["err_avg"] < 0.02, res       # EF drives the average error down


@pytest.mark.slow
def test_elastic_remesh_preserves_state():
    """Re-sharding a train state onto a smaller mesh (device loss) keeps
    values identical — the elastic-scaling path."""
    res = run_sub("""
        import json
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_config
        from repro.models import init_lm
        from repro.sharding.rules import param_sharding
        from repro.launch.mesh import make_mesh_for

        cfg = get_config("hymba_1_5b", reduced=True)
        params = init_lm(jax.random.PRNGKey(0), cfg)
        mesh8 = make_mesh_for(8, model_parallel=4)
        p8 = jax.device_put(params, param_sharding(params, mesh8))
        # "lose" half the devices -> remesh to 4
        mesh4 = make_mesh_for(4, model_parallel=2)
        p4 = jax.device_put(jax.tree_util.tree_map(np.asarray, p8),
                            param_sharding(params, mesh4))
        diff = max(jax.tree_util.tree_leaves(jax.tree_util.tree_map(
            lambda a, b: float(jnp.max(jnp.abs(a - b))), params, p4)))
        print(json.dumps({"diff": diff,
                          "mesh4": dict(mesh4.shape)}))
    """)
    assert res["diff"] == 0.0
    assert res["mesh4"] == {"data": 2, "model": 2}


@pytest.mark.slow
def test_dryrun_cell_compiles_on_toy_mesh():
    """End-to-end build_cell -> lower -> compile on an 8-device mesh with a
    reduced config (fast proxy for the 512-device dry-run)."""
    res = run_sub("""
        import json
        import jax
        from repro.configs import get_config
        from repro.launch.specs import build_cell
        from repro.launch.hlostats import analyze_hlo
        from repro.models.config import ShapeCell

        mesh = jax.make_mesh((2, 4), ("data", "model"))
        cfg = get_config("gemma3_12b", reduced=True)
        cell = ShapeCell("t", 64, 8, "train")
        low = build_cell(cfg, cell, mesh)
        with mesh:
            comp = jax.jit(low.fn, in_shardings=low.in_shardings,
                           out_shardings=low.out_shardings,
                           donate_argnums=low.donate_argnums
                           ).lower(*low.arg_specs).compile()
        st = analyze_hlo(comp.as_text())
        mem = comp.memory_analysis()
        print(json.dumps({
            "flops": st.flops,
            "wire": st.wire_bytes,
            "temp": mem.temp_size_in_bytes}))
    """)
    assert res["flops"] > 0
    assert res["temp"] > 0
