"""StreamManager unit tests: FIFO lane reclaim, max_lanes saturation,
event accounting, and scheduler element retirement (§IV-C)."""
import numpy as np

from repro.core import (ComputationalElement, StreamManager, inout,
                        make_scheduler, out)


def ce(*args, cost_s=0.0, name=""):
    return ComputationalElement(fn=None, args=tuple(args), name=name,
                                cost_s=cost_s)


def link(child, *parents):
    child.parents = list(parents)
    for p in parents:
        p.children.append(child)
    return child


class DoneSet:
    """Explicit completion oracle for driving StreamManager directly."""

    def __init__(self):
        self.done = set()

    def finish(self, *elements):
        self.done.update(e.uid for e in elements)

    def __call__(self, element):
        return element.uid in self.done


# ----------------------------------------------------------------------
# FIFO lane reclaim
# ----------------------------------------------------------------------

def test_fifo_lane_reclaim_order():
    sm = StreamManager()
    done = DoneSet()
    first = [ce(name=f"a{i}") for i in range(3)]
    for e in first:
        sm.assign(e, done)
    assert [e.stream for e in first] == [0, 1, 2]

    # Release in order 1, 2, 0: the free pool must hand lanes back in that
    # FIFO order, not lane-id order.
    done.finish(*first)
    for idx in (1, 2, 0):
        sm.release(first[idx])
    second = [ce(name=f"b{i}") for i in range(3)]
    for e in second:
        sm.assign(e, done)
    assert [e.stream for e in second] == [1, 2, 0]
    assert sm.lanes_created == 3            # reused, never created anew


def test_new_lane_only_when_no_empty_lane():
    sm = StreamManager()
    done = DoneSet()
    e1 = ce(name="e1")
    sm.assign(e1, done)
    # e1 still in flight: an independent element must get a fresh lane.
    e2 = ce(name="e2")
    sm.assign(e2, done)
    assert e2.stream != e1.stream
    assert sm.lanes_created == 2


# ----------------------------------------------------------------------
# max_lanes saturation -> least-loaded fallback
# ----------------------------------------------------------------------

def test_max_lanes_saturation_falls_back_to_least_loaded():
    sm = StreamManager(max_lanes=2)
    done = DoneSet()
    a, b = ce(name="a"), ce(name="b")
    sm.assign(a, done)
    sm.assign(b, done)
    assert sm.lanes_created == 2

    # Load lane of `a` with one more element: lane(a)=2 pending, lane(b)=1.
    extra = link(ce(name="extra"), a)
    done_oracle = done
    sm.assign(extra, done_oracle)
    assert extra.stream == a.stream

    # Saturated: the next independent element must go to the least-loaded
    # lane (b's), not create lane 3.
    c = ce(name="c")
    sm.assign(c, done)
    assert sm.lanes_created == 2
    assert c.stream == b.stream


# ----------------------------------------------------------------------
# Event accounting: same-lane parents are free, cross-lane parents cost one
# ----------------------------------------------------------------------

def test_tail_parent_needs_no_event():
    sm = StreamManager()
    done = DoneSet()
    p = ce(name="p", cost_s=1e-3)
    sm.assign(p, done)
    child = link(ce(name="child"), p)
    lane, events = sm.assign(child, done)
    assert lane.lane_id == p.stream         # first child inherits
    assert events == []                     # ordered by the lane queue
    assert sm.events_created == 0


def test_cross_lane_parent_costs_one_event():
    sm = StreamManager()
    done = DoneSet()
    p1, p2 = ce(name="p1", cost_s=2e-3), ce(name="p2", cost_s=1e-3)
    sm.assign(p1, done)
    sm.assign(p2, done)
    assert p1.stream != p2.stream
    child = link(ce(name="child"), p1, p2)
    lane, events = sm.assign(child, done)
    # Inherits the costlier parent's lane; the other parent needs one event.
    assert lane.lane_id == p1.stream
    assert events == [p2]
    assert sm.events_created == 1


def test_earlier_same_lane_parent_needs_no_event():
    sm = StreamManager()
    done = DoneSet()
    p = ce(name="p", cost_s=1e-3)
    sm.assign(p, done)
    c1 = link(ce(name="c1", cost_s=1e-3), p)
    sm.assign(c1, done)
    assert c1.stream == p.stream
    # c2 depends on BOTH p and c1; both sit on the same lane (c1 is tail,
    # p precedes it) -> zero events.
    c2 = link(ce(name="c2"), p, c1)
    lane, events = sm.assign(c2, done)
    assert lane.lane_id == p.stream
    assert events == []


def test_non_tail_same_lane_parent_needs_no_event():
    """Pins the simplified same-lane skip: a parent that is NOT the lane
    tail (the element arrived via saturated fallback, not inheritance) is
    still ordered by the lane's FIFO queue — no event."""
    sm = StreamManager(max_lanes=1)
    done = DoneSet()
    p = ce(name="p", cost_s=1e-3)
    sm.assign(p, done)
    c1 = link(ce(name="c1", cost_s=1e-3), p)
    sm.assign(c1, done)                     # inherits; lane queue [p, c1]
    # r depends only on p, which now sits mid-queue; max_lanes=1 forces r
    # onto the same lane via fallback.
    r = link(ce(name="r"), p)
    lane, events = sm.assign(r, done)
    assert lane.lane_id == p.stream
    assert events == []
    assert sm.events_created == 0


def test_finished_parent_needs_no_event():
    sm = StreamManager()
    done = DoneSet()
    p1, p2 = ce(name="p1"), ce(name="p2")
    sm.assign(p1, done)
    sm.assign(p2, done)
    done.finish(p2)
    child = link(ce(name="child"), p1, p2)
    _, events = sm.assign(child, done)
    assert p2 not in events                 # completed: no synchronization


# ----------------------------------------------------------------------
# Scheduler element retirement (sync must not accumulate history)
# ----------------------------------------------------------------------

def test_sync_clears_retired_elements():
    s = make_scheduler("parallel", simulate=True)
    for rounds in range(3):
        for i in range(4):
            x = s.array(np.zeros(1024, np.float32), name=f"x{rounds}_{i}")
            s.launch(None, [inout(x)], name="k", cost_s=1e-4)
        s.sync()
        # Retired elements must not be re-walked by the next sync.
        assert s._elements == []
    assert s.dag.num_elements == 24         # 4 kernels + 4 h2d per round
