"""Graph capture & replay: plan recording, cache keying/invalidation,
replay correctness (real executor) and replay performance (simulator vs the
CUDA-Graphs oracle of §V-D)."""
import statistics

import numpy as np
import pytest

from repro.core import const, inout, make_scheduler, out


def _episode(s, n=1024, cost=1e-4, tag=""):
    """VEC-shaped episode: two squares + a reduce, fresh arrays."""
    x1 = s.array(np.ones(n, np.float32), name=f"x1{tag}")
    x2 = s.array(np.full(n, 2.0, np.float32), name=f"x2{tag}")
    y1 = s.array(shape=(n,), dtype=np.float32, name=f"y1{tag}")
    y2 = s.array(shape=(n,), dtype=np.float32, name=f"y2{tag}")
    z = s.array(shape=(n,), dtype=np.float32, name=f"z{tag}")
    s.launch(None, [const(x1), out(y1)], name="SQ1", cost_s=cost)
    s.launch(None, [const(x2), out(y2)], name="SQ2", cost_s=cost)
    s.launch(None, [const(y1), const(y2), out(z)], name="RED", cost_s=cost)
    return z


# ----------------------------------------------------------------------
# Recording, cache keying, invalidation
# ----------------------------------------------------------------------

def test_capture_records_then_replays():
    s = make_scheduler("parallel", simulate=True)
    for _ep in range(4):
        with s.capture("vec"):
            _episode(s)
        s.sync()
    st = s.stats()
    assert st["plan_records"] == 1
    assert st["plan_replays"] == 3
    assert st["plan_invalidations"] == 0
    # every episode's elements entered the DAG (transfers + kernels)
    assert st["elements"] == 4 * 5


def test_plan_cache_keyed_by_argument_shapes():
    s = make_scheduler("parallel", simulate=True)
    for _ep in range(2):
        for n in (256, 512):
            with s.capture("vec"):
                _episode(s, n=n)
            s.sync()
    st = s.stats()
    assert st["plans_cached"] == 2          # one plan per shape signature
    assert st["plan_records"] == 2
    assert st["plan_replays"] == 2


def test_divergent_episode_invalidates_plan_and_records_new_shape():
    s = make_scheduler("parallel", simulate=True)
    with s.capture("vec"):
        _episode(s)
    s.sync()
    # same first launch, then a different kernel -> mid-episode divergence:
    # the stale plan is invalidated and the replayed prefix is transplanted
    # into a recording of the new shape.
    with s.capture("vec"):
        x = s.array(np.ones(1024, np.float32), name="xx")
        y = s.array(shape=(1024,), dtype=np.float32, name="yy")
        s.launch(None, [const(x), out(y)], name="SQ1", cost_s=1e-4)
        s.launch(None, [const(y), inout(x)], name="OTHER", cost_s=1e-4)
    s.sync()
    st = s.stats()
    assert st["plan_invalidations"] == 1
    assert st["plans_cached"] == 1          # the divergent shape got cached
    # the new shape now replays
    with s.capture("vec"):
        x = s.array(np.ones(1024, np.float32), name="xx2")
        y = s.array(shape=(1024,), dtype=np.float32, name="yy2")
        s.launch(None, [const(x), out(y)], name="SQ1", cost_s=1e-4)
        s.launch(None, [const(y), inout(x)], name="OTHER", cost_s=1e-4)
    s.sync()
    assert s.stats()["plan_replays"] == 1


def test_shorter_episode_invalidates_plan():
    s = make_scheduler("parallel", simulate=True)
    with s.capture("vec"):
        _episode(s)
    s.sync()
    with s.capture("vec"):      # only the first two launches of the episode
        x1 = s.array(np.ones(1024, np.float32), name="a")
        y1 = s.array(shape=(1024,), dtype=np.float32, name="b")
        s.launch(None, [const(x1), out(y1)], name="SQ1", cost_s=1e-4)
    s.sync()
    assert s.stats()["plan_invalidations"] == 1


def test_capture_is_noop_for_serial_policy():
    s = make_scheduler("serial", simulate=True)
    for _ in range(2):
        with s.capture("vec"):
            _episode(s)
        s.sync()
    assert s.stats()["plan_records"] == 0


def test_capture_contexts_cannot_nest():
    s = make_scheduler("parallel", simulate=True)
    with s.capture("a"):
        with pytest.raises(RuntimeError):
            with s.capture("b"):
                pass


# ----------------------------------------------------------------------
# Replay correctness — real ThreadLaneExecutor, bit-identical outputs
# ----------------------------------------------------------------------

def test_replay_bit_identical_on_real_executor():
    import jax

    sq = jax.jit(lambda a, _o: a * a)
    red = jax.jit(lambda a, b, _o: a - b)

    def run_eager():
        s = make_scheduler("parallel")
        try:
            rng = np.random.RandomState(7)
            x1 = s.array(rng.randn(512).astype(np.float32))
            x2 = s.array(rng.randn(512).astype(np.float32))
            y1 = s.array(shape=(512,), dtype=np.float32)
            y2 = s.array(shape=(512,), dtype=np.float32)
            z = s.array(shape=(512,), dtype=np.float32)
            s.launch(sq, [const(x1), out(y1)], name="SQ1")
            s.launch(sq, [const(x2), out(y2)], name="SQ2")
            s.launch(red, [const(y1), const(y2), out(z)], name="RED")
            return np.asarray(z).copy()
        finally:
            s.shutdown()

    ref = run_eager()
    s = make_scheduler("parallel")
    try:
        for _ep in range(3):
            rng = np.random.RandomState(7)
            x1 = s.array(rng.randn(512).astype(np.float32))
            x2 = s.array(rng.randn(512).astype(np.float32))
            y1 = s.array(shape=(512,), dtype=np.float32)
            y2 = s.array(shape=(512,), dtype=np.float32)
            z = s.array(shape=(512,), dtype=np.float32)
            with s.capture("ep"):
                s.launch(sq, [const(x1), out(y1)], name="SQ1")
                s.launch(sq, [const(x2), out(y2)], name="SQ2")
                s.launch(red, [const(y1), const(y2), out(z)], name="RED")
            np.testing.assert_array_equal(np.asarray(z), ref)
            s.sync()
        assert s.stats()["plan_replays"] == 2
    finally:
        s.shutdown()


@pytest.mark.parametrize("bname", ["VEC", "ML", "HITS"])
def test_replay_bit_identical_on_benchmarks(bname):
    """Replayed benchmark episodes on the real executor must produce exactly
    the eager results (acceptance criterion)."""
    from repro.benchsuite import BENCHMARKS

    bench = BENCHMARKS[bname]
    data = bench.make_data(0.001)
    s_eager = make_scheduler("parallel")
    try:
        ref = bench.build(s_eager, data, gpu=None, iters=1)
    finally:
        s_eager.shutdown()
    s = make_scheduler("parallel")
    try:
        for _ep in range(3):
            with s.capture(bname):
                outs = bench.build(s, data, gpu=None, iters=1)
            for k in ref:
                np.testing.assert_array_equal(outs[k], ref[k])
        assert s.stats()["plan_replays"] >= 2
    finally:
        s.shutdown()


def test_replay_orders_against_prior_work_on_same_arrays():
    """Back-to-back replays binding the same arrays must chain through entry
    dependencies (RAW/WAR against the previous episode's frontier)."""
    import jax

    addc = jax.jit(lambda a: a + 1.0)
    s = make_scheduler("parallel")
    try:
        x = s.array(np.zeros(64, np.float32), name="x")
        for _ep in range(4):
            with s.capture("inc"):
                s.launch(addc, [inout(x)], name="INC")
        assert float(np.asarray(x)[0]) == 4.0
    finally:
        s.shutdown()


# ----------------------------------------------------------------------
# Explicit replay API
# ----------------------------------------------------------------------

def test_explicit_replay_with_fresh_bindings():
    import jax

    dbl = jax.jit(lambda a, _o: 2.0 * a)
    s = make_scheduler("parallel")
    try:
        x = s.array(np.arange(16, dtype=np.float32), name="xin")
        y = s.array(shape=(16,), dtype=np.float32, name="yout")
        with s.capture("dbl"):
            s.launch(dbl, [const(x), out(y)], name="DBL")
        s.sync()
        plans = s.plan_cache.candidates("dbl")
        assert len(plans) == 1
        plan = plans[0]
        x2 = s.array(np.full(16, 3.0, np.float32), name="x2")
        y2 = s.array(shape=(16,), dtype=np.float32, name="y2")
        s.replay(plan, {"xin": x2, "yout": y2})
        np.testing.assert_array_equal(np.asarray(y2), np.full(16, 6.0, np.float32))
        # unbound slots reuse the captured arrays
        s.replay(plan)
        np.testing.assert_array_equal(np.asarray(y),
                                      2.0 * np.arange(16, dtype=np.float32))
    finally:
        s.shutdown()


def test_host_write_mid_replay_demotes_to_eager():
    """A host write to a plan-bound array between launches must produce the
    same results as eager execution (the plan's recorded transfer structure
    cannot cover the fresh host data)."""
    import jax

    cp = jax.jit(lambda a, _o: a + 0.0)

    def run(write_mid, captured):
        s = make_scheduler("parallel")
        try:
            outs = []
            for _ep in range(3):
                x = s.array(np.full(64, 1.0, np.float32), name="x")
                z1 = s.array(shape=(64,), dtype=np.float32, name="z1")
                z2 = s.array(shape=(64,), dtype=np.float32, name="z2")
                import contextlib
                ctx = s.capture("hw") if captured else contextlib.nullcontext()
                with ctx:
                    s.launch(cp, [const(x), out(z1)], name="K1")
                    if write_mid:
                        x.write(np.full(64, 100.0, np.float32))
                    s.launch(cp, [const(x), out(z2)], name="K2")
                outs.append((np.asarray(z1).copy(), np.asarray(z2).copy()))
                s.sync()
            return outs
        finally:
            s.shutdown()

    ref = run(write_mid=True, captured=False)
    got = run(write_mid=True, captured=True)
    for (r1, r2), (g1, g2) in zip(ref, got):
        np.testing.assert_array_equal(g1, r1)
        np.testing.assert_array_equal(g2, r2)     # sees the written value

    # Asymmetric case: plan recorded WITHOUT the write (so it contains no
    # second prefetch), later episode writes mid-way — K2 must still see
    # the new host value, not the stale device copy.
    s = make_scheduler("parallel")
    try:
        for ep in range(3):
            x = s.array(np.full(64, 1.0, np.float32), name="x")
            z1 = s.array(shape=(64,), dtype=np.float32, name="z1")
            z2 = s.array(shape=(64,), dtype=np.float32, name="z2")
            with s.capture("hw2"):
                s.launch(cp, [const(x), out(z1)], name="K1")
                if ep == 2:
                    x.write(np.full(64, 100.0, np.float32))
                s.launch(cp, [const(x), out(z2)], name="K2")
            expect2 = 100.0 if ep == 2 else 1.0
            np.testing.assert_array_equal(
                np.asarray(z2), np.full(64, expect2, np.float32))
            s.sync()
    finally:
        s.shutdown()


def test_host_read_mid_record_blocks_plan_storage():
    """A host read between launches retires the in-trace writer, so a plan
    recorded across it would lose the RAW edge (a race when replayed without
    the read).  The recording must be abandoned; trailing reads/syncs after
    the last launch stay capturable."""
    s = make_scheduler("parallel", simulate=True)
    with s.capture("midread"):
        x = s.array(np.ones(256, np.float32), name="x")
        y = s.array(shape=(256,), dtype=np.float32, name="y")
        z = s.array(shape=(256,), dtype=np.float32, name="z")
        s.launch(None, [const(x), out(y)], name="K1", cost_s=1e-4)
        _ = y[0]                       # retires K1 mid-episode
        s.launch(None, [const(y), out(z)], name="K2", cost_s=1e-4)
    s.sync()
    assert s.stats()["plan_records"] == 0      # racy plan not cached
    # trailing read: harmless, plan stored and replayable
    for _ep in range(2):
        with s.capture("tailread"):
            x = s.array(np.ones(256, np.float32), name="x2")
            y = s.array(shape=(256,), dtype=np.float32, name="y2")
            s.launch(None, [const(x), out(y)], name="K1", cost_s=1e-4)
            _ = y[0]
        s.sync()
    st = s.stats()
    assert st["plan_records"] == 1 and st["plan_replays"] == 1


def test_explicit_replay_rejects_aliased_bindings():
    """Binding one array to two slots would drop the WAW/WAR ordering eager
    execution enforces; replay() must refuse."""
    s = make_scheduler("parallel", simulate=True)
    with s.capture("pair"):
        x = s.array(np.ones(128, np.float32), name="xin")
        y1 = s.array(shape=(128,), dtype=np.float32, name="o1")
        y2 = s.array(shape=(128,), dtype=np.float32, name="o2")
        s.launch(None, [const(x), out(y1)], name="A", cost_s=1e-4)
        s.launch(None, [const(x), out(y2)], name="B", cost_s=1e-4)
    s.sync()
    plan = s.plan_cache.candidates("pair")[0]
    shared = s.array(np.zeros(128, np.float32), name="shared")
    with pytest.raises(ValueError):
        s.replay(plan, {"o1": shared, "o2": shared})


def test_explicit_replay_rejects_stale_host_copy():
    """replay() must refuse to re-run a recorded H2D prefetch over an array
    whose newest value lives only on the device."""
    import jax

    dbl = jax.jit(lambda a, _o: 2.0 * a)
    bump = jax.jit(lambda a: a + 1.0)
    s = make_scheduler("parallel")
    try:
        x = s.array(np.full(16, 2.0, np.float32), name="x")
        y = s.array(shape=(16,), dtype=np.float32, name="y")
        with s.capture("st"):
            s.launch(dbl, [const(x), out(y)], name="DBL")
        s.sync()
        plan = s.plan_cache.candidates("st")[0]
        s.launch(bump, [inout(x)], name="BUMP")   # x now newest on device
        s.sync()
        with pytest.raises(ValueError):
            s.replay(plan)                        # would clobber device x
    finally:
        s.shutdown()


def test_explicit_replay_rejects_bad_bindings():
    s = make_scheduler("parallel", simulate=True)
    with s.capture("vec"):
        _episode(s)
    s.sync()
    plan = s.plan_cache.candidates("vec")[0]
    bad = s.array(np.zeros(7, np.float32))
    with pytest.raises(ValueError):
        s.replay(plan, {"x1": bad})            # shape mismatch
    with pytest.raises(ValueError):
        s.replay(plan, {"nope": bad})          # unknown slot


# ----------------------------------------------------------------------
# Performance acceptance (simulator): replay ~ oracle, >> eager
# ----------------------------------------------------------------------

def _episode_times(mode, bench, gpu, overhead, episodes=4, warmup=2):
    from repro.benchsuite.costmodel import sim_hardware

    kw = {} if mode == "oracle" else {"launch_overhead_s": overhead}
    s = make_scheduler("parallel", simulate=True,
                       hw=sim_hardware(gpu, "parallel", True),
                       oracle=(mode == "oracle"), **kw)
    data = bench.make_data(0.02)
    times = []
    for _ in range(warmup + episodes):
        t0 = s.executor.host_time
        if mode == "replay":
            with s.capture(bench.name):
                bench.build(s, data, gpu=gpu, iters=1)
        else:
            bench.build(s, data, gpu=gpu, iters=1)
        times.append(s.executor.host_time - t0)
    if mode == "replay":
        assert s.stats()["plan_replays"] >= episodes
    return statistics.median(times[warmup:])


def test_replay_matches_oracle_and_beats_eager():
    """Acceptance criterion: on repeated episodes of the paper's 6
    benchmarks, steady-state replay is within 5% of the CUDA-Graphs oracle
    emulation and >= 25% faster than eager at high launch overhead."""
    from repro.benchsuite import BENCHMARKS, GTX1660S

    overhead = 5e-4
    for bname, bench in BENCHMARKS.items():
        te = _episode_times("eager", bench, GTX1660S, overhead)
        tr = _episode_times("replay", bench, GTX1660S, overhead)
        to = _episode_times("oracle", bench, GTX1660S, overhead)
        assert tr <= 1.05 * to + 1e-9, (
            f"{bname}: replay {tr*1e6:.1f}us not within 5% of oracle "
            f"{to*1e6:.1f}us")
        assert tr <= 0.75 * te, (
            f"{bname}: replay {tr*1e6:.1f}us not >=25% faster than eager "
            f"{te*1e6:.1f}us")


def test_invalidation_releases_reserved_lanes():
    """Repeated divergence in a long-running loop must not leak reserved
    lane sets: a dropped plan's lanes return to the eager pool."""
    s = make_scheduler("parallel", simulate=True)
    for cycle in range(10):
        with s.capture("flaky"):
            _episode(s)                 # record (cycle 0) / replay
        s.sync()
        with s.capture("flaky"):        # diverging episode -> invalidate
            x = s.array(np.ones(1024, np.float32))
            y = s.array(shape=(1024,), dtype=np.float32)
            s.launch(None, [const(x), out(y)], name="SQ1", cost_s=1e-4)
            s.launch(None, [const(y), inout(x)], name=f"DIV{cycle}",
                     cost_s=1e-4)
        s.sync()
    reserved = [l for l in s.streams.lanes.values() if l.reserved]
    assert len(reserved) <= 8           # only live plans keep reservations
    assert s.stats()["plan_invalidations"] >= 10


def test_unhashable_config_values_are_capturable():
    """Launch kwargs the eager path accepts (lists, dicts) must not break
    plan recording, matching, or replayed-element configs."""
    s = make_scheduler("parallel", simulate=True)
    for _ep in range(3):
        x = s.array(np.ones(256, np.float32))
        y = s.array(shape=(256,), dtype=np.float32)
        with s.capture("cfg"):
            e = s.launch(None, [const(x), out(y)], name="K", cost_s=1e-4,
                         block=[8, 8], opts={"k": 1})
        assert e.config["block"] == [8, 8]
        s.sync()
    st = s.stats()
    assert st["plan_records"] == 1 and st["plan_replays"] == 2


def test_plan_lanes_do_not_leak_into_eager_pool():
    s = make_scheduler("parallel", simulate=True)
    for _ in range(3):
        with s.capture("vec"):
            _episode(s)
        s.sync()
    reserved = {lid for lid, l in s.streams.lanes.items() if l.reserved}
    assert reserved
    # eager work after replays must not land on reserved plan lanes
    w = s.array(np.zeros(256, np.float32), name="w")
    e = s.launch(None, [inout(w)], name="EAGER", cost_s=1e-4)
    assert e.stream not in reserved
    s.sync()


def test_plan_cache_replacement_stat_and_displacement():
    """``records`` counts net-new signatures only; a same-signature store
    is a replacement (returned as displaced so reservations are freed)."""
    from repro.core.capture import ExecutionPlan, PlanCache

    def mk(key, sig_tag):
        return ExecutionPlan(
            name="n", key=key, elements=(), slots=(), fns=(), configs=(),
            slot_arrays=(), lane_devices=(), kernel_positions=(),
            device_mem=((0, sig_tag),))

    pc = PlanCache(max_plans_per_name=2)
    p1 = mk("k1", 1)
    assert pc.store(p1) == []
    assert pc.records == 1 and pc.replacements == 0
    p1b = mk("k1b", 1)                  # same signature -> replacement
    assert pc.store(p1b) == [p1]
    assert pc.records == 1 and pc.replacements == 1
    pc.store(mk("k2", 2))
    assert pc.store(mk("k3", 3)) == [p1b]   # LRU overflow displaces p1b
    assert pc.records == 3 and pc.replacements == 1
    assert pc.stats()["plan_replacements"] == 1
    assert pc.stats()["plan_records"] == 3


def test_plan_cache_overflow_releases_displaced_reservations():
    """Overflowing max_plans_per_name must release every displaced plan's
    lane reservations — no reserved-lane leak, however many signatures
    cycle through one capture name."""
    s = make_scheduler("parallel", simulate=True)

    def ep(n):
        with s.capture("many"):
            _episode(s, n=n)
        s.sync()

    shapes = [256 + 32 * i for i in range(9)]
    for n in shapes[:8]:
        ep(n)                           # record
        ep(n)                           # replay -> reserves a lane set
    st = s.stats()
    assert st["plan_records"] == 8 and st["plan_replays"] == 8
    assert len(s.plan_cache) == 8
    ep(shapes[8])                       # 9th signature displaces the oldest
    assert len(s.plan_cache) == 8
    assert s.stats()["plan_records"] == 9
    live_keys = {p.key for p in s.plan_cache.candidates("many")}
    assert set(s.streams._plan_lanes) <= live_keys
    reserved_ids = {lid for insts in s.streams._plan_lanes.values()
                    for inst in insts for lid in inst.values()}
    leaked = [l.lane_id for l in s.streams.lanes.values()
              if l.reserved and l.lane_id not in reserved_ids]
    assert not leaked
