"""Deadline/SLO-aware scheduling (ISSUE 8): EDF ordering, element-boundary
preemption with pause/resume, deadline capture/replay, no-deadline
bit-identity, per-tenant SLO attainment, and serving-engine EDF batching."""
import threading

import numpy as np
import pytest

from _hypothesis_fallback import given, settings, st
from repro.benchsuite.slo import (BULK_TENANT, LATENCY_TENANT,
                                  build_slo_workload)
from repro.core import const, inout, make_scheduler, out
from repro.runtime.serving import ServingEngine


# ----------------------------------------------------------------------
# EDF ordering & preemption (simulated)
# ----------------------------------------------------------------------

def test_edf_gives_deadlined_kernel_capacity_first():
    """Two equal-priority full-occupancy kernels: the deadline'd one takes
    the device at full rate (EDF fill) and finishes in its solo time; the
    deadline-free one only gets leftover capacity."""
    s = make_scheduler("parallel", simulate=True, auto_prefetch=False)
    xa = s.array(shape=(256,), dtype=np.float32, name="a")
    xb = s.array(shape=(256,), dtype=np.float32, name="b")
    free = s.launch(None, [inout(xa)], name="free", cost_s=1e-3,
                    parallel_fraction=1.0)
    urgent = s.launch(None, [inout(xb)], name="urgent", cost_s=1e-3,
                      parallel_fraction=1.0, deadline_s=1.5e-3)
    s.sync()
    assert urgent.t_end - urgent.t_start == pytest.approx(1e-3, rel=1e-3)
    assert urgent.t_end < free.t_end
    assert s.stats()["deadline_elements"] >= 1
    assert s.stats()["edf_fill_rounds"] > 0


def test_slo_scenario_preemption_beats_baseline():
    """The benchsuite adversarial scenario: deadlines + preemption cut the
    latency tenant's p99 while conserving total work (makespan)."""
    def run(use_deadlines):
        s = make_scheduler(simulate=True, num_devices=1,
                           tenant_quotas={BULK_TENANT: 4})
        build_slo_workload(s, bulk_units=16, latency_chains=2, per_chain=4,
                           use_deadlines=use_deadlines)
        s.sync()
        res = (s.tenant_stats()[LATENCY_TENANT]["latency_p99_s"],
               s.timeline.makespan, dict(s.stats()))
        s.shutdown()
        return res

    base_p99, base_mk, base_st = run(False)
    dl_p99, dl_mk, dl_st = run(True)
    assert base_p99 / dl_p99 >= 2.0
    assert dl_mk / base_mk <= 1.10
    assert dl_st["edf_preemptions"] > 0
    assert dl_st["edf_resumes"] == dl_st["edf_preemptions"]  # all resumed
    # The deadline-blind run must not even report deadline machinery.
    assert "deadline_elements" not in base_st


@st.composite
def _chain_specs(draw):
    """1-3 kernel chains, each with a deadline choice and per-stage costs."""
    chains = []
    for _ in range(draw(st.integers(min_value=1, max_value=3))):
        length = draw(st.integers(min_value=1, max_value=4))
        dl = draw(st.sampled_from([None, 5e-4, 2e-3, 1e-2]))
        costs = [draw(st.floats(min_value=1e-5, max_value=1e-3))
                 for _ in range(length)]
        chains.append((dl, costs))
    return chains


@settings(max_examples=20, deadline=None)
@given(_chain_specs())
def test_edf_never_violates_dag_order(chains):
    """Property: whatever mix of deadlines EDF reorders by, a child never
    starts before every parent has finished (DAG edges dominate EDF rank)."""
    s = make_scheduler("parallel", simulate=True, auto_prefetch=False)
    kernels = []
    for c, (dl, costs) in enumerate(chains):
        x = s.array(np.zeros(64, np.float32), name=f"c{c}")
        for k, cost in enumerate(costs):
            y = s.array(shape=(64,), dtype=np.float32, name=f"c{c}_{k}")
            kernels.append(s.launch(None, [const(x), out(y)],
                                    name=f"k{c}_{k}", cost_s=cost,
                                    parallel_fraction=1.0, deadline_s=dl))
            x = y
    s.sync()
    for k in kernels:
        for p in k.parents:
            assert k.t_start >= p.t_end - 1e-12, (
                f"{k.name} started before parent {p.name} finished")


def test_pause_resume_bit_identical_on_real_executor():
    """Real ThreadLaneExecutor: force a preemption (queued bulk chain paused
    behind a blocked head while an urgent deadline'd launch arrives), then
    let everything drain — results must match the deadline-free run."""
    gate = threading.Event()

    def blocker(a, _o):
        gate.wait(5.0)
        return a + 1

    step = lambda a, _o: a + 1
    lat = lambda a, _o: a * 2

    def run(use_deadline):
        gate.clear()
        s = make_scheduler("parallel")
        try:
            x = s.array(np.arange(64, dtype=np.float32), name="x")
            y = x
            # Deep single-lane bulk chain: head blocks on the gate, the rest
            # sit QUEUED — exactly the state preemption may pause.
            for k in range(6):
                yn = s.array(shape=(64,), dtype=np.float32, name=f"b{k}")
                fn = blocker if k == 0 else step
                s.launch(fn, [const(y), out(yn)], name=f"bulk{k}",
                         cost_s=1e-2, tenant="bulk")
                y = yn
            u = s.array(np.ones(64, np.float32), name="u")
            v = s.array(shape=(64,), dtype=np.float32, name="v")
            # Declared cost >> deadline window: slack is negative at the
            # submit-time risk check regardless of wall-clock timing, so
            # the preemption decision is deterministic.
            s.launch(lat, [const(u), out(v)], name="urgent", cost_s=1e-2,
                     tenant="lat",
                     deadline_s=(1e-4 if use_deadline else None))
            gate.set()
            s.sync()
            st = dict(s.stats())
            return np.asarray(y).copy(), np.asarray(v).copy(), st
        finally:
            gate.set()
            s.shutdown()

    bulk_ref, lat_ref, _ = run(False)
    bulk_dl, lat_dl, st = run(True)
    np.testing.assert_array_equal(bulk_dl, bulk_ref)
    np.testing.assert_array_equal(lat_dl, lat_ref)
    assert st.get("deadline_elements", 0) >= 1
    # The queued bulk tail was paused (deterministic: the gate holds the
    # lane head until after the urgent submit's risk check) ...
    assert st.get("edf_preemptions", 0) > 0
    # ... and every pause was matched by a resume before shutdown.
    assert st["edf_preemptions"] == st["edf_resumes"]


# ----------------------------------------------------------------------
# No-deadline bit-identity
# ----------------------------------------------------------------------

def test_no_deadline_schedule_bit_identical_with_monitor_armed():
    """A scheduler whose monitor is armed (slo_targets for a tenant that
    never launches) must produce a bit-identical timeline to the default
    scheduler on a deadline-free workload."""
    def spans(**kw):
        s = make_scheduler(simulate=True, num_devices=1,
                           tenant_quotas={BULK_TENANT: 2}, **kw)
        build_slo_workload(s, bulk_units=6, latency_chains=1, per_chain=3,
                           use_deadlines=False)
        s.sync()
        out_ = sorted((sp.name, sp.lane, sp.t0, sp.t1)
                      for sp in s.timeline.spans)
        st = dict(s.stats())
        s.shutdown()
        return out_, st

    ref, ref_st = spans()
    armed, armed_st = spans(slo_targets={"ghost-tenant": 1.0})
    assert armed == ref
    assert "deadline_elements" not in ref_st
    assert armed_st.get("deadline_elements", 0) == 0
    assert armed_st.get("edf_preemptions", 0) == 0


# ----------------------------------------------------------------------
# Tenant SLO targets & attainment stats
# ----------------------------------------------------------------------

def test_tenant_slo_target_stamps_deadlines_and_reports_attainment():
    s = make_scheduler(simulate=True, num_devices=1,
                       tenant_quotas={BULK_TENANT: 4},
                       slo_targets={LATENCY_TENANT: 0.05})
    build_slo_workload(s, bulk_units=8, latency_chains=1, per_chain=3,
                       use_deadlines=False)   # deadline comes from the SLO
    s.sync()
    ts = s.tenant_stats()
    lat = ts[LATENCY_TENANT]
    assert lat["deadlined"] > 0
    assert lat["slo_attainment"] == pytest.approx(1.0)   # 50ms is generous
    assert "slo_attainment" not in ts[BULK_TENANT]
    assert s.stats()["deadline_elements"] > 0
    s.shutdown()


# ----------------------------------------------------------------------
# Capture/replay of deadline'd episodes
# ----------------------------------------------------------------------

def test_replay_restamps_deadlines_and_preserves_edf_rank():
    """Deadline'd episodes replay from one plan; each replay re-stamps a
    fresh absolute deadline (monitor registers the replayed elements) and
    the deadline'd kernel still EDF-outranks the deadline-free one."""
    s = make_scheduler("parallel", simulate=True, auto_prefetch=False)

    def episode():
        xa = s.array(shape=(256,), dtype=np.float32, name="a")
        xb = s.array(shape=(256,), dtype=np.float32, name="b")
        with s.capture("ep"):
            s.launch(None, [inout(xa)], name="free", cost_s=1e-3,
                     parallel_fraction=1.0)
            s.launch(None, [inout(xb)], name="urgent", cost_s=1e-3,
                     parallel_fraction=1.0, deadline_s=1.5e-3)
        s.sync()

    counts = []
    for _ in range(3):
        episode()
        counts.append(s.stats()["deadline_elements"])
    st = s.stats()
    assert st["plan_records"] == 1
    assert st["plan_replays"] == 2
    # Each replay registered the urgent kernel afresh (fresh deadline_t).
    assert counts == [1, 2, 3]
    urgent = [sp for sp in s.timeline.spans if sp.name == "urgent"]
    free = [sp for sp in s.timeline.spans if sp.name == "free"]
    assert len(urgent) == 3 and len(free) == 3
    for u, f in zip(sorted(urgent, key=lambda sp: sp.t0),
                    sorted(free, key=lambda sp: sp.t0)):
        assert u.t1 - u.t0 == pytest.approx(1e-3, rel=1e-3)  # full EDF rate
        assert u.t1 < f.t1
    s.shutdown()


def test_deadline_retag_invalidates_plan():
    """deadline_s is part of the plan signature: re-running the episode with
    a different deadline must record a fresh plan, not replay the stale
    one (EDF rank and preemption eligibility differ)."""
    s = make_scheduler("parallel", simulate=True, auto_prefetch=False)

    def episode(dl):
        xa = s.array(shape=(256,), dtype=np.float32, name="a")
        with s.capture("ep"):
            s.launch(None, [inout(xa)], name="k", cost_s=1e-3,
                     parallel_fraction=1.0, deadline_s=dl)
        s.sync()

    episode(1e-3)
    episode(1e-3)
    assert s.stats()["plan_replays"] == 1
    episode(5e-3)                       # retag: signature mismatch
    st = s.stats()
    assert st["plan_records"] == 2
    assert st["plan_replays"] == 1
    episode(5e-3)                       # the retagged plan now replays
    assert s.stats()["plan_replays"] == 2
    s.shutdown()


# ----------------------------------------------------------------------
# Serving engine: EDF batch assembly + age-based partial-batch flush
# ----------------------------------------------------------------------

def _engine_shell(batch=2):
    eng = ServingEngine.__new__(ServingEngine)
    eng.batch = batch
    eng.max_new = 4
    eng.sched = make_scheduler("parallel", simulate=True)
    eng.capture = False
    eng._queue = __import__("collections").deque()
    eng._rid = 0
    eng._pending = []
    return eng


def test_serving_deadlined_batch_issues_first():
    """A deadline'd tenant's batch EDF-outranks the stride order: it issues
    before the earlier-submitted deadline-free bulk batches, which then
    drain in the usual stride order."""
    eng = _engine_shell(batch=2)
    order = []
    eng._issue_batch = lambda plen, ntok, tenant, prio, group: \
        order.append(tenant)
    rng = np.random.RandomState(0)
    for _ in range(4):
        eng.submit(rng.randint(0, 100, 8), 4, tenant="bulk", priority=0)
    for _ in range(2):
        eng.submit(rng.randint(0, 100, 8), 4, tenant="lat", priority=0,
                   deadline_s=1e-3)
    eng.flush()
    assert order == ["lat", "bulk", "bulk"]


def test_serving_deadline_free_flush_order_unchanged():
    """Without deadlines the EDF sort keys are all +inf: batch assembly must
    keep the exact legacy weighted-fair order."""
    eng = _engine_shell(batch=2)
    order = []
    eng._issue_batch = lambda plen, ntok, tenant, prio, group: \
        order.append((tenant, len(group)))
    rng = np.random.RandomState(0)
    for _ in range(6):
        eng.submit(rng.randint(0, 100, 8), 4, tenant="bulk", priority=0)
    for _ in range(6):
        eng.submit(rng.randint(0, 100, 8), 4, tenant="lat", priority=3)
    eng.flush()
    assert order == [("bulk", 2), ("lat", 2), ("lat", 2), ("lat", 2),
                     ("bulk", 2), ("bulk", 2)]


def test_serving_max_batch_wait_holds_then_releases_partial_batches():
    """With max_batch_wait_s set, a young partial batch with a comfortable
    deadline is held back; force=True (or deadline pressure) releases it."""
    eng = _engine_shell(batch=4)
    eng.max_batch_wait_s = 10.0
    order = []
    eng._issue_batch = lambda plen, ntok, tenant, prio, group: \
        order.append((tenant, len(group)))
    rng = np.random.RandomState(1)
    eng.submit(rng.randint(0, 100, 8), 4, tenant="a", priority=0)
    eng.submit(rng.randint(0, 100, 8), 4, tenant="a", priority=0)
    eng.flush()
    assert order == []                  # young + partial + no pressure: held
    eng.flush(force=True)
    assert order == [("a", 2)]
    # A tight deadline defeats the hold even for a fresh partial batch.
    order.clear()
    eng.submit(rng.randint(0, 100, 8), 4, tenant="a", priority=0,
               deadline_s=1e-3)
    eng.flush()
    assert order == [("a", 1)]
