"""Runtime daemon: lifecycle state machine, persistent store, monitor,
admission policy, wire framing, and socket end-to-end flows.

The crash/restart recovery suite lives in ``test_daemon_recovery.py``;
clean-shutdown satellites in ``test_shutdown.py``.
"""
import json
import os
import socket
import subprocess
import sys
import tempfile
import threading
import time

import pytest

from _hypothesis_fallback import given, settings, st
from repro.daemon import (AdmissionPolicy, DaemonClient, DaemonError,
                          DaemonServer, Ewma, IllegalTransitionError,
                          JobRecord, JobState, JobStore, LEGAL_TRANSITIONS,
                          RuntimeMonitor, SpikeDetector, TERMINAL_STATES)
from repro.daemon.jobs import JobCancelled, JobContext, run_job
from repro.daemon.lifecycle import validate_history
from repro.daemon.wire import ProtocolError, recv_msg, send_msg

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")


def _server(tmp, **kw):
    """In-process daemon on a sim scheduler (no jax import needed)."""
    kw.setdefault("sched_kw", {"simulate": True})
    kw.setdefault("workers", 2)
    kw.setdefault("monitor_interval_s", 0.02)
    srv = DaemonServer(os.path.join(tmp, "d.sock"),
                       store_path=os.path.join(tmp, "jobs.jsonl"), **kw)
    return srv.start()


# ======================================================================
# Lifecycle state machine
# ======================================================================

def test_lifecycle_happy_path_records_timestamps():
    j = JobRecord("j1", "noop", submit_t=100.0)
    for dst in (JobState.ADMITTED, JobState.RUNNING, JobState.FINISHED):
        j.transition(dst, t=101.0)
    assert j.state is JobState.FINISHED and j.terminal
    assert j.attempts == 1
    assert [(a, b) for a, b, _ in j.transitions] == [
        ("queued", "admitted"), ("admitted", "running"),
        ("running", "finished")]
    assert j.transition_time(JobState.RUNNING) == 101.0
    assert validate_history(j.transitions) == []


def test_illegal_transition_raises_and_mutates_nothing():
    j = JobRecord("j1", "noop")
    with pytest.raises(IllegalTransitionError):
        j.transition(JobState.FINISHED)     # queued -> finished is illegal
    assert j.state is JobState.QUEUED and j.transitions == []
    j.transition(JobState.CANCELLED)
    with pytest.raises(IllegalTransitionError):
        j.transition(JobState.ADMITTED)     # terminal states are absorbing
    assert len(j.transitions) == 1


def test_pause_resume_cycle_and_shed_edges_are_legal():
    j = JobRecord("j1", "sleep")
    for dst in (JobState.ADMITTED, JobState.RUNNING, JobState.PAUSED,
                JobState.RUNNING, JobState.PAUSED, JobState.CANCELLED):
        j.transition(dst)
    assert validate_history(j.transitions) == []
    shed = JobRecord("j2", "sleep")
    shed.transition(JobState.CANCELLED, reason="shed:queue_full")
    assert shed.reason.startswith("shed:")
    assert validate_history(shed.transitions) == []


def test_validate_history_flags_corruptions():
    assert validate_history([("queued", "finished", 0.0)])
    assert validate_history([("admitted", "running", 0.0)])  # bad start
    assert validate_history([("queued", "admitted", 0.0),
                             ("running", "finished", 1.0)])  # broken chain
    assert validate_history([("queued", "bogus", 0.0)])      # unknown state


def test_job_record_json_roundtrip():
    j = JobRecord("j1", "chain", params={"n": 3}, tenant="t", priority=2,
                  deadline_s=1.5, submit_t=9.0)
    j.transition(JobState.ADMITTED)
    j.transition(JobState.RUNNING)
    j.transition(JobState.FAILED, reason="boom")
    j.result = {"x": 1}
    back = JobRecord.from_json(json.loads(json.dumps(j.to_json())))
    assert back.to_json() == j.to_json()
    assert back.state is JobState.FAILED and back.reason == "boom"


_STATE_LIST = sorted(JobState, key=lambda s: s.value)


@settings(max_examples=60, deadline=None)
@given(st.lists(st.integers(0, len(_STATE_LIST) - 1), min_size=1,
                max_size=20))
def test_property_random_walks_never_record_illegal_history(steps):
    """Drive a JobRecord with arbitrary requested transitions: every edge
    either raises (and changes nothing) or lands in the recorded history —
    and the history always validates clean."""
    j = JobRecord("jp", "noop")
    for idx in steps:
        dst = _STATE_LIST[idx]
        before = (j.state, len(j.transitions))
        try:
            j.transition(dst)
        except IllegalTransitionError:
            assert (j.state, len(j.transitions)) == before
        else:
            assert dst in LEGAL_TRANSITIONS[before[0]]
            assert j.state is dst
    assert validate_history(j.transitions) == []
    if j.transitions:
        assert j.transitions[-1][1] == j.state.value


# ======================================================================
# Persistent store
# ======================================================================

def test_store_roundtrip_and_last_record_wins(tmp_path):
    path = str(tmp_path / "jobs.jsonl")
    st1 = JobStore(path)
    j = JobRecord("j1", "noop", submit_t=1.0)
    st1.put(j)
    j.transition(JobState.ADMITTED)
    st1.update(j)
    j.transition(JobState.RUNNING)
    st1.update(j)
    st1.close(compact=False)
    # three journal lines, one job, latest state wins
    assert len(open(path).read().splitlines()) == 3
    st2 = JobStore(path)
    assert len(st2) == 1
    assert st2.get("j1").state is JobState.RUNNING
    assert st2.replayed == 3


def test_store_tolerates_torn_tail(tmp_path):
    path = str(tmp_path / "jobs.jsonl")
    st1 = JobStore(path)
    st1.put(JobRecord("j1", "noop"))
    st1.put(JobRecord("j2", "noop"))
    st1.close(compact=False)
    with open(path, "a") as fh:            # simulated crash mid-append
        fh.write('{"t": 1.0, "job": {"job_id": "j3", "ki')
    st2 = JobStore(path)
    assert len(st2) == 2 and st2.truncated_tail == 1
    st2.put(JobRecord("j4", "noop"))       # journal still appendable
    st2.close(compact=False)
    assert len(JobStore(path)) == 3


def test_store_recover_contract(tmp_path):
    path = str(tmp_path / "jobs.jsonl")
    st1 = JobStore(path)
    specs = [("q1", JobState.QUEUED), ("q2", JobState.QUEUED),
             ("a1", JobState.ADMITTED), ("r1", JobState.RUNNING),
             ("p1", JobState.PAUSED), ("f1", JobState.FINISHED),
             ("c1", JobState.CANCELLED)]
    for i, (jid, state) in enumerate(specs):
        j = JobRecord(jid, "noop", submit_t=float(i))
        path_to = {JobState.QUEUED: [], JobState.ADMITTED: ["admitted"],
                   JobState.RUNNING: ["admitted", "running"],
                   JobState.PAUSED: ["admitted", "running", "paused"],
                   JobState.FINISHED: ["admitted", "running", "finished"],
                   JobState.CANCELLED: ["cancelled"]}[state]
        for name in path_to:
            j.transition(JobState(name))
        st1.put(j)
    st1.close()
    st2 = JobStore(path)
    requeued, failed = st2.recover()
    assert [j.job_id for j in requeued] == ["q1", "q2"]   # submit order
    assert {j.job_id for j in failed} == {"a1", "r1", "p1"}
    for j in failed:
        assert j.state is JobState.FAILED and j.reason == "daemon restart"
        assert validate_history(j.transitions) == []
    assert st2.get("f1").state is JobState.FINISHED       # terminals kept
    # recovery is itself journaled: a second replay sees FAILED directly
    st2.close(compact=False)
    st3 = JobStore(path)
    assert st3.get("r1").state is JobState.FAILED
    assert st3.recover() == ([st3.get("q1"), st3.get("q2")], [])


def test_store_compact_rewrites_one_line_per_job(tmp_path):
    path = str(tmp_path / "jobs.jsonl")
    st1 = JobStore(path)
    j = JobRecord("j1", "noop")
    st1.put(j)
    for dst in (JobState.ADMITTED, JobState.RUNNING, JobState.FINISHED):
        j.transition(dst)
        st1.update(j)
    st1.close(compact=True)                # 4 lines -> 1
    assert len(open(path).read().splitlines()) == 1
    st2 = JobStore(path)
    back = st2.get("j1")
    assert back.state is JobState.FINISHED
    assert len(back.transitions) == 3      # history survives compaction


# ======================================================================
# Monitor: EWMA, spikes, cooldown, drift
# ======================================================================

def test_ewma_converges():
    e = Ewma(alpha=0.5)
    assert e.get(7.0) == 7.0               # default until first update
    e.update(10.0)
    assert e.value == 10.0                 # first observation seeds
    e.update(0.0)
    assert e.value == 5.0


def test_spike_detector_fires_before_absorbing_and_cools_down():
    d = SpikeDetector(factor=3.0, floor=2.0, cooldown_s=1.0, alpha=0.5)
    assert not d.observe(1.0, now=0.0)     # below 3*floor
    assert d.observe(20.0, now=1.0)        # step change: spike pre-absorb
    assert d.active(now=1.5) and not d.active(now=2.5)
    # once the baseline has absorbed the new level, it is not a spike
    for t in range(2, 8):
        d.observe(20.0, now=float(t))
    assert not d.observe(20.0, now=9.0)
    assert d.spikes >= 1


def test_monitor_depth_spike_opens_cooldown_and_snapshot_reports_it():
    depth = {"v": 0}
    mon = RuntimeMonitor(None, interval_s=None, spike_factor=3.0,
                         spike_floor=2.0, cooldown_s=5.0,
                         queue_depth_fn=lambda: depth["v"])
    t = [0.0]

    def sample():
        t[0] += 0.1
        return mon.sample_once(now=t[0])

    for _ in range(5):
        snap = sample()
    assert not snap.spiking
    depth["v"] = 50                        # burst lands
    snap = sample()
    assert snap.spiking and snap.cooldown_remaining_s > 4.0
    assert snap.queue_depth == 50
    assert mon.stats()["monitor_spikes"] >= 1


def test_monitor_arrival_rate_uses_window_not_instant():
    arr = {"v": 0}
    mon = RuntimeMonitor(None, interval_s=None, spike_factor=3.0,
                         spike_floor=4.0, rate_floor=4.0, rate_window_s=1.0,
                         arrivals_fn=lambda: arr["v"])
    now = 0.0
    for _ in range(20):                    # steady 1 job per 0.02s = 50/s?
        now += 0.02
        arr["v"] += 0                      # no arrivals: baseline
        mon.sample_once(now=now)
    arr["v"] += 1                          # ONE submit between samples
    snap = mon.sample_once(now=now + 0.02)
    # one arrival over the 1s window is 1 job/s, far below 3*floor=12 —
    # must NOT read as a 50/s instantaneous spike.
    assert not snap.spiking
    arr["v"] += 40                         # genuine burst
    snap = mon.sample_once(now=now + 0.04)
    assert snap.spiking


def test_monitor_drift_alarm_needs_persistence():
    from repro.core.scheduler import make_scheduler
    s = make_scheduler("parallel", simulate=True)
    mon = RuntimeMonitor(s, interval_s=None, drift_grace=2)
    assert mon.sample_once(now=1.0).drift_alarms == 0
    # corrupt the pool ledger: logical accounting now disagrees with itself
    s.memory.pools[0].add(0xDEAD, 1234)
    snap = mon.sample_once(now=2.0)
    assert snap.drift_alarms == 0          # one dirty sample: grace
    snap = mon.sample_once(now=3.0)
    assert snap.drift_alarms == 1          # persisted: alarm
    assert any("untracked" in p for p in snap.drift_problems)
    s.memory.pools[0].discard(0xDEAD)      # repaired: streak resets
    snap = mon.sample_once(now=4.0)
    assert snap.drift_alarms == 1 and mon._drift_streak == 0
    s.close()


def test_memory_logical_vs_physical_byte_accounting():
    import numpy as np
    from repro.core.scheduler import make_scheduler
    s = make_scheduler("parallel", simulate=True)
    a = s.array(np.zeros(256, np.float32), name="a")
    b = s.array(np.zeros(64, np.float32), name="b")
    from repro.core import const, out
    s._launch(None, [const(a), out(b)], name="k", cost_s=1e-4)
    s.sync()
    logical = s.memory.logical_resident_bytes()
    assert logical[0] == a.nbytes + b.nbytes
    # the simulator installs no physical device values
    assert s.memory.physical_resident_bytes()[0] == 0
    s.close()


# ======================================================================
# Admission policy
# ======================================================================

def _snap(**kw):
    from repro.daemon.monitor import MonitorSnapshot
    return MonitorSnapshot(**kw)


def test_policy_sheds_on_full_queue_and_spike_but_not_high_priority():
    pol = AdmissionPolicy(max_queue_depth=10, spike_shed_depth=4,
                          shed_below_priority=1)
    lo, hi = JobRecord("lo", "noop", priority=0), \
        JobRecord("hi", "noop", priority=5)
    assert pol.admit(lo, _snap(queue_depth=3)).admitted
    d = pol.admit(lo, _snap(queue_depth=10))
    assert d.action == "shed" and "queue_full" in d.reason
    d = pol.admit(lo, _snap(queue_depth=6, spiking=True))
    assert d.action == "shed" and "spike" in d.reason
    # a spike must not lock out the latency tenant
    assert pol.admit(hi, _snap(queue_depth=6, spiking=True)).admitted
    # below the spike-shed depth, low priority is still admitted
    assert pol.admit(lo, _snap(queue_depth=2, spiking=True)).admitted
    assert pol.stats()["policy_shed"] == 2


def test_policy_dispatch_defers_on_slots_memory_and_cooldown():
    pol = AdmissionPolicy(max_running=2, mem_high_watermark=0.9)
    j = JobRecord("j", "noop", priority=0)
    assert pol.dispatch(j, _snap(running=1)).admitted
    d = pol.dispatch(j, _snap(running=2))
    assert not d.admitted and "running_slots" in d.reason
    d = pol.dispatch(j, _snap(mem_occupancy=0.95))
    assert not d.admitted and "mem_pressure" in d.reason
    d = pol.dispatch(j, _snap(spiking=True))
    assert not d.admitted and "cooldown" in d.reason
    hi = JobRecord("h", "noop", priority=9)
    assert pol.dispatch(hi, _snap(spiking=True)).admitted
    s = pol.stats()
    assert s["policy_defer_events"] == 3
    assert s["policy_deferred_jobs"] == 1  # same job deferred thrice


# ======================================================================
# Wire framing
# ======================================================================

def test_wire_roundtrip_and_eof():
    a, b = socket.socketpair()
    try:
        msgs = [{"op": "ping"}, {"x": [1, 2.5, None, "é"]}, {}]
        for m in msgs:
            send_msg(a, m)
        for m in msgs:
            assert recv_msg(b) == m
        a.close()
        assert recv_msg(b) is None         # clean EOF
    finally:
        b.close()


def test_wire_rejects_oversized_header():
    a, b = socket.socketpair()
    try:
        a.sendall(b"\xff\xff\xff\xff")
        with pytest.raises(ProtocolError):
            recv_msg(b)
    finally:
        a.close()
        b.close()


# ======================================================================
# JobContext + run_job
# ======================================================================

def test_job_context_checkpoint_cancel_and_pause_callbacks():
    ctx = JobContext(None, "j1")
    ctx.checkpoint()
    events = []
    ctx.on_pause = lambda: (events.append("pause"), ctx.pause_event.set())
    ctx.on_resume = lambda: events.append("resume")
    ctx.pause_event.clear()
    ctx.checkpoint()                       # pauses, callback resumes it
    assert events == ["pause", "resume"] and ctx.paused_times == 1
    ctx.cancel_requested = True
    with pytest.raises(JobCancelled):
        ctx.checkpoint()


def test_run_job_unknown_kind():
    with pytest.raises(ValueError, match="unknown job kind"):
        run_job(None, "nope")


def test_run_job_sleep_in_process():
    out = run_job(None, "sleep", {"total_s": 0.02, "steps": 2})
    assert out == {"slept_s": 0.02, "checkpoints": 2}


# ======================================================================
# Server end-to-end over the socket (sim scheduler, in-process server)
# ======================================================================

def test_server_submit_wait_status_stats_roundtrip(tmp_path):
    srv = _server(str(tmp_path))
    try:
        with DaemonClient(srv.socket_path) as c:
            assert c.ping()["ok"]
            r = c.submit("noop", {"k": [1, 2]}, tenant="acme", priority=3)
            job = c.wait(r["job_id"], timeout=10)
            assert job["state"] == "finished"
            assert job["result"] == {"echo": {"k": [1, 2]}}
            assert job["tenant"] == "acme" and job["priority"] == 3
            assert validate_history([tuple(t) for t in
                                     job["transitions"]]) == []
            assert c.status(r["job_id"])["state"] == "finished"
            st = c.stats()
            assert st["server"]["arrivals"] == 1
            assert st["policy"]["policy_admitted"] == 1
            assert st["store"]["by_state"] == {"finished": 1}
            assert "mem_occupancy" in st["scheduler"]
            assert st["job_tenant_stats"]["acme"]["finished"] == 1
            assert st["job_tenant_stats"]["acme"]["queue_delay_mean_s"] >= 0
            with pytest.raises(DaemonError, match="unknown job kind"):
                c.submit("not_a_kind")
            with pytest.raises(DaemonError, match="unknown job_id"):
                c.status("j-nope")
    finally:
        srv.stop()


def test_server_two_connections_interleave(tmp_path):
    srv = _server(str(tmp_path))
    try:
        c1, c2 = DaemonClient(srv.socket_path), DaemonClient(srv.socket_path)
        ids = [c.submit("sleep", {"total_s": 0.03, "steps": 3},
                        tenant=t)["job_id"]
               for c, t in [(c1, "a"), (c2, "b"), (c1, "a"), (c2, "b")]]
        for jid, c in zip(ids, [c2, c1, c2, c1]):   # cross-waiting is fine
            assert c.wait(jid, timeout=10)["state"] == "finished"
        c1.close()
        c2.close()
    finally:
        srv.stop()


def test_server_cancel_queued_and_running(tmp_path):
    srv = _server(str(tmp_path), workers=1)
    try:
        with DaemonClient(srv.socket_path) as c:
            blocker = c.submit("sleep", {"total_s": 5.0,
                                         "steps": 100})["job_id"]
            queued = c.submit("sleep", {"total_s": 5.0})["job_id"]
            # cancel while queued: immediate, never runs
            assert c.cancel(queued)["job"]["state"] == "cancelled"
            jq = c.status(queued)
            assert [tuple(t[:2]) for t in jq["transitions"]] == [
                ("queued", "cancelled")]
            # cancel while running: lands at the next checkpoint
            deadline = time.monotonic() + 5
            while c.status(blocker)["state"] != "running":
                assert time.monotonic() < deadline
                time.sleep(0.01)
            c.cancel(blocker)
            jb = c.wait(blocker, timeout=10)
            assert jb["state"] == "cancelled"
            assert jb["reason"] == "client cancel"
    finally:
        srv.stop()


def test_server_pause_resume_journals_transitions(tmp_path):
    srv = _server(str(tmp_path), workers=1)
    try:
        with DaemonClient(srv.socket_path) as c:
            jid = c.submit("sleep", {"total_s": 3.0,
                                     "steps": 60})["job_id"]
            deadline = time.monotonic() + 5
            while c.status(jid)["state"] != "running":
                assert time.monotonic() < deadline
                time.sleep(0.01)
            c.pause(jid)
            while c.status(jid)["state"] != "paused":
                assert time.monotonic() < deadline
                time.sleep(0.01)
            c.resume(jid)
            c.cancel(jid)
            job = c.wait(jid, timeout=10)
            edges = [tuple(t[:2]) for t in job["transitions"]]
            assert ("running", "paused") in edges
            assert ("paused", "running") in edges
            assert validate_history([tuple(t) for t in
                                     job["transitions"]]) == []
    finally:
        srv.stop()


def test_server_failed_job_reports_reason(tmp_path):
    srv = _server(str(tmp_path))
    try:
        with DaemonClient(srv.socket_path) as c:
            jid = c.submit("sleep", {"total_s": "not-a-number"})["job_id"]
            job = c.wait(jid, timeout=10)
            assert job["state"] == "failed"
            assert "float" in job["reason"] or "str" in job["reason"]
            with pytest.raises(DaemonError, match="ended failed"):
                c.result(jid)
    finally:
        srv.stop()


def test_server_drain_blocks_submissions_then_resumes(tmp_path):
    srv = _server(str(tmp_path))
    try:
        with DaemonClient(srv.socket_path) as c:
            jid = c.submit("sleep", {"total_s": 0.05})["job_id"]
            d = c.drain(timeout=10)
            assert d["drained"] and d["running"] == 0
            assert c.status(jid)["state"] == "finished"
            with pytest.raises(DaemonError, match="draining"):
                c.submit("noop")
            c.resume_admission()
            assert c.submit("noop")["ok"]
    finally:
        srv.stop()


def test_server_sheds_under_sustained_overload_admits_when_calm(tmp_path):
    policy = AdmissionPolicy(max_queue_depth=12, spike_shed_depth=4,
                             shed_below_priority=1, max_running=1)
    srv = _server(str(tmp_path), workers=1, policy=policy,
                  monitor=RuntimeMonitor(interval_s=0.02, spike_factor=3.0,
                                         spike_floor=2.0, rate_floor=50.0,
                                         cooldown_s=2.0),
                  monitor_interval_s=0.02)
    try:
        with DaemonClient(srv.socket_path) as c:
            # calm wave: trickled submissions all admitted
            for _ in range(3):
                assert c.submit("sleep", {"total_s": 0.01})["ok"]
                time.sleep(0.05)
            assert srv.policy.shed == 0
            # overload: burst to build depth, pause a beat for the monitor
            # to see the step change, then keep pushing into the cooldown
            outcomes = []
            for _wave in range(3):
                for _ in range(10):
                    outcomes.append(c.submit(
                        "sleep", {"total_s": 0.3, "steps": 3}))
                time.sleep(0.08)
            shed = [o for o in outcomes if o.get("shed")]
            assert shed, "sustained overload must shed low-priority work"
            assert all("shed:" in o["reason"] for o in shed)
            # shed jobs are journaled QUEUED -> CANCELLED, legally
            job = c.status(shed[0]["job_id"])
            assert job["state"] == "cancelled"
            assert [tuple(t[:2]) for t in job["transitions"]] == [
                ("queued", "cancelled")]
            # high-priority work still gets in during the storm
            assert c.submit("sleep", {"total_s": 0.01},
                            priority=5)["ok"]
            st = c.stats(scheduler=False)
            assert st["policy"]["policy_shed"] == len(shed)
            assert st["monitor"]["monitor_spikes"] >= 1
    finally:
        srv.stop()


def test_server_restart_on_same_socket_path(tmp_path):
    srv = _server(str(tmp_path))
    srv.stop()
    srv2 = _server(str(tmp_path))          # stale paths are reclaimed
    try:
        with DaemonClient(srv2.socket_path) as c:
            assert c.ping()["ok"]
    finally:
        srv2.stop()


# ======================================================================
# Two concurrent client *processes* via the CLI, bit-identical results
# ======================================================================

def _cli(sock, *args):
    return subprocess.run(
        [sys.executable, "-m", "repro.daemon", "--socket", sock, *args],
        capture_output=True, text=True, timeout=240,
        env={**os.environ, "PYTHONPATH": SRC}, cwd=REPO)


def test_two_cli_processes_bit_identical_to_in_process(tmp_path):
    from repro.core.scheduler import make_scheduler
    specs = [{"n": 3, "size": 128, "seed": 11}, {"n": 4, "size": 96,
                                                 "seed": 23}]
    with make_scheduler("parallel") as s:  # real executor: same jit path
        expected = [run_job(s, "chain", p) for p in specs]

    srv = DaemonServer(str(tmp_path / "d.sock"),
                       store_path=str(tmp_path / "jobs.jsonl"),
                       workers=2).start()
    try:
        results = [None, None]
        errs = [None, None]

        def client(i):
            try:
                p = specs[i]
                proc = _cli(srv.socket_path, "submit", "chain",
                            "-p", f"n={p['n']}", "-p", f"size={p['size']}",
                            "-p", f"seed={p['seed']}", "--wait")
                assert proc.returncode == 0, proc.stderr
                results[i] = json.loads(proc.stdout)
            except BaseException as exc:   # surfaced below
                errs[i] = exc

        ts = [threading.Thread(target=client, args=(i,)) for i in range(2)]
        for t in ts:
            t.start()
        for t in ts:
            t.join(timeout=240)
        assert errs == [None, None], errs
        for i, job in enumerate(results):
            assert job["state"] == "finished", job
            assert job["result"] == expected[i]   # bit-identical floats
    finally:
        srv.stop()


def test_cli_socket_roundtrip_smoke(tmp_path):
    """The CI smoke path: serve in a subprocess, ping + noop over the
    socket from a second process, clean shutdown."""
    sock = str(tmp_path / "d.sock")
    env = {**os.environ, "PYTHONPATH": SRC,
           "REPRO_DAEMON_SOCKET": sock,
           "REPRO_DAEMON_STORE": str(tmp_path / "jobs.jsonl")}
    serve = subprocess.Popen(
        [sys.executable, "-m", "repro.daemon", "serve", "--executor", "sim"],
        env=env, cwd=REPO, stdout=subprocess.DEVNULL,
        stderr=subprocess.DEVNULL)
    try:
        deadline = time.monotonic() + 30
        while not os.path.exists(sock):
            assert time.monotonic() < deadline, "daemon never bound"
            time.sleep(0.05)
        out = _cli(sock, "submit", "noop", "-p", "hello=1", "--wait")
        assert out.returncode == 0, out.stderr
        job = json.loads(out.stdout)
        assert job["result"] == {"echo": {"hello": 1}}
        assert _cli(sock, "stats", "--no-scheduler").returncode == 0
        assert _cli(sock, "shutdown").returncode == 0
        assert serve.wait(timeout=30) == 0
    finally:
        if serve.poll() is None:
            serve.kill()
            serve.wait()
