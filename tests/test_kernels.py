"""Pallas kernel validation (interpret mode) against pure-jnp oracles.

Each kernel is swept over shapes/dtypes (explicit grid + hypothesis-driven
random shapes) and asserted allclose to its ref.py oracle.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_fallback import given, settings, st

from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.rmsnorm.ops import rmsnorm
from repro.kernels.rmsnorm.ref import rmsnorm_ref
from repro.kernels.rwkv6.ops import wkv6
from repro.kernels.rwkv6.ref import wkv6_ref


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------- flash
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,S,H,Hkv,hd,window,softcap", [
    (2, 256, 4, 2, 64, 0, 0.0),        # GQA
    (1, 512, 8, 8, 32, 0, 0.0),        # MHA
    (2, 256, 4, 2, 64, 128, 0.0),      # sliding window
    (1, 256, 4, 1, 64, 0, 30.0),       # softcap (gemma-style), MQA
    (1, 128, 2, 2, 128, 64, 20.0),     # window + softcap
])
def test_flash_attention_matches_ref(B, S, H, Hkv, hd, window, softcap,
                                     dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, S, H, hd), dtype)
    k = jax.random.normal(ks[1], (B, S, Hkv, hd), dtype)
    v = jax.random.normal(ks[2], (B, S, Hkv, hd), dtype)
    got = flash_attention(q, k, v, window=window, softcap=softcap,
                          block_q=64, block_k=64)
    ref = attention_ref(q, k, v, window=window, softcap=softcap)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


@pytest.mark.slow
@settings(max_examples=10, deadline=None)
@given(st.sampled_from([64, 128, 256]), st.sampled_from([1, 2]),
       st.sampled_from([16, 32, 64]), st.sampled_from([1, 2, 4]),
       st.booleans())
def test_flash_attention_property(S, B, hd, g, windowed):
    Hkv = 2
    H = Hkv * g
    ks = jax.random.split(jax.random.PRNGKey(S * 7 + hd), 3)
    q = jax.random.normal(ks[0], (B, S, H, hd), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, Hkv, hd), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, Hkv, hd), jnp.float32)
    window = S // 2 if windowed else 0
    got = flash_attention(q, k, v, window=window, block_q=32, block_k=32)
    ref = attention_ref(q, k, v, window=window)
    np.testing.assert_allclose(got, ref, rtol=3e-5, atol=3e-5)


def test_flash_matches_model_chunked_path():
    """The Pallas kernel and the XLA chunked fallback must agree."""
    from repro.models.attention import _sdpa_chunked

    class Cfg:
        logit_softcap = 0.0
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    q = jax.random.normal(ks[0], (2, 256, 4, 32), jnp.float32)
    k = jax.random.normal(ks[1], (2, 256, 2, 32), jnp.float32)
    v = jax.random.normal(ks[2], (2, 256, 2, 32), jnp.float32)
    a = flash_attention(q, k, v, block_q=64, block_k=64)
    b = _sdpa_chunked(Cfg, q, k, v, q_chunk=64, kv_chunk=64)
    np.testing.assert_allclose(a.reshape(2, 256, -1), b, rtol=3e-5, atol=3e-5)


# ---------------------------------------------------------------- rwkv6
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,T,H,hd,chunk", [
    (2, 64, 2, 16, 16),
    (1, 128, 4, 32, 32),
    (2, 96, 1, 64, 32),    # chunk not dividing T -> falls back to smaller
])
def test_wkv6_matches_ref(B, T, H, hd, chunk, dtype):
    ks = jax.random.split(jax.random.PRNGKey(1), 6)
    r = jax.random.normal(ks[0], (B, T, H, hd), dtype) * 0.5
    k = jax.random.normal(ks[1], (B, T, H, hd), dtype) * 0.5
    v = jax.random.normal(ks[2], (B, T, H, hd), dtype) * 0.5
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (B, T, H, hd), dtype)) * 0.5 + 0.4
    u = jax.random.normal(ks[4], (H, hd), dtype) * 0.3
    s0 = jax.random.normal(ks[5], (B, H, hd, hd), jnp.float32) * 0.1

    y, sT = wkv6(r, k, v, w, u, s0, chunk=chunk)
    flat = lambda x: jnp.swapaxes(x, 1, 2).reshape(B * H, T, hd)
    y_ref, sT_ref = wkv6_ref(flat(r), flat(k), flat(v), flat(w),
                             jnp.tile(u[None], (B, 1, 1)).reshape(B * H, hd),
                             s0.reshape(B * H, hd, hd))
    y_ref = jnp.swapaxes(y_ref.reshape(B, H, T, hd), 1, 2).reshape(B, T, H * hd)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(y_ref, np.float32), **_tol(dtype))
    np.testing.assert_allclose(sT.reshape(B * H, hd, hd), sT_ref,
                               **_tol(dtype))


def test_wkv6_state_carry_composes():
    """Running two half-sequences with carried state == one full run."""
    B, T, H, hd = 1, 64, 2, 16
    ks = jax.random.split(jax.random.PRNGKey(2), 5)
    r, k, v = (jax.random.normal(ks[i], (B, T, H, hd)) * 0.5 for i in range(3))
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (B, T, H, hd))) * 0.5 + 0.4
    u = jax.random.normal(ks[4], (H, hd)) * 0.3
    s0 = jnp.zeros((B, H, hd, hd), jnp.float32)
    y_full, s_full = wkv6(r, k, v, w, u, s0, chunk=16)
    y1, s_mid = wkv6(r[:, :32], k[:, :32], v[:, :32], w[:, :32], u, s0,
                     chunk=16)
    y2, s_end = wkv6(r[:, 32:], k[:, 32:], v[:, 32:], w[:, 32:], u, s_mid,
                     chunk=16)
    np.testing.assert_allclose(jnp.concatenate([y1, y2], axis=1), y_full,
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(s_end, s_full, rtol=1e-5, atol=1e-5)


def test_wkv6_matches_model_layer():
    """Kernel agrees with the model's scan implementation (rwkv.py)."""
    from repro.configs import get_config
    from repro.models import rwkv as R
    cfg = get_config("rwkv6_1_6b", reduced=True)
    p = R.init_rwkv(jax.random.PRNGKey(0), cfg)
    B, S, d = 2, 32, cfg.d_model
    hd = cfg.ssm.head_dim
    H = d // hd
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S, d)) * 0.1
    state = R.init_rwkv_state(cfg, B)
    y_model, _ = R.apply_rwkv_seq(cfg, p, x, state)

    # same projections, kernel recurrence
    x_prev = jnp.concatenate([state["shift"][:, None, :], x[:, :-1, :]], 1)
    r, k, v, g, w = R._projections(p, x, x_prev, x.dtype)
    resh = lambda t: t.reshape(B, S, H, hd)
    y_k, _ = wkv6(resh(r), resh(k), resh(v), resh(w.astype(x.dtype)),
                  p["bonus_u"], state["wkv"], chunk=16)
    y_k = R._group_norm(y_k.reshape(B * S, d), p["ln_x_scale"], H
                        ).reshape(B, S, d)
    y_k = y_k * jax.nn.silu(g)
    y_k = y_k @ p["wo"].astype(x.dtype)
    np.testing.assert_allclose(y_k, y_model, rtol=2e-4, atol=2e-4)


# --------------------------------------------------------------- rmsnorm
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape", [(64, 128), (3, 17, 256), (1, 8, 512)])
def test_rmsnorm_matches_ref(shape, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 2)
    x = jax.random.normal(ks[0], shape, dtype)
    scale = jax.random.normal(ks[1], (shape[-1],), dtype) * 0.1 + 1.0
    np.testing.assert_allclose(
        np.asarray(rmsnorm(x, scale), np.float32),
        np.asarray(rmsnorm_ref(x, scale), np.float32), **_tol(dtype))


@settings(max_examples=15, deadline=None)
@given(st.integers(1, 64), st.sampled_from([128, 256, 384]))
def test_rmsnorm_property(rows, d):
    x = jax.random.normal(jax.random.PRNGKey(rows), (rows, d), jnp.float32)
    scale = jnp.ones((d,))
    got = rmsnorm(x, scale)
    np.testing.assert_allclose(got, rmsnorm_ref(x, scale), rtol=2e-5,
                               atol=2e-5)
    # invariant: output row RMS ~= 1 for unit scale
    rms = np.sqrt(np.mean(np.asarray(got) ** 2, axis=-1))
    np.testing.assert_allclose(rms, 1.0, atol=1e-2)
