"""Pure-logic tests of the sharding rules (no compilation)."""
import os
import subprocess
import sys
import textwrap


REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_sub(code: str) -> str:
    prog = ("import os\n"
            "os.environ['XLA_FLAGS'] = "
            "'--xla_force_host_platform_device_count=8'\n"
            + textwrap.dedent(code))
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    out = subprocess.run([sys.executable, "-c", prog], capture_output=True,
                         text=True, env=env, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_fit_spec_divisibility_and_param_rules():
    out = run_sub("""
        import jax
        from jax.sharding import PartitionSpec as P
        from repro.sharding.rules import fit_spec, _param_rule, dp_axes
        mesh = jax.make_mesh((2, 4), ("data", "model"))

        # divisibility guard drops non-dividing axes
        assert fit_spec(("data", "model"), (8, 12), mesh) == P("data", "model")
        assert fit_spec(("data", "model"), (7, 12), mesh) == P(None, "model")
        assert fit_spec(("data", "model"), (8, 13), mesh) == P("data", None)
        # tuple axes
        assert fit_spec((("data", "model"), None), (16, 3), mesh) == \\
            P(("data", "model"), None)
        assert fit_spec((("data", "model"), None), (12, 3), mesh) == \\
            P(None, None)

        # param rules: FSDP+TP on matrices, replicate vectors
        assert _param_rule("blocks.ffn.w_in", (64, 128), mesh, "data") == \\
            P("data", "model")
        assert _param_rule("blocks.ffn.w_out", (128, 64), mesh, "data") == \\
            P("model", "data")
        assert _param_rule("blocks.ln1.scale", (64,), mesh, "data") == P(None)
        # MoE expert weights: EP when expert count divides
        assert _param_rule("moe.w_in", (4, 64, 32), mesh, "data") == \\
            P("model", "data", None)
        assert _param_rule("moe.w_in", (6, 64, 32), mesh, "data") == \\
            P(None, "data", "model")
        print("OK")
    """)
    assert "OK" in out


def test_use_weight_noop_outside_mesh():
    import jax.numpy as jnp
    from repro.sharding.context import shard_activations, use_weight
    w = jnp.ones((8, 8))
    assert use_weight(w, (None, "model")) is w
    assert shard_activations(w) is w


def test_cache_sharding_kv_head_fallback():
    out = run_sub("""
        import jax, jax.numpy as jnp
        from repro.sharding.rules import cache_sharding
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        cache = ({"k": jnp.zeros((2, 8, 16, 4, 8)),    # Hkv=4 divides tp=4
                  "v": jnp.zeros((2, 8, 16, 3, 8))},)  # Hkv=3 -> hd fallback
        sh = cache_sharding(cache, mesh)
        assert "model" in str(sh[0]["k"].spec[3])
        assert sh[0]["v"].spec[3] is None and "model" in str(sh[0]["v"].spec[4])
        print("OK")
    """)
    assert "OK" in out
