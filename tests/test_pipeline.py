"""Pipeline parallelism (GPipe over the pod axis): correctness vs the
sequential reference, forward and backward."""
import os
import subprocess
import sys
import textwrap

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_sub(code: str) -> str:
    prog = ("import os\n"
            "os.environ['XLA_FLAGS'] = "
            "'--xla_force_host_platform_device_count=8'\n"
            + textwrap.dedent(code))
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    out = subprocess.run([sys.executable, "-c", prog], capture_output=True,
                         text=True, env=env, timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_pipeline_forward_matches_sequential():
    out = run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.runtime.pipeline import pipeline_apply

        mesh = jax.make_mesh((4, 2), ("pod", "data"))
        S, L_per, d = 4, 2, 16         # 4 stages x 2 layers
        rng = np.random.RandomState(0)
        Ws = jnp.asarray(rng.randn(S, L_per, d, d).astype(np.float32) * 0.3)

        def stage_fn(Wstage, x):
            for i in range(L_per):
                x = jnp.tanh(x @ Wstage[i])
            return x

        n_micro, mb = 6, 8
        xs = jnp.asarray(rng.randn(n_micro, mb, d).astype(np.float32))

        fwd = jax.jit(pipeline_apply(stage_fn, mesh, axis="pod"))
        got = fwd(Ws, xs)

        ref = xs
        for s in range(S):
            ref = jax.vmap(lambda x: stage_fn(Ws[s], x))(ref)
        err = float(jnp.max(jnp.abs(got - ref)))
        assert err < 1e-5, err
        print("fwd OK", err)
    """)
    assert "fwd OK" in out


def test_pipeline_backward_matches_sequential():
    out = run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.runtime.pipeline import pipeline_loss_fn

        mesh = jax.make_mesh((2, 4), ("pod", "data"))
        S, L_per, d = 2, 2, 8
        rng = np.random.RandomState(1)
        Ws = jnp.asarray(rng.randn(S, L_per, d, d).astype(np.float32) * 0.3)
        xs = jnp.asarray(rng.randn(4, 4, d).astype(np.float32))
        ys = jnp.asarray(rng.randn(4, 4, d).astype(np.float32))

        def stage_fn(Wstage, x):
            for i in range(L_per):
                x = jnp.tanh(x @ Wstage[i])
            return x

        def loss_tail(outs, ys):
            return jnp.mean((outs - ys) ** 2)

        loss = pipeline_loss_fn(stage_fn, loss_tail, mesh, axis="pod")
        g_pipe = jax.jit(jax.grad(loss))(Ws, xs, ys)

        def ref_loss(Ws):
            out = xs
            for s in range(S):
                out = jax.vmap(lambda x: stage_fn(Ws[s], x))(out)
            return jnp.mean((out - ys) ** 2)

        g_ref = jax.grad(ref_loss)(Ws)
        err = float(jnp.max(jnp.abs(g_pipe - g_ref)))
        assert err < 1e-5, err
        print("bwd OK", err)
    """)
    assert "bwd OK" in out
