"""Offline import guarantees: modules that only *use* jax lazily must be
importable (e.g. for test collection) on a host where jax is absent."""
import os
import subprocess
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_NOJAX_PROBE = """
import sys, types, os

class _BlockJax:
    # Raising from find_spec makes any `import jax` fail exactly as it
    # would on a host without the package installed.
    def find_spec(self, name, path=None, target=None):
        if name == "jax" or name.startswith("jax."):
            raise ImportError("jax blocked for offline-import test")

sys.meta_path.insert(0, _BlockJax())
for m in [m for m in list(sys.modules) if m == "jax" or m.startswith("jax.")]:
    del sys.modules[m]

import repro.core            # lazy-jax by design (executors import in-function)
import repro
pkg = types.ModuleType("repro.runtime")
pkg.__path__ = [os.path.join(os.path.dirname(repro.__file__), "runtime")]
sys.modules["repro.runtime"] = pkg   # bypass runtime/__init__ (imports steps)

import repro.runtime.spacesharing as sp
assert hasattr(sp, "SubmeshPool") and hasattr(sp, "SpaceSharedRunner")
print("NOJAX_IMPORT_OK")
"""


def test_spacesharing_imports_without_jax():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    proc = subprocess.run([sys.executable, "-c", _NOJAX_PROBE],
                          capture_output=True, text=True, env=env, cwd=REPO)
    assert proc.returncode == 0, proc.stderr
    assert "NOJAX_IMPORT_OK" in proc.stdout
