"""Beyond-paper scheduler extensions: launch-config autotuning (the paper's
§VI future work) and Chrome-trace timeline export."""
import json
import os
import tempfile

import numpy as np

from repro.core import const, make_scheduler, out


def test_autotune_explores_then_exploits_best_config():
    costs = {32: 3e-3, 64: 1e-3, 128: 2e-3}
    s = make_scheduler("parallel", simulate=True)
    choices = []
    for i in range(30):
        x = s.array(np.zeros(1024, np.float32), name=f"a{i}")
        y = s.array(np.zeros(1024, np.float32), name=f"b{i}")
        cfg = s._tune("k", {"block": [32, 64, 128]})
        choices.append(cfg["block"])
        s.launch(None, [const(x), out(y)], name="k",
                 cost_s=costs[cfg["block"]], block=cfg["block"])
        s.sync()
    assert set(choices[:6]) == {32, 64, 128}      # exploration round-robin
    assert all(c == 64 for c in choices[8:])      # locks in the fastest


def test_chrome_trace_export():
    s = make_scheduler("parallel", simulate=True)
    for i in range(4):
        x = s.array(np.zeros(1 << 20, np.float32), name=f"x{i}")
        y = s.array(np.zeros(1 << 20, np.float32), name=f"y{i}")
        s.launch(None, [const(x), out(y)], name=f"k{i}", cost_s=1e-3)
    s.sync()
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "trace.json")
        s.timeline.to_chrome_trace(path)
        tr = json.load(open(path))
        ev = tr["traceEvents"]
        assert any(e.get("cat") == "h2d" for e in ev)
        assert any(e.get("cat") == "compute" for e in ev)
        # complete events have positive durations and microsecond stamps
        xs = [e for e in ev if e["ph"] == "X"]
        assert xs and all(e["dur"] > 0 for e in xs)
