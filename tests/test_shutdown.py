"""Clean-shutdown satellites: GrScheduler.close() joins executor workers and
releases spill tiers; stats()/tenant_stats() are consistent snapshots under
concurrent submission (no torn counters for a monitor loop).
"""
import os
import threading
import time

import numpy as np
import pytest

from repro.core import const, make_scheduler, out
from repro.core.scheduler import GrScheduler
from repro.core.tiers import DiskTier


def _lane_threads():
    return [t for t in threading.enumerate() if t.name.startswith("lane-")]


def test_close_joins_real_executor_worker_threads():
    s = make_scheduler("parallel", num_devices=2)
    x = s.array(np.arange(64, dtype=np.float32), name="x")
    y = s.array(np.zeros(64, np.float32), name="y")

    def fn(a, b):
        import jax.numpy as jnp
        return jnp.asarray(a) * 2

    s._launch(fn, [const(x), out(y)], name="dbl")
    s.sync()
    assert _lane_threads(), "expected live lane workers while open"
    s.close()
    for t in _lane_threads():
        assert not t.is_alive(), f"{t.name} still alive after close()"
    assert not _lane_threads()


def test_close_is_idempotent_and_shutdown_is_an_alias():
    s = make_scheduler("parallel", simulate=True)
    s.close()
    s.close()
    s.shutdown()                           # alias, also post-close safe
    assert s._closed


def test_context_manager_closes_even_on_error():
    with pytest.raises(RuntimeError, match="boom"):
        with make_scheduler("parallel", simulate=True) as s:
            raise RuntimeError("boom")
    assert s._closed


def test_close_drains_inflight_work_first():
    s = make_scheduler("parallel")
    x = s.array(np.ones(32, np.float32), name="x")
    y = s.array(np.zeros(32, np.float32), name="y")
    started = threading.Event()

    def slow(a, b):
        started.set()
        time.sleep(0.2)
        import jax.numpy as jnp
        return jnp.asarray(a) + 1

    e = s._launch(slow, [const(x), out(y)], name="slow")
    assert started.wait(10)
    s.close()                              # must drain, not abandon
    assert e.done_event.is_set()
    assert not _lane_threads()


def test_close_releases_disk_tier_spool_directory():
    s = make_scheduler("parallel", simulate=True,
                       memory_budget=8 * 1024, spill_tiers=[DiskTier()])
    spool = s.memory.tiers[0].spool_dir
    assert os.path.isdir(spool)
    # force dirty spills through the tier
    arrs = []
    for i in range(6):
        a = s.array(np.zeros(1024, np.float32), name=f"a{i}")
        b = s.array(np.zeros(1024, np.float32), name=f"b{i}")
        s._launch(None, [const(a), out(b)], name=f"k{i}", cost_s=1e-4)
        arrs += [a, b]
    s.sync()
    s.close()
    assert not os.path.isdir(spool), "spool dir must not rely on GC/atexit"


def test_serving_engine_owns_vs_borrowed_scheduler():
    pytest.importorskip("jax")
    import jax
    from repro.configs import get_config
    from repro.models import init_lm
    from repro.runtime.serving import ServingEngine

    cfg = get_config("qwen2_moe_a2_7b", reduced=True)
    params = init_lm(jax.random.PRNGKey(0), cfg)
    rng = np.random.RandomState(0)
    with ServingEngine(cfg, params, batch_size=2, max_new_tokens=2) as eng:
        reqs = [eng.submit(rng.randint(0, cfg.vocab, 8)) for _ in range(2)]
        done = eng.drain()
        assert len(done) == 2 and all(r.result is not None for r in reqs)
    assert eng.sched._closed                # engine owned it -> closed

    borrowed = make_scheduler("parallel")
    with ServingEngine(cfg, params, batch_size=2, max_new_tokens=2,
                       scheduler=borrowed) as eng2:
        eng2.submit(rng.randint(0, cfg.vocab, 8))
    assert not borrowed._closed             # borrowed -> left open
    borrowed.close()


# ======================================================================
# Satellite 2: consistent stats snapshots under concurrency
# ======================================================================

def _stats_invariants(st):
    assert st["elements"] >= 0
    assert 0.0 <= st["mem_occupancy"] <= 1.0 + 1e-9
    assert st["mem_resident_bytes"] >= 0


def test_stats_and_tenant_stats_consistent_under_concurrent_launches():
    s = make_scheduler("parallel", num_devices=2,
                       memory_budget=1 << 20)
    stop = threading.Event()
    errors = []

    def reader():
        try:
            while not stop.is_set():
                _stats_invariants(s.stats())
                ts = s.tenant_stats()
                for _t, d in ts.items():
                    assert d["elements"] >= 1
                    assert d["busy_s"] >= 0.0
        except Exception as exc:            # surfaced below
            errors.append(exc)

    readers = [threading.Thread(target=reader) for _ in range(2)]
    for t in readers:
        t.start()

    def fn(a, b):
        import jax.numpy as jnp
        return jnp.asarray(a) * 0.5

    try:
        for i in range(40):
            x = s.array(np.ones(256, np.float32), name=f"x{i}")
            y = s.array(np.zeros(256, np.float32), name=f"y{i}")
            s._launch(fn, [const(x), out(y)],
                      name="halve", tenant=f"t{i % 3}")
            if i % 8 == 7:
                s.sync()
        s.sync()
    finally:
        stop.set()
        for t in readers:
            t.join(timeout=10)
    assert errors == [], errors
    ts = s.tenant_stats()
    assert sum(d["elements"] for d in ts.values()) >= 40
    s.close()


def test_timeline_device_busy_since_walks_incrementally():
    s = make_scheduler("parallel", simulate=True)
    idx, busy = s.timeline.device_busy_since(0)
    assert busy == 0.0
    a = s.array(np.zeros(512, np.float32), name="a")
    b = s.array(np.zeros(512, np.float32), name="b")
    s._launch(None, [const(a), out(b)], name="k", cost_s=5e-3)
    s.sync()
    idx2, busy2 = s.timeline.device_busy_since(idx)
    assert idx2 > idx and busy2 >= 5e-3    # kernel + h2d transfers
    idx3, busy3 = s.timeline.device_busy_since(idx2)
    assert idx3 == idx2 and busy3 == 0.0   # nothing new since
    s.close()


def test_stats_snapshot_taken_under_submission_lock(monkeypatch):
    """stats() must hold the pipeline lock for its whole merge: patch one
    sub-stats source to assert the lock is held when it is sampled."""
    s = make_scheduler("parallel", simulate=True)
    seen = {}
    orig = type(s.memory).stats

    def probing_stats(self):
        seen["locked"] = s.pipeline._lock._is_owned()
        return orig(self)

    monkeypatch.setattr(type(s.memory), "stats", probing_stats)
    s.stats()
    assert seen["locked"] is True
    s.close()
