"""Multi-tenant QoS: priority-weighted space-sharing, priority-aware lane
selection, tenant quotas, the thread-safe submission pipeline, per-tenant
stats, and capture/replay of priority-tagged episodes (ISSUE 3)."""
import threading

import numpy as np
import pytest

from repro.benchsuite.multitenant import (BULK_TENANT, LATENCY_TENANT,
                                          build_contention)
from repro.core import (ComputationalElement, ElementKind, StreamManager,
                        const, inout, make_scheduler, out, priority_weight)


def ce(*args, cost_s=0.0, name="", priority=0, tenant="default"):
    return ComputationalElement(fn=None, args=tuple(args), name=name,
                                cost_s=cost_s, priority=priority,
                                tenant=tenant)


def link(child, *parents):
    child.parents = list(parents)
    for p in parents:
        p.children.append(child)
    return child


class DoneSet:
    def __init__(self):
        self.done = set()

    def finish(self, *elements):
        self.done.update(e.uid for e in elements)

    def __call__(self, element):
        return element.uid in self.done


# ----------------------------------------------------------------------
# Priority weights & the weighted water-fill
# ----------------------------------------------------------------------

def test_priority_weight_mapping():
    assert priority_weight(0) == 1.0
    assert priority_weight(3) == 8.0
    assert priority_weight(-1) == 0.5


def test_weighted_waterfill_favors_high_priority():
    """Two full-occupancy kernels: priority 3 gets 8/9 of the device while
    both run, so it finishes ~1.8x sooner; total work is conserved."""
    s = make_scheduler("parallel", simulate=True, auto_prefetch=False)
    xa = s.array(shape=(256,), dtype=np.float32, name="a")
    xb = s.array(shape=(256,), dtype=np.float32, name="b")
    lo = s.launch(None, [inout(xa)], name="lo", cost_s=1e-3,
                  parallel_fraction=1.0, priority=0)
    hi = s.launch(None, [inout(xb)], name="hi", cost_s=1e-3,
                  parallel_fraction=1.0, priority=3)
    s.sync()
    dur_hi = hi.t_end - hi.t_start
    dur_lo = lo.t_end - lo.t_start
    # hi: rate 8/9 while contended -> 1e-3 * 9/8 = 1.125e-3
    assert dur_hi == pytest.approx(1.125e-3, rel=1e-3)
    # lo: 1/9 rate until hi finishes, then full rate -> ends at ~2e-3 total
    assert dur_lo == pytest.approx(2e-3, rel=1e-2)
    assert hi.t_end < lo.t_end


def test_equal_priorities_reduce_to_unweighted_fill():
    """With equal weights the weighted fill must reproduce the original
    behaviour: three pf=0.75 kernels each run at (1/3)/0.75 of solo rate."""
    s = make_scheduler("parallel", simulate=True, auto_prefetch=False)
    ks = []
    for i in range(3):
        x = s.array(shape=(64,), dtype=np.float32, name=f"x{i}")
        ks.append(s.launch(None, [inout(x)], name=f"k{i}", cost_s=1e-3,
                           parallel_fraction=0.75))
    s.sync()
    for k in ks:
        assert k.t_end - k.t_start == pytest.approx(2.25e-3, rel=1e-2)


def test_pf_ceiling_preserved_under_weighting():
    """A high-priority kernel's allocation is still capped by its parallel
    fraction: a pf=0.25 priority-5 kernel cannot exceed solo rate, and the
    leftover capacity spills to the low-priority kernel."""
    s = make_scheduler("parallel", simulate=True, auto_prefetch=False)
    xa = s.array(shape=(64,), dtype=np.float32, name="a")
    xb = s.array(shape=(64,), dtype=np.float32, name="b")
    hi = s.launch(None, [inout(xa)], name="hi", cost_s=1e-3,
                  parallel_fraction=0.25, priority=5)
    lo = s.launch(None, [inout(xb)], name="lo", cost_s=1e-3,
                  parallel_fraction=0.75, priority=0)
    s.sync()
    # hi capped at pf -> solo rate; lo gets the remaining 0.75 -> solo too.
    assert hi.t_end - hi.t_start == pytest.approx(1e-3, rel=1e-2)
    assert lo.t_end - lo.t_start == pytest.approx(1e-3, rel=1e-2)


# ----------------------------------------------------------------------
# Inheritance by auto-inserted transfers
# ----------------------------------------------------------------------

def test_h2d_transfer_inherits_priority_and_tenant():
    s = make_scheduler("parallel", simulate=True)
    x = s.array(np.zeros(1024, np.float32), name="x")
    k = s.launch(None, [inout(x)], name="k", cost_s=1e-4,
                 priority=2, tenant="lat")
    h2d = [p for p in k.parents if p.kind is ElementKind.TRANSFER]
    assert len(h2d) == 1
    assert h2d[0].priority == 2 and h2d[0].tenant == "lat"
    s.sync()


def test_d2d_transfer_inherits_priority_and_tenant():
    s = make_scheduler("parallel", simulate=True, num_devices=2,
                       placement="round-robin")
    x = s.array(np.zeros(1024, np.float32), name="x")
    s.launch(None, [inout(x)], name="k0", cost_s=1e-4)           # device 0
    k1 = s.launch(None, [inout(x)], name="k1", cost_s=1e-4,     # device 1
                  priority=3, tenant="lat")
    d2d = [p for p in k1.parents if p.kind is ElementKind.D2D]
    assert len(d2d) == 1
    assert d2d[0].priority == 3 and d2d[0].tenant == "lat"
    s.sync()


# ----------------------------------------------------------------------
# Priority-aware lane acquisition & tenant quotas
# ----------------------------------------------------------------------

def test_saturated_fallback_avoids_lower_priority_tail():
    sm = StreamManager(max_lanes=2)
    done = DoneSet()
    low = ce(name="low", priority=0)
    hi_busy = ce(name="hi_busy", priority=3)
    sm.assign(low, done)        # lane 0, low-priority tail
    sm.assign(hi_busy, done)    # lane 1, high-priority tail
    # Saturated: the new high-priority element must NOT queue behind the
    # low-priority tail while the lane-1 alternative exists.
    hi = ce(name="hi", priority=3)
    lane, _ = sm.assign(hi, done)
    assert lane.lane_id == hi_busy.stream
    assert sm.priority_bypasses == 1
    # An equal-priority element sees no blocked lanes: least-loaded wins
    # (lane 0 has 1 pending, lane 1 now has 2).
    other = ce(name="other", priority=0)
    lane2, _ = sm.assign(other, done)
    assert lane2.lane_id == low.stream


def test_tenant_quota_caps_busy_lanes():
    sm = StreamManager(tenant_quotas={"bulk": 2})
    done = DoneSet()
    b = [ce(name=f"b{i}", tenant="bulk") for i in range(4)]
    for e in b:
        sm.assign(e, done)
    # Third/fourth bulk submissions fold onto the tenant's own 2 lanes.
    assert sm.lanes_created == 2
    assert {b[0].stream, b[1].stream} == {b[2].stream, b[3].stream}
    assert sm.quota_fallbacks == 2
    # An unrelated tenant is not constrained by bulk's quota.
    other = ce(name="lat0", tenant="lat")
    sm.assign(other, done)
    assert sm.lanes_created == 3
    # Once bulk's lanes drain, it may again use fresh/free lanes.
    done.finish(*b)
    b4 = ce(name="b4", tenant="bulk")
    sm.assign(b4, done)
    assert sm.quota_fallbacks == 2


def test_tenant_quota_counts_shared_lanes():
    """A lane hosting several tenants' work still counts toward each of
    their quotas — the flooding tenant cannot slip past its cap because
    someone else queued on one of its lanes."""
    sm = StreamManager(tenant_quotas={"bulk": 2})
    done = DoneSet()
    b0, b1 = ce(name="b0", tenant="bulk"), ce(name="b1", tenant="bulk")
    sm.assign(b0, done)
    sm.assign(b1, done)
    # A "lat" child of b0 inherits b0's lane: that lane now serves both.
    lat = link(ce(name="lat", tenant="lat"), b0)
    sm.assign(lat, done)
    assert lat.stream == b0.stream
    b2 = ce(name="b2", tenant="bulk")
    sm.assign(b2, done)
    assert sm.lanes_created == 2        # quota held: no third lane for bulk
    assert sm.quota_fallbacks == 1


# ----------------------------------------------------------------------
# Thread-safe submission pipeline (acceptance: >=4 concurrent submitters)
# ----------------------------------------------------------------------

def _build_tenant_chains(s, tid, chains=3, per=4):
    for c in range(chains):
        x = s.array(np.zeros(256, np.float32), name=f"t{tid}_x{c}")
        for k in range(per):
            s.launch(None, [inout(x)], name=f"t{tid}_k{c}_{k}", cost_s=1e-5,
                     priority=tid % 3, tenant=f"tenant{tid}")


def test_concurrent_submitters_match_single_thread_reference():
    """>=4 threads submitting to one GrScheduler: no lost elements, DAG
    node/edge counts equal the single-threaded reference (disjoint arrays
    make the counts interleaving-invariant), and the sim drains fully."""
    n_threads, chains, per = 4, 3, 4
    ref = make_scheduler("parallel", simulate=True)
    for tid in range(n_threads):
        _build_tenant_chains(ref, tid, chains, per)
    ref.sync()

    s = make_scheduler("parallel", simulate=True)
    errs = []
    barrier = threading.Barrier(n_threads)   # all submitters truly concurrent

    def worker(tid):
        try:
            barrier.wait()
            _build_tenant_chains(s, tid, chains, per)
        except BaseException as exc:  # surfaced below
            errs.append(exc)

    threads = [threading.Thread(target=worker, args=(tid,))
               for tid in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    s.sync()
    assert not errs
    assert s.dag.num_elements == ref.dag.num_elements
    assert s.dag.num_edges == ref.dag.num_edges
    # Every submitted element actually completed in the simulator.
    assert len(s.executor._end) == s.dag.num_elements
    assert s.stats()["pipeline_threads_seen"] >= n_threads
    # All four tenants show up in the QoS attribution.
    assert len(s.tenant_stats()) == n_threads


def test_concurrent_submitters_real_executor_values():
    """Concurrent submitters on the real ThreadLaneExecutor: every chain
    computes the right value (dependencies intact under contention)."""
    import jax
    inc = jax.jit(lambda a: a + 1.0)
    n_threads, per = 4, 5
    s = make_scheduler("parallel")
    arrays, errs = {}, []

    def worker(tid):
        try:
            x = s.array(np.zeros(32, np.float32), name=f"x{tid}")
            arrays[tid] = x
            for _ in range(per):
                s.launch(inc, [inout(x)], name=f"inc{tid}",
                         tenant=f"tenant{tid}")
        except BaseException as exc:
            errs.append(exc)

    try:
        threads = [threading.Thread(target=worker, args=(tid,))
                   for tid in range(n_threads)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        s.sync()
        assert not errs
        for _tid, x in arrays.items():
            np.testing.assert_allclose(np.asarray(x), float(per))
    finally:
        s.shutdown()


def test_host_read_does_not_block_other_tenants_launches():
    """No priority inversion through the pipeline lock: while one tenant's
    host read blocks on its slow in-flight kernel (real executor), another
    tenant's launch() must complete promptly."""
    import time
    s = make_scheduler("parallel")

    def slow_fn(a):
        time.sleep(0.5)
        return a + 1.0

    x = s.array(np.zeros(8, np.float32), name="x")
    launch_latency = [None]
    try:
        s.launch(slow_fn, [inout(x)], name="slow", tenant="bulk")

        def reader():
            np.asarray(x)          # blocks ~0.5s on the slow kernel

        def submitter():
            time.sleep(0.1)        # let the reader start blocking first
            t0 = time.perf_counter()
            y = s.array(np.zeros(8, np.float32), name="y")
            s.launch(lambda a: a + 1.0, [inout(y)], name="fast",
                     priority=3, tenant="lat")
            launch_latency[0] = time.perf_counter() - t0

        ra = threading.Thread(target=reader)
        rb = threading.Thread(target=submitter)
        ra.start(); rb.start(); ra.join(); rb.join()
        s.sync()
        assert launch_latency[0] < 0.25, \
            f"launch stalled {launch_latency[0]:.3f}s behind a host read"
    finally:
        s.shutdown()


# ----------------------------------------------------------------------
# Per-tenant QoS stats
# ----------------------------------------------------------------------

def test_tenant_stats_report_latency_and_queueing():
    s = make_scheduler("parallel", simulate=True)
    build_contention(s, bulk_kernels=3, latency_streams=1, per_stream=3,
                     n=1 << 10)
    s.sync()
    ts = s.tenant_stats()
    assert set(ts) == {BULK_TENANT, LATENCY_TENANT}
    for t in ts.values():
        assert t["elements"] > 0
        assert t["latency_p99_s"] >= t["latency_p50_s"] > 0
        assert t["queue_delay_p99_s"] >= 0
        assert t["makespan_s"] > 0
    # The bulk flood dominates the device for far longer.
    assert ts[BULK_TENANT]["makespan_s"] > ts[LATENCY_TENANT]["makespan_s"]


# ----------------------------------------------------------------------
# Acceptance: contention benchmark targets
# ----------------------------------------------------------------------

def test_priority_weighting_improves_latency_p99_2x():
    """ISSUE 3 acceptance: weighted p99 >= 2x better than priority-blind,
    aggregate makespan regresses <= 10%."""
    def run(weighted):
        s = make_scheduler("parallel", simulate=True)
        build_contention(s, use_priority=weighted)
        s.sync()
        return s.timeline.makespan, s.tenant_stats()

    mk_blind, ts_blind = run(False)
    mk_wtd, ts_wtd = run(True)
    p99_blind = ts_blind[LATENCY_TENANT]["latency_p99_s"]
    p99_wtd = ts_wtd[LATENCY_TENANT]["latency_p99_s"]
    assert p99_blind / p99_wtd >= 2.0, \
        f"p99 improvement only {p99_blind / p99_wtd:.2f}x"
    assert mk_wtd <= 1.10 * mk_blind, \
        f"makespan regressed {mk_wtd / mk_blind:.3f}x"


# ----------------------------------------------------------------------
# Capture/replay of priority-tagged episodes
# ----------------------------------------------------------------------

def _qos_episode(s, tag=""):
    xa = s.array(np.ones(256, np.float32), name=f"qa{tag}")
    xb = s.array(np.ones(256, np.float32), name=f"qb{tag}")
    s.launch(None, [inout(xa)], name="hi", cost_s=1e-3,
             parallel_fraction=1.0, priority=3, tenant="lat")
    s.launch(None, [inout(xb)], name="lo", cost_s=1e-3,
             parallel_fraction=1.0, priority=0, tenant="bulk")


def test_replay_preserves_priority_weighting():
    s = make_scheduler("parallel", simulate=True)
    for ep in range(3):
        with s.capture("qos"):
            _qos_episode(s, tag=str(ep))
        s.sync()
    assert s.stats()["plan_replays"] == 2
    # Every episode — recorded and replayed — ran with the same weighting:
    # the priority-3 kernel's span is ~1.8x shorter each time.
    hi = sorted((sp for sp in s.timeline.spans if sp.name == "hi"),
                key=lambda sp: sp.t0)
    lo = sorted((sp for sp in s.timeline.spans if sp.name == "lo"),
                key=lambda sp: sp.t0)
    assert len(hi) == len(lo) == 3
    for h, l in zip(hi, lo):
        assert h.priority == 3 and h.tenant == "lat"
        assert l.priority == 0 and l.tenant == "bulk"
        assert h.dur == pytest.approx(1.125e-3, rel=1e-2)
        assert l.dur == pytest.approx(2e-3, rel=2e-2)


def test_priority_retag_records_separate_plan():
    """Re-issuing the same structure at a different priority must not hit
    the old plan (the weighting is part of the structural signature)."""
    s = make_scheduler("parallel", simulate=True)
    x1 = s.array(np.ones(256, np.float32), name="p1")
    with s.capture("retag"):
        s.launch(None, [inout(x1)], name="k", cost_s=1e-4, priority=0)
    s.sync()
    x2 = s.array(np.ones(256, np.float32), name="p2")
    with s.capture("retag"):
        s.launch(None, [inout(x2)], name="k", cost_s=1e-4, priority=2)
    s.sync()
    st = s.stats()
    assert st["plan_records"] == 2
    assert st["plan_replays"] == 0
    assert st["plans_cached"] == 2


def test_capture_roundtrip_priority_tagged_real_executor():
    """Acceptance: capture/replay round-trips priority-tagged episodes
    bit-identically on the real executor."""
    import jax
    sq = jax.jit(lambda a, _o: a * a)
    addc = jax.jit(lambda a, _o: a + 2.0)

    def episode(s, tag):
        x = s.array(np.arange(64, dtype=np.float32), name=f"x{tag}")
        y = s.array(np.zeros(64, np.float32), name=f"y{tag}")
        z = s.array(np.zeros(64, np.float32), name=f"z{tag}")
        s.launch(sq, [const(x), out(y)], name="sq",
                 priority=3, tenant="lat")
        s.launch(addc, [const(x), out(z)], name="addc",
                 priority=0, tenant="bulk")
        return y, z

    ref = np.arange(64, dtype=np.float32)
    s = make_scheduler("parallel")
    try:
        for ep in range(3):
            y, z = episode(s, ep)
            np.testing.assert_array_equal(np.asarray(y), ref * ref)
            np.testing.assert_array_equal(np.asarray(z), ref + 2.0)
        # Same episodes under capture: record once, replay twice, outputs
        # bit-identical to the eager runs above.
        for ep in range(3):
            with s.capture("qos_real"):
                y, z = episode(s, f"c{ep}")
            np.testing.assert_array_equal(np.asarray(y), ref * ref)
            np.testing.assert_array_equal(np.asarray(z), ref + 2.0)
        st = s.stats()
        assert st["plan_replays"] >= 2
        # Replayed elements kept their tags all the way to the timeline.
        tags = {(sp.tenant, sp.priority) for sp in s.timeline.spans
                if sp.name in ("sq", "addc")}
        assert ("lat", 3) in tags and ("bulk", 0) in tags
    finally:
        s.shutdown()
