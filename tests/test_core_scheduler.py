"""Scheduler-level tests: real execution correctness, stream policies,
host-sync granularity, serial-vs-parallel timing properties."""
import numpy as np
import pytest
from _hypothesis_fallback import given, settings, st

import jax

from repro.core import const, inout, make_scheduler, out


# ----------------------------------------------------------------------
# Real-executor correctness: async parallel execution == numpy semantics
# ----------------------------------------------------------------------

_OPS = {
    "add": (jax.jit(lambda a, b: a + b), lambda a, b: a + b),
    "mul": (jax.jit(lambda a, b: a * b), lambda a, b: a * b),
    "axpy": (jax.jit(lambda a, b: 2.0 * a + b), lambda a, b: 2.0 * a + b),
}


@st.composite
def random_program(draw):
    n_arrays = draw(st.integers(2, 4))
    n_ops = draw(st.integers(1, 10))
    ops = []
    for _ in range(n_ops):
        opname = draw(st.sampled_from(sorted(_OPS)))
        src_a = draw(st.integers(0, n_arrays - 1))
        src_b = draw(st.integers(0, n_arrays - 1))
        dst = draw(st.integers(0, n_arrays - 1))
        ops.append((opname, src_a, src_b, dst))
    return n_arrays, ops


@settings(max_examples=25, deadline=None)
@given(random_program())
def test_parallel_execution_matches_sequential_semantics(prog):
    n_arrays, ops = prog
    rng = np.random.RandomState(0)
    init = [rng.randn(32).astype(np.float32) for _ in range(n_arrays)]

    # sequential numpy oracle
    ref = [v.copy() for v in init]
    for opname, a, b, d in ops:
        ref[d] = _OPS[opname][1](ref[a], ref[b]).astype(np.float32)

    sched = make_scheduler("parallel")
    try:
        arrs = [sched.array(v.copy(), name=f"a{i}") for i, v in enumerate(init)]
        for opname, a, b, d in ops:
            fn = _OPS[opname][0]
            args = [const(arrs[a]), const(arrs[b]), out(arrs[d])]
            sched.launch(jax.jit(lambda x, y, _o, f=_OPS[opname][1]: f(x, y)),
                         [const(arrs[a]), const(arrs[b]), out(arrs[d])],
                         name=opname)
        for i, arr in enumerate(arrs):
            np.testing.assert_allclose(np.asarray(arr), ref[i], rtol=1e-6,
                                       err_msg=f"array {i}")
    finally:
        sched.shutdown()


def test_serial_and_parallel_same_results():
    def run(policy):
        s = make_scheduler(policy)
        try:
            x = s.array(np.arange(64, dtype=np.float32), name="x")
            y = s.array(np.zeros(64, np.float32), name="y")
            z = s.array(np.zeros(64, np.float32), name="z")
            s.launch(jax.jit(lambda a, _: a * a), [const(x), out(y)], name="sq")
            s.launch(jax.jit(lambda a, _: a + 3), [const(x), out(z)], name="p3")
            s.launch(jax.jit(lambda a, b: a + b), [const(y), inout(z)], name="mix")
            return np.asarray(z).copy()
        finally:
            s.shutdown()

    np.testing.assert_allclose(run("serial"), run("parallel"))


# ----------------------------------------------------------------------
# Stream-management policies (§IV-C)
# ----------------------------------------------------------------------

def test_first_child_inherits_parent_stream():
    s = make_scheduler("parallel", simulate=True)
    A = s.array(np.zeros(1024, np.float32), name="A")
    B = s.array(np.zeros(1024, np.float32), name="B")
    k1 = s.launch(None, [inout(A)], name="K1", cost_s=1e-3)
    k2 = s.launch(None, [const(A), out(B)], name="K2", cost_s=1e-3)
    assert k2.stream == k1.stream          # first child inherits
    C = s.array(np.zeros(1024, np.float32), name="C")
    k3 = s.launch(None, [const(A), out(C)], name="K3", cost_s=1e-3)
    assert k3.stream != k1.stream          # second child gets another lane
    s.sync()


def test_independent_kernels_get_distinct_lanes():
    s = make_scheduler("parallel", simulate=True)
    es = []
    for i in range(4):
        X = s.array(np.zeros(1024, np.float32), name=f"X{i}")
        es.append(s.launch(None, [inout(X)], name=f"K{i}", cost_s=1e-3))
    assert len({e.stream for e in es}) == 4
    s.sync()


def test_fifo_lane_reuse_after_sync():
    s = make_scheduler("parallel", simulate=True)
    X = s.array(np.zeros(1024, np.float32), name="X")
    s.launch(None, [inout(X)], name="K1", cost_s=1e-4)
    s.sync()
    lanes_before = s.streams.lanes_created
    Y = s.array(np.zeros(1024, np.float32), name="Y")
    s.launch(None, [inout(Y)], name="K2", cost_s=1e-4)
    s.sync()
    assert s.streams.lanes_created == lanes_before  # reused, not created


def test_event_count_matches_cross_lane_parents():
    s = make_scheduler("parallel", simulate=True)
    A = s.array(np.zeros(1024, np.float32), name="A")
    B = s.array(np.zeros(1024, np.float32), name="B")
    C = s.array(np.zeros(1024, np.float32), name="C")
    s.launch(None, [inout(A)], name="K1", cost_s=1e-3)
    s.launch(None, [inout(B)], name="K2", cost_s=1e-3)
    ev0 = s.streams.events_created
    # K3 depends on both K1 and K2 -> at most one event (other parent's lane
    # is inherited)
    s.launch(None, [const(A), const(B), out(C)], name="K3", cost_s=1e-3)
    assert s.streams.events_created - ev0 == 1
    s.sync()


# ----------------------------------------------------------------------
# Host-access synchronization granularity (§IV-B)
# ----------------------------------------------------------------------

def test_host_read_syncs_only_owning_lane():
    s = make_scheduler("parallel", simulate=True)
    A = s.array(np.zeros(1 << 20, np.float32), name="A")
    B = s.array(np.zeros(1024, np.float32), name="B")
    s.launch(None, [inout(A)], name="slow", cost_s=1.0)
    kb = s.launch(None, [inout(B)], name="fast", cost_s=1e-4)
    _ = B[0]                       # host read of B: must NOT wait for `slow`
    assert s.executor.host_time < 0.5, (
        f"host read of B waited for unrelated slow kernel "
        f"(host_time={s.executor.host_time})")
    s.sync()
    assert s.executor.host_time >= 1.0


def test_host_write_waits_for_readers():
    s = make_scheduler("parallel", simulate=True)
    A = s.array(np.zeros(1024, np.float32), name="A")
    B = s.array(np.zeros(1024, np.float32), name="B")
    k = s.launch(None, [const(A), out(B)], name="reader", cost_s=0.25)
    A[0] = 7.0                     # WAR: host write must wait for `reader`
    assert s.executor.host_time >= 0.25
    s.sync()


def test_consecutive_host_accesses_fast_path():
    s = make_scheduler("parallel", simulate=True)
    A = s.array(np.zeros(1024, np.float32), name="A")
    A[0] = 1.0
    A[1] = 2.0
    _ = A[0]
    assert s.dag.num_elements == 0  # no DAG traffic for host-only accesses


# ----------------------------------------------------------------------
# Timing properties (simulated): parallel never slower than serial
# ----------------------------------------------------------------------

@st.composite
def timed_program(draw):
    n = draw(st.integers(2, 10))
    ops = []
    for i in range(n):
        reads = draw(st.lists(st.integers(0, i - 1), max_size=2,
                              unique=True)) if i > 0 else []
        cost = draw(st.floats(1e-4, 5e-3))
        mb = draw(st.integers(0, 8))
        ops.append((reads, cost, mb * (1 << 20)))
    return ops


@settings(max_examples=40, deadline=None)
@given(timed_program())
def test_parallel_schedule_not_slower_than_serial(ops):
    def build(policy):
        s = make_scheduler(policy, simulate=True)
        outs = []
        for i, (reads, cost, nbytes) in enumerate(ops):
            y = s.array(np.zeros(max(1, nbytes // 4), np.float32), name=f"y{i}")
            args = [const(outs[r]) for r in reads] + [out(y)]
            s.launch(None, args, name=f"k{i}", cost_s=cost)
            outs.append(y)
        s.sync()
        return s.timeline.makespan

    ts = build("serial")
    tp = build("parallel")
    assert tp <= ts * 1.001 + 1e-4, f"parallel {tp} slower than serial {ts}"


def test_oracle_not_slower_than_runtime_scheduler():
    def build(**kw):
        s = make_scheduler("parallel", simulate=True, **kw)
        prev = None
        for i in range(8):
            y = s.array(np.zeros(1 << 20, np.float32), name=f"y{i}")
            args = ([const(prev)] if prev is not None and i % 3 == 0 else []) + [out(y)]
            s.launch(None, args, name=f"k{i}", cost_s=1e-3)
            prev = y
        s.sync()
        return s.timeline.makespan

    t_runtime = build()
    t_oracle = build(oracle=True)
    assert t_oracle <= t_runtime * 1.001 + 1e-6


# ----------------------------------------------------------------------
# History / straggler detection
# ----------------------------------------------------------------------

def test_history_and_straggler_detection():
    from repro.core import KernelHistory
    h = KernelHistory(straggler_factor=3.0, min_samples=3)
    for _ in range(5):
        assert not h.record("k", {"block": 128}, 1.0)
    assert h.record("k", {"block": 128}, 10.0)       # straggler
    assert h.estimate("k", {"block": 128}) == pytest.approx(1.0)
    h.record("k", {"block": 32}, 0.5)
    assert h.best_config("k") == {"block": "32"}


def test_overlap_metrics_bounds():
    s = make_scheduler("parallel", simulate=True)
    for i in range(5):
        X = s.array(np.zeros(2 << 20, np.float32), name=f"X{i}")
        Y = s.array(np.zeros(2 << 20, np.float32), name=f"Y{i}")
        s.launch(None, [const(X), out(Y)], name=f"K{i}", cost_s=2e-3)
    s.sync()
    m = s.timeline.overlap_metrics()
    for k, v in m.items():
        assert 0.0 <= v <= 1.0, (k, v)
    assert m["TOT"] > 0  # something overlapped
