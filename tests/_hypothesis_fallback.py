"""Offline fallback for `hypothesis`.

The tier-1 suite must collect and run on machines where `hypothesis` is not
installed and cannot be fetched.  When the real library is available we
re-export it untouched; otherwise ``@given`` degrades to a small number of
deterministic pseudo-random examples (seeded per example index, so failures
are reproducible) and ``@settings`` only caps the example count.

Usage in test modules::

    from _hypothesis_fallback import given, settings, st
"""
from __future__ import annotations

try:  # pragma: no cover - exercised only where hypothesis exists
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    import random

    # Keep the fallback fast: even when a test asks for max_examples=200,
    # run at most this many fixed examples.
    _MAX_FALLBACK_EXAMPLES = 10
    _DEFAULT_EXAMPLES = 5

    class _Strategy:
        def example(self, rng: random.Random):
            raise NotImplementedError

    class _Integers(_Strategy):
        def __init__(self, lo, hi):
            self.lo, self.hi = lo, hi

        def example(self, rng):
            return rng.randint(self.lo, self.hi)

    class _Floats(_Strategy):
        def __init__(self, lo, hi):
            self.lo, self.hi = lo, hi

        def example(self, rng):
            return rng.uniform(self.lo, self.hi)

    class _Booleans(_Strategy):
        def example(self, rng):
            return rng.random() < 0.5

    class _SampledFrom(_Strategy):
        def __init__(self, seq):
            self.seq = list(seq)

        def example(self, rng):
            return self.seq[rng.randrange(len(self.seq))]

    class _Lists(_Strategy):
        def __init__(self, elements, min_size=0, max_size=None, unique=False):
            self.elements = elements
            self.min_size = min_size
            self.max_size = max_size if max_size is not None else min_size + 4
            self.unique = unique

        def example(self, rng):
            size = rng.randint(self.min_size, self.max_size)
            out, seen = [], set()
            attempts = 0
            while len(out) < size and attempts < 100 * (size + 1):
                attempts += 1
                v = self.elements.example(rng)
                if self.unique:
                    if v in seen:
                        continue
                    seen.add(v)
                out.append(v)
            return out

    class _CompositeResult(_Strategy):
        def __init__(self, fn, args, kwargs):
            self.fn, self.args, self.kwargs = fn, args, kwargs

        def example(self, rng):
            return self.fn(lambda s: s.example(rng), *self.args,
                           **self.kwargs)

    class _StrategiesModule:
        @staticmethod
        def integers(min_value, max_value):
            return _Integers(min_value, max_value)

        @staticmethod
        def floats(min_value, max_value):
            return _Floats(min_value, max_value)

        @staticmethod
        def booleans():
            return _Booleans()

        @staticmethod
        def sampled_from(seq):
            return _SampledFrom(seq)

        @staticmethod
        def lists(elements, *, min_size=0, max_size=None, unique=False):
            return _Lists(elements, min_size, max_size, unique)

        @staticmethod
        def composite(fn):
            def make(*args, **kwargs):
                return _CompositeResult(fn, args, kwargs)
            return make

    st = _StrategiesModule()

    def given(*strategies):
        def decorate(fn):
            # NOTE: the wrapper takes no parameters on purpose — pytest must
            # not mistake the strategy-filled arguments for fixtures.
            def wrapper():
                n = min(wrapper._max_examples, _MAX_FALLBACK_EXAMPLES)
                for i in range(n):
                    rng = random.Random(0xC0FFEE + 9176 * i)
                    vals = [s.example(rng) for s in strategies]
                    try:
                        fn(*vals)
                    except Exception:
                        print(f"falsifying example (fallback #{i}): {vals!r}")
                        raise
            wrapper.__name__ = fn.__name__
            wrapper.__qualname__ = getattr(fn, "__qualname__", fn.__name__)
            wrapper.__doc__ = fn.__doc__
            wrapper.__module__ = fn.__module__
            wrapper._max_examples = _DEFAULT_EXAMPLES
            return wrapper
        return decorate

    def settings(max_examples=_DEFAULT_EXAMPLES, deadline=None, **_ignored):
        def decorate(fn):
            if hasattr(fn, "_max_examples"):
                fn._max_examples = max_examples
            return fn
        return decorate
