"""Multi-device scheduling: placement policies, D2D insertion, simulated
per-device capacity, and real-executor correctness."""
import numpy as np
import pytest

import jax

from repro.benchsuite.multidevice import (build_locality_heavy,
                                          build_task_parallel)
from repro.core import (ElementKind, SimExecutor, SimHardware, const, inout,
                        make_scheduler, out)


# ----------------------------------------------------------------------
# Simulated scaling
# ----------------------------------------------------------------------

def _task_parallel_makespan(num_devices, placement="affinity"):
    s = make_scheduler("parallel", simulate=True, num_devices=num_devices,
                       placement=placement)
    build_task_parallel(s, branches=4, chain=4)
    s.sync()
    return s.timeline.makespan, s.stats()


def test_two_devices_beat_one_on_task_parallel():
    t1, _ = _task_parallel_makespan(1)
    t2, st2 = _task_parallel_makespan(2)
    assert t1 / t2 >= 1.5, f"2-device speedup only {t1 / t2:.2f}"
    # all lanes pinned, chains stay local
    assert st2["d2d_transfers"] == 0


def test_four_devices_scale_further():
    t2, _ = _task_parallel_makespan(2)
    t4, _ = _task_parallel_makespan(4)
    assert t4 < t2


def test_single_device_multidevice_api_is_identity():
    """num_devices=1 must behave exactly like the pre-multi-device runtime."""
    t_plain, st = _task_parallel_makespan(1)
    assert st["d2d_transfers"] == 0
    assert "lanes_per_device" not in st     # multi-device stats stay hidden


# ----------------------------------------------------------------------
# Placement policies
# ----------------------------------------------------------------------

def test_affinity_inserts_fewer_d2d_than_round_robin():
    def run(placement):
        s = make_scheduler("parallel", simulate=True, num_devices=2,
                           placement=placement)
        build_locality_heavy(s, groups=4, iters=6)
        s.sync()
        return s.stats()["d2d_transfers"]

    rr, aff = run("round-robin"), run("affinity")
    assert aff < rr
    assert aff == 0                         # persistent data never migrates


def test_round_robin_cycles_devices():
    s = make_scheduler("parallel", simulate=True, num_devices=3,
                       placement="round-robin")
    es = []
    for i in range(6):
        x = s.array(np.zeros(1024, np.float32), name=f"x{i}")
        es.append(s.launch(None, [inout(x)], name=f"k{i}", cost_s=1e-3))
    s.sync()
    assert [e.device for e in es] == [0, 1, 2, 0, 1, 2]


def test_min_load_spreads_independent_kernels():
    s = make_scheduler("parallel", simulate=True, num_devices=2,
                       placement="min-load")
    es = []
    for i in range(4):
        x = s.array(np.zeros(1024, np.float32), name=f"x{i}")
        es.append(s.launch(None, [inout(x)], name=f"k{i}", cost_s=1e-3))
    s.sync()
    per_dev = {d: sum(1 for e in es if e.device == d) for d in (0, 1)}
    assert per_dev == {0: 2, 1: 2}


def test_affinity_follows_input_bytes():
    s = make_scheduler("parallel", simulate=True, num_devices=2,
                       placement="affinity")
    big = s.array(np.zeros(1 << 20, np.float32), name="big")
    small = s.array(np.zeros(64, np.float32), name="small")
    k_big = s.launch(None, [inout(big)], name="warm_big", cost_s=1e-3)
    k_small = s.launch(None, [inout(small)], name="warm_small", cost_s=1e-3)
    assert k_big.device != k_small.device   # min-load fallback spread them
    y = s.array(shape=(1,), dtype=np.float32, name="y")
    k = s.launch(None, [const(big), const(small), out(y)], name="consume",
                 cost_s=1e-3)
    assert k.device == k_big.device         # big input wins
    s.sync()


# ----------------------------------------------------------------------
# Placement policies under contention (asymmetric DAG)
# ----------------------------------------------------------------------

def _asymmetric_contended(placement):
    """Two heavy bulk kernels contend with a short chain on a persistent
    array A.  Costs are distinct so min-load comparisons never tie.

    Launch order: bulk1 (5ms), warm-A (0.1ms), bulk2 (6ms), then two chain
    hops on A (0.2ms each).  Returns (kernel devices, d2d count)."""
    s = make_scheduler("parallel", simulate=True, num_devices=2,
                       placement=placement)
    ks = []
    b1 = s.array(np.zeros(1 << 12, np.float32), name="b1")
    ks.append(s.launch(None, [inout(b1)], name="bulk1", cost_s=5e-3))
    A = s.array(np.zeros(1 << 12, np.float32), name="A")
    ks.append(s.launch(None, [inout(A)], name="warmA", cost_s=1e-4))
    b2 = s.array(np.zeros(1 << 12, np.float32), name="b2")
    ks.append(s.launch(None, [inout(b2)], name="bulk2", cost_s=6e-3))
    ks.append(s.launch(None, [inout(A)], name="hop1", cost_s=2e-4))
    ks.append(s.launch(None, [inout(A)], name="hop2", cost_s=2e-4))
    s.sync()
    return [k.device for k in ks], s.stats()["d2d_transfers"]


def test_affinity_keeps_contended_chain_local():
    devices, d2d = _asymmetric_contended("affinity")
    # bulk1 -> dev0 (fallback), warm/bulk2 -> dev1 (less loaded); the chain
    # then follows A's bytes and never migrates.
    assert devices == [0, 1, 1, 1, 1]
    assert d2d == 0


def test_min_load_migrates_contended_chain():
    devices, d2d = _asymmetric_contended("min-load")
    # bulk2 lands next to A (dev1 was less loaded), so min-load pulls the
    # chain's first hop to the idle device despite locality: one migration.
    assert devices == [0, 1, 1, 0, 0]
    assert d2d == 1


def test_round_robin_scatters_contended_chain():
    devices, d2d = _asymmetric_contended("round-robin")
    # Pure cycling: hop2's device differs from hop1's, dragging A across
    # the link once even though nothing about load or locality asked for it.
    assert devices == [0, 1, 0, 1, 0]
    assert d2d == 1


# ----------------------------------------------------------------------
# D2D transfer elements
# ----------------------------------------------------------------------

def test_d2d_inserted_for_cross_device_read():
    s = make_scheduler("parallel", simulate=True, num_devices=2,
                       placement="round-robin")
    x = s.array(np.zeros(1 << 20, np.float32), name="x")
    s.launch(None, [inout(x)], name="k0", cost_s=1e-3)      # device 0
    k1 = s.launch(None, [inout(x)], name="k1", cost_s=1e-3)  # device 1
    assert k1.device == 1
    assert s.d2d_transfers == 1
    # The D2D element is the kernel's parent (RAW through the moved copy).
    kinds = [p.kind for p in k1.parents]
    assert ElementKind.D2D in kinds
    s.sync()
    d2d = [sp for sp in s.timeline.spans if sp.kind == "d2d"]
    assert len(d2d) == 1
    # The copy occupies the link for bytes / d2d_gbps seconds.
    expect = (1 << 22) / (s.executor.hw.d2d_gbps * 1e9)
    assert d2d[0].dur == pytest.approx(expect, rel=1e-6)


def test_d2d_moves_ownership_once_per_migration():
    s = make_scheduler("parallel", simulate=True, num_devices=2,
                       placement="affinity")
    x = s.array(np.zeros(1024, np.float32), name="x")
    s.launch(None, [inout(x)], name="k0", cost_s=1e-3)
    # Affinity keeps every later consumer on the owning device: no D2D.
    for i in range(5):
        s.launch(None, [inout(x)], name=f"k{i + 1}", cost_s=1e-3)
    s.sync()
    assert s.d2d_transfers == 0


def test_sim_hardware_promoted_to_requested_devices():
    hw = SimHardware(h2d_gbps=10.0)
    s = make_scheduler("parallel", simulate=True, hw=hw, num_devices=2)
    assert isinstance(s.executor, SimExecutor)
    assert s.executor.hw.num_devices == 2
    assert s.executor.hw.h2d_gbps == 10.0   # calibration preserved


def test_per_device_capacity_is_independent():
    """Two full-occupancy kernels: same device -> serialized; two devices ->
    concurrent."""
    def run(num_devices, placement):
        s = make_scheduler("parallel", simulate=True,
                           num_devices=num_devices, placement=placement)
        for i in range(2):
            x = s.array(np.zeros(1024, np.float32), name=f"x{i}")
            s.launch(None, [inout(x)], name=f"k{i}", cost_s=1e-2,
                     parallel_fraction=1.0)
        s.sync()
        return s.timeline.makespan

    t1 = run(1, "round-robin")
    t2 = run(2, "round-robin")
    assert t1 >= 2e-2 * 0.99
    assert t2 <= 1.1e-2


# ----------------------------------------------------------------------
# Real executor (ThreadLaneExecutor): correctness with any device count
# ----------------------------------------------------------------------

@pytest.mark.parametrize("placement", ["round-robin", "min-load", "affinity"])
def test_real_executor_multidevice_matches_numpy(placement):
    s = make_scheduler("parallel", num_devices=2, placement=placement)
    try:
        x = s.array(np.arange(64, dtype=np.float32), name="x")
        y = s.array(np.zeros(64, np.float32), name="y")
        z = s.array(np.zeros(64, np.float32), name="z")
        s.launch(jax.jit(lambda a, _: a * a), [const(x), out(y)], name="sq")
        s.launch(jax.jit(lambda a, _: a + 3), [const(x), out(z)], name="p3")
        s.launch(jax.jit(lambda a, b: a + b), [const(y), inout(z)],
                 name="mix")
        ref = np.arange(64, dtype=np.float32)
        np.testing.assert_allclose(np.asarray(z), ref ** 2 + ref + 3)
    finally:
        s.shutdown()


def test_real_executor_task_parallel_chains():
    s = make_scheduler("parallel", num_devices=2, placement="affinity")
    try:
        outs = []
        for b in range(3):
            x = s.array(np.full(32, float(b), np.float32), name=f"x{b}")
            for _ in range(3):
                y = s.array(np.zeros(32, np.float32), name=f"y{b}")
                s.launch(jax.jit(lambda a, _: a + 1), [const(x), out(y)],
                         name="inc")
                x = y
            outs.append(x)
        for b, o in enumerate(outs):
            np.testing.assert_allclose(np.asarray(o), b + 3)
    finally:
        s.shutdown()
