"""Shared pytest config for the tier-1 suite.

The ``slow`` marker (declared in pytest.ini) carves out the fast tier that
CI runs on every push (``scripts/ci_fast.sh`` / ``-m "not slow"``).  Slow
standalone tests carry an explicit ``@pytest.mark.slow``; for the
arch-parametrized model tests the heavyweight configs are marked here so
the parametrize decorators stay readable.
"""
import pytest

# Reduced configs that still take many seconds per test to jit on CPU.
_SLOW_ARCHS = ("seamless_m4t_medium", "gemma3_12b")


def pytest_collection_modifyitems(items):
    for item in items:
        if (item.fspath.basename == "test_models.py"
                and any(f"[{a}]" in item.name for a in _SLOW_ARCHS)):
            item.add_marker(pytest.mark.slow)
