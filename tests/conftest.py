"""Shared pytest config for the tier-1 suite.

The ``slow`` marker (declared in pytest.ini) carves out the fast tier that
CI runs on every push (``scripts/ci_fast.sh`` / ``-m "not slow"``).  Slow
standalone tests carry an explicit ``@pytest.mark.slow``; for the
arch-parametrized model tests the heavyweight configs are marked here so
the parametrize decorators stay readable.
"""
import pytest

# Reduced configs that still take many seconds per test to jit on CPU.
_SLOW_ARCHS = ("seamless_m4t_medium", "gemma3_12b")


def pytest_collection_modifyitems(items):
    for item in items:
        if (item.fspath.basename == "test_models.py"
                and any(f"[{a}]" in item.name for a in _SLOW_ARCHS)):
            item.add_marker(pytest.mark.slow)


@pytest.fixture(autouse=True)
def _verify_memory_accounting(monkeypatch):
    """Reconcile the MemoryManager's residency ledger at every ``sync``.

    ``MemoryManager.verify()`` cross-checks logical residency (array
    location bits, tier membership) against the pool ledger; running it at
    each quiescent point turns silent accounting drift anywhere in the fast
    suite into an immediate failure at the sync that caused it, instead of
    a bogus eviction three scenarios later.  Sim-only: the real executor's
    worker threads may still be installing physical values when ``sync``
    observes the logical state mid-test teardown."""
    from repro.core.scheduler import GrScheduler

    orig_sync = GrScheduler.sync

    def sync_and_verify(self, *a, **kw):
        out = orig_sync(self, *a, **kw)
        if type(self.executor).__name__ == "SimExecutor":
            report = self.memory.verify(raise_on_drift=False)
            assert report.ok, \
                f"memory accounting drift at sync: {report}"
        return out

    monkeypatch.setattr(GrScheduler, "sync", sync_and_verify)
