"""Static analysis + sanitizer (ISSUE 10).

Covers: the access-mode checker on seeded mis-declarations and on the
shipped benchsuite declarations (zero false positives), the happens-before
verifier on green plans and on seeded edge-drop/liveness/structure
mutations (greedy and planopt-rewritten), the live-DAG window verifier,
the ``sanitize=True`` runtime mode (race detection on both executors,
write-through-const canary, bit-identical when off), the structured
``MemoryManager.verify`` drift report + daemon monitor surfacing, and the
journal auditor (every seeded mutation flagged, clean journals pass).
"""
import dataclasses
import json
import os
import tempfile

import numpy as np
import pytest

import repro.api as gr
from repro.analysis import (SanitizerError, Sanitizer, analyze_function,
                            verify_elements, verify_plan, verify_scheduler,
                            audit_journal, PlanVerificationError)
from repro.core import const, inout, make_scheduler, out
from repro.core.element import AccessMode, ComputationalElement, ElementKind
from repro.core.memory import MemoryDriftError


# ----------------------------------------------------------------------
# Access-mode checker: seeded mis-declarations
# ----------------------------------------------------------------------

def _issues(gf):
    report = analyze_function(gf)
    assert report.skipped is None, f"unexpectedly skipped: {report.skipped}"
    return report, report.issues


def test_mode_checker_flags_out_that_reads_its_input():
    bad = gr.function(lambda x, y: (x + y,), modes=("const", "out"),
                      name="bad_out_reads")
    _report, issues = _issues(bad)
    assert any(i.kind == "under" and i.arg == 1 for i in issues), issues


def test_mode_checker_flags_inout_never_read():
    bad = gr.function(lambda x, y: (x * 2.0,), modes=("const", "inout"),
                      name="bad_inout_dead")
    _report, issues = _issues(bad)
    assert any(i.kind == "over" and i.arg == 1 for i in issues), issues


def test_mode_checker_flags_more_outputs_than_writable_args():
    bad = gr.function(lambda x: (x * 2.0, x + 1.0), modes=("const",),
                      name="bad_extra_out")
    _report, issues = _issues(bad)
    assert any(i.kind == "under" for i in issues), issues


def test_mode_checker_flags_declared_write_that_never_happens():
    bad = gr.function(lambda x, y, z: (x * 2.0,),
                      modes=("const", "out", "out"), name="bad_missing_out")
    _report, issues = _issues(bad)
    assert any(i.kind == "over" for i in issues), issues


def test_mode_checker_flags_inplace_const_mutation():
    def kernel(x, y):
        if isinstance(x, np.ndarray):   # concrete probe only; pure on trace
            x += 1.0
        return (x * 2.0,)

    bad = gr.function(kernel, modes=("const", "out"), name="bad_const_mut")
    _report, issues = _issues(bad)
    assert any(i.kind == "under" and i.declared == "const"
               for i in issues), issues


def test_mode_checker_clean_declaration_and_shape_only_use():
    good = gr.function(lambda x, y: (x * 2.0,), modes=("const", "out"),
                       name="good_square")
    report, issues = _issues(good)
    assert not issues and report.reads == (True, False)
    # Using an out placeholder's *shape* (not its value) is legal.
    import jax.numpy as jnp
    shapeonly = gr.function(lambda x, y: (jnp.zeros_like(y) + x,),
                            modes=("const", "out"), name="good_shape_only")
    _report, issues = _issues(shapeonly)
    assert not issues, issues


def test_mode_checker_skips_unanalyzable_never_errors():
    sim_only = gr.function(None, modes=("inout",), name="bad_sim_only")
    report = analyze_function(sim_only)
    assert report.skipped and not report.issues


def test_mode_checker_zero_false_positives_on_shipped_declarations():
    import importlib

    from repro.analysis.cli import _LINT_MODULES
    from repro.analysis.modes import lint_functions
    for mod in _LINT_MODULES:
        importlib.import_module(mod)
    reports = [r for r in lint_functions()
               if not r.function.startswith(("bad_", "good_"))]
    assert len(reports) >= 20, "lint swept almost nothing"
    bad = [str(i) for r in reports for i in r.issues]
    assert not bad, bad


# ----------------------------------------------------------------------
# Plan verifier: green plans + seeded mutations
# ----------------------------------------------------------------------

def _vec_episode(s, tag=""):
    n = 256
    x1 = s.array(np.ones(n, np.float32), name=f"x1{tag}")
    x2 = s.array(np.full(n, 2.0, np.float32), name=f"x2{tag}")
    y1 = s.array(shape=(n,), dtype=np.float32, name=f"y1{tag}")
    y2 = s.array(shape=(n,), dtype=np.float32, name=f"y2{tag}")
    z = s.array(shape=(n,), dtype=np.float32, name=f"z{tag}")
    s.launch(None, [const(x1), out(y1)], name="SQ1", cost_s=1e-4)
    s.launch(None, [const(x2), out(y2)], name="SQ2", cost_s=1e-4)
    s.launch(None, [const(y1), const(y2), out(z)], name="RED", cost_s=1e-4)


def _captured_plan(**kw):
    s = make_scheduler("parallel", simulate=True, **kw)
    with s.capture("vec"):
        _vec_episode(s)
    plan = s.plan_cache.all_plans()[0]
    s.sync()
    s.shutdown()
    return plan


def _mutate_element(plan, idx, **changes):
    els = list(plan.elements)
    els[idx] = dataclasses.replace(els[idx], **changes)
    return dataclasses.replace(plan, elements=tuple(els))


def test_plan_verifier_green_on_captured_plan():
    plan = _captured_plan()
    assert verify_plan(plan) == []
    assert len(plan.elements) >= 5      # transfers + 3 kernels


def test_plan_verifier_flags_dropped_wait_event():
    plan = _captured_plan()
    flagged = 0
    for i, pe in enumerate(plan.elements):
        for ev in pe.wait_events:
            mut = _mutate_element(
                plan, i,
                wait_events=tuple(e for e in pe.wait_events if e != ev))
            vs = verify_plan(mut)
            if vs:
                flagged += 1
                assert all(v.kind in ("parent-order", "race") for v in vs)
    assert flagged >= 1, "no wait_event drop was ever flagged"


def test_plan_verifier_flags_unordered_conflict_as_race():
    plan = _captured_plan()
    # Drop an enforced cross-lane edge *and* its parent claim: the pair is
    # then genuinely unordered and must surface as a race, not merely as a
    # parent-order inconsistency.
    for i, pe in enumerate(plan.elements):
        for ev in pe.wait_events:
            mut = _mutate_element(
                plan, i,
                wait_events=tuple(e for e in pe.wait_events if e != ev),
                parents=tuple(p for p in pe.parents if p != ev))
            races = [v for v in verify_plan(mut) if v.kind == "race"]
            if races:
                assert any(k in str(races[0])
                           for k in ("RAW", "WAR", "WAW"))
                return
    pytest.fail("no dropped edge produced an unordered conflicting pair")


def test_plan_verifier_flags_planopt_rewritten_plan():
    from repro.benchsuite import build_task_parallel
    s = make_scheduler("parallel", simulate=True, num_devices=2,
                       placement="round-robin", plan_optimize=True)
    with s.capture("tp"):
        build_task_parallel(s, branches=3, chain=3, n=1 << 10)
    plan = s.plan_cache.all_plans()[0]
    s.sync()
    s.shutdown()
    assert plan.optimized, "planopt never rewrote the captured plan"
    assert verify_plan(plan) == []
    for i, pe in enumerate(plan.elements):
        for ev in pe.wait_events:
            mut = _mutate_element(
                plan, i,
                wait_events=tuple(e for e in pe.wait_events if e != ev),
                parents=tuple(p for p in pe.parents if p != ev))
            if verify_plan(mut):
                return
    pytest.fail("no edge drop on the optimized plan was flagged")


def test_plan_verifier_flags_index_scramble_as_structure():
    plan = _captured_plan()
    mut = _mutate_element(plan, 1, index=5)
    vs = verify_plan(mut)
    assert vs and vs[0].kind == "structure"


def test_plan_verifier_flags_read_of_evicted_slot():
    # Budget fits ~3.5 arrays; reusing 3 inputs across two passes forces
    # evictions and reloads inside one captured episode.
    n = 1 << 10
    s = make_scheduler("parallel", simulate=True,
                       memory_budget=int(n * 4 * 3.5))
    xs = [s.array(np.ones(n, np.float32), name=f"x{i}") for i in range(3)]
    with s.capture("reuse"):
        for rep in range(2):
            for i, x in enumerate(xs):
                y = s.array(shape=(n,), dtype=np.float32,
                            name=f"y{rep}_{i}")
                s.launch(None, [const(x), out(y)], name=f"K{rep}_{i}",
                         cost_s=1e-4)
    plan = s.plan_cache.all_plans()[0]
    s.sync()
    s.shutdown()
    assert verify_plan(plan) == []
    evict_idx = [i for i, pe in enumerate(plan.elements)
                 if pe.kind is ElementKind.EVICT]
    assert evict_idx, "budgeted capture recorded no evictions"
    placing = (ElementKind.TRANSFER, ElementKind.RELOAD, ElementKind.D2D)
    for i in evict_idx:
        for slot, _m in plan.elements[i].arg_slots:
            for j in range(i + 1, len(plan.elements)):
                pe = plan.elements[j]
                if pe.kind in placing and any(sl == slot
                                              for sl, _ in pe.arg_slots):
                    # Neutralize the element that re-materializes the slot:
                    # every later read now sees evicted data.
                    mut = _mutate_element(plan, j, arg_slots=tuple(
                        (sl, m) for sl, m in pe.arg_slots if sl != slot))
                    vs = verify_plan(mut)
                    if any(v.kind == "liveness" for v in vs):
                        return
    pytest.fail("suppressing a reload never produced a liveness violation")


# ----------------------------------------------------------------------
# Live-DAG window verifier
# ----------------------------------------------------------------------

def test_live_window_green_then_dropped_parent_flagged():
    s = make_scheduler("parallel", simulate=True)
    _vec_episode(s)
    assert verify_scheduler(s) == []
    window = list(s._elements)
    s.sync()
    s.shutdown()
    red = next(e for e in window if e.name == "RED")
    sq1 = next(e for e in window if e.name == "SQ1")
    assert sq1 in red.parents
    red.parents = [p for p in red.parents if p is not sq1]
    vs = verify_elements(window)
    assert any(v.kind == "race" and "RAW" in v.message for v in vs), vs


def test_live_window_host_barrier_and_serial_total_order():
    # Serial policy: every launch is host-blocking, the window is totally
    # ordered by construction and must verify with zero edges.
    s = make_scheduler("serial", simulate=True)
    _vec_episode(s)
    assert verify_scheduler(s) == []
    s.sync()
    s.shutdown()
    # Host reads bridge ordering across retired dependencies.
    s = make_scheduler("parallel")
    y = s.array(shape=(8,), dtype=np.float32, name="hy")
    xs = s.array(np.ones(8, np.float32), name="hx")
    s.launch(lambda a, b: (a * 2.0,), [const(xs), out(y)], name="W1",
             cost_s=1e-4)
    float(y[0])                       # host read: frontier barrier
    s.launch(lambda a, b: (a * 3.0,), [const(xs), out(y)], name="W2",
             cost_s=1e-4)
    assert verify_scheduler(s) == []
    s.verify()                        # raising form, same result
    s.sync()
    s.shutdown()


# ----------------------------------------------------------------------
# Sanitizer runtime mode
# ----------------------------------------------------------------------

def _mk_element(args, name, cost=1e-3):
    return ComputationalElement(fn=None, args=tuple(args), name=name,
                                cost_s=cost)


def test_sanitizer_unit_detects_all_three_race_shapes():
    s = make_scheduler("parallel", simulate=True)
    a = s.array(np.ones(16, np.float32), name="a")

    san = Sanitizer()
    w1, w2 = _mk_element([out(a)], "W1"), _mk_element([out(a)], "W2")
    san.pre_exec(w1)
    with pytest.raises(SanitizerError, match="WAW"):
        san.pre_exec(w2)                          # write-write overlap

    san = Sanitizer()
    r, w = _mk_element([const(a)], "R"), _mk_element([out(a)], "W")
    san.pre_exec(r)
    with pytest.raises(SanitizerError, match="WAR"):
        san.pre_exec(w)                           # write begins mid-read

    san = Sanitizer()
    w, r = _mk_element([out(a)], "W"), _mk_element([const(a)], "R")
    san.pre_exec(w)
    with pytest.raises(SanitizerError, match="RAW"):
        san.pre_exec(r)                           # read begins mid-write
    assert san.races_detected == 1
    s.shutdown()


def test_sanitizer_detects_torn_read():
    s = make_scheduler("parallel", simulate=True)
    a = s.array(np.ones(16, np.float32), name="a")
    san = Sanitizer()
    r = _mk_element([const(a)], "R")
    san.pre_exec(r)
    # A write the hooks never saw (lost instrumentation / out-of-band
    # mutation) bumps the version between the read's start and end.
    key = r.args[0].key
    san._state[key].version += 1
    with pytest.raises(SanitizerError, match="torn read"):
        san.post_exec(r)
    s.shutdown()


def test_sanitizer_checksum_catches_write_through_const():
    s = make_scheduler("parallel")
    a = s.array(np.ones(16, np.float32), name="a")   # host-only value
    san = Sanitizer(checksums=True)
    e = _mk_element([const(a)], "R")
    san.pre_exec(e)
    a.host[0] += 1.0                  # in-place mutation the DAG cannot see
    with pytest.raises(SanitizerError, match="write through const"):
        san.post_exec(e)
    s.shutdown()


def test_sim_executor_overlap_raises_through_hooks():
    s = make_scheduler("parallel", simulate=True, sanitize=True)
    a = s.array(np.ones(16, np.float32), name="a")
    e1, e2 = _mk_element([out(a)], "W1"), _mk_element([out(a)], "W2")
    # Bypass dependency inference: two conflicting writers, no parents, on
    # two lanes — they start at the same sim timestamp and must trip the
    # sanitizer the moment the second one begins.
    s.executor.submit(e1, 0, ())
    with pytest.raises(SanitizerError, match="WAW"):
        s.executor.submit(e2, 1, ())
    assert s.stats()["sanitizer_races_detected"] == 1


def test_sanitize_off_installs_no_hooks_and_is_bit_identical():
    def run(sanitize):
        s = make_scheduler("parallel", sanitize=sanitize)
        if not sanitize:
            assert s.executor.pre_exec is None
            assert s.executor.post_exec is None
            assert s.sanitizer is None
        x = s.array(np.linspace(0.25, 4.0, 512).astype(np.float32))
        y = s.array(shape=(512,), dtype=np.float32)
        z = s.array(shape=(512,), dtype=np.float32)
        sq = gr.function(lambda a, b: (a * a,), modes=("const", "out"),
                         name="good_sq", scheduler=s)
        add = gr.function(lambda a, b, c: (a + b,),
                          modes=("const", "const", "out"), name="good_add",
                          scheduler=s)
        sq(x, y)
        add(x, y, z)
        result = np.array(z)
        if sanitize:
            st = s.stats()
            assert st["sanitizer_elements_checked"] > 0
            assert st["sanitizer_races_detected"] == 0
        s.sync()
        s.shutdown()
        return result

    plain, sane = run(False), run(True)
    assert plain.tobytes() == sane.tobytes()      # bit-identical


def test_sanitized_scheduler_runs_clean_scenarios_green():
    from repro.benchsuite import build_task_parallel
    s = make_scheduler("parallel", simulate=True, sanitize=True)
    build_task_parallel(s, branches=3, chain=3, n=1 << 10)
    s.sync()
    st = s.stats()
    assert st["sanitizer_elements_checked"] > 0
    assert st["sanitizer_races_detected"] == 0
    # Captured plans are verified at capture time under sanitize=True.
    with s.capture("tp2"):
        build_task_parallel(s, branches=2, chain=2, n=1 << 10)
    s.sync()
    s.verify()
    s.shutdown()


# ----------------------------------------------------------------------
# Memory drift: structured report + monitor surfacing
# ----------------------------------------------------------------------

def test_memory_verify_raises_structured_drift_report():
    s = make_scheduler("parallel", simulate=True)
    _vec_episode(s)
    s.sync()
    assert s.memory.verify().ok
    pool = s.memory.pools[0]
    pool.resident_bytes += 4096           # seed ledger drift
    try:
        with pytest.raises(MemoryDriftError) as exc:
            s.memory.verify()
        report = exc.value.report
        assert not report.ok
        assert any("ledger" in p for p in report.problems)
        assert report.logical                 # structured diff present
        assert json.dumps(report.to_json())   # serializable
        # Non-raising form for samplers:
        assert not s.memory.verify(raise_on_drift=False).ok
    finally:
        pool.resident_bytes -= 4096
    assert s.memory.verify().ok
    s.shutdown()


def test_monitor_surfaces_drift_report():
    from repro.daemon import RuntimeMonitor
    s = make_scheduler("parallel", simulate=True)
    _vec_episode(s)
    s.sync()
    mon = RuntimeMonitor(s, interval_s=None, drift_grace=1)
    mon.sample_once()
    assert mon.stats()["monitor_drift_report"]["ok"]
    pool = s.memory.pools[0]
    pool.resident_bytes += 4096
    try:
        mon.sample_once()
        st = mon.stats()
        assert st["monitor_drift_alarms"] >= 1
        assert not st["monitor_drift_report"]["ok"]
        assert any("ledger" in p for p in st["monitor_drift_problems"])
    finally:
        pool.resident_bytes -= 4096
    s.shutdown()


# ----------------------------------------------------------------------
# Journal auditor
# ----------------------------------------------------------------------

def _record(jid, edges, state, t0=100.0):
    """One journal line: edges is a list of (src, dst) walked in order."""
    trans = [[src, dst, t0 + i] for i, (src, dst) in enumerate(edges)]
    return {"t": t0, "job": {"job_id": jid, "kind": "sleep", "params": {},
                             "tenant": "default", "priority": 0,
                             "deadline_s": None, "submit_t": t0,
                             "state": state, "reason": "", "result": None,
                             "attempts": 1, "transitions": trans}}


def _write_journal(lines):
    fd, path = tempfile.mkstemp(suffix=".jsonl")
    with os.fdopen(fd, "w") as fh:
        for rec in lines:
            fh.write((rec if isinstance(rec, str) else json.dumps(rec))
                     + "\n")
    return path


_GOOD_EDGES = [("queued", "admitted"), ("admitted", "running"),
               ("running", "finished")]


def test_journal_auditor_passes_clean_and_torn_tail():
    path = _write_journal([
        _record("j1", _GOOD_EDGES[:1], "admitted"),
        _record("j1", _GOOD_EDGES[:2], "running"),
        _record("j1", _GOOD_EDGES, "finished"),
        '{"t": 1, "job": {"job_id": "j2", "trunca',      # crash frontier
    ])
    audit = audit_journal(path)
    assert audit.ok and audit.torn_tail and audit.jobs == 1
    assert audit.records == 3 and audit.notes


@pytest.mark.parametrize("mutation,needle", [
    ("illegal_edge", "illegal"),
    ("rewrite", "rewritten"),
    ("state_mismatch", "last transition"),
    ("nonmonotone", "precedes"),
    ("torn_middle", "torn record"),
    ("empty_history", "empty transition"),
])
def test_journal_auditor_flags_every_mutation(mutation, needle):
    if mutation == "illegal_edge":
        lines = [_record("j1", [("queued", "running"),
                                ("running", "finished")], "finished")]
    elif mutation == "rewrite":
        lines = [_record("j1", _GOOD_EDGES[:2], "running"),
                 _record("j1", [("queued", "cancelled")], "cancelled")]
    elif mutation == "state_mismatch":
        lines = [_record("j1", _GOOD_EDGES, "running")]
    elif mutation == "nonmonotone":
        rec = _record("j1", _GOOD_EDGES, "finished")
        rec["job"]["transitions"][2][2] = 1.0        # time goes backwards
        lines = [rec]
    elif mutation == "torn_middle":
        lines = [_record("j1", _GOOD_EDGES[:1], "admitted"),
                 '{"t": 1, "job": {"job_id": "j1", "trunc',
                 _record("j1", _GOOD_EDGES[:2], "running")]
    else:
        lines = [_record("j1", [], "running")]
    audit = audit_journal(_write_journal(lines))
    assert not audit.ok
    assert any(needle in p for p in audit.problems), audit.problems


def test_jobstore_audit_and_daemon_cli_exit_codes(capsys):
    from repro.daemon.cli import main as daemon_main
    from repro.daemon.lifecycle import JobRecord, JobState
    from repro.daemon.store import JobStore

    with pytest.raises(ValueError, match="no journal"):
        JobStore(None).audit()

    tmp = tempfile.mkdtemp(prefix="analysis_store_")
    path = os.path.join(tmp, "jobs.jsonl")
    store = JobStore(path)
    job = JobRecord(job_id="j1", kind="sleep", submit_t=1.0)
    store.put(job)
    job.transition(JobState.ADMITTED, t=2.0)
    store.put(job)
    job.transition(JobState.RUNNING, t=3.0)
    job.transition(JobState.FINISHED, t=4.0)
    store.put(job)
    audit = store.audit()
    assert audit.ok and audit.jobs == 1 and audit.records == 3
    store.close(compact=False)

    assert daemon_main(["jobs", "--audit", "--store", path]) == 0
    capsys.readouterr()
    # Corrupt a middle record: the CLI must exit non-zero.
    lines = open(path).read().splitlines()
    lines.insert(1, '{"t": 1, "job": {"job_id": "j1", "trunc')
    with open(path, "w") as fh:
        fh.write("\n".join(lines) + "\n")
    assert daemon_main(["jobs", "--audit", "--store", path]) == 1
    out = capsys.readouterr().out
    assert "torn record" in out


# ----------------------------------------------------------------------
# PlanVerificationError formatting
# ----------------------------------------------------------------------

def test_plan_verification_error_carries_violations():
    plan = _captured_plan()
    mut = _mutate_element(plan, 1, index=5)
    vs = verify_plan(mut)
    err = PlanVerificationError("vec", vs)
    assert err.violations == vs and "structure" in str(err)
