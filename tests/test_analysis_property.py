"""Property tests for the happens-before verifier (ISSUE 10).

Two properties over randomly generated scheduler DAGs:

* every DAG the scheduler builds (paper §IV-D inference) passes the
  verifier — the inference must cover every conflicting pair;
* dropping any single parent edge makes the verifier's race report agree
  *exactly* with an independent O(n³) reachability oracle computed here
  from scratch (matrix transitive closure, nothing shared with the
  verifier's incremental bitmask closure): every genuinely-uncovered
  conflicting pair is flagged, and nothing else is (no false positives).

Degrades to fixed seeds via ``_hypothesis_fallback`` when hypothesis is
not installed.
"""
import numpy as np
from _hypothesis_fallback import given, settings, st

from repro.analysis import verify_elements
from repro.core import const, inout, make_scheduler, out

_WRAP = (const, out, inout)


def _build_window(codes):
    """Random episode: code -> (array index, access mode) single-arg
    launches on a shared pool of 3 arrays.  Returns the submission-ordered
    element window (kernels + auto-inserted transfers), post-sync."""
    s = make_scheduler("parallel", simulate=True)
    pool = [s.array(np.ones(64, np.float32), name=f"p{i}") for i in range(3)]
    for k, code in enumerate(codes):
        arr = pool[code % 3]
        wrap = _WRAP[(code // 3) % 3]
        s.launch(None, [wrap(arr)], name=f"OP{k}", cost_s=1e-5)
    window = list(s._elements)
    s.sync()
    s.shutdown()
    return window


def _oracle_unordered_pairs(elements):
    """Independent O(n³) check: conflicting access pairs with no parent
    path between them, via full boolean matrix transitive closure."""
    n = len(elements)
    pos = {e.uid: i for i, e in enumerate(elements)}
    reach = [[False] * n for _ in range(n)]
    for j, e in enumerate(elements):
        for p in e.parents:
            i = pos.get(p.uid)
            if i is not None:
                reach[i][j] = True
    for k in range(n):
        rk = reach[k]
        for i in range(n):
            if reach[i][k]:
                ri = reach[i]
                for j in range(n):
                    if rk[j]:
                        ri[j] = True
    accesses = {}
    for i, e in enumerate(elements):
        for key, mode in e.arg_modes():
            accesses.setdefault(key, []).append((i, mode))
    unordered = set()
    for acc in accesses.values():
        for a in range(len(acc)):
            i, mi = acc[a]
            for b in range(a + 1, len(acc)):
                j, mj = acc[b]
                if mi.conflicts_with(mj) and not (reach[i][j]
                                                  or reach[j][i]):
                    unordered.add(frozenset((elements[i].uid,
                                             elements[j].uid)))
    return unordered


def _race_pairs(violations):
    return {frozenset(v.elements) for v in violations if v.kind == "race"}


@settings(max_examples=25, deadline=None)
@given(st.lists(st.integers(0, 8), min_size=3, max_size=9))
def test_scheduler_dags_always_verify(codes):
    window = _build_window(codes)
    assert _oracle_unordered_pairs(window) == set()   # inference covered all
    assert verify_elements(window) == []


@settings(max_examples=25, deadline=None)
@given(st.lists(st.integers(0, 8), min_size=3, max_size=9))
def test_any_single_dropped_edge_matches_oracle_exactly(codes):
    window = _build_window(codes)
    mutants = 0
    for child in window:
        for parent in list(child.parents):
            child.parents.remove(parent)
            try:
                expected = _oracle_unordered_pairs(window)
                got = _race_pairs(verify_elements(window))
                assert got == expected, (
                    f"dropping {parent.name}->{child.name}: verifier "
                    f"reported {got}, oracle says {expected}")
                if expected:
                    mutants += 1
            finally:
                child.parents.append(parent)
    # The generator must actually produce conflicting workloads: at least
    # one drop per multi-write episode has to uncover a pair.
    writes = sum(1 for e in window
                 for _k, m in e.arg_modes() if m.writes)
    if writes >= 4:
        assert mutants >= 1, "no dropped edge ever uncovered a pair"
