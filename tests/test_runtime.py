"""TaskGraphTrainer + checkpointing + fault tolerance tests."""
import os
import tempfile

import numpy as np
import pytest

import jax

from repro.checkpoint import CheckpointManager
from repro.configs import get_config
from repro.core import GrScheduler, make_scheduler
from repro.runtime import TaskGraphTrainer


@pytest.fixture(scope="module")
def cfg():
    return get_config("qwen3_32b", reduced=True)


def test_trainer_runs_through_scheduler(cfg):
    tr = TaskGraphTrainer(cfg, seq_len=32, global_batch=4, accum=2)
    try:
        rep = tr.run(6)
        assert rep.steps_run == 6
        assert rep.losses and all(np.isfinite(rep.losses))
        # the loop was actually scheduled: train_step kernels + host elements
        stats = tr.sched.stats()
        assert stats["elements"] >= 6
    finally:
        tr.sched.shutdown()


@pytest.mark.slow
def test_trainer_deterministic_across_schedulers(cfg):
    """Parallel-async scheduling must not change training results."""
    def losses(policy):
        tr = TaskGraphTrainer(cfg, seq_len=32, global_batch=4, accum=1,
                              scheduler=GrScheduler(policy=policy))
        try:
            return tr.run(5, metrics_every=1).losses
        finally:
            tr.sched.shutdown()

    np.testing.assert_allclose(losses("serial"), losses("parallel"),
                               rtol=1e-5)


@pytest.mark.slow
def test_checkpoint_restart_exact_resume(cfg):
    """Crash at step 5, restore from step 4, finish: the loss trajectory
    after resume must equal an uninterrupted run (deterministic stream)."""
    with tempfile.TemporaryDirectory() as d:
        tr1 = TaskGraphTrainer(cfg, seq_len=32, global_batch=4, accum=1,
                               ckpt_dir=os.path.join(d, "a"), ckpt_every=2,
                               seed=7)
        try:
            ref = tr1.run(8, metrics_every=1).losses
        finally:
            tr1.sched.shutdown()

        tr2 = TaskGraphTrainer(cfg, seq_len=32, global_batch=4, accum=1,
                               ckpt_dir=os.path.join(d, "b"), ckpt_every=2,
                               seed=7)
        try:
            rep = tr2.run_with_restart(8, fail_at=5)
        finally:
            tr2.sched.shutdown()
        # steps 5..8 after restart-from-4 must match the reference tail
        np.testing.assert_allclose(rep.losses[-1], ref[-1], rtol=1e-5)


def test_checkpoint_manager_atomic_and_gc():
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, keep=2, async_save=False)
        state = {"w": np.arange(8, dtype=np.float32),
                 "nested": {"b": np.ones((2, 2))}}
        for step in (1, 2, 3):
            state["w"] = state["w"] + 1
            mgr.save(step, state)
        assert mgr.latest_step() == 3
        restored = mgr.restore(like=state)
        np.testing.assert_array_equal(restored["w"], state["w"])
        # gc kept only the newest 2
        kept = sorted(x for x in os.listdir(d) if x.startswith("step_"))
        assert kept == ["step_2", "step_3"]
        # no tmp dirs left behind
        assert not [x for x in os.listdir(d) if x.endswith(".tmp")]


def test_straggler_detection_in_sim():
    """A straggling kernel is detected via the scheduler's history (§IV-A)."""
    s = make_scheduler("parallel", simulate=True)
    import numpy as np
    from repro.core import const, out
    for i in range(6):
        x = s.array(np.zeros(1024, np.float32), name=f"x{i}")
        y = s.array(np.zeros(1024, np.float32), name=f"y{i}")
        cost = 1e-3 if i < 5 else 50e-3     # last one straggles
        s.launch(None, [const(x), out(y)], name="step", cost_s=cost)
    s.sync()
    assert s.executor.history.stragglers_seen >= 1
    assert s.executor.history.is_straggler("step", {}, 50e-3)


def test_quantized_adamw_converges():
    """8-bit AdamW behaves like fp32 AdamW on a quadratic toy problem."""
    import jax.numpy as jnp
    from repro.optim import AdamW

    def run(quantized):
        opt = AdamW(lr=0.05, weight_decay=0.0, warmup=1, total_steps=400,
                    quantized=quantized)
        params = {"w": jnp.ones((4, 512)) * 3.0}
        state = opt.init(params)
        for _ in range(150):
            grads = {"w": 2 * params["w"]}        # d/dw of w^2
            params, state, _ = opt.update(grads, state, params)
        return float(jnp.max(jnp.abs(params["w"])))

    final_fp32 = run(False)
    final_q8 = run(True)
    assert final_fp32 < 0.15
    assert final_q8 < 0.3, f"q8 AdamW diverged: {final_q8}"
