"""Unit + property tests for the runtime DAG dependency inference (Fig. 3)."""
import numpy as np
import pytest
from _hypothesis_fallback import given, settings, st

from repro.core import ComputationDAG, ComputationalElement, const, inout, out


class FakeArray:
    def __init__(self, name):
        self.name = name


def ce(*args, name=""):
    return ComputationalElement(fn=None, args=tuple(args), name=name)


def test_raw_dependency():
    dag = ComputationDAG()
    A = FakeArray("A")
    k1 = ce(inout(A), name="K1")
    dag.add(k1)
    k2 = ce(const(A), name="K2")
    dag.add(k2)
    assert k2.parents == [k1]


def test_fig3_reader_does_not_consume_writer_entry():
    """Fig. 3 case C: consecutive readers all depend on the writer."""
    dag = ComputationDAG()
    A = FakeArray("A")
    k1 = ce(inout(A), name="K1")
    dag.add(k1)
    k2 = ce(const(A), name="K2")
    dag.add(k2)
    k3 = ce(const(A), name="K3")
    dag.add(k3)
    assert k2.parents == [k1]
    assert k3.parents == [k1]          # depends on K1, NOT on K2
    assert id(A) in k1.dep_set          # K1's set not updated by readers


def test_fig3_war_antidependency_through_readers():
    """Fig. 3 case B: a writer after readers depends on the readers only."""
    dag = ComputationDAG()
    A = FakeArray("A")
    k1 = ce(inout(A), name="K1")
    dag.add(k1)
    k2 = ce(const(A), name="K2")
    dag.add(k2)
    k3 = ce(const(A), name="K3")
    dag.add(k3)
    k4 = ce(inout(A), name="K4")
    dag.add(k4)
    assert set(k4.parents) == {k2, k3}  # both readers, not K1
    # the write consumed every earlier dependency-set entry for A
    assert id(A) not in k1.dep_set
    assert id(A) not in k2.dep_set
    assert id(A) not in k3.dep_set


def test_waw_dependency_without_readers():
    dag = ComputationDAG()
    A = FakeArray("A")
    k1 = ce(out(A), name="K1")
    dag.add(k1)
    k2 = ce(out(A), name="K2")
    dag.add(k2)
    assert k2.parents == [k1]


def test_independent_kernels_share_readonly_input():
    """Two kernels reading the same const array must be independent (§IV-A)."""
    dag = ComputationDAG()
    X, Y, Z = FakeArray("X"), FakeArray("Y"), FakeArray("Z")
    k1 = ce(const(X), out(Y), name="K1")
    dag.add(k1)
    k2 = ce(const(X), out(Z), name="K2")
    dag.add(k2)
    assert k2.parents == []


def test_empty_dep_set_retires_from_frontier():
    dag = ComputationDAG()
    A = FakeArray("A")
    k1 = ce(inout(A), name="K1")
    dag.add(k1)
    k2 = ce(inout(A), name="K2")
    dag.add(k2)
    assert not k1.active and k1 not in dag.frontier
    assert k2.active


def test_retire_propagates_to_ancestors():
    dag = ComputationDAG()
    A, B = FakeArray("A"), FakeArray("B")
    k1 = ce(out(A), name="K1")
    k2 = ce(const(A), out(B), name="K2")
    dag.add(k1)
    dag.add(k2)
    dag.retire(k2)
    assert not k1.active and not k2.active


def test_diamond():
    dag = ComputationDAG()
    A, B, C = FakeArray("A"), FakeArray("B"), FakeArray("C")
    k0 = ce(out(A), name="K0")
    k1 = ce(const(A), out(B), name="K1")
    k2 = ce(const(A), out(C), name="K2")
    k3 = ce(const(B), const(C), name="K3")
    for k in (k0, k1, k2, k3):
        dag.add(k)
    assert k1.parents == [k0] and k2.parents == [k0]
    assert set(k3.parents) == {k1, k2}


def test_duplicate_array_in_args_uses_strongest_mode():
    dag = ComputationDAG()
    A = FakeArray("A")
    k1 = ce(out(A), name="K1")
    dag.add(k1)
    k2 = ce(const(A), inout(A), name="K2")  # same array twice
    dag.add(k2)
    k3 = ce(const(A), name="K3")
    dag.add(k3)
    assert k3.parents == [k2]   # K2 counted as writer


# ----------------------------------------------------------------------
# Corner cases backing the capture/replay refactor
# ----------------------------------------------------------------------

def test_war_after_retire_introduces_no_dependency():
    """A writer issued after the host retired the readers (and hence their
    ancestors) must start a fresh frontier — no stale WAR edges."""
    dag = ComputationDAG()
    A = FakeArray("A")
    k1 = ce(inout(A), name="K1")
    k2 = ce(const(A), name="K2")
    dag.add(k1)
    dag.add(k2)
    dag.retire(k2)                  # host observed K2 (and ancestor K1)
    k3 = ce(inout(A), name="K3")
    dag.add(k3)
    assert k3.parents == []
    assert k3.active and k3 in dag.frontier


def test_inout_self_dependency_is_impossible():
    """An element reading and writing the same array (even via duplicate
    args) must never become its own parent."""
    dag = ComputationDAG()
    A = FakeArray("A")
    k1 = ce(const(A), inout(A), name="K1")
    dag.add(k1)
    assert k1 not in k1.parents and k1.parents == []
    k2 = ce(inout(A), const(A), name="K2")
    dag.add(k2)
    assert k2 not in k2.parents and k2.parents == [k1]


def test_reader_then_writer_arg_order_on_same_element():
    """const(A) before out(A) on one element merges to the writing mode:
    downstream readers see it as the last writer, and the element consumes
    the previous frontier exactly once."""
    dag = ComputationDAG()
    A = FakeArray("A")
    k1 = ce(out(A), name="K1")
    dag.add(k1)
    rw = ce(const(A), out(A), name="RW")
    dag.add(rw)
    assert rw.parents == [k1]
    assert id(A) not in k1.dep_set      # consumed by the write exactly once
    k3 = ce(const(A), name="K3")
    dag.add(k3)
    assert k3.parents == [rw]


def test_dead_state_is_evicted_in_long_loops():
    """Satellite fix: per-array frontier state must not grow without bound
    when a serving loop touches a fresh array per episode."""
    dag = ComputationDAG()
    for i in range(5000):
        e = ce(inout(FakeArray(f"t{i}")), name=f"K{i}")
        dag.add(e)
        dag.retire(e)
    assert len(dag._state) < 1024


def test_managed_keys_are_id_reuse_proof():
    """ManagedArray-style handles key the frontier by a monotonic aid mapped
    into a namespace disjoint from id() — a recycled address can never alias
    a dead array's state."""
    from repro.core import dep_key

    class Managed:
        _next = [0]

        def __init__(self):
            self.aid = Managed._next[0]
            Managed._next[0] += 1

    a, b = Managed(), Managed()
    assert dep_key(a) != dep_key(b)
    assert dep_key(a) < 0 and dep_key(b) < 0
    plain = FakeArray("p")
    assert dep_key(plain) == id(plain) >= 0


def test_snapshot_is_frozen_and_reflects_live_frontier():
    dag = ComputationDAG()
    A, B = FakeArray("A"), FakeArray("B")
    k1 = ce(inout(A), name="K1")
    k2 = ce(const(A), out(B), name="K2")
    dag.add(k1)
    dag.add(k2)
    snap = dag.snapshot()
    assert snap.writers[id(A)] is k1
    assert snap.readers[id(A)] == (k2,)
    with pytest.raises(TypeError):
        snap.writers[id(B)] = k1            # read-only mapping
    dag.retire_all()
    snap2 = dag.snapshot()
    assert not snap2.writers and not snap2.frontier
    assert snap.frontier                     # old snapshot unchanged


# ----------------------------------------------------------------------
# Property-based validation against a sequential-consistency oracle.
# ----------------------------------------------------------------------

@st.composite
def programs(draw):
    n_arrays = draw(st.integers(2, 5))
    n_ops = draw(st.integers(1, 24))
    ops = []
    for _ in range(n_ops):
        n_args = draw(st.integers(1, min(3, n_arrays)))
        idxs = draw(st.lists(st.integers(0, n_arrays - 1),
                             min_size=n_args, max_size=n_args, unique=True))
        modes = [draw(st.sampled_from(["const", "inout", "out"]))
                 for _ in idxs]
        ops.append(list(zip(idxs, modes)))
    return n_arrays, ops


@settings(max_examples=200, deadline=None)
@given(programs())
def test_dependency_closure_matches_hazard_oracle(prog):
    """The transitive closure of inferred edges must contain every
    RAW/WAR/WAW hazard pair (correctness), and must never order two
    hazard-free elements (maximality of parallelism for readers)."""
    n_arrays, ops = prog
    arrays = [FakeArray(f"a{i}") for i in range(n_arrays)]
    dag = ComputationDAG()
    elements = []
    for spec in ops:
        args = []
        for idx, mode in spec:
            args.append({"const": const, "inout": inout, "out": out}[mode](arrays[idx]))
        e = ComputationalElement(fn=None, args=tuple(args))
        dag.add(e)
        elements.append((e, spec))

    # transitive closure of the runtime DAG
    order = {e.uid: i for i, (e, _) in enumerate(elements)}
    reach = {e.uid: set() for e, _ in elements}
    for e, _ in elements:
        for p in e.parents:
            reach[e.uid].add(p.uid)
            reach[e.uid] |= reach[p.uid]

    def hazard(spec_a, spec_b):
        """True if b must be ordered after a (any RAW/WAR/WAW on a shared array)."""
        for ia, ma in spec_a:
            for ib, mb in spec_b:
                if ia != ib:
                    continue
                wa = ma in ("inout", "out")
                wb = mb in ("inout", "out")
                if wa or wb:
                    return True
        return False

    for i, (ea, sa) in enumerate(elements):
        for j in range(i + 1, len(elements)):
            eb, sb = elements[j]
            if hazard(sa, sb):
                assert ea.uid in reach[eb.uid], (
                    f"missing hazard edge {ea.name}->{eb.name}")
            # read-read sharing must stay unordered *unless* forced
            # transitively through some other array's hazard chain — so no
            # assertion on the converse; direct edges are checked below.

    # No DIRECT edge between two hazard-free elements
    for i, (ea, sa) in enumerate(elements):
        for j in range(i + 1, len(elements)):
            eb, sb = elements[j]
            if ea in eb.parents:
                assert hazard(sa, sb), "spurious direct edge between hazard-free elements"


@settings(max_examples=60, deadline=None)
@given(programs())
def test_frontier_empty_after_retire_all(prog):
    """After retire_all, no element stays active, the frontier is empty and
    a subsequent element can inherit no dependencies."""
    n_arrays, ops = prog
    arrays = [FakeArray(f"a{i}") for i in range(n_arrays)]
    dag = ComputationDAG()
    added = []
    for spec in ops:
        args = tuple({"const": const, "inout": inout, "out": out}[m](arrays[i])
                     for i, m in spec)
        e = ComputationalElement(fn=None, args=args)
        dag.add(e)
        added.append(e)
    dag.retire_all()
    assert not dag.frontier
    assert all(not e.active for e in added)
    probe = ce(*[inout(a) for a in arrays], name="probe")
    dag.add(probe)
    assert probe.parents == []
