"""Crash recovery: SIGKILL the daemon mid-queue, restart on the same store,
and prove the restart contract — QUEUED jobs resume exactly once, in-flight
jobs are re-marked FAILED, and no journal ever records an illegal history.
"""
import json
import os
import signal
import subprocess
import sys
import time

from repro.daemon import DaemonClient, DaemonServer, JobState, JobStore
from repro.daemon.lifecycle import validate_history

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")


def _spawn_daemon(sock, store, *extra):
    proc = subprocess.Popen(
        [sys.executable, "-m", "repro.daemon", "--socket", sock, "serve",
         "--store", store, "--executor", "sim", "--workers", "1",
         "--monitor-interval", "0.02", *extra],
        env={**os.environ, "PYTHONPATH": SRC}, cwd=REPO,
        stdout=subprocess.DEVNULL, stderr=subprocess.DEVNULL)
    deadline = time.monotonic() + 30
    while not os.path.exists(sock):
        assert proc.poll() is None, "daemon died during startup"
        assert time.monotonic() < deadline, "daemon never bound its socket"
        time.sleep(0.05)
    return proc


def test_sigkill_midqueue_then_restart_runs_queued_exactly_once(tmp_path):
    sock = str(tmp_path / "d.sock")
    store_path = str(tmp_path / "jobs.jsonl")
    proc = _spawn_daemon(sock, store_path)
    try:
        c = DaemonClient(sock)
        # one long job occupies the single worker; the rest stay QUEUED
        ids = [c.submit("sleep", {"total_s": 30.0, "steps": 300})["job_id"]]
        ids += [c.submit("sleep", {"total_s": 0.05, "steps": 2})["job_id"]
                for _ in range(4)]
        deadline = time.monotonic() + 10
        while c.status(ids[0])["state"] != "running":
            assert time.monotonic() < deadline
            time.sleep(0.02)
        c.close()
    finally:
        proc.send_signal(signal.SIGKILL)
        proc.wait(timeout=10)

    # Restart against the same store (in-process this time), drain it.
    srv = DaemonServer(sock, store_path=store_path,
                       sched_kw={"simulate": True}, workers=2,
                       monitor_interval_s=0.02).start()
    try:
        assert srv.wait_idle(timeout=30), "restarted daemon never drained"
        with DaemonClient(sock) as c2:
            killed = c2.status(ids[0])
            assert killed["state"] == "failed"
            assert killed["reason"] == "daemon restart"
            for jid in ids[1:]:
                job = c2.status(jid)
                assert job["state"] == "finished", job
                # exactly once: a single dispatcher ever admitted it
                assert job["attempts"] == 1
                admits = [t for t in job["transitions"]
                          if tuple(t[:2]) == ("queued", "admitted")]
                assert len(admits) == 1
    finally:
        srv.stop()

    # The full journal — both daemon generations — validates clean.
    final = JobStore(store_path)
    assert len(final) == 5
    for job in final.jobs():
        assert validate_history(job.transitions) == [], job.job_id
        assert job.terminal
    final.close(compact=False)


def test_sigkill_tears_at_most_one_record_and_restart_truncates(tmp_path):
    """Whatever instant the kill lands at, replay loses at most the record
    in flight, and the restarted journal stays appendable."""
    sock = str(tmp_path / "d.sock")
    store_path = str(tmp_path / "jobs.jsonl")
    proc = _spawn_daemon(sock, store_path)
    try:
        c = DaemonClient(sock)
        for _ in range(6):
            c.submit("sleep", {"total_s": 0.02, "steps": 1})
        c.close()
    finally:
        proc.send_signal(signal.SIGKILL)   # may land mid-append
        proc.wait(timeout=10)

    st = JobStore(store_path)              # replay + frontier truncation
    n = len(st)
    assert n >= 5                          # at most the in-flight record lost
    requeued, failed = st.recover()
    for j in st.jobs():
        assert validate_history(j.transitions) == []
    # journal is appendable and self-consistent after recovery
    st.close(compact=True)
    st2 = JobStore(store_path)
    assert len(st2) == n
    assert not any(j.state in (JobState.ADMITTED, JobState.RUNNING,
                               JobState.PAUSED) for j in st2.jobs())
    st2.close(compact=False)


def test_clean_shutdown_compacts_and_restart_requeues_nothing(tmp_path):
    sock = str(tmp_path / "d.sock")
    store_path = str(tmp_path / "jobs.jsonl")
    srv = DaemonServer(sock, store_path=store_path,
                       sched_kw={"simulate": True},
                       monitor_interval_s=0.02).start()
    with DaemonClient(sock) as c:
        for i in range(5):
            c.submit("noop", {"i": i})
        assert srv.wait_idle(timeout=10)
    srv.stop()                             # drain + compact
    assert len(open(store_path).read().splitlines()) == 5  # one line per job
    st = JobStore(store_path)
    requeued, failed = st.recover()
    assert requeued == [] and failed == []
    assert all(j.state is JobState.FINISHED for j in st.jobs())
    st.close(compact=False)


def test_restart_preserves_results_for_status_queries(tmp_path):
    sock = str(tmp_path / "d.sock")
    store_path = str(tmp_path / "jobs.jsonl")
    srv = DaemonServer(sock, store_path=store_path,
                       sched_kw={"simulate": True},
                       monitor_interval_s=0.02).start()
    with DaemonClient(sock) as c:
        jid = c.submit("noop", {"payload": "kept"})["job_id"]
        res = c.result(jid, timeout=10)
    srv.stop()
    srv2 = DaemonServer(sock, store_path=store_path,
                        sched_kw={"simulate": True},
                        monitor_interval_s=0.02).start()
    try:
        with DaemonClient(sock) as c2:
            job = c2.status(jid)
            assert job["state"] == "finished" and job["result"] == res
    finally:
        srv2.stop()
