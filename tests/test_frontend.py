"""GrFunction frontend + ambient runtime (ISSUE 4).

Covers: declare-once call semantics (modes, output allocation, call-scoped
options), the ambient-runtime resolution order (explicit > bound > ambient >
array-inferred) and its edge cases (nesting, cross-thread isolation, the
no-runtime error), capture keyed by declared-function identity, the
deprecated ``scheduler.launch`` shim, and the ManagedArray
write-after-transfer ownership regression."""
import threading

import numpy as np
import pytest

import repro.api as gr
from repro.core import AccessMode, ElementKind, make_scheduler
from repro.core.frontend import set_runtime


@pytest.fixture(autouse=True)
def _clean_default_runtime():
    """Never leak a module-level default runtime across tests."""
    prev = set_runtime(None)
    yield
    set_runtime(prev)


def sim():
    return make_scheduler("parallel", simulate=True)


def kernels_in(s):
    return [sp for sp in s.timeline.spans if sp.kind == "compute"]


# ----------------------------------------------------------------------
# GrFunction call semantics
# ----------------------------------------------------------------------

def test_declared_modes_drive_dependencies():
    s = sim()
    sq = gr.function(None, modes=("const", "out"), name="SQ", cost_s=1e-4)
    red = gr.function(None, modes=("const", "const", "out"), name="RED",
                      cost_s=1e-4)
    x1, x2 = s.array(np.ones(64, np.float32)), s.array(np.ones(64, np.float32))
    y1 = s.array(shape=(64,), dtype=np.float32)
    y2 = s.array(shape=(64,), dtype=np.float32)
    z = s.array(shape=(1,), dtype=np.float32)
    e1 = sq(x1, y1, scheduler=s)
    e2 = sq(x2, y2, scheduler=s)
    e3 = red(y1, y2, z, scheduler=s)
    s.sync()
    assert {p.name for p in e3.parents} == {e1.name, e2.name}
    assert e1.stream != e2.stream           # independent branches overlap
    assert [a.mode for a in e3.args] == [AccessMode.CONST, AccessMode.CONST,
                                         AccessMode.OUT]


def test_output_allocation_like_input_and_explicit_spec():
    s = make_scheduler("parallel")
    try:
        import jax
        dbl = gr.function(jax.jit(lambda a, _o: a * 2.0),
                          modes=("const", "out"), outputs=0, name="DBL")
        total = gr.function(jax.jit(lambda a, _o: a.sum()[None]),
                            modes=("const", "out"),
                            outputs=((1,), np.float32), name="SUM")
        x = s.array(np.arange(32, dtype=np.float32), name="x")
        y = dbl(x, scheduler=s)             # runtime-allocated from spec
        z = total(y, scheduler=s)
        np.testing.assert_allclose(np.asarray(z), [2.0 * np.arange(32).sum()])
        np.testing.assert_allclose(np.asarray(y), 2.0 * np.arange(32))
        assert y.shape == (32,) and y.dtype == np.float32
    finally:
        s.shutdown()


def test_output_spec_tuple_sequence_and_pair_disambiguation():
    """A 2-tuple of non-shape specs is a sequence (one per OUT position);
    a ((shape,), dtype) 2-tuple is a single pair."""
    s = sim()
    two = gr.function(None, modes=("const", "const", "out", "out"),
                      outputs=(0, 1), name="TWO", cost_s=1e-4)
    a = s.array(np.zeros((4,), np.float32))
    b = s.array(np.zeros((8,), np.float64))
    o1, o2 = two(a, b, scheduler=s)
    assert o1.shape == (4,) and o1.dtype == np.float32
    assert o2.shape == (8,) and o2.dtype == np.float64
    pair = gr.function(None, modes=("const", "out"),
                       outputs=((3, 3), np.int32), name="PAIR", cost_s=1e-4)
    o = pair(a, scheduler=s)
    assert o.shape == (3, 3) and o.dtype == np.int32
    # A 2-sequence of explicit pairs is a sequence, not one pair.
    pairs2 = gr.function(None, modes=("const", "out", "out"),
                         outputs=[((4,), np.float32), ((8,), np.int32)],
                         name="PAIRS2", cost_s=1e-4)
    p1, p2 = pairs2(a, scheduler=s)
    assert p1.shape == (4,) and p1.dtype == np.float32
    assert p2.shape == (8,) and p2.dtype == np.int32
    s.sync()
    bad = gr.function(None, modes=("const", "out"), outputs="nope",
                      name="BAD")
    with pytest.raises(TypeError, match="output spec"):
        bad(a, scheduler=s)


def test_with_options_overrides_outputs_without_polluting_config():
    s = sim()
    f = gr.function(None, modes=("const", "out"), outputs=0, name="K",
                    cost_s=1e-4)
    x = s.array(np.zeros(4, np.float32))
    g = f.with_options(outputs=((8,), np.int64))
    y = g(x, scheduler=s)
    assert y.shape == (8,) and y.dtype == np.int64   # override honored
    assert "outputs" not in g.config                 # not leaked to config
    y0 = f(x, scheduler=s)
    assert y0.shape == (4,) and y0.dtype == np.float32
    s.sync()


def test_missing_non_out_argument_raises():
    s = sim()
    f = gr.function(None, modes=("const", "inout"), name="K")
    x = s.array(np.zeros(8, np.float32))
    with pytest.raises(TypeError, match="must be supplied"):
        f(x, scheduler=s)


def test_allocation_without_spec_raises():
    s = sim()
    f = gr.function(None, modes=("const", "out"), name="K")
    x = s.array(np.zeros(8, np.float32))
    with pytest.raises(TypeError, match="outputs= spec"):
        f(x, scheduler=s)


def test_with_options_scopes_qos_and_cost_without_mutating_declaration():
    s = sim()
    f = gr.function(None, modes=("inout",), name="K", cost_s=1e-4)
    x = s.array(np.zeros(8, np.float32))
    e = f.with_options(priority=2, tenant="lat", cost_s=5e-4,
                       parallel_fraction=0.5)(x, scheduler=s)
    assert (e.priority, e.tenant, e.cost_s) == (2, "lat", 5e-4)
    assert e.config["parallel_fraction"] == 0.5
    e2 = f(x, scheduler=s)                  # the declaration is untouched
    assert (e2.priority, e2.tenant, e2.cost_s) == (0, "default", 1e-4)
    assert "parallel_fraction" not in e2.config
    assert e.fn_key == e2.fn_key == f.fid   # same declared identity
    s.sync()


def test_with_options_device_pins_placement():
    s = make_scheduler("parallel", simulate=True, num_devices=2,
                       placement="round-robin")
    f = gr.function(None, modes=("inout",), name="K", cost_s=1e-4)
    xs = [s.array(np.zeros(8, np.float32), name=f"x{i}") for i in range(4)]
    es = [f.with_options(device=1)(x, scheduler=s) for x in xs]
    s.sync()
    assert all(e.device == 1 for e in es)   # round-robin bypassed
    # and the auto-inserted prefetches followed the pinned device
    transfers = [sp for sp in s.timeline.spans if sp.kind == "h2d"]
    assert len(transfers) == 4


# ----------------------------------------------------------------------
# Ambient runtime resolution
# ----------------------------------------------------------------------

def test_no_active_runtime_raises_clear_error():
    f = gr.function(None, modes=("inout",), name="K")
    with pytest.raises(gr.NoActiveRuntimeError,
                       match="gr.runtime|scheduler="):
        f(object())
    with pytest.raises(gr.NoActiveRuntimeError):
        gr.get_runtime()
    with pytest.raises(gr.NoActiveRuntimeError):
        gr.array(np.zeros(4, np.float32))


def test_ambient_runtime_resolves_arrays_and_calls():
    f = gr.function(None, modes=("const", "out"), name="K", cost_s=1e-4)
    with gr.runtime(policy="parallel", simulate=True) as s:
        x = gr.array(np.zeros(16, np.float32), name="x")
        y = gr.array(shape=(16,), dtype=np.float32, name="y")
        e = f(x, y)
        assert x._scheduler is s
        assert e in s._elements
        s.sync()
    assert gr.current_runtime() is None     # popped on exit


def test_nested_runtime_contexts_inner_wins_and_unwind():
    f = gr.function(None, modes=("inout",), name="K", cost_s=1e-4)
    with gr.runtime(policy="parallel", simulate=True) as outer:
        xo = gr.array(np.zeros(8, np.float32))
        with gr.runtime(policy="parallel", simulate=True) as inner:
            assert gr.get_runtime() is inner
            xi = gr.array(np.zeros(8, np.float32))
            assert xi._scheduler is inner
            f(xi)
        assert gr.get_runtime() is outer    # inner popped, outer restored
        f(xo)
        outer.sync()
        inner.sync()
        assert len(kernels_in(outer)) == 1
        assert len(kernels_in(inner)) == 1
    with pytest.raises(gr.NoActiveRuntimeError):
        gr.get_runtime()


def test_explicit_scheduler_beats_ambient_beats_array_inference():
    f = gr.function(None, modes=("inout",), name="K", cost_s=1e-4)
    s_exp, s_amb, s_arr = sim(), sim(), sim()
    x = s_arr.array(np.zeros(8, np.float32))
    with gr.runtime(scheduler=s_amb):
        assert f(x, scheduler=s_exp) in s_exp._elements
        assert f(x) in s_amb._elements      # ambient wins over the array's
    assert f(x) in s_arr._elements          # falls back to the array's owner
    for s in (s_exp, s_amb, s_arr):
        s.sync()


def test_module_level_default_runtime():
    s = sim()
    f = gr.function(None, modes=("inout",), name="K", cost_s=1e-4)
    set_runtime(s)
    x = gr.array(np.zeros(8, np.float32))
    f(x)
    with gr.runtime(policy="parallel", simulate=True) as inner:
        assert gr.get_runtime() is inner    # thread stack beats the default
    assert gr.get_runtime() is s
    s.sync()
    assert len(kernels_in(s)) == 1


def test_runtime_adopting_scheduler_rejects_factory_kwargs():
    s = sim()
    with pytest.raises(TypeError, match="adopts an existing"):
        gr.runtime(scheduler=s, num_devices=2)
    with pytest.raises(TypeError, match="policy"):
        gr.runtime("serial", scheduler=s)   # would silently ignore "serial"


def test_shared_runtime_instance_is_safe_across_threads():
    """The scheduler is created eagerly, so one runtime object entered from
    several threads concurrently pushes the same scheduler everywhere (no
    lazy-creation race, no spurious LIFO error on exit)."""
    rt = gr.runtime(policy="parallel", simulate=True)
    f = gr.function(None, modes=("inout",), name="K", cost_s=1e-5)
    errs = []
    barrier = threading.Barrier(4)

    def worker(tid):
        try:
            barrier.wait()
            with rt as s:
                assert s is rt.scheduler
                f(gr.array(np.zeros(8, np.float32), name=f"x{tid}"))
            assert gr.current_runtime() is None
        except BaseException as exc:
            errs.append(exc)

    threads = [threading.Thread(target=worker, args=(t,)) for t in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errs
    rt.scheduler.sync()
    assert len(kernels_in(rt.scheduler)) == 4


def test_cross_thread_isolation_of_runtime_stack():
    """4 threads each enter their own ambient runtime (multitenant-harness
    pattern: barrier + shared declared function) — every thread's work must
    land on its own scheduler and the stacks must never bleed across."""
    n_threads, chains, per = 4, 3, 4
    stage = gr.function(None, modes=("inout",), name="K", cost_s=1e-5)
    scheds, errs = {}, []
    barrier = threading.Barrier(n_threads)

    def worker(tid):
        try:
            with gr.runtime(policy="parallel", simulate=True) as s:
                scheds[tid] = s
                barrier.wait()              # all runtimes active at once
                for c in range(chains):
                    x = gr.array(np.zeros(64, np.float32),
                                 name=f"t{tid}_x{c}")
                    for k in range(per):
                        e = stage.with_options(
                            name=f"t{tid}_k{c}_{k}",
                            tenant=f"tenant{tid}")(x)
                        assert e in s._elements
                barrier.wait()              # everyone still nested
                assert gr.get_runtime() is s
                s.sync()
            assert gr.current_runtime() is None
        except BaseException as exc:
            errs.append(exc)

    threads = [threading.Thread(target=worker, args=(tid,))
               for tid in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errs
    for tid, s in scheds.items():
        assert len(kernels_in(s)) == chains * per
        assert set(s.tenant_stats()) == {f"tenant{tid}"}


# ----------------------------------------------------------------------
# Capture keyed by declared-function identity
# ----------------------------------------------------------------------

def _episode(s, f, x, y):
    with s.capture("ep"):
        f(x, y, scheduler=s)


def test_capture_replays_across_recreated_closures():
    """The declaration is the identity: re-wrapping the same GrFunction's
    underlying callable per episode (the serving pattern) keeps replaying
    one plan."""
    s = sim()
    f = gr.function(None, modes=("const", "out"), name="K", cost_s=1e-4)
    x = s.array(np.zeros(32, np.float32), name="x")
    for i in range(4):
        y = s.array(shape=(32,), dtype=np.float32, name=f"y{i}")
        _episode(s, f, x, y)
        s.sync()
    st = s.stats()
    assert st["plan_records"] >= 1
    assert st["plan_replays"] >= 2


def test_capture_distinguishes_equal_named_declarations():
    """Two declarations that collide on name/config/cost must not alias one
    plan — fn_key is part of the match."""
    s = sim()
    f1 = gr.function(None, modes=("const", "out"), name="K", cost_s=1e-4)
    f2 = gr.function(None, modes=("const", "out"), name="K", cost_s=1e-4)
    x = s.array(np.zeros(32, np.float32), name="x")
    y1 = s.array(shape=(32,), dtype=np.float32, name="y1")
    _episode(s, f1, x, y1)
    s.sync()
    records = s.stats()["plan_records"]
    y2 = s.array(shape=(32,), dtype=np.float32, name="y2")
    _episode(s, f2, x, y2)                  # same shapes, different identity
    s.sync()
    st = s.stats()
    assert st["plan_replays"] == 0
    assert st["plan_records"] > records     # re-recorded, not replayed


@pytest.mark.parametrize("simulate", [True, False])
def test_grfunction_capture_roundtrip_bit_identical(simulate):
    """GrFunction-driven episodes under capture: replayed episodes produce
    bit-identical results to the recorded run (sim + real executors)."""
    if simulate:
        s = sim()
        dbl = gr.function(None, modes=("const", "out"), name="DBL",
                          cost_s=1e-4)
    else:
        import jax
        s = make_scheduler("parallel")
        dbl = gr.function(jax.jit(lambda a, _o: a * 2.0),
                          modes=("const", "out"), name="DBL")
    try:
        x = s.array(np.arange(64, dtype=np.float32), name="x")
        results = []
        for _ in range(4):
            y = s.array(shape=(64,), dtype=np.float32, name="y")
            with s.capture("bitident"):
                dbl(x, y, scheduler=s)
            results.append(np.asarray(y).copy())
        assert s.stats()["plan_replays"] >= 1
        if not simulate:
            for r in results[1:]:
                np.testing.assert_array_equal(results[0], r)
    finally:
        s.shutdown()


def test_spacesharing_runner_keeps_declared_identity_across_submits():
    """SpaceSharedRunner re-creates its kernel closure per submit (it binds
    the submit's fn/element) but must reuse one declared identity per
    (name, arity), or captured episodes could never replay."""
    import jax
    from repro.runtime.spacesharing import SpaceSharedRunner, SubmeshPool
    runner = SpaceSharedRunner(SubmeshPool(n_lanes=1))
    try:
        f = jax.jit(lambda a: a + 1.0)
        results = [runner.submit(f, [runner.sched.array(
            np.full(8, i, np.float32), name=f"in{i}")], name="task")
            for i in range(3)]
        vals = [np.asarray(r.get()) for r in results]
        for i, v in enumerate(vals):
            np.testing.assert_allclose(v, i + 1.0)
        keys = {e.fn_key for e in runner.sched._elements
                if e.kind is ElementKind.KERNEL}
        assert len(keys) == 1 and None not in keys
    finally:
        runner.sched.shutdown()


def test_capture_replays_out_of_range_device_pin():
    """A pin beyond num_devices clamps identically at record and match
    time — identical episodes must replay, not re-record per episode."""
    s = make_scheduler("parallel", simulate=True, num_devices=2)
    f = gr.function(None, modes=("const", "out"), name="K", cost_s=1e-4)
    x = s.array(np.zeros(32, np.float32), name="x")
    for i in range(4):
        y = s.array(shape=(32,), dtype=np.float32, name=f"y{i}")
        with s.capture("pinned"):
            e = f.with_options(device=7)(x, y, scheduler=s)
        assert e.device == 1                # clamped to the last device
        s.sync()
    st = s.stats()
    # 2 records is the usual warm-up (x flips to device-resident after the
    # first episode); before clamping ahead of capture matching this was 4
    # records / 0 replays — every episode re-recorded.
    assert st["plan_records"] == 2
    assert st["plan_replays"] == 2


# ----------------------------------------------------------------------
# The deprecated launch shim
# ----------------------------------------------------------------------

def test_launch_shim_still_works_and_warns():
    from repro.core import const, out
    s = sim()
    x = s.array(np.zeros(16, np.float32))
    y = s.array(shape=(16,), dtype=np.float32)
    with pytest.warns(DeprecationWarning, match="repro.api.function"):
        e = s.launch(None, [const(x), out(y)], name="K", cost_s=1e-4)
    s.sync()
    assert e.kind is ElementKind.KERNEL
    assert e.fn_key is None                 # legacy launches carry no identity


# ----------------------------------------------------------------------
# ManagedArray host-write ownership regression (satellite bugfix)
# ----------------------------------------------------------------------

def test_write_on_never_transferred_array_keeps_location_bits():
    s = sim()
    x = s.array(np.zeros(16, np.float32), name="x")
    x.write(np.ones(16, np.float32))
    assert x.host_valid and not x.device_valid
    assert x.device_id is None              # nothing to go stale
    x[0] = 3.0
    assert x.host_valid and not x.device_valid and x.device_id is None


def test_write_after_d2d_clears_stale_ownership():
    """x migrates dev0 -> dev1 (D2D moves ownership), then the host writes
    it: no device owns a valid copy anymore, so device_id must clear —
    a stale id previously mis-keyed capture slot-state matching and the
    migrate stage's ownership claims."""
    s = make_scheduler("parallel", simulate=True, num_devices=2,
                       placement="round-robin")
    f = gr.function(None, modes=("inout",), name="K", cost_s=1e-4)
    x = s.array(np.zeros(32, np.float32), name="x")
    f.with_options(device=0)(x, scheduler=s)       # prefetch + run on dev0
    assert (x.device_valid, x.device_id) == (True, 0)
    f.with_options(device=1)(x, scheduler=s)       # D2D migrates to dev1
    assert (x.device_valid, x.device_id) == (True, 1)
    d2d_before = s.d2d_transfers
    assert d2d_before == 1
    s.sync()
    x.write(np.ones(32, np.float32))               # host overwrite
    assert x.host_valid and not x.device_valid
    assert x.device_id is None                     # regression: was stale 1
    # Re-running on dev0 must H2D-prefetch (fresh host data), not D2D the
    # dead device copy.
    f.with_options(device=0)(x, scheduler=s)
    s.sync()
    assert s.d2d_transfers == d2d_before
    assert (x.device_valid, x.device_id) == (True, 0)


def test_write_keeps_capture_slot_state_stable_across_episodes():
    """The trainer's write-then-launch pattern: after the fix, the re-written
    array presents the same slot state every episode, so one recorded plan
    keeps replaying instead of re-recording per episode."""
    s = sim()
    f = gr.function(None, modes=("const", "out"), name="STEP", cost_s=1e-4)
    x = s.array(np.zeros(32, np.float32), name="x")
    for i in range(4):
        x.write(np.full(32, float(i), np.float32))
        y = s.array(shape=(32,), dtype=np.float32, name=f"y{i}")
        with s.capture("step"):
            f(x, y, scheduler=s)
        s.sync()
    st = s.stats()
    # Without clearing device_id on write, episode 1 re-records (x presents
    # a stale device_id=0 the recorded slot never had).
    assert st["plan_records"] == 1
    assert st["plan_replays"] == 3
