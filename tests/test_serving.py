"""ServingEngine: batched request serving through the scheduler, plus
metamorphic properties of the overlap metrics."""
import numpy as np
import pytest
from _hypothesis_fallback import given, settings, st

import jax

from repro.configs import get_config
from repro.core.timeline import Timeline
from repro.models import init_lm
from repro.runtime.serving import ServingEngine


def test_serving_engine_batches_and_matches_direct_decode():
    cfg = get_config("qwen2_moe_a2_7b", reduced=True)
    params = init_lm(jax.random.PRNGKey(0), cfg)
    eng = ServingEngine(cfg, params, batch_size=2, max_new_tokens=6)
    try:
        rng = np.random.RandomState(0)
        reqs = [eng.submit(rng.randint(0, cfg.vocab, 16)) for _ in range(5)]
        eng.flush()
        done = eng.collect()
        assert len(done) == 5
        assert all(r.result is not None and r.result.shape == (6,)
                   for r in done)
        # independent batches got distinct lanes (space-sharing)
        assert eng.stats()["lanes_created"] >= 2

        # same prompt twice -> identical greedy generations
        p = rng.randint(0, cfg.vocab, 16)
        a, b = eng.submit(p), eng.submit(p)
        eng.flush()
        eng.collect()
        np.testing.assert_array_equal(a.result, b.result)
    finally:
        eng.sched.shutdown()


# ----------------------------------------------------------------------
# Multi-tenant QoS: weighted-fair batch assembly + tagged launches
# ----------------------------------------------------------------------

def _engine_shell(batch=2):
    """ServingEngine shell without model compilation: enough state for
    submit()/flush() ordering tests (issue step stubbed per-test)."""
    from repro.core import make_scheduler
    eng = ServingEngine.__new__(ServingEngine)
    eng.batch = batch
    eng.max_new = 4
    eng.sched = make_scheduler("parallel", simulate=True)
    eng.capture = False
    eng._queue = __import__("collections").deque()
    eng._rid = 0
    eng._pending = []
    return eng


def test_weighted_fair_batch_assembly_order():
    """Stride scheduling: a priority-3 tenant (weight 8) issues all its
    batches before the priority-0 tenant's second batch, but the first
    slot still honours the shared virtual-time floor (no starvation)."""
    eng = _engine_shell(batch=2)
    order = []
    eng._issue_batch = lambda plen, ntok, tenant, prio, group: \
        order.append((tenant, len(group)))
    rng = np.random.RandomState(0)
    for _ in range(6):      # 3 bulk batches
        eng.submit(rng.randint(0, 100, 8), 4, tenant="bulk", priority=0)
    for _ in range(6):      # 3 latency batches
        eng.submit(rng.randint(0, 100, 8), 4, tenant="lat", priority=3)
    eng.flush()
    assert order == [("bulk", 2), ("lat", 2), ("lat", 2), ("lat", 2),
                     ("bulk", 2), ("bulk", 2)]
    # Virtual time is per-flush: a fresh flush starts both tenants level
    # (no stale debt, no unbounded burst for a returning tenant).
    order.clear()
    for _ in range(2):
        eng.submit(rng.randint(0, 100, 8), 4, tenant="bulk", priority=0)
        eng.submit(rng.randint(0, 100, 8), 4, tenant="lat", priority=3)
    eng.flush()
    assert order == [("bulk", 2), ("lat", 2)]


def test_tenant_high_priority_batch_issues_before_its_own_low():
    """Within one tenant, the ready queue is priority-ordered: a priority-3
    batch never waits behind the tenant's own priority-0 batch (and the
    stride charge uses the high-priority weight first)."""
    eng = _engine_shell(batch=2)
    order = []
    eng._issue_batch = lambda plen, ntok, tenant, prio, group: \
        order.append(prio)
    rng = np.random.RandomState(2)
    eng.submit(rng.randint(0, 100, 8), 4, tenant="m", priority=0)
    eng.submit(rng.randint(0, 100, 16), 4, tenant="m", priority=3)
    eng.flush()
    assert order == [3, 0]


def test_weighted_fair_keeps_shape_batches_intact():
    """Grouping by (shape, tenant, priority) must not mix tenants or
    shapes inside one batch."""
    eng = _engine_shell(batch=2)
    seen = []
    eng._issue_batch = lambda plen, ntok, tenant, prio, group: \
        seen.append((plen, ntok, tenant, prio,
                     [r.tenant for r in group], [len(r.tokens) for r in group]))
    rng = np.random.RandomState(1)
    eng.submit(rng.randint(0, 100, 8), 4, tenant="a", priority=0)
    eng.submit(rng.randint(0, 100, 16), 4, tenant="a", priority=0)
    eng.submit(rng.randint(0, 100, 8), 4, tenant="b", priority=1)
    eng.flush()
    assert len(seen) == 3                      # no cross-shape/tenant merge
    for plen, ntok, tenant, _prio, tenants, plens in seen:
        assert all(t == tenant for t in tenants)
        assert all(p == plen for p in plens)


def test_serving_two_tenants_end_to_end():
    """Full engine with two tenants: results stay correct, launches carry
    the tags, and per-tenant stats are reported."""
    cfg = get_config("qwen2_moe_a2_7b", reduced=True)
    params = init_lm(jax.random.PRNGKey(0), cfg)
    eng = ServingEngine(cfg, params, batch_size=2, max_new_tokens=4)
    try:
        rng = np.random.RandomState(0)
        p = rng.randint(0, cfg.vocab, 12)
        a = eng.submit(p, tenant="lat", priority=3)
        b = eng.submit(p, tenant="bulk", priority=0)
        eng.flush()
        eng.collect()
        # Same prompt, same greedy decode — tenancy must not change results.
        np.testing.assert_array_equal(a.result, b.result)
        ts = eng.tenant_stats()
        assert {"lat", "bulk"} <= set(ts)
        assert ts["lat"]["elements"] > 0 and ts["bulk"]["elements"] > 0
    finally:
        eng.sched.shutdown()


# ----------------------------------------------------------------------
# metamorphic properties of the overlap accounting (Fig. 10 math)
# ----------------------------------------------------------------------

@st.composite
def timelines(draw):
    tl = Timeline()
    n = draw(st.integers(2, 12))
    for i in range(n):
        t0 = draw(st.floats(0, 10))
        dur = draw(st.floats(0.01, 3))
        kind = draw(st.sampled_from(["compute", "h2d", "d2h"]))
        tl.record(i, f"s{i}", kind, i % 3, t0, t0 + dur)
    return tl


@settings(max_examples=50, deadline=None)
@given(timelines(), st.floats(0.1, 100))
def test_overlap_metrics_shift_invariant(tl, shift):
    """Translating every span in time must not change any overlap metric."""
    base = tl.overlap_metrics()
    tl2 = Timeline()
    for s in tl.spans:
        tl2.record(s.uid, s.name, s.kind, s.lane, s.t0 + shift, s.t1 + shift)
    shifted = tl2.overlap_metrics()
    for k in base:
        assert base[k] == pytest.approx(shifted[k], abs=1e-9)


@settings(max_examples=50, deadline=None)
@given(timelines())
def test_overlap_metrics_bounded_and_consistent(tl):
    m = tl.overlap_metrics()
    for k, v in m.items():
        assert -1e-9 <= v <= 1 + 1e-9, (k, v)
    comp = [s for s in tl.spans if s.kind == "compute"]
    xfer = [s for s in tl.spans if s.kind in ("h2d", "d2h")]
    if not xfer:
        assert m["CT"] == 0 and m["TC"] == 0
    if len(comp) < 2:
        assert m["CC"] == 0
