"""ServingEngine: batched request serving through the scheduler, plus
metamorphic properties of the overlap metrics."""
import numpy as np
import pytest
from _hypothesis_fallback import given, settings, st

import jax

from repro.configs import get_config
from repro.core.timeline import Timeline
from repro.models import init_lm
from repro.runtime.serving import ServingEngine


def test_serving_engine_batches_and_matches_direct_decode():
    cfg = get_config("qwen2_moe_a2_7b", reduced=True)
    params = init_lm(jax.random.PRNGKey(0), cfg)
    eng = ServingEngine(cfg, params, batch_size=2, max_new_tokens=6)
    try:
        rng = np.random.RandomState(0)
        reqs = [eng.submit(rng.randint(0, cfg.vocab, 16)) for _ in range(5)]
        eng.flush()
        done = eng.collect()
        assert len(done) == 5
        assert all(r.result is not None and r.result.shape == (6,)
                   for r in done)
        # independent batches got distinct lanes (space-sharing)
        assert eng.stats()["lanes_created"] >= 2

        # same prompt twice -> identical greedy generations
        p = rng.randint(0, cfg.vocab, 16)
        a, b = eng.submit(p), eng.submit(p)
        eng.flush()
        eng.collect()
        np.testing.assert_array_equal(a.result, b.result)
    finally:
        eng.sched.shutdown()


# ----------------------------------------------------------------------
# metamorphic properties of the overlap accounting (Fig. 10 math)
# ----------------------------------------------------------------------

@st.composite
def timelines(draw):
    tl = Timeline()
    n = draw(st.integers(2, 12))
    for i in range(n):
        t0 = draw(st.floats(0, 10))
        dur = draw(st.floats(0.01, 3))
        kind = draw(st.sampled_from(["compute", "h2d", "d2h"]))
        tl.record(i, f"s{i}", kind, i % 3, t0, t0 + dur)
    return tl


@settings(max_examples=50, deadline=None)
@given(timelines(), st.floats(0.1, 100))
def test_overlap_metrics_shift_invariant(tl, shift):
    """Translating every span in time must not change any overlap metric."""
    base = tl.overlap_metrics()
    tl2 = Timeline()
    for s in tl.spans:
        tl2.record(s.uid, s.name, s.kind, s.lane, s.t0 + shift, s.t1 + shift)
    shifted = tl2.overlap_metrics()
    for k in base:
        assert base[k] == pytest.approx(shifted[k], abs=1e-9)


@settings(max_examples=50, deadline=None)
@given(timelines())
def test_overlap_metrics_bounded_and_consistent(tl):
    m = tl.overlap_metrics()
    for k, v in m.items():
        assert -1e-9 <= v <= 1 + 1e-9, (k, v)
    comp = [s for s in tl.spans if s.kind == "compute"]
    xfer = [s for s in tl.spans if s.kind in ("h2d", "d2h")]
    if not xfer:
        assert m["CT"] == 0 and m["TC"] == 0
    if len(comp) < 2:
        assert m["CC"] == 0
