"""Tiered spill hierarchy (ISSUE 6): the pluggable BackingTier stack.

Covers the tier stack end to end: construction (``make_tiers`` names /
instances / errors), the bit-identical no-tiers default, the peer-device
tier's strict sim-makespan win over flat D2H, physical round trips on the
real executor for every tier (disk and lossless-compressed bit-exact,
bf16 within its designed bound), stack ordering (capacity overflow to the
next tier), spool-file hygiene (shutdown + GC), the ``verify()`` debug
hook, capture/replay under a tier stack and checkpoint
snapshot-through-spill (hard-linked disk payloads, tier-read compressed
payloads, exact restore).
"""
import gc
import glob
import os

import numpy as np
import pytest

from repro.benchsuite.outofcore import (build_outofcore, verify_outofcore,
                                        working_set_bytes)
from repro.checkpoint import CheckpointManager
from repro.core import (BackingTier, CompressedHostTier, DiskTier, ElementKind,
                        PeerDeviceTier, function, make_scheduler)
from repro.core.tiers import make_tiers

N = 256
CHUNK = 4 * N

STAGE = function(lambda x, o: x * 2.0 + 1.0, modes=("const", "out"),
                 name="tier_stage", outputs=0)


def _stage(sched, cost_s=1e-4):
    return STAGE.with_options(scheduler=sched, cost_s=cost_s)


def _tiered_outofcore(tiers, *, simulate, chunks=6, n=N, cost_s=1e-4,
                      num_devices=1, device=None, budget=None):
    if budget is None:
        budget = working_set_bytes(chunks, n) // 2
    s = make_scheduler("parallel", simulate=simulate, num_devices=num_devices,
                       memory_budget=budget, spill_tiers=tiers)
    arrays = build_outofcore(s, chunks=chunks, n=n, cost_s=cost_s,
                             device=device)
    return s, arrays


# ======================================================================
# Construction and the flat default
# ======================================================================

def test_make_tiers_accepts_names_instances_and_rejects_junk():
    tiers = make_tiers(["peer-device", CompressedHostTier(lossy=True), "disk"])
    assert [t.name for t in tiers] == ["peer-device", "compressed-host",
                                      "disk"]
    assert tiers[1].lossy
    assert make_tiers(None) == []
    with pytest.raises(ValueError, match="unknown spill tier"):
        make_tiers(["nvme-of"])
    with pytest.raises(TypeError):
        make_tiers([42])
    tiers[2].close()                   # remove the spool dir it created


def test_no_tiers_default_is_bit_identical_flat_d2h():
    """``spill_tiers=None`` (and ``[]``) must execute the exact PR 5
    schedule: identical timeline spans, identical memory stats, no
    ``mem_tiers`` key, every EVICT on the D2H engine."""
    def run(**kw):
        s = make_scheduler("parallel", simulate=True,
                           memory_budget=working_set_bytes(6, N) // 2, **kw)
        build_outofcore(s, chunks=6, n=N, cost_s=1e-4)
        s.sync()
        spans = [(sp.name, sp.kind, sp.lane, sp.t0, sp.t1)
                 for sp in s.timeline.spans]
        stats = {k: v for k, v in s.stats().items() if k.startswith("mem_")}
        return spans, stats
    spans_default, st_default = run()
    spans_none, st_none = run(spill_tiers=None)
    spans_empty, st_empty = run(spill_tiers=[])
    assert spans_default == spans_none == spans_empty
    assert st_default == st_none == st_empty
    assert "mem_tiers" not in st_default
    evict_kinds = {k for name, k, *_ in spans_default
                   if name.startswith("evict_")}
    assert evict_kinds == {"d2h"}


def test_stack_miss_falls_back_to_flat_d2h():
    """A stack whose every tier refuses (capacity 0) behaves like flat
    D2H: no tier residency, plain EVICT write-backs."""
    tier = CompressedHostTier(capacity_bytes=0)
    s, arrays = _tiered_outofcore([tier], simulate=True)
    s.sync()
    st = s.stats()
    assert st["mem_spills"] >= 1
    assert st["mem_tiers"]["compressed-host"]["spills"] == 0
    assert all(a.backing_tier is None
               for a in arrays["x"] + arrays["y"] + arrays["z"])
    assert s.memory.verify().ok


# ======================================================================
# Peer-device tier: sim makespan acceptance
# ======================================================================

def test_peer_tier_sim_strictly_beats_flat_d2h():
    """The ISSUE acceptance: out-of-core with a peer tier beats flat D2H
    on simulated makespan (D2D at 50 GB/s vs PCIe at 12 GB/s), with the
    spilled blocks parked device-resident on the idle peer."""
    kw = dict(simulate=True, chunks=6, n=1 << 16, cost_s=1e-5,
              num_devices=2, device=0,
              budget={0: working_set_bytes(6, 1 << 16) // 2, 1: None})
    s_flat, _ = _tiered_outofcore(None, **kw)
    s_flat.sync()
    s_peer, arrays = _tiered_outofcore([PeerDeviceTier()], **kw)
    s_peer.sync()
    assert s_peer.timeline.makespan < s_flat.timeline.makespan
    tstats = s_peer.stats()["mem_tiers"]["peer-device"]
    assert tstats["spills"] >= 1 and tstats["wire_bytes"] > 0
    # Peer spills ran on the D2D link, not the D2H engine.
    assert any(sp.kind == "d2d" and sp.name.startswith("evict_")
               for sp in s_peer.timeline.spans)
    # Peer-parked blocks are device-resident (no backing_tier: the migrate
    # stage brings them back with a plain D2D).
    assert all(a.backing_tier is None
               for a in arrays["x"] + arrays["y"] + arrays["z"])
    assert s_peer.memory.verify().ok


def test_peer_tier_refuses_without_budget_room():
    """A peer with no free budget never accepts — spills must not cascade."""
    s = make_scheduler("parallel", simulate=True, num_devices=2,
                       memory_budget={0: 2 * CHUNK, 1: CHUNK},
                       spill_tiers=[PeerDeviceTier()])
    stage = STAGE.with_options(scheduler=s, cost_s=1e-4, device=0)
    xs = [s.array(np.ones(N, np.float32), name=f"pr_{i}") for i in range(3)]
    for x in xs:
        stage(x)
    s.sync()
    # Device 1's budget (1 chunk) can never hold a spill while also being
    # eligible: anything routed there would exceed its budget.
    assert s.memory.pools[1].resident_bytes <= CHUNK
    assert s.memory.verify().ok


# ======================================================================
# Physical round trips on the real executor
# ======================================================================

def test_disk_tier_real_roundtrip_bit_exact(tmp_path):
    tier = DiskTier(spool_dir=str(tmp_path))
    s, arrays = _tiered_outofcore([tier], simulate=False)
    try:
        assert verify_outofcore(arrays)
        s.sync()
        st = s.stats()["mem_tiers"]["disk"]
        assert st["spills"] >= 1 and tier.files_written >= 1
        # Bit-exact: the closed form in float32 exactly.
        for x, z in zip(arrays["x"], arrays["z"]):
            expect = np.asarray(x.host, np.float32) * 4.0 + 3.0
            np.testing.assert_array_equal(np.asarray(z), expect)
        assert s.memory.verify().ok
    finally:
        s.shutdown()
    # Satellite 2: no leaked spool files after shutdown.
    assert glob.glob(os.path.join(str(tmp_path), "blk_*")) == []


def test_compressed_lossless_real_roundtrip_bit_exact():
    tier = CompressedHostTier(lossy=False)
    s, arrays = _tiered_outofcore([tier], simulate=False)
    try:
        assert verify_outofcore(arrays)
        s.sync()
        st = s.stats()["mem_tiers"]["compressed-host"]
        assert st["spills"] >= 1 and not st["lossy"]
        for x, z in zip(arrays["x"], arrays["z"]):
            expect = np.asarray(x.host, np.float32) * 4.0 + 3.0
            np.testing.assert_array_equal(np.asarray(z), expect)
        assert s.memory.verify().ok
    finally:
        s.shutdown()


def test_compressed_lossy_real_roundtrip_within_bf16_bound():
    """bf16 demotion: exact only to the tier's reported ``max_abs_error``
    bound (~2^-8 relative), never bit-exact — the exactness flag is the
    contract."""
    tier = CompressedHostTier(lossy=True)
    s, arrays = _tiered_outofcore([tier], simulate=False)
    try:
        s.sync()
        st = s.stats()["mem_tiers"]["compressed-host"]
        assert st["lossy"] and st["lossy_blocks"] >= 1
        bound = st["max_abs_error"]
        assert 0.0 < bound < 0.05
        for x, z in zip(arrays["x"], arrays["z"]):
            expect = np.asarray(x.host, np.float32) * 4.0 + 3.0
            # One lossy hop per value at most (y spilled, z = 2*y + 1).
            assert np.max(np.abs(np.asarray(z) - expect)) <= 2 * bound + 1e-7
        assert s.memory.verify().ok
    finally:
        s.shutdown()


def test_peer_tier_real_roundtrip_bit_exact():
    tier = PeerDeviceTier()
    s, arrays = _tiered_outofcore([tier], simulate=False, num_devices=2,
                                  device=0,
                                  budget={0: working_set_bytes(6, N) // 2,
                                          1: None})
    try:
        assert verify_outofcore(arrays)
        for x, z in zip(arrays["x"], arrays["z"]):
            expect = np.asarray(x.host, np.float32) * 4.0 + 3.0
            np.testing.assert_array_equal(np.asarray(z), expect)
        s.sync()
        assert s.stats()["mem_tiers"]["peer-device"]["spills"] >= 1
        assert s.memory.verify().ok
    finally:
        s.shutdown()


def test_host_read_restores_through_tier():
    """``ma.read()`` of a tier-resident block must decode the payload
    synchronously (host access localization through the tier)."""
    tier = CompressedHostTier(lossy=False)
    s = make_scheduler("parallel", memory_budget=2 * CHUNK,
                       spill_tiers=[tier])
    try:
        x = s.array(np.full(N, 2.0, np.float32), name="hr_x")
        y = _stage(s)(x)                     # dirty device-only output
        x2 = s.array(np.full(N, 5.0, np.float32), name="hr_x2")
        _stage(s)(x2)                        # pressure: y spilled to tier
        s.sync()
        assert y.backing_tier == "compressed-host"
        np.testing.assert_array_equal(y.read(), np.full(N, 5.0, np.float32))
        assert y.backing_tier is None and y.host_valid
        assert s.memory.verify().ok
    finally:
        s.shutdown()


# ======================================================================
# Stack ordering, capacity overflow, hygiene
# ======================================================================

def test_stack_overflows_to_next_tier(tmp_path):
    """First-accepting-tier-wins: a capacity-bounded compressed tier takes
    blocks until full, the rest overflow to disk."""
    comp = CompressedHostTier(lossy=False, capacity_bytes=CHUNK)
    disk = DiskTier(spool_dir=str(tmp_path))
    s, arrays = _tiered_outofcore([comp, disk], simulate=True, chunks=8)
    s.sync()
    st = s.stats()["mem_tiers"]
    assert st["compressed-host"]["spills"] >= 1
    assert st["disk"]["spills"] >= 1
    assert st["compressed-host"]["spilled_bytes_resident"] <= CHUNK
    assert s.memory.verify().ok
    s.shutdown()


def test_disk_spool_removed_on_gc(tmp_path):
    """Satellite 2: a tier-resident block that becomes garbage must drop
    its spool file via the weakref finalizer — no leaks between spill and
    shutdown."""
    tier = DiskTier(spool_dir=str(tmp_path))
    s = make_scheduler("parallel", memory_budget=2 * CHUNK,
                       spill_tiers=[tier])
    try:
        x = s.array(np.ones(N, np.float32), name="gc_x")
        y = _stage(s)(x)
        x2 = s.array(np.ones(N, np.float32), name="gc_x2")
        _stage(s)(x2)                        # y spilled to disk
        s.sync()
        assert y.backing_tier == "disk"
        assert len(glob.glob(os.path.join(str(tmp_path), "blk_*"))) == 1
        del y
        gc.collect()
        assert glob.glob(os.path.join(str(tmp_path), "blk_*")) == []
        assert s.memory.verify().ok
    finally:
        s.shutdown()


def test_disk_own_spool_dir_removed_on_shutdown():
    tier = DiskTier()
    spool = tier.spool_dir
    assert os.path.isdir(spool)
    s, arrays = _tiered_outofcore([tier], simulate=False)
    s.shutdown()
    assert not os.path.exists(spool)


def test_pool_occupancy_and_verify_hook():
    """Satellite 1: ``MemoryPool.stats()`` exposes occupancy, scheduler
    stats aggregate it, per-tier ``spilled_bytes_resident`` is reported
    and ``verify()`` is clean after a tiered workload."""
    tier = CompressedHostTier(lossy=False)
    s, arrays = _tiered_outofcore([tier], simulate=True)
    s.sync()
    pstats = s.memory.pools[0].stats()
    assert 0.0 <= pstats["occupancy"] <= 1.0
    st = s.stats()
    assert 0.0 <= st["mem_occupancy"] <= 1.0
    tstats = st["mem_tiers"]["compressed-host"]
    assert tstats["spilled_bytes_resident"] == sum(
        a.nbytes for a in arrays["x"] + arrays["y"] + arrays["z"]
        if a.backing_tier == "compressed-host")
    assert s.memory.verify().ok
    # The unbounded default reports occupancy 0 (nothing to fill).
    s2 = make_scheduler("parallel", simulate=True)
    assert s2.memory.pools[0].stats()["occupancy"] == 0.0


# ======================================================================
# Capture/replay under a tier stack
# ======================================================================

def test_capture_replays_tier_spills():
    """A captured episode that spills to a tier must replay (same tier
    residency at episode entry) and keep the tier bookkeeping exact."""
    tier = CompressedHostTier(lossy=False)
    s = make_scheduler("parallel", memory_budget=2 * CHUNK,
                       spill_tiers=[tier])
    try:
        outs = []
        for ep in range(3):
            with s.capture("tier_ep"):
                # Second allocation forces the first (dirty, non-frontier)
                # output onto the tier *inside* the episode, so the plan
                # records a tier EVICT.
                x = s.array(np.full(N, float(ep), np.float32),
                            name=f"tc{ep}_a")
                y = _stage(s)(x)
                x2 = s.array(np.full(N, float(ep + 10), np.float32),
                             name=f"tc{ep}_b")
                y2 = _stage(s)(x2)
                outs.append((y, y2))
            s.sync()
        st = s.stats()
        assert st["plan_records"] == 1 and st["plan_replays"] == 2
        (plan,) = s.plan_cache.candidates("tier_ep")
        evict_cfgs = [cfg for pe, cfg in zip(plan.elements, plan.configs)
                      if pe.kind is ElementKind.EVICT]
        assert any(cfg.get("tier") == "compressed-host"
                   for cfg in evict_cfgs)
        for ep, (y, y2) in enumerate(outs):
            np.testing.assert_array_equal(
                y.read(), np.full(N, 2.0 * ep + 1.0, np.float32))
            np.testing.assert_array_equal(
                y2.read(), np.full(N, 2.0 * (ep + 10) + 1.0, np.float32))
        assert s.memory.verify().ok
    finally:
        s.shutdown()


# ======================================================================
# Snapshot-through-spill (checkpoint integration)
# ======================================================================

def test_save_managed_hard_links_disk_spills(tmp_path):
    """A disk-resident block is checkpointed by hard-linking the published
    spool file — zero data movement — and restores bit-exact; the spill
    stays resident (the checkpoint is a copy-on-write reference)."""
    tier = DiskTier(spool_dir=str(tmp_path / "spool"))
    s = make_scheduler("parallel", memory_budget=2 * CHUNK,
                       spill_tiers=[tier])
    mgr = CheckpointManager(str(tmp_path / "ckpt"), async_save=False)
    try:
        x = s.array(np.arange(N, dtype=np.float32), name="sl_x")
        y = _stage(s)(x)
        x2 = s.array(np.ones(N, np.float32), name="sl_x2")
        y2 = _stage(s)(x2)                   # y spilled to disk
        s.sync()
        assert y.backing_tier == "disk"
        expect_y = np.arange(N, dtype=np.float32) * 2.0 + 1.0
        stats = mgr.save_managed(7, {"y": y, "y2": y2})
        assert stats["spill_links"] == 1
        assert stats["spill_link_bytes"] == y.nbytes
        assert y.backing_tier == "disk"      # spill undisturbed
        # The link shares the spool inode (metadata-only snapshot).
        ckpt_file = os.path.join(str(tmp_path / "ckpt"), "step_7", "y.npy")
        from repro.core.element import dep_key
        spool_file = tier.path_for(dep_key(y))
        assert os.path.samefile(ckpt_file, spool_file)
        # Restore into fresh arrays: bit-exact through the link.
        ny = s.array(np.zeros(N, np.float32), name="sl_ny")
        ny2 = s.array(np.zeros(N, np.float32), name="sl_ny2")
        mgr.restore_managed({"y": ny, "y2": ny2}, step=7)
        np.testing.assert_array_equal(ny.read(), expect_y)
        np.testing.assert_array_equal(ny2.read(), np.full(N, 3.0, np.float32))
        assert s.memory.verify().ok
    finally:
        s.shutdown()


def test_save_managed_reads_compressed_tier_nondestructively():
    tier = CompressedHostTier(lossy=False)
    s = make_scheduler("parallel", memory_budget=2 * CHUNK,
                       spill_tiers=[tier])
    import tempfile
    ckpt_dir = tempfile.mkdtemp(prefix="grjax_ckpt_")
    mgr = CheckpointManager(ckpt_dir, async_save=False)
    try:
        x = s.array(np.arange(N, dtype=np.float32), name="tr_x")
        y = _stage(s)(x)
        x2 = s.array(np.ones(N, np.float32), name="tr_x2")
        _stage(s)(x2)                        # y spilled (compressed)
        s.sync()
        assert y.backing_tier == "compressed-host"
        stats = mgr.save_managed(1, {"y": y})
        assert stats["tier_reads"] == 1 and stats["spill_links"] == 0
        assert y.backing_tier == "compressed-host"   # peek, not reload
        ny = s.array(np.zeros(N, np.float32), name="tr_ny")
        mgr.restore_managed({"y": ny}, step=1)
        np.testing.assert_array_equal(
            ny.read(), np.arange(N, dtype=np.float32) * 2.0 + 1.0)
        assert s.memory.verify().ok
    finally:
        s.shutdown()
        import shutil
        shutil.rmtree(ckpt_dir, ignore_errors=True)
