"""Per-architecture smoke tests (reduced configs, CPU) + decode/cache
consistency against the full-sequence forward."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import (cross_entropy_loss, forward_decode, forward_prefill,
                          forward_train, init_cache, init_lm)

B, S = 2, 32


def make_batch(cfg, key, seq=S):
    batch = {"tokens": jax.random.randint(key, (B, seq), 0, cfg.vocab)}
    if cfg.n_encoder_layers:
        batch["frames"] = jax.random.normal(key, (B, seq // 4, cfg.d_model))
    if cfg.frontend == "vision":
        batch["patches"] = jax.random.normal(
            key, (B, cfg.n_frontend_tokens, cfg.d_model)) * 0.02
    return batch


@pytest.fixture(scope="module")
def key():
    return jax.random.PRNGKey(0)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_shapes_and_finite(arch, key):
    cfg = get_config(arch, reduced=True)
    params = init_lm(key, cfg)
    batch = make_batch(cfg, key)
    logits, aux = forward_train(cfg, params, batch)
    assert logits.shape == (B, S, cfg.vocab)
    assert logits.dtype == jnp.float32
    assert np.all(np.isfinite(np.asarray(logits)))
    loss = cross_entropy_loss(logits, batch["tokens"])
    assert np.isfinite(float(loss))
    if cfg.moe:
        assert float(aux["moe_aux"]) > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_decode_finite(arch, key):
    cfg = get_config(arch, reduced=True)
    params = init_lm(key, cfg)
    batch = make_batch(cfg, key)
    cross = S // 4 if cfg.n_encoder_layers else 0
    cache = init_cache(cfg, B, S + 4, cross_len=cross)
    lg, cache = forward_prefill(cfg, params, batch, cache)
    assert lg.shape == (B, cfg.vocab)
    nxt = jnp.argmax(lg, -1)[:, None].astype(jnp.int32)
    lg2, _ = forward_decode(cfg, params, nxt, cache, jnp.int32(S))
    assert lg2.shape == (B, cfg.vocab)
    assert np.all(np.isfinite(np.asarray(lg2)))


@pytest.mark.parametrize("arch", ["gemma3_12b", "rwkv6_1_6b", "hymba_1_5b",
                                  "qwen2_moe_a2_7b", "starcoder2_15b"])
def test_decode_matches_full_forward(arch, key):
    """Autoregressive consistency: logits from prefill(S)+decode(token S)
    must equal the full forward over S+1 tokens at the last position.
    Validates KV-cache indexing, RWKV/Mamba state carrying and sliding
    windows in one shot."""
    cfg = get_config(arch, reduced=True)
    params = init_lm(key, cfg)
    seq = S + 1
    batch_full = make_batch(cfg, key, seq=seq)
    logits_full, _ = forward_train(cfg, params, batch_full)

    batch_prefix = {k: (v[:, :S] if k == "tokens" else v)
                    for k, v in batch_full.items()}
    cache = init_cache(cfg, B, seq + 4)
    lg_prefill, cache = forward_prefill(cfg, params, batch_prefix, cache)
    np.testing.assert_allclose(np.asarray(lg_prefill),
                               np.asarray(logits_full[:, S - 1]),
                               rtol=2e-2, atol=2e-2)

    last_tok = batch_full["tokens"][:, S:S + 1]
    lg_decode, _ = forward_decode(cfg, params, last_tok, cache, jnp.int32(S))
    np.testing.assert_allclose(np.asarray(lg_decode),
                               np.asarray(logits_full[:, S]),
                               rtol=2e-2, atol=2e-2)


@pytest.mark.parametrize("arch", ["qwen3_32b", "dbrx_132b", "rwkv6_1_6b",
                                  "hymba_1_5b", "seamless_m4t_medium"])
def test_gradients_finite(arch, key):
    cfg = get_config(arch, reduced=True)
    params = init_lm(key, cfg)
    batch = make_batch(cfg, key)

    def loss_fn(p):
        logits, aux = forward_train(cfg, p, batch)
        return (cross_entropy_loss(logits, batch["tokens"])
                + aux["moe_aux"] + aux["moe_z"])

    grads = jax.grad(loss_fn)(params)
    leaves = jax.tree_util.tree_leaves(grads)
    assert leaves
    for g in leaves:
        assert np.all(np.isfinite(np.asarray(g, dtype=np.float32)))
    # at least the embedding must receive signal
    assert float(jnp.max(jnp.abs(grads["embed"]))) > 0


def test_param_counts_match_published_sizes():
    """Config-derived parameter counts should land near the models' names."""
    expect = {
        "gemma3_12b": (10e9, 14e9),
        "starcoder2_15b": (14e9, 18e9),
        "qwen3_32b": (30e9, 35e9),
        "nemotron_4_340b": (320e9, 360e9),
        "dbrx_132b": (125e9, 140e9),
        "rwkv6_1_6b": (1.3e9, 1.9e9),
        "internvl2_76b": (65e9, 80e9),
        "hymba_1_5b": (1.1e9, 1.9e9),
    }
    for arch, (lo, hi) in expect.items():
        n = get_config(arch).param_count()
        assert lo <= n <= hi, f"{arch}: {n/1e9:.1f}B outside [{lo/1e9},{hi/1e9}]"
    # MoE active counts
    assert get_config("dbrx_132b").param_count(True) < 45e9
    assert get_config("qwen2_moe_a2_7b").param_count(True) < 4e9


def test_long_context_eligibility():
    from repro.configs import cells
    eligible = {a: get_config(a).subquadratic for a in ARCH_IDS}
    assert eligible["rwkv6_1_6b"] and eligible["hymba_1_5b"]
    assert eligible["gemma3_12b"]           # 5:1 local:global
    assert not eligible["qwen3_32b"] and not eligible["nemotron_4_340b"]
    skips = [reason for _, reason in cells("qwen3_32b") if reason]
    assert len(skips) == 1 and "full-attention" in skips[0]
