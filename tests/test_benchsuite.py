"""Paper benchmark suite: correctness under async scheduling + the paper's
headline claims (always faster than serial; parity with the oracle)."""
import numpy as np
import pytest

from repro.benchsuite import BENCHMARKS, GPUS, GTX1660S
from repro.benchsuite.costmodel import sim_hardware
from repro.core import make_scheduler

TINY = 2e-5
NAMES = sorted(BENCHMARKS)


@pytest.mark.parametrize("name", NAMES)
def test_parallel_execution_correct(name):
    b = BENCHMARKS[name]
    data = b.make_data(TINY)
    s = make_scheduler("parallel")
    try:
        got = b.build(s, data, gpu=None, iters=2)
        ref = b.run_reference(data, iters=2)
        for k in ref:
            np.testing.assert_allclose(got[k], ref[k], rtol=2e-3, atol=1e-4,
                                       err_msg=f"{name}:{k}")
    finally:
        s.shutdown()


@pytest.mark.parametrize("name", NAMES)
def test_serial_equals_parallel(name):
    b = BENCHMARKS[name]
    data = b.make_data(TINY)

    def run(policy):
        s = make_scheduler(policy)
        try:
            return b.build(s, data, gpu=None, iters=2)
        finally:
            s.shutdown()

    ser, par = run("serial"), run("parallel")
    for k in ser:
        np.testing.assert_allclose(par[k], ser[k], rtol=1e-5, atol=1e-6)


def _makespan(bench, gpu, policy, scale=0.02, iters=4, **kw):
    s = make_scheduler(policy, simulate=True,
                       hw=sim_hardware(gpu, policy), **kw)
    bench.build(s, bench.make_data(scale), gpu=gpu, iters=iters)
    return s.timeline.makespan


@pytest.mark.parametrize("gpu_name", sorted(GPUS))
@pytest.mark.parametrize("name", NAMES)
def test_parallel_always_faster_than_serial(name, gpu_name):
    """§V-C: 'We always deliver better performance over the serial
    scheduler'."""
    b, gpu = BENCHMARKS[name], GPUS[gpu_name]
    ts = _makespan(b, gpu, "serial")
    tp = _makespan(b, gpu, "parallel")
    assert tp < ts, f"{name}/{gpu_name}: parallel {tp} !< serial {ts}"


@pytest.mark.parametrize("name", NAMES)
def test_no_slowdown_vs_oracle(name):
    """§V-D: no significant slowdown vs hand-optimized scheduling."""
    b = BENCHMARKS[name]
    tp = _makespan(b, GTX1660S, "parallel")
    to = _makespan(b, GTX1660S, "parallel", oracle=True)
    assert tp <= to * 1.02 + 1e-6, f"runtime {tp} vs oracle {to}"


def test_geomean_speedup_band():
    """Geomean speedup across benchmarks x GPUs lands in the paper's band
    (44% reported; simulator calibrated to 35-75%)."""
    vals = []
    for gpu in GPUS.values():
        for b in BENCHMARKS.values():
            vals.append(_makespan(b, gpu, "serial")
                        / _makespan(b, gpu, "parallel"))
    gm = float(np.exp(np.mean(np.log(vals))))
    assert 1.30 <= gm <= 1.80, f"geomean speedup {gm}"


def test_vec_speedup_is_pure_transfer_overlap():
    """Fig. 11: VEC has no computation-computation overlap; its win comes
    entirely from transfer/compute overlap."""
    b = BENCHMARKS["VEC"]
    s = make_scheduler("parallel", simulate=True,
                       hw=sim_hardware(GTX1660S, "parallel"))
    b.build(s, b.make_data(0.02), gpu=GTX1660S, iters=4)
    m = s.timeline.overlap_metrics()
    assert m["CC"] < 0.05
    assert m["CT"] > 0.5


def test_bs_space_shares():
    """Fig. 11: B&S overlaps its 10 independent kernels (high CC)."""
    b = BENCHMARKS["B&S"]
    s = make_scheduler("parallel", simulate=True,
                       hw=sim_hardware(GTX1660S, "parallel"))
    b.build(s, b.make_data(0.02), gpu=GTX1660S, iters=4)
    assert s.timeline.overlap_metrics()["CC"] > 0.5


def test_footprints_scale(tmp_path):
    for b in BENCHMARKS.values():
        assert b.footprint_bytes(0.02) > b.footprint_bytes(0.002)


def test_prefetch_disabled_slower():
    """§V-C: disabling automatic prefetching leaves the page-fault
    controller as the bottleneck — still faster than serial, but worse
    than prefetching."""
    b = BENCHMARKS["VEC"]
    gpu = GTX1660S

    def t(policy, prefetch):
        s = make_scheduler(policy, simulate=True,
                           hw=sim_hardware(gpu, policy, prefetch=prefetch))
        b.build(s, b.make_data(0.02), gpu=gpu, iters=4)
        return s.timeline.makespan

    t_serial = t("serial", True)
    t_par = t("parallel", True)
    t_par_nopf = t("parallel", False)
    assert t_par < t_par_nopf <= t_serial * 1.001
