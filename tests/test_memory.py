"""Device-memory manager: budgeted placement, transparent spill/evict,
out-of-core workloads — plus the ISSUE 5 satellite regressions.

Covers the MemoryPool/MemoryManager subsystem end to end: LRU accounting,
DAG-ordered EVICT elements on both executors, budget-aware placement
(refusal + the ``min-pressure`` policy), capture/replay gating on recorded
per-device peaks, the memory-conservation property (resident bytes always
equal the device-valid arrays' bytes), the forced-H2D multi-device
prefetch fix, the capture-demotion location-bit audit and the concurrent
sync-vs-launch stress test.
"""
import threading

import numpy as np
import pytest

from _hypothesis_fallback import given, settings, st
from repro.core import (DeviceOutOfMemoryError, ElementKind, MemoryPool,
                        function, make_scheduler)
from repro.benchsuite.outofcore import (build_outofcore, verify_outofcore,
                                        working_set_bytes)

N = 256
CHUNK = 4 * N

STAGE = function(lambda x, o: x * 2.0 + 1.0, modes=("const", "out"),
                 name="mem_stage", outputs=0)
STAGE2 = function(lambda a, b, o: a + b, modes=("const", "const", "out"),
                  name="mem_stage2", outputs=0)


def _stage(sched, cost_s=1e-4):
    return STAGE.with_options(scheduler=sched, cost_s=cost_s)


def _mem(sched):
    return {k: v for k, v in sched.stats().items() if k.startswith("mem_")}


def assert_conservation(sched, arrays):
    """resident_bytes per device == Σ nbytes of device-valid arrays there."""
    for d in range(sched.num_devices):
        expect = sum(a.nbytes for a in arrays
                     if a.device_valid and (a.device_id or 0) == d)
        got = sched.memory.pools[d].resident_bytes
        assert got == expect, f"device {d}: tracked {got} != actual {expect}"


def assert_stack_conservation(sched, arrays):
    """Whole-stack conservation (ISSUE 6): device pools *plus* host-side
    tier residency together account for exactly the managed bytes whose
    only valid copy the runtime is holding — device-valid arrays (peer
    spills included: they stay device-resident) and tier-backed arrays."""
    assert_conservation(sched, arrays)
    expect = sum(a.nbytes for a in arrays
                 if a.device_valid or getattr(a, "backing_tier", None))
    got = (sum(p.resident_bytes for p in sched.memory.pools)
           + sum(t.resident_bytes for t in sched.memory.tiers
                 if t.location == "host"))
    assert got == expect, f"stack: tracked {got} != actual {expect}"
    assert sched.memory.verify().ok


# ======================================================================
# MemoryPool unit behaviour
# ======================================================================

def test_pool_budget_lru_and_stats():
    p = MemoryPool(0, budget_bytes=100)
    p.add(1, 40)
    p.add(2, 40)
    assert p.resident_bytes == 80 and p.peak_bytes == 80
    p.touch(1)                       # 2 becomes LRU
    assert p.lru_keys() == [2, 1]
    assert p.discard(2) == 40
    assert p.resident_bytes == 40 and p.peak_bytes == 80
    assert p.fits(100) and not p.fits(101)
    assert MemoryPool(0).fits(1 << 60)       # unlimited


def test_pool_re_add_updates_bytes():
    p = MemoryPool(0)
    p.add(1, 10)
    p.add(1, 30)                     # same key, new size
    assert p.resident_bytes == 30


# ======================================================================
# Spill/evict on the simulator and the real executor
# ======================================================================

def test_out_of_core_sim_spills_within_budget():
    budget = working_set_bytes(6, N) // 2
    s_unl = make_scheduler("parallel", simulate=True)
    build_outofcore(s_unl, chunks=6, n=N)
    s_unl.sync()
    s = make_scheduler("parallel", simulate=True, memory_budget=budget)
    arrays = build_outofcore(s, chunks=6, n=N)
    s.sync()
    st = _mem(s)
    assert st["mem_spills"] >= 1
    assert st["mem_resident_bytes"] <= budget
    assert s.memory.pools[0].peak_bytes <= budget
    # Acceptance envelope: spill traffic must not blow up the makespan.
    assert s.timeline.makespan <= 2.0 * s_unl.timeline.makespan
    assert_conservation(s, arrays["x"] + arrays["y"] + arrays["z"])
    # Spill write-backs occupy the D2H engine on the sim timeline.
    assert any(sp.kind == "d2h" and sp.name.startswith("evict_")
               for sp in s.timeline.spans)


def test_out_of_core_real_correct_through_spills():
    budget = working_set_bytes(6, N) // 2
    s = make_scheduler("parallel", memory_budget=budget)
    try:
        arrays = build_outofcore(s, chunks=6, n=N)
        assert verify_outofcore(arrays)
        s.sync()
        st = _mem(s)
        assert st["mem_spills"] >= 1
        # The real executor actually releases spilled device buffers.
        evicted = [a for a in arrays["x"] + arrays["y"]
                   if not a.device_valid]
        assert evicted and all(a.device is None for a in evicted)
        assert_conservation(s, arrays["x"] + arrays["y"] + arrays["z"])
    finally:
        s.shutdown()


def test_unlimited_budget_never_evicts_and_matches_timeline():
    """budget=None (default) and an over-provisioned budget execute the
    identical schedule with zero spill stats."""
    def run(budget):
        s = make_scheduler("parallel", simulate=True, memory_budget=budget)
        arrays = build_outofcore(s, chunks=4, n=N)
        s.sync()
        spans = [(sp.name, sp.kind, sp.lane, sp.t0, sp.t1)
                 for sp in s.timeline.spans]
        return spans, _mem(s), arrays
    spans_none, st_none, arrays = run(None)
    spans_big, st_big, _ = run(1 << 40)
    assert spans_none == spans_big
    for st in (st_none, st_big):
        assert st["mem_spills"] == 0 and st["mem_evict_blocks"] == 0
    assert st_none["mem_peak_bytes"] == working_set_bytes(4, N)
    s = make_scheduler("parallel", simulate=True)
    assert not s.memory.bounded


def test_evict_is_dag_ordered_after_readers():
    """The EVICT element must depend on the victim's in-flight reader —
    the same transparent-transfer ordering the paper uses for H2D."""
    s = make_scheduler("parallel", simulate=True, memory_budget=2 * CHUNK)
    x = s.array(np.ones(N, np.float32), name="ev_x")
    _stage(s, cost_s=5e-3)(x)                # slow reader holds x busy
    y = s.array(np.ones(N, np.float32), name="ev_y")
    _stage(s, cost_s=1e-4)(y)                # needs 2 chunks -> evicts x
    evicts = [e for e in s._elements if e.kind is ElementKind.EVICT]
    assert len(evicts) >= 1
    victim = evicts[0]
    assert victim.args[0].array is x
    deps = {p.uid for p in victim.parents}
    # reader returned the allocated output; find the kernel element via DAG
    kernels = [e for e in s._elements if e.kind is ElementKind.KERNEL]
    assert kernels[0].uid in deps
    s.sync()
    assert not x.device_valid and x.host_valid


def test_clean_copies_drop_without_spill_traffic():
    """Arrays whose host copy is still valid are dropped, not written back:
    evict_blocks counts them, spills/spill_bytes do not."""
    s = make_scheduler("parallel", simulate=True, memory_budget=3 * CHUNK)
    xs = [s.array(np.ones(N, np.float32), name=f"cl_{i}") for i in range(3)]
    for x in xs[:2]:
        _stage(s)(x)                         # fills budget; x0 clean-evicted
    elements = list(s._elements)
    s.sync()
    st = _mem(s)
    assert st["mem_evict_blocks"] >= 1
    clean_evicts = [e for e in elements
                    if e.kind is ElementKind.EVICT and e.transfer_bytes == 0]
    dirty_evicts = [e for e in elements
                    if e.kind is ElementKind.EVICT and e.transfer_bytes > 0]
    assert st["mem_spills"] == len(dirty_evicts)
    assert st["mem_evict_blocks"] == len(clean_evicts) + len(dirty_evicts)


# ======================================================================
# Budget-aware placement
# ======================================================================

def test_placement_refuses_overbudget_device():
    """Every policy refuses a device whose budget is smaller than the
    element's working set (round-robin would otherwise alternate)."""
    s = make_scheduler("parallel", simulate=True, num_devices=2,
                       placement="round-robin",
                       memory_budget={0: CHUNK, 1: 64 * CHUNK})
    outs = []
    for i in range(4):
        x = s.array(np.ones(N, np.float32), name=f"pl_{i}")
        outs.append(_stage(s)(x))            # ws = 2 chunks > device 0 budget
    elements = list(s._elements)
    s.sync()
    assert all(e._scheduler is s for e in outs)
    kernels = [e for e in elements if e.kind is ElementKind.KERNEL]
    assert kernels and all(k.device == 1 for k in kernels)


def test_min_pressure_policy_balances_bytes():
    s = make_scheduler("parallel", simulate=True, num_devices=2,
                       placement="min-pressure",
                       memory_budget=8 * CHUNK)
    for i in range(6):
        x = s.array(np.ones(N, np.float32), name=f"mp_{i}")
        _stage(s)(x)
    elements = list(s._elements)
    s.sync()
    kernels = [e for e in elements if e.kind is ElementKind.KERNEL]
    per_dev = {d: sum(1 for k in kernels if k.device == d) for d in (0, 1)}
    assert per_dev[0] == per_dev[1] == 3
    assert s.streams.placement.name == "min-pressure"


def test_min_pressure_degrades_to_min_load_when_unbounded():
    s = make_scheduler("parallel", simulate=True, num_devices=2,
                       placement="min-pressure")
    for i in range(4):
        x = s.array(np.ones(N, np.float32), name=f"ml_{i}")
        _stage(s)(x)
    elements = list(s._elements)
    s.sync()
    kernels = [e for e in elements if e.kind is ElementKind.KERNEL]
    assert {k.device for k in kernels} == {0, 1}


def test_oversized_working_set_raises():
    s = make_scheduler("parallel", simulate=True, memory_budget=CHUNK)
    x = s.array(np.ones(N, np.float32), name="big")
    with pytest.raises(DeviceOutOfMemoryError):
        _stage(s)(x)                          # needs 2 chunks, budget is 1


# ======================================================================
# Capture/replay under budgets
# ======================================================================

def test_capture_records_device_mem_and_replays_evicts():
    s = make_scheduler("parallel", simulate=True, memory_budget=3 * CHUNK)
    for ep in range(3):
        with s.capture("oc_ep"):
            xs = [s.array(np.zeros(N, np.float32), name=f"ce{ep}_{i}")
                  for i in range(2)]
            for x in xs:
                _stage(s)(x)
        s.sync()
    st = s.stats()
    assert st["plan_records"] == 1 and st["plan_replays"] == 2
    (plan,) = s.plan_cache.candidates("oc_ep")
    assert plan.device_mem and plan.device_mem[0][1] <= 3 * CHUNK
    assert any(pe.kind is ElementKind.EVICT for pe in plan.elements)
    assert st["mem_evict_blocks"] >= 3       # evictions replayed too


def test_replay_falls_back_to_eager_when_budget_shrinks():
    s = make_scheduler("parallel", simulate=True, memory_budget=16 * CHUNK)
    def episode():
        with s.capture("shrink_ep"):
            xs = [s.array(np.zeros(N, np.float32)) for _ in range(2)]
            outs = [_stage(s)(x) for x in xs]
        s.sync()
        return outs
    episode()
    episode()
    assert s.stats()["plan_replays"] == 1
    (plan,) = s.plan_cache.candidates("shrink_ep")
    # Budget shrinks below the plan's recorded peak: transparent capture
    # must not replay it (it would blow the budget) — the episode runs
    # eagerly and re-records a spill-aware plan under the new budget.
    s.memory.pools[0].budget_bytes = plan.device_mem[0][1] - 1
    episode()
    st = s.stats()
    assert st["plan_replays"] == 1           # no replay of the unfitting plan
    assert st["plan_records"] == 2           # a spill-aware plan was recorded
    # Explicit replay of an unfitting plan is refused outright.
    with pytest.raises(DeviceOutOfMemoryError):
        s.replay(plan)
    episode()                                # the new plan replays fine
    assert s.stats()["plan_replays"] == 2


def test_replay_pins_plan_default_arrays_under_pressure():
    """A replay under foreign memory pressure must never evict an array
    the plan will bind later (e.g. persistent device-resident weights):
    evicting one flips its location bits and guarantees a divergence at
    its first use, so replay would never stick exactly in the out-of-core
    regime it exists for."""
    s = make_scheduler("parallel", simulate=True, memory_budget=6 * CHUNK)
    w = s.array(np.ones(N, np.float32), name="pw_w")   # persistent weights

    def episode(tag):
        with s.capture("pin_ep"):
            x = s.array(np.ones(N, np.float32), name=f"pw_x{tag}")
            y = _stage(s)(x)
            STAGE2.with_options(scheduler=s, cost_s=1e-4,
                                name="pw_k2")(y, w)
        s.sync()

    episode(0)        # records with w host-resident (h2d traced)
    episode(1)        # w now device-resident -> diverges, re-records
    episode(2)        # replays the device-resident-w plan
    assert s.stats()["plan_replays"] == 1
    # Fill the budget with foreign arrays so w becomes the LRU victim
    # candidate during the next replay's dynamic reservation.
    foreign = [s.array(np.ones(N, np.float32), name=f"pw_f{i}")
               for i in range(2)]
    for f in foreign:
        _stage(s)(f)
    s.sync()
    assert w.device_valid
    episode(3)        # must still replay: w is pinned, foreign evicted
    st = s.stats()
    assert st["plan_replays"] == 2
    assert w.device_valid and w.device_id == 0
    assert st["mem_evict_blocks"] >= 1      # the pressure was real


# ======================================================================
# Satellite 1: forced H2D for multi-device host-only reads
# ======================================================================

@pytest.mark.parametrize("simulate", [True, False])
def test_multidevice_forces_h2d_without_auto_prefetch(simulate):
    s = make_scheduler("parallel", simulate=simulate, num_devices=2,
                       auto_prefetch=False, placement="round-robin")
    try:
        x0 = s.array(np.full(N, 2.0, np.float32), name="fp_x0")
        x1 = s.array(np.full(N, 3.0, np.float32), name="fp_x1")
        y0 = _stage(s)(x0)                   # lands on device 0
        y1 = _stage(s)(x1)                   # lands on device 1
        elements = list(s._elements)
        s.sync()
        # The host-only read args were localized despite auto_prefetch=False.
        h2d = [e for e in elements if e.kind is ElementKind.TRANSFER]
        assert {e.args[0].array.name for e in h2d} >= {"fp_x0", "fp_x1"}
        assert x0.device_valid and x1.device_valid
        if not simulate:
            assert np.allclose(np.asarray(y0), 5.0)
            assert np.allclose(np.asarray(y1), 7.0)
        assert_conservation(s, [x0, x1, y0, y1])
    finally:
        s.shutdown()


def test_single_device_auto_prefetch_off_unchanged():
    """The paper's fault-driven single-device mode stays prefetch-free."""
    s = make_scheduler("parallel", simulate=True, auto_prefetch=False)
    x = s.array(np.ones(N, np.float32), name="sd_x")
    _stage(s)(x)
    elements = list(s._elements)
    s.sync()
    assert not any(e.kind is ElementKind.TRANSFER for e in elements)


# ======================================================================
# Satellite 2: capture demotion cannot desync bits from residency
# ======================================================================

def test_capture_demotion_keeps_bits_and_residency_in_lockstep():
    """Host-write demotion mid-replay: the un-flushed plan suffix (kernels
    *and* transfers) is dropped, the episode finishes eagerly, and at every
    step the logical location bits equal the tracked residency."""
    s = make_scheduler("parallel", memory_budget=64 * CHUNK)
    alive = []      # every episode's arrays: the conservation universe
    try:
        def episode(write_mid=False):
            with s.capture("demote_ep"):
                a = s.array(np.full(N, 1.0, np.float32), name="dm_a")
                b = _stage(s)(a)
                if write_mid:
                    # a is plan-bound: the write must demote the replay.
                    a.write(np.full(N, 10.0, np.float32))
                c = s.array(np.full(N, 2.0, np.float32), name="dm_c")
                d = _stage(s)(c)
                alive.extend([a, b, c, d])
                assert_conservation(s, alive)
            s.sync()
            assert_conservation(s, alive)
            return a, b, c, d

        episode()                             # record
        episode()                             # replay
        assert s.stats()["plan_replays"] == 1
        a, b, c, d = episode(write_mid=True)  # demoted mid-replay
        assert np.allclose(np.asarray(b), 3.0)      # pre-write result
        assert np.allclose(np.asarray(d), 5.0)
        assert np.allclose(np.asarray(a), 10.0)     # the host write stuck
        assert_conservation(s, alive)
        # The plan survives demotion: clean episodes keep replaying.
        episode()
        assert s.stats()["plan_replays"] >= 2
    finally:
        s.shutdown()


def test_host_write_drops_residency_with_device_copy():
    s = make_scheduler("parallel", simulate=True, memory_budget=8 * CHUNK)
    x = s.array(np.ones(N, np.float32), name="hw_x")
    y = _stage(s)(x)
    s.sync()
    assert s.memory.pools[0].resident_bytes == 2 * CHUNK
    y.write(np.zeros(N, np.float32))          # host overwrite of the output
    assert not y.device_valid and y.device_id is None
    assert s.memory.pools[0].resident_bytes == CHUNK
    assert_conservation(s, [x, y])


# ======================================================================
# Satellite 4: concurrent sync vs racing launches + conservation property
# ======================================================================

def test_concurrent_sync_vs_launch_stress():
    """4 submitter threads race a syncing thread: the barrier must cover
    work submitted during the unlocked drain, every element must complete,
    and the final values must be correct."""
    s = make_scheduler("parallel", num_devices=2, memory_budget=256 * CHUNK)
    try:
        stage = _stage(s)
        results, errors = {}, []
        start = threading.Barrier(5)

        def submitter(tid):
            try:
                start.wait()
                outs = []
                for i in range(12):
                    x = s.array(np.full(N, float(tid * 100 + i), np.float32),
                                name=f"st{tid}_{i}")
                    outs.append((tid * 100 + i, x, stage(x)))
                    if i % 4 == 3:
                        s.sync()
                results[tid] = outs
            except Exception as exc:          # pragma: no cover - fail path
                errors.append(exc)

        threads = [threading.Thread(target=submitter, args=(t,))
                   for t in range(4)]
        for t in threads:
            t.start()
        start.wait()
        for _ in range(6):
            s.sync()                          # racing barriers
        for t in threads:
            t.join()
        s.sync()
        assert not errors
        # Barrier actually covered everything: every element retired and
        # completed, values correct.
        assert not s.dag.frontier
        assert not s._elements
        for _tid, outs in results.items():
            for val, _x, arr in outs:
                assert np.allclose(np.asarray(arr), 2.0 * val + 1.0)
        arrays = [a for outs in results.values()
                  for _, x, arr in outs for a in (x, arr)]
        assert_conservation(s, arrays)
    finally:
        s.shutdown()


@settings(max_examples=10, deadline=None)
@given(st.integers(min_value=0, max_value=2 ** 32 - 1))
def test_memory_conservation_property(seed):
    """At every step of a randomized workload, resident_bytes equals the
    sum of nbytes over device-valid arrays — whatever mix of launches,
    evictions, host reads and host writes got us there."""
    rng = np.random.RandomState(seed)
    s = make_scheduler("parallel", simulate=True, num_devices=2,
                       placement="min-pressure",
                       memory_budget=5 * CHUNK)
    stage = _stage(s)
    arrays = [s.array(rng.rand(N).astype(np.float32), name=f"pp_{i}")
              for i in range(3)]
    for step in range(20):
        op = rng.randint(4)
        if op == 0 and len(arrays) < 12:
            arrays.append(s.array(rng.rand(N).astype(np.float32),
                                  name=f"pp_n{step}"))
        elif op == 1:
            arrays.append(stage(arrays[rng.randint(len(arrays))]))
        elif op == 2:
            arrays[rng.randint(len(arrays))].read()
        else:
            arrays[rng.randint(len(arrays))].write(
                rng.rand(N).astype(np.float32))
        assert_conservation(s, arrays)
    s.sync()
    assert_conservation(s, arrays)


# ======================================================================
# ISSUE 6: the spill-tier stack — whole-stack conservation + replay gating
# ======================================================================

@settings(max_examples=10, deadline=None)
@given(st.integers(min_value=0, max_value=2 ** 32 - 1))
def test_whole_stack_conservation_property(seed):
    """Same randomized workload, but under a full tier stack (peer-device
    then compressed-host): at every step, device pools + host-tier
    residency exactly cover device-valid and tier-backed bytes, and the
    ``verify()`` cross-check of bits vs ledgers stays clean."""
    from repro.core import CompressedHostTier, PeerDeviceTier
    rng = np.random.RandomState(seed)
    s = make_scheduler("parallel", simulate=True, num_devices=2,
                       placement="min-pressure",
                       memory_budget={0: 4 * CHUNK, 1: 3 * CHUNK},
                       spill_tiers=[PeerDeviceTier(),
                                    CompressedHostTier(lossy=False)])
    stage = _stage(s)
    arrays = [s.array(rng.rand(N).astype(np.float32), name=f"ws_{i}")
              for i in range(3)]
    for step in range(20):
        op = rng.randint(4)
        if op == 0 and len(arrays) < 10:
            arrays.append(s.array(rng.rand(N).astype(np.float32),
                                  name=f"ws_n{step}"))
        elif op == 1:
            arrays.append(stage(arrays[rng.randint(len(arrays))]))
        elif op == 2:
            arrays[rng.randint(len(arrays))].read()
        else:
            arrays[rng.randint(len(arrays))].write(
                rng.rand(N).astype(np.float32))
        assert_stack_conservation(s, arrays)
    s.sync()
    assert_stack_conservation(s, arrays)


def test_replay_budget_gate_with_tier_stack():
    """The shrunk-budget regression under a tier stack: a plan recorded
    with tier spills must stop replaying when the budget shrinks below
    its recorded peak, re-record a plan for the new budget, and keep the
    whole-stack accounting exact throughout."""
    from repro.core import CompressedHostTier
    s = make_scheduler("parallel", simulate=True, memory_budget=16 * CHUNK,
                       spill_tiers=[CompressedHostTier(lossy=False)])
    alive = []

    def episode():
        with s.capture("tshrink_ep"):
            xs = [s.array(np.zeros(N, np.float32)) for _ in range(2)]
            outs = [_stage(s)(x) for x in xs]
        s.sync()
        alive.extend(xs + outs)
        assert_stack_conservation(s, alive)
        return outs

    episode()
    episode()
    assert s.stats()["plan_replays"] == 1
    (plan,) = s.plan_cache.candidates("tshrink_ep")
    s.memory.pools[0].budget_bytes = plan.device_mem[0][1] - 1
    episode()
    st = s.stats()
    assert st["plan_replays"] == 1           # unfitting plan not replayed
    assert st["plan_records"] == 2           # tier-spill-aware re-record
    with pytest.raises(DeviceOutOfMemoryError):
        s.replay(plan)
    episode()                                # the new plan replays fine
    assert s.stats()["plan_replays"] == 2
    assert_stack_conservation(s, alive)
