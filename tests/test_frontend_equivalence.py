"""GrFunction <-> legacy-launch equivalence property test (ISSUE 4).

A randomized DAG program is driven twice — once through the deprecated
``s.launch`` surface with per-call const/out/inout annotations, once through
declared GrFunctions — and must produce *identical* runtime behaviour:

* the same inferred DAG edges (including auto-inserted transfers/D2D),
* the same lane assignments and device placements,
* (sim executors) the same discrete-event timeline, bit for bit,
* (real executor) the same computed values.

The frontend is a surface, not a scheduler: any divergence here means the
declared path grew semantics the paper's programming model doesn't have."""
import warnings

import numpy as np
import pytest

import repro.api as gr
from repro.core import Arg, AccessMode, make_scheduler

# ----------------------------------------------------------------------
# Random program generation
# ----------------------------------------------------------------------
# Each template is (modes, kernel) where the kernel consumes the device
# values in argument order and returns new values for every writable
# argument — executable on the real executor, ignored by the simulator.

def _templates():
    import jax

    return {
        "copy2": (("const", "out"),
                  jax.jit(lambda a, _o: a * 2.0)),
        "bump": (("inout",),
                 jax.jit(lambda a: a + 1.0)),
        "add": (("const", "const", "out"),
                jax.jit(lambda a, b, _o: a + b)),
        "axpy": (("const", "inout"),
                 jax.jit(lambda a, b: b + 0.5 * a)),
        "split": (("const", "out", "out"),
                  jax.jit(lambda a, _o1, _o2: (a + 1.0, a - 1.0))),
    }


def random_program(seed: int, n_arrays: int = 6, n_kernels: int = 14):
    """A reproducible random DAG: (template_name, array_indices, cost)."""
    rng = np.random.RandomState(seed)
    names = sorted(_templates())
    prog = []
    for _i in range(n_kernels):
        tname = names[rng.randint(len(names))]
        modes, _ = _templates()[tname]
        idxs = rng.choice(n_arrays, size=len(modes), replace=False)
        cost = float(rng.choice([1e-5, 1e-4, 1e-3]))
        prog.append((tname, [int(j) for j in idxs], cost))
    return prog


def make_arrays(s, n_arrays: int):
    return [s.array(np.full(64, i + 1.0, np.float32), name=f"a{i}")
            for i in range(n_arrays)]


def run_legacy(s, prog, arrays):
    mode_of = {"const": AccessMode.CONST, "out": AccessMode.OUT,
               "inout": AccessMode.INOUT}
    tmpl = _templates()
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        for i, (tname, idxs, cost) in enumerate(prog):
            modes, fn = tmpl[tname]
            args = [Arg(arrays[j], mode_of[m]) for j, m in zip(idxs, modes)]
            s.launch(fn, args, name=f"k{i}_{tname}", cost_s=cost)


def run_frontend(s, prog, arrays):
    tmpl = _templates()
    # Declared once per template (the declare-once idiom); per-call name and
    # cost are call-scoped options.
    fns = {tname: gr.function(fn, modes=modes, name=tname)
           for tname, (modes, fn) in tmpl.items()}
    with gr.runtime(scheduler=s):
        for i, (tname, idxs, cost) in enumerate(prog):
            fns[tname].with_options(name=f"k{i}_{tname}", cost_s=cost)(
                *(arrays[j] for j in idxs))


def structure(s):
    """Order-preserving, uid-free view of every scheduled element."""
    return [(e.name, e.kind.value, e.stream, e.device,
             sorted(p.name for p in e.parents))
            for e in s._elements]


def sim_timeline(s):
    return [(sp.name, sp.kind, sp.lane, sp.t0, sp.t1)
            for sp in s.timeline.spans]


def _run(surface, seed, **sched_kw):
    s = make_scheduler("parallel", simulate=True, **sched_kw)
    prog = random_program(seed)
    arrays = make_arrays(s, 6)
    (run_legacy if surface == "legacy" else run_frontend)(s, prog, arrays)
    struct = structure(s)
    s.sync()
    return struct, sim_timeline(s), s.stats()


@pytest.mark.parametrize("seed", [0, 1, 2, 7])
@pytest.mark.parametrize("num_devices", [1, 2])
def test_equivalence_sim(seed, num_devices):
    """Identical DAG edges, lane/device assignments and (bit-identical)
    discrete-event timelines on 1- and 2-device simulators."""
    kw = dict(num_devices=num_devices, placement="round-robin")
    struct_l, tl_l, stats_l = _run("legacy", seed, **kw)
    struct_f, tl_f, stats_f = _run("frontend", seed, **kw)
    assert struct_f == struct_l
    assert tl_f == tl_l
    for key in ("elements", "edges", "d2d_transfers", "lanes_created",
                "events_created"):
        assert stats_f[key] == stats_l[key], key


@pytest.mark.parametrize("seed", [0, 3])
def test_equivalence_real_executor(seed):
    """Real ThreadLaneExecutor: identical DAG edges and identical computed
    values (lane reuse is timing-dependent there, so lanes/timeline are not
    compared)."""
    def run(surface):
        s = make_scheduler("parallel")
        try:
            prog = random_program(seed, n_kernels=10)
            arrays = make_arrays(s, 6)
            (run_legacy if surface == "legacy" else run_frontend)(
                s, prog, arrays)
            edges = [(e.name, e.kind.value, sorted(p.name for p in e.parents))
                     for e in s._elements]
            s.sync()
            values = [np.asarray(a).copy() for a in arrays]
        finally:
            s.shutdown()
        return edges, values

    edges_l, vals_l = run("legacy")
    edges_f, vals_f = run("frontend")
    assert edges_f == edges_l
    for vl, vf in zip(vals_l, vals_f):
        np.testing.assert_array_equal(vl, vf)
