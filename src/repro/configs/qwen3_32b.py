"""Qwen3-32B: dense, qk-norm, GQA kv=8, SwiGLU, RMSNorm.

[hf:Qwen/Qwen3-8B scaled; hf] — 64L, d_model=5120, 64H, d_ff=25600,
vocab=151936.
"""
from ..models.config import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-32b",
    family="dense",
    n_layers=64,
    d_model=5120,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=25600,
    vocab=151936,
    norm="rmsnorm",
    mlp="swiglu",
    qk_norm=True,
    rope_theta=1_000_000.0,
    source="[hf:Qwen/Qwen3-8B; hf]",
)
