"""Nemotron-4 340B: dense, squared-ReLU MLP, GQA kv=8, layernorm.

[arXiv:2402.16819; unverified] — 96L, d_model=18432, 96H, d_ff=73728,
vocab=256000.  Trains with 8-bit optimizer state + gradient-accumulation
scan to fit the v5e single-pod memory budget (DESIGN.md §5).
"""
from ..models.config import ArchConfig

CONFIG = ArchConfig(
    name="nemotron-4-340b",
    family="dense",
    n_layers=96,
    d_model=18432,
    n_heads=96,
    n_kv_heads=8,
    d_ff=73728,
    vocab=256000,
    norm="layernorm",
    mlp="relu2",
    rope_theta=10_000.0,
    source="[arXiv:2402.16819; unverified]",
)
