"""InternVL2-76B: InternViT frontend (STUB) + InternLM2-76B-style LM
backbone.

[arXiv:2404.16821; unverified] — 80L, d_model=8192, 64H GQA kv=8,
d_ff=28672 (SwiGLU), vocab=128256.  ``input_specs`` provides 256 patch
embeddings per image that replace the first token positions.
"""
from ..models.config import ArchConfig

CONFIG = ArchConfig(
    name="internvl2-76b",
    family="vlm",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=28672,
    vocab=128256,
    norm="rmsnorm",
    mlp="swiglu",
    rope_theta=1_000_000.0,
    frontend="vision",
    n_frontend_tokens=256,
    source="[arXiv:2404.16821; unverified]",
)
