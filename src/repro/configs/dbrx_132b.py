"""DBRX-132B: fine-grained MoE, 16 experts top-4, GQA kv=8.

[hf:databricks/dbrx-base; unverified] — 40L, d_model=6144, 48H,
d_ff_expert=10752 (SwiGLU), vocab=100352.
"""
from ..models.config import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="dbrx-132b",
    family="moe",
    n_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=10752,
    vocab=100352,
    norm="layernorm",
    mlp="swiglu",
    rope_theta=500_000.0,
    moe=MoEConfig(n_experts=16, top_k=4, d_ff_expert=10752),
    source="[hf:databricks/dbrx-base; unverified]",
)
