"""RWKV-6 "Finch" 1.6B: attention-free, data-dependent decay WKV.

[arXiv:2404.05892; unverified] — 24L, d_model=2048, d_ff=7168 (channel
mix), vocab=65536, head_dim 64.  O(1) decode state -> runs long_500k.
"""
from ..models.config import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="rwkv6-1.6b",
    family="ssm",
    n_layers=24,
    d_model=2048,
    n_heads=32,                  # d_model / ssm.head_dim
    n_kv_heads=32,
    d_ff=7168,
    vocab=65536,
    norm="layernorm",
    mlp="gelu",                  # unused (channel-mix FFN)
    ssm=SSMConfig(kind="rwkv6", head_dim=64),
    source="[arXiv:2404.05892; unverified]",
)
