"""Architecture registry: one module per assigned architecture.

``get_config(arch_id)`` returns the exact published config;
``get_config(arch_id, reduced=True)`` the CPU smoke-test version.
"""
from __future__ import annotations

import importlib
from typing import Dict, List

from ..models.config import ArchConfig, SHAPES, ShapeCell

ARCH_IDS: List[str] = [
    "seamless_m4t_medium",
    "gemma3_12b",
    "starcoder2_15b",
    "qwen3_32b",
    "nemotron_4_340b",
    "dbrx_132b",
    "qwen2_moe_a2_7b",
    "rwkv6_1_6b",
    "internvl2_76b",
    "hymba_1_5b",
]

_ALIASES = {i.replace("_", "-"): i for i in ARCH_IDS}


def get_config(arch_id: str, reduced: bool = False) -> ArchConfig:
    arch_id = _ALIASES.get(arch_id, arch_id)
    if arch_id not in ARCH_IDS:
        raise KeyError(f"unknown arch {arch_id!r}; known: {ARCH_IDS}")
    mod = importlib.import_module(f".{arch_id}", __package__)
    cfg: ArchConfig = mod.CONFIG
    return cfg.reduced() if reduced else cfg


def all_configs() -> Dict[str, ArchConfig]:
    return {a: get_config(a) for a in ARCH_IDS}


def cells(arch_id: str):
    """The shape cells that apply to this arch (skips recorded in dry-run)."""
    cfg = get_config(arch_id)
    out = []
    for name, cell in SHAPES.items():
        if name == "long_500k" and not cfg.subquadratic:
            out.append((cell, "skip: pure full-attention arch — a 500k dense "
                              "KV cache targets the sub-quadratic regime "
                              "(DESIGN.md §4)"))
        else:
            out.append((cell, None))
    return out
