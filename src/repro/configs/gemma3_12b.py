"""Gemma-3 12B: dense decoder, 5:1 local:global sliding-window attention.

[hf:google/gemma-3-1b-pt scaled; unverified] — 48L, d_model=3840, 16H GQA
kv=8 (head_dim 256), d_ff=15360 (GeGLU), vocab=262144, 128k context via
window 1024 local layers + global every 6th layer.
"""
from ..models.config import ArchConfig

CONFIG = ArchConfig(
    name="gemma3-12b",
    family="dense",
    n_layers=48,
    d_model=3840,
    n_heads=16,
    n_kv_heads=8,
    head_dim=256,
    d_ff=15360,
    vocab=262144,
    norm="rmsnorm",
    mlp="geglu",
    qk_norm=True,
    rope_theta=1_000_000.0,
    sliding_window=1024,
    global_every=6,              # 5 local : 1 global
    tie_embeddings=True,
    source="[hf:google/gemma-3-1b-pt; unverified]",
)
