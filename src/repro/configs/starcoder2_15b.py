"""StarCoder2-15B: dense code LM, GQA kv=4, RoPE, gelu MLP, layernorm.

[arXiv:2402.19173; hf] — 40L, d_model=6144, 48H, d_ff=24576, vocab=49152.
"""
from ..models.config import ArchConfig

CONFIG = ArchConfig(
    name="starcoder2-15b",
    family="dense",
    n_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv_heads=4,
    d_ff=24576,
    vocab=49152,
    norm="layernorm",
    mlp="gelu",
    rope_theta=100_000.0,
    source="[arXiv:2402.19173; hf]",
)
