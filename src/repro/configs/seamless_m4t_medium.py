"""SeamlessM4T-medium: speech/text encoder-decoder transformer backbone.

[arXiv:2308.11596; hf] — 12 encoder + 12 decoder layers, d_model=1024,
16 heads (GQA kv=16 == MHA), d_ff=4096, vocab=256206.  The audio frontend
(conformer feature extractor) is a STUB: ``input_specs`` provides
precomputed frame embeddings (B, S/4, d) per DESIGN.md §4.
"""
from ..models.config import ArchConfig

CONFIG = ArchConfig(
    name="seamless-m4t-medium",
    family="encdec",
    n_layers=12,                 # decoder layers
    n_encoder_layers=12,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab=256206,
    norm="layernorm",
    mlp="gelu",
    rope_theta=10_000.0,
    frontend="audio",
    n_frontend_tokens=0,         # frames supplied as encoder input
    source="[arXiv:2308.11596; hf]",
)
