"""Hymba-1.5B: hybrid blocks with parallel attention + Mamba heads.

[arXiv:2411.13676; hf] — 32L, d_model=1600, 25H GQA kv=5, d_ff=5504,
vocab=32001, ssm_state=16; sliding-window attention (1024) keeps the
attention path sub-quadratic -> runs long_500k.
"""
from ..models.config import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="hymba-1.5b",
    family="hybrid",
    n_layers=32,
    d_model=1600,
    n_heads=25,
    n_kv_heads=5,
    d_ff=5504,
    vocab=32001,
    norm="rmsnorm",
    mlp="swiglu",
    rope_theta=10_000.0,
    sliding_window=1024,
    global_every=0,              # all attention heads local (SWA)
    ssm=SSMConfig(kind="mamba", state_dim=16, expand=2, conv_dim=4),
    source="[arXiv:2411.13676; hf]",
)
