"""Optimizers + distributed-optimization tricks."""
from .adamw import AdamW, AdamWState, Q8State, dequantize_q8, quantize_q8
from .compress import (compress_with_feedback, compressed_psum,
                       init_error_feedback)

__all__ = ["AdamW", "AdamWState", "Q8State", "quantize_q8", "dequantize_q8",
           "compress_with_feedback", "compressed_psum",
           "init_error_feedback"]
