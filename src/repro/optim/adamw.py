"""AdamW with global-norm clipping, warmup-cosine schedule, and optional
8-bit (blockwise-quantized) first/second moments.

The 8-bit state is a *distributed-optimization* feature (DESIGN.md §5): for
the 340B config it cuts optimizer memory from 8 bytes/param to ~2.06,
which is what lets nemotron-4-340b train on a single 256-chip v5e pod.
Quantization is blockwise absmax along the last axis (block 256) with
dequant-update-requant each step; error stays bounded by the block scale.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

BLOCK = 128


# ----------------------------------------------------------------------
# blockwise int8 quantization
# ----------------------------------------------------------------------
def quantize_q8(x):
    """Blockwise-absmax int8 quantization along the LAST axis only.

    codes: int8 of shape (*lead, nb, BLOCK); scales: f32 (*lead, nb).
    Blocking only the trailing axis keeps every leading (FSDP/TP-sharded)
    dimension intact — a flatten-the-whole-tensor layout forced GSPMD into
    full rematerialization (replicate-then-reshard) of fp32 moments.
    """
    if x.ndim == 0:
        x = x[None]
    *lead, last = x.shape
    pad = (-last) % BLOCK
    if pad:
        x = jnp.pad(x, [(0, 0)] * len(lead) + [(0, pad)])
    nb = (last + pad) // BLOCK
    xb = x.reshape(*lead, nb, BLOCK)
    scale = jnp.max(jnp.abs(xb), axis=-1) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    codes = jnp.clip(jnp.round(xb / scale[..., None]), -127, 127
                     ).astype(jnp.int8)
    return codes, scale.astype(jnp.float32)


def dequantize_q8(codes, scales, shape):
    xb = codes.astype(jnp.float32) * scales[..., None]
    *lead, nb, blk = xb.shape
    full = xb.reshape(*lead, nb * blk)
    last = shape[-1] if shape else 1
    if nb * blk != last:
        full = full[..., :last]
    return full.reshape(shape)


class Q8State(NamedTuple):
    codes: Any
    scales: Any


# ----------------------------------------------------------------------
class AdamWState(NamedTuple):
    step: Any
    m: Any
    v: Any


class AdamW:
    def __init__(self, lr: float = 3e-4, b1: float = 0.9, b2: float = 0.95,
                 eps: float = 1e-8, weight_decay: float = 0.1,
                 clip_norm: float = 1.0, warmup: int = 100,
                 total_steps: int = 10_000, quantized: bool = False):
        self.lr, self.b1, self.b2, self.eps = lr, b1, b2, eps
        self.weight_decay = weight_decay
        self.clip_norm = clip_norm
        self.warmup, self.total_steps = warmup, total_steps
        self.quantized = quantized

    # -- schedule -------------------------------------------------------
    def schedule(self, step):
        warm = jnp.minimum(step / max(1, self.warmup), 1.0)
        prog = jnp.clip((step - self.warmup)
                        / max(1, self.total_steps - self.warmup), 0.0, 1.0)
        cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
        return self.lr * warm * (0.1 + 0.9 * cos)

    # -- state ----------------------------------------------------------
    def _zeros_like(self, p):
        if self.quantized and p.ndim >= 2:
            codes, scales = quantize_q8(jnp.zeros(p.shape, jnp.float32))
            return Q8State(codes, scales)
        return jnp.zeros(p.shape, jnp.float32)

    def init(self, params) -> AdamWState:
        zeros = jax.tree_util.tree_map(self._zeros_like, params)
        zeros2 = jax.tree_util.tree_map(self._zeros_like, params)
        return AdamWState(step=jnp.zeros((), jnp.int32), m=zeros, v=zeros2)

    # -- update ---------------------------------------------------------
    def update(self, grads, state: AdamWState, params):
        step = state.step + 1
        lr = self.schedule(step)

        # global-norm clip (f32 accumulation)
        leaves = jax.tree_util.tree_leaves(grads)
        gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                             for g in leaves))
        scale = jnp.minimum(1.0, self.clip_norm / (gnorm + 1e-9))

        b1, b2 = self.b1, self.b2
        c1 = 1.0 - b1 ** step.astype(jnp.float32)
        c2 = 1.0 - b2 ** step.astype(jnp.float32)

        def upd(p, g, m, v):
            g = g.astype(jnp.float32) * scale
            is_q = isinstance(m, Q8State)
            mf = dequantize_q8(m.codes, m.scales, p.shape) if is_q else m
            vf = dequantize_q8(v.codes, v.scales, p.shape) if is_q else v
            mf = b1 * mf + (1 - b1) * g
            vf = b2 * vf + (1 - b2) * jnp.square(g)
            mh, vh = mf / c1, vf / c2
            delta = mh / (jnp.sqrt(vh) + self.eps)
            if p.ndim >= 2:  # decoupled weight decay on matrices only
                delta = delta + self.weight_decay * p.astype(jnp.float32)
            new_p = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
            if is_q:
                mc, ms = quantize_q8(mf)
                vc, vs = quantize_q8(vf)
                return new_p, Q8State(mc, ms), Q8State(vc, vs)
            return new_p, mf, vf

        flat_p, treedef = jax.tree_util.tree_flatten(params)
        flat_g = treedef.flatten_up_to(grads)
        flat_m = treedef.flatten_up_to(state.m)
        flat_v = treedef.flatten_up_to(state.v)
        out = [upd(p, g, m, v) for p, g, m, v
               in zip(flat_p, flat_g, flat_m, flat_v)]
        new_params = treedef.unflatten([o[0] for o in out])
        new_m = treedef.unflatten([o[1] for o in out])
        new_v = treedef.unflatten([o[2] for o in out])
        metrics = {"grad_norm": gnorm, "lr": lr}
        return new_params, AdamWState(step, new_m, new_v), metrics
