"""Gradient compression with error feedback (distributed-optimization trick).

Int8 blockwise quantization of gradients before the data-parallel reduction,
with per-device error-feedback accumulators (Seide et al. / 1-bit Adam
lineage): the quantization residual is carried into the next step, so the
*expected* update is unbiased and convergence is preserved.

TPU/JAX note (DESIGN.md §5): JAX exposes no int8 collectives, so the wire
format of the reduction itself is bf16 (half of fp32 volume); the int8
codes bound the information content and the error-feedback math is identical
to what an int8-native interconnect would use.  ``compressed_psum`` is used
by the shard_map data-parallel step variant and validated in
tests/test_distributed.py on a fake multi-device mesh.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .adamw import dequantize_q8, quantize_q8


def compress_with_feedback(grad, err):
    """Quantize (grad + err) to int8 blocks; return (dequantized bf16,
    new_err).  grad/err: f32 arrays of equal shape."""
    g = grad.astype(jnp.float32) + err
    codes, scales = quantize_q8(g)
    deq = dequantize_q8(codes, scales, g.shape)
    new_err = g - deq
    return deq.astype(jnp.bfloat16), new_err


def compressed_psum(grads, errs, axis_name: str):
    """Error-feedback compressed data-parallel mean-reduction.

    Returns (reduced f32 grads, new error-feedback state).  Must run inside
    shard_map/pmap with ``axis_name`` bound.
    """
    n = jax.lax.psum(1, axis_name)

    def one(g, e):
        q, new_e = compress_with_feedback(g, e)
        red = jax.lax.psum(q.astype(jnp.float32), axis_name) / n
        return red, new_e

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_e = treedef.flatten_up_to(errs)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (treedef.unflatten([o[0] for o in out]),
            treedef.unflatten([o[1] for o in out]))


def init_error_feedback(params):
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)
