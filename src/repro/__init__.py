"""GrJAX: runtime DAG scheduling with resource sharing (GrCUDA paper repro)
as a multi-pod JAX training/inference framework.  See DESIGN.md."""

__version__ = "1.0.0"
