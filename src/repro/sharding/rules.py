"""Parameter/activation sharding rules (DESIGN.md §5).

Parallelism mapping over the production mesh ``("pod","data","model")``:

* **DP**   — batch over ``pod`` × ``data``;
* **FSDP** — every weight matrix additionally sharded over ``data`` (ZeRO-3;
  GSPMD inserts the per-layer all-gathers / reduce-scatters);
* **TP**   — head / FFN / expert / vocab dimensions over ``model``;
* **EP**   — MoE expert axis over ``model`` when divisible, else the expert
  FFN dim;
* **SP**   — long sequences over ``data`` for prefill cells.

Every rule is divisibility-guarded: an axis is applied to a dimension only
when it divides evenly (e.g. hymba's 25 heads fall back to unsharded heads
while its FFN still gets TP).  This is what makes all 10 architectures lower
on the same mesh without bespoke configs.
"""
from __future__ import annotations

import re
from typing import Dict, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def _axis_size(mesh: Mesh, name) -> int:
    if name is None:
        return 1
    if isinstance(name, (tuple, list)):
        out = 1
        for n in name:
            out *= _axis_size(mesh, n)
        return out
    return mesh.shape[name] if name in mesh.shape else 0


def fit_spec(spec: Sequence, shape: Tuple[int, ...], mesh: Mesh) -> P:
    """Drop axes that don't exist in the mesh or don't divide the dim."""
    fitted = []
    for dim, ax in zip(shape, spec):
        size = _axis_size(mesh, ax)
        if ax is None or size == 0 or size == 1 or dim % size != 0:
            fitted.append(None)
        else:
            fitted.append(ax)
    return P(*fitted)


def dp_axes(mesh: Mesh):
    """The data-parallel axes present in this mesh."""
    axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    return axes if len(axes) > 1 else (axes[0] if axes else None)


# ----------------------------------------------------------------------
# parameter rules, keyed by the trailing path element
# ----------------------------------------------------------------------
def _param_rule(path: str, shape, mesh: Mesh, fsdp: str = "data") -> P:
    """Spec for an *unstacked* parameter; `path` is dot-joined tree path."""
    tp = "model"
    leaf = path.split(".")[-1]
    r = len(shape)

    def S(*ax):
        return fit_spec(ax, shape, mesh)

    if leaf == "embed":
        return S(tp, fsdp)                      # (V, d): vocab-TP + FSDP
    if leaf == "lm_head":
        return S(fsdp, tp)                      # (d, V)
    if leaf in ("wq", "wk", "wv", "wg", "wr", "w_in", "w_gate", "w_decay_a",
                "frontend_proj"):
        if r == 3:                               # MoE expert weights (E, d, f)
            return S(tp, fsdp, None) if shape[0] % _axis_size(mesh, tp) == 0 \
                else S(None, fsdp, tp)
        return S(fsdp, tp)                      # (d, out)
    if leaf in ("wo", "w_out", "wv_out", "w_decay_b"):
        if r == 3:                               # (E, f, d)
            return S(tp, None, fsdp) if shape[0] % _axis_size(mesh, tp) == 0 \
                else S(None, tp, fsdp)
        return S(tp, fsdp)                      # (out, d)
    if leaf == "router":
        return S(fsdp, None)
    if leaf in ("conv_w",):
        return S(None, tp)
    if leaf in ("A_log", "D", "dt_bias", "w_dt", "w_B", "w_C"):
        return S(tp) if r == 1 else S(tp, None)
    if leaf == "bonus_u":
        return S(tp, None)                      # (H, hd)
    # norms / scales / mixers / biases: replicate
    return P(*([None] * r))


def param_sharding(params, mesh: Mesh, fsdp=None):
    """NamedSharding tree for a parameter tree (handles the stacked
    ``n_groups`` leading axis under blocks/encoder).  FSDP spans every
    data-parallel axis present (pod x data on the multi-pod mesh — ZeRO
    degree 512, not 256)."""
    if fsdp is None:
        fsdp = dp_axes(mesh)

    def one(path, leaf):
        keys = [getattr(k, "key", getattr(k, "idx", None)) for k in path]
        pstr = ".".join(str(k) for k in keys)
        shape = leaf.shape
        stacked = any(str(k) in ("blocks", "encoder") for k in keys)
        if stacked and len(shape) >= 1:
            inner = _param_rule(pstr, shape[1:], mesh, fsdp)
            spec = P(*((None,) + tuple(inner)))
        else:
            spec = _param_rule(pstr, shape, mesh, fsdp)
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(one, params)


# ----------------------------------------------------------------------
# activations / batches / caches
# ----------------------------------------------------------------------
def batch_spec(mesh: Mesh, seq_shard: bool = False) -> Dict[str, P]:
    dp = dp_axes(mesh)
    seq_ax = "model" if seq_shard else None
    return {
        "tokens": P(dp, seq_ax),
        "labels": P(dp, seq_ax),
        "frames": P(dp, seq_ax, None),
        "patches": P(dp, None, None),
    }


def cache_sharding(cache, mesh: Mesh):
    """KV caches: batch over DP, kv-heads over TP when divisible; recurrent
    states: channel dims over TP."""
    dp = dp_axes(mesh)

    def one(path, leaf):
        keys = [str(getattr(k, "key", getattr(k, "idx", ""))) for k in path]
        name = keys[-1] if keys else ""
        shape = leaf.shape
        # caches carry a leading n_groups axis from the stacked scan
        if name in ("k", "v", "cross_k", "cross_v"):
            # kv-head TP when divisible, else shard the head_dim
            if shape[3] % max(1, _axis_size(mesh, "model")) == 0:
                spec = (None, dp, None, "model", None)  # (G,B,S,H,hd)
            else:
                spec = (None, dp, None, None, "model")
        elif name == "wkv":
            spec = (None, dp, "model", None, None)      # (G,B,H,hd,hd)
        elif name == "h":
            spec = (None, dp, "model", None)            # (G,B,inner,N)
        elif name in ("shift", "cmix_shift"):
            spec = (None, dp, "model")                  # (G,B,d)
        elif name == "conv":
            spec = (None, dp, None, "model")            # (G,B,k-1,inner)
        else:
            spec = (None,) * len(shape)
        return NamedSharding(mesh, fit_spec(spec, shape, mesh))

    return jax.tree_util.tree_map_with_path(one, cache)


def state_sharding(state_tree, params_sharding):
    """Optimizer state mirrors parameter sharding (m, v, quantized blocks)."""

    def one(leaf_sharding, state_leaf):
        return leaf_sharding

    return jax.tree_util.tree_map(lambda s: s, params_sharding)


def logical_to_physical(mesh: Mesh, tree, sharding_tree):
    return jax.tree_util.tree_map(
        lambda x, s: jax.device_put(x, s), tree, sharding_tree)
