"""Sharding rules over the (pod, data, model) production mesh."""
from .rules import (batch_spec, cache_sharding, param_sharding,
                    state_sharding, logical_to_physical)

__all__ = ["param_sharding", "cache_sharding", "batch_spec",
           "state_sharding", "logical_to_physical"]
