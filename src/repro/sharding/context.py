"""Use-site logical sharding constraints (ZeRO-3 materialization policy).

Parameters are *stored* FSDP-sharded (fp32 masters spread over the data
axes — see rules.py).  If a matmul consumed them directly, GSPMD would see a
contracted dimension sharded over ``data`` and often lowers that to huge
fp32 partial-sum all-reduces of activations.  Instead, every layer wraps its
weights in ``use_weight(w, *candidate_specs)``: the bf16 copy is constrained
to a TP-only layout, so GSPMD materializes a **bf16 all-gather of the
weight** (half the wire bytes of fp32) right before use and a reduce-scatter
of the gradient in the backward — textbook ZeRO-3 with mixed-precision
gathers.

Outside a mesh context (CPU tests, single device) everything is a no-op.
"""
from __future__ import annotations

import threading
from contextlib import contextmanager
from typing import Optional, Sequence, Tuple

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

_STATE = threading.local()


def _mesh_axes():
    return getattr(_STATE, "axes", None)


def _mesh():
    return getattr(_STATE, "mesh", None)


def _named(spec: P):
    return NamedSharding(_mesh(), spec)


def _dp_axes():
    axes = _mesh_axes()
    if not axes:
        return None
    dp = tuple(a for a in ("pod", "data") if a in axes)
    return dp if len(dp) > 1 else (dp[0] if dp else None)


@contextmanager
def sharding_rules(mesh):
    """Activate use-site constraints for lowering under ``mesh``."""
    _STATE.axes = dict(mesh.shape)
    _STATE.mesh = mesh
    try:
        yield
    finally:
        _STATE.axes = None
        _STATE.mesh = None


def _fit(spec: Sequence, shape: Tuple[int, ...]) -> Optional[P]:
    axes = _mesh_axes()
    out = []
    ok = False
    for dim, ax in zip(shape, spec):
        if ax is None:
            out.append(None)
            continue
        size = axes.get(ax, 0) if not isinstance(ax, tuple) else 0
        if isinstance(ax, tuple):
            size = 1
            for a in ax:
                size *= axes.get(a, 0)
        if size and size > 0 and dim % size == 0:
            out.append(ax)
            ok = True
        else:
            out.append(None)
    return P(*out) if ok else P(*([None] * len(shape)))


def use_weight(w, *candidate_specs):
    """Constrain a weight (already cast to compute dtype) to the first
    candidate TP layout that divides evenly; no-op outside a mesh context.

    (Refuted experiment note, kept for the §Perf log: pre-pinning the bf16
    copy to a storage-like layout did NOT stop GSPMD from gathering f32 —
    the fix that works is the bf16 working copy cast once per step in
    make_train_step.)"""
    if _mesh_axes() is None or not candidate_specs:
        return w
    for spec in candidate_specs:
        p = _fit(spec, w.shape)
        if any(a is not None for a in p):
            return jax.lax.with_sharding_constraint(w, _named(p))
    return jax.lax.with_sharding_constraint(
        w, _named(P(*([None] * w.ndim))))


def shard_activations(x, *, seq_axis=None):
    """Constrain token activations (B, S, d) to batch-over-DP."""
    if _mesh_axes() is None:
        return x
    dp = _dp_axes()
    if dp is None:
        return x
    spec = [dp] + [None] * (x.ndim - 1)
    if seq_axis is not None and x.ndim >= 2:
        spec[1] = seq_axis
    return jax.lax.with_sharding_constraint(x, _named(_fit(spec, x.shape)))


def shard_heads(x):
    """Constrain (B, S, H, hd) attention tensors: batch over DP, heads over
    "model" when divisible, and — critically — head_dim explicitly
    REPLICATED.  Without this GSPMD may shard the contracted hd dim (e.g.
    propagating through hymba's 25-head reshape), turning every blocked
    score matmul into a partial-sum all-reduce (~6 TiB/step at 32k)."""
    if _mesh_axes() is None or x.ndim != 4:
        return x
    dp = _dp_axes()
    tp = _mesh_axes().get("model", 1)
    head_ax = "model" if (tp > 1 and x.shape[2] % tp == 0) else None
    spec = P(dp, None, head_ax, None)
    return jax.lax.with_sharding_constraint(x, _named(spec))


def pin_attention_blocks(qg, kb, vb):
    """Pin the blocked-attention scan inputs: (nq|nk, B, chunk, Hkv[, g],
    hd) — batch over DP, kv-heads over "model" when divisible, and hd/chunk
    dims REPLICATED so the score matmul never contracts a sharded dim."""
    if _mesh_axes() is None:
        return qg, kb, vb
    dp = _dp_axes()
    tp = _mesh_axes().get("model", 1)
    hkv = kb.shape[3]
    h_ax = "model" if (tp > 1 and hkv % tp == 0) else None
    qspec = P(None, dp, None, h_ax, None, None)
    kspec = P(None, dp, None, h_ax, None)
    qg = jax.lax.with_sharding_constraint(qg, _named(qspec))
    kb = jax.lax.with_sharding_constraint(kb, _named(kspec))
    vb = jax.lax.with_sharding_constraint(vb, _named(kspec))
    return qg, kb, vb


def constrain_like_params(tree):
    """Constrain a parameter-shaped tree (e.g. gradients / accumulators) to
    the FSDP *storage* sharding — turns data-parallel gradient all-reduces
    into reduce-scatters and keeps the fp32 accumulator sharded."""
    mesh = _mesh()
    if mesh is None:
        return tree
    from .rules import param_sharding
    shardings = param_sharding(tree, mesh)
    return jax.tree_util.tree_map(
        lambda x, sh: jax.lax.with_sharding_constraint(x, sh),
        tree, shardings)
