"""GrFunction frontend — declare-once kernels + the ambient runtime.

The paper's core promise (§III–IV) is that the polyglot API makes GPU task
parallelism *transparent*: host code calls kernels like plain functions and
the runtime infers the DAG — no per-call dependency annotations, no runtime
handle threaded through every call site.  This module is that surface for
GrJAX:

* :func:`function` wraps a JAX/Pallas callable **once** with everything the
  runtime needs to schedule it — its signature's access modes, an optional
  cost model and tuning space, and (for out-allocating kernels) an output
  spec::

      sq = gr.function(square_kernel, modes=("const", "out"),
                       outputs=0, name="square")

  after which every invocation is just ``sq(x, y)`` — or ``y = sq(x)``,
  with the runtime allocating the output :class:`ManagedArray` from the
  declared spec.  Call-scoped options never re-annotate the signature::

      sq.with_options(tenant="a", priority=1)(x, y)

* the **ambient runtime**: ``with gr.runtime(policy=..., num_devices=...):``
  (or a module-level default via :func:`set_runtime`) makes ManagedArrays
  and GrFunctions resolve their scheduler implicitly through a thread-local
  stack.  Explicit ``scheduler=`` always wins; each thread sees only its own
  stack, so concurrent tenants never leak runtimes into each other.

Every call funnels into ``GrScheduler._launch`` — the same engine behind
the deprecated ``scheduler.launch`` shim — so DAG inference, lane
assignment, QoS weighting and capture/replay behave identically whichever
surface issued the kernel.  Capture plans are keyed by the *declared*
function's identity (``GrFunction.fid``), not the Python callable, so
closures re-created per episode keep replaying one plan.
"""
from __future__ import annotations

import itertools
import threading
import weakref
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from .element import AccessMode, Arg, DEFAULT_TENANT
from .managed import ManagedArray
from .scheduler import GrScheduler, make_scheduler

_FN_IDS = itertools.count()

# Accepted spellings for declared access modes (paper §IV-D annotations).
_MODE_NAMES: Dict[str, AccessMode] = {
    "const": AccessMode.CONST, "in": AccessMode.CONST,
    "input": AccessMode.CONST,
    "out": AccessMode.OUT, "output": AccessMode.OUT,
    "inout": AccessMode.INOUT,
}

# Option keys consumed by the frontend itself; everything else a caller
# passes to with_options()/``__call__`` merges into the launch config
# (e.g. ``parallel_fraction`` for the simulator's occupancy model).
_OPTION_KEYS = ("scheduler", "name", "priority", "tenant", "cost_s",
                "device", "tune", "outputs", "deadline_s")


class NoActiveRuntimeError(RuntimeError):
    """No ambient runtime on this thread and no explicit ``scheduler=``."""


# ======================================================================
# Ambient runtime: thread-local stack over a module-level default
# ======================================================================

_tls = threading.local()
_default_runtime: Optional[GrScheduler] = None


def _stack() -> list:
    stack = getattr(_tls, "stack", None)
    if stack is None:
        stack = _tls.stack = []
    return stack


def current_runtime() -> Optional[GrScheduler]:
    """Innermost ambient scheduler of this thread, the module-level default
    when the thread's stack is empty, or None."""
    stack = _stack()
    return stack[-1] if stack else _default_runtime


def get_runtime() -> GrScheduler:
    """Like :func:`current_runtime` but raising a directive error when no
    runtime is active — the failure mode every implicit resolution shares."""
    rt = current_runtime()
    if rt is None:
        raise NoActiveRuntimeError(
            "no GrJAX runtime is active on this thread: enter one with "
            "`with gr.runtime(...):`, install a process-wide default via "
            "`gr.set_runtime(make_scheduler(...))`, or pass `scheduler=` "
            "explicitly")
    return rt


def set_runtime(sched: Optional[GrScheduler]) -> Optional[GrScheduler]:
    """Install ``sched`` as the module-level default runtime (shared by all
    threads whose own stack is empty); returns the previous default.  Pass
    None to clear."""
    global _default_runtime
    prev = _default_runtime
    _default_runtime = sched
    return prev


class runtime:
    """``with gr.runtime(policy=..., num_devices=...) as sched:`` — push an
    ambient scheduler onto this thread's runtime stack.

    Keyword arguments are forwarded to :func:`make_scheduler` unless an
    existing scheduler is adopted via ``scheduler=``.  The scheduler is
    created eagerly at construction, so one ``runtime`` instance can be
    entered from several threads (or re-entered) without racing on lazy
    creation — every entry pushes the same scheduler.  Contexts nest: the
    innermost runtime wins, and exiting restores the enclosing one.  The
    stack is thread-local — a runtime entered on one thread is invisible to
    every other thread (each tenant thread enters its own).
    """

    def __init__(self, policy: str = "parallel", *,
                 scheduler: Optional[GrScheduler] = None, **make_kw) -> None:
        if scheduler is not None and (make_kw or policy != "parallel"):
            extra = sorted(make_kw) + (["policy"] if policy != "parallel"
                                       else [])
            raise TypeError("runtime(scheduler=...) adopts an existing "
                            "scheduler as-is; it cannot be combined with "
                            f"factory arguments {extra}")
        if scheduler is None:
            scheduler = make_scheduler(**dict(make_kw, policy=policy))
        self.scheduler = scheduler

    def __enter__(self) -> GrScheduler:
        _stack().append(self.scheduler)
        return self.scheduler

    def __exit__(self, exc_type, exc, tb) -> bool:
        stack = _stack()
        if not stack or stack[-1] is not self.scheduler:
            raise RuntimeError("runtime contexts must unwind LIFO on the "
                               "thread that entered them")
        stack.pop()
        return False


def array(data=None, *, shape: Optional[Tuple[int, ...]] = None,
          dtype=np.float32, name: str = "",
          scheduler: Optional[GrScheduler] = None) -> ManagedArray:
    """Create a :class:`ManagedArray` on the ambient runtime (or on an
    explicit ``scheduler=``, which wins)."""
    sched = scheduler if scheduler is not None else get_runtime()
    return sched.array(data, shape=shape, dtype=dtype, name=name)


# ======================================================================
# GrFunction
# ======================================================================

def _resolve_mode(mode: Union[str, AccessMode]) -> AccessMode:
    if isinstance(mode, AccessMode):
        return mode
    try:
        return _MODE_NAMES[str(mode).lower()]
    except KeyError:
        raise ValueError(f"unknown access mode {mode!r}; use one of "
                         f"{sorted(set(_MODE_NAMES))}") from None


class GrFunction:
    """A kernel declared once: callable + access modes + cost/tuning model.

    Instances are immutable from the caller's perspective;
    :meth:`with_options` returns a shallow variant sharing the same declared
    identity (``fid``), so call-scoped options (tenant, priority, cost,
    device pinning, simulator occupancy, even a per-call display name) never
    fork the capture-plan keying or the kernel history.
    """

    def __init__(self, fn: Optional[Callable],
                 modes: Sequence[Union[str, AccessMode]], *,
                 name: Optional[str] = None,
                 outputs: Any = None,
                 cost_s: float = 0.0,
                 tune: Optional[dict] = None,
                 config: Optional[dict] = None,
                 scheduler: Optional[GrScheduler] = None,
                 priority: int = 0,
                 tenant: str = DEFAULT_TENANT,
                 device: Optional[int] = None,
                 deadline_s: Optional[float] = None,
                 lint_shapes: Optional[Sequence] = None,
                 _fid: Optional[int] = None) -> None:
        self.fn = fn
        self.modes: Tuple[AccessMode, ...] = tuple(
            _resolve_mode(m) for m in modes)
        self.name = name or getattr(fn, "__name__", None) or "kernel"
        # Declared identity: shared by every with_options() variant, distinct
        # across declarations.  Capture plans key on it (element.fn_key).
        self.fid = next(_FN_IDS) if _fid is None else _fid
        self.outputs = self._normalize_outputs(outputs)
        self.cost_s = cost_s
        self.tune = tune
        self.config = dict(config or {})
        self.scheduler = scheduler
        self.priority = priority
        self.tenant = tenant
        self.device = device
        self.deadline_s = deadline_s
        # Shadow-operand hints for the access-mode checker (repro.analysis):
        # one (shape, dtype) pair per declared argument, for kernels whose
        # generic float shadows would not trace (e.g. integer index args).
        self.lint_shapes = (tuple((tuple(s), np.dtype(d))
                                  for s, d in lint_shapes)
                            if lint_shapes is not None else None)

    # -- declaration helpers -------------------------------------------
    def _out_positions(self) -> Tuple[int, ...]:
        return tuple(i for i, m in enumerate(self.modes)
                     if m is AccessMode.OUT)

    @staticmethod
    def _is_shape_dtype_pair(spec: Any) -> bool:
        """A single ``(shape, dtype)`` pair: a 2-sequence whose head is a
        shape (ints) and whose tail parses as a dtype.  The dtype probe is
        what separates one pair from a 2-element *sequence of specs* (e.g.
        two pairs, or two like-input indices)."""
        if not (isinstance(spec, (tuple, list)) and len(spec) == 2
                and isinstance(spec[0], (tuple, list))
                and all(isinstance(d, (int, np.integer)) for d in spec[0])):
            return False
        try:
            np.dtype(spec[1])
        except TypeError:
            return False
        return True

    def _normalize_outputs(self, outputs: Any):
        """``outputs`` describes how to allocate OUT-mode arguments the
        caller omits: an int (allocate like that input index), a
        ``(shape, dtype)`` pair, a callable ``(*given) -> (shape, dtype)``,
        or a sequence of those — one per OUT position, in order.  A
        2-tuple is a single pair only when its head is a shape sequence;
        any other list/tuple is a sequence of specs."""
        if outputs is None:
            return None
        out_n = len(self._out_positions())
        if (isinstance(outputs, (list, tuple))
                and not self._is_shape_dtype_pair(outputs)):
            specs = list(outputs)
        else:
            specs = [outputs]
        if len(specs) == 1 and out_n > 1:
            specs = specs * out_n
        if len(specs) != out_n:
            raise ValueError(
                f"{self.name}: {len(specs)} output spec(s) for {out_n} "
                f"'out'-mode argument(s)")
        return tuple(specs)

    def _allocate(self, pos: int, out_idx: int, given: Tuple[Any, ...],
                  sched: GrScheduler, call_name: str) -> ManagedArray:
        if self.outputs is None:
            raise TypeError(
                f"{call_name}: argument {pos} ('out') was not supplied and "
                f"the declaration has no outputs= spec to allocate it from")
        spec = self.outputs[out_idx]
        if isinstance(spec, bool) or spec is Ellipsis:
            raise TypeError(f"{call_name}: invalid output spec {spec!r}")
        if isinstance(spec, int):
            try:
                like = given[spec]
            except IndexError:
                raise TypeError(
                    f"{call_name}: output spec refers to input {spec} but "
                    f"only {len(given)} argument(s) were "
                    f"supplied") from None
            shape, dtype = tuple(like.shape), like.dtype
        elif callable(spec):
            shape, dtype = spec(*given)
        elif self._is_shape_dtype_pair(spec):
            shape, dtype = spec
        else:
            raise TypeError(
                f"{call_name}: output spec {spec!r} is not an input index, "
                f"a (shape, dtype) pair, or a callable")
        return sched.array(shape=tuple(shape), dtype=dtype,
                           name=f"{call_name}_o{out_idx}")

    # -- options --------------------------------------------------------
    def with_options(self, **opts) -> "GrFunction":
        """Return a variant with call-scoped options bound (same declared
        identity).  Known keys: ``scheduler, name, priority, tenant, cost_s,
        device, tune, deadline_s``; anything else merges into the launch
        config."""
        known = {k: opts.pop(k) for k in _OPTION_KEYS if k in opts}
        if "outputs" in known:
            outputs = known["outputs"]      # re-normalized by the ctor
        else:
            outputs = list(self.outputs) if self.outputs is not None else None
        return GrFunction(
            self.fn, self.modes,
            name=known.get("name", self.name),
            outputs=outputs,
            cost_s=known.get("cost_s", self.cost_s),
            tune=known.get("tune", self.tune),
            config=dict(self.config, **opts),
            scheduler=known.get("scheduler", self.scheduler),
            priority=known.get("priority", self.priority),
            tenant=known.get("tenant", self.tenant),
            device=known.get("device", self.device),
            deadline_s=known.get("deadline_s", self.deadline_s),
            lint_shapes=self.lint_shapes,
            _fid=self.fid)

    # -- the call -------------------------------------------------------
    def _resolve_scheduler(self, explicit: Optional[GrScheduler],
                           arrays: Tuple[Any, ...]) -> GrScheduler:
        if explicit is not None:
            return explicit
        if self.scheduler is not None:
            return self.scheduler
        rt = current_runtime()
        if rt is not None:
            return rt
        for a in arrays:               # last resort: the arrays know theirs
            sched = getattr(a, "_scheduler", None)
            if sched is not None:
                return sched
        raise NoActiveRuntimeError(
            f"cannot resolve a runtime for GrFunction {self.name!r}: enter "
            "`with gr.runtime(...):`, install a default via "
            "`gr.set_runtime(...)`, bind one with "
            "`.with_options(scheduler=...)`, or pass `scheduler=` to the "
            "call")

    def __call__(self, *arrays, scheduler: Optional[GrScheduler] = None,
                 **overrides):
        """Invoke the declared kernel on managed handles.

        Positional arguments fill the declared modes in order; trailing
        ``out`` arguments may be omitted when the declaration carries an
        ``outputs=`` spec — the runtime then allocates them and returns the
        allocated array(s) (single array, or a tuple).  When every argument
        is supplied, the scheduled :class:`ComputationalElement` is returned
        instead.  ``**overrides`` accepts the same keys as
        :meth:`with_options`, scoped to this call only.
        """
        gf = self.with_options(**overrides) if overrides else self
        sched = gf._resolve_scheduler(scheduler, arrays)
        n = len(gf.modes)
        if len(arrays) > n:
            raise TypeError(f"{gf.name}: takes at most {n} argument(s), "
                            f"got {len(arrays)}")
        allocated = []
        if len(arrays) < n:
            out_positions = gf._out_positions()
            full = list(arrays)
            for pos in range(len(arrays), n):
                if gf.modes[pos] is not AccessMode.OUT:
                    raise TypeError(
                        f"{gf.name}: argument {pos} "
                        f"('{gf.modes[pos].value}') must be supplied — only "
                        f"trailing 'out' arguments can be runtime-allocated")
                ma = gf._allocate(pos, out_positions.index(pos), arrays,
                                  sched, gf.name)
                allocated.append(ma)
                full.append(ma)
            arrays = tuple(full)
        args = tuple(Arg(a, m) for a, m in zip(arrays, gf.modes))
        element = sched._launch(
            gf.fn, args, name=gf.name, cost_s=gf.cost_s, tune=gf.tune,
            priority=gf.priority, tenant=gf.tenant, device=gf.device,
            deadline_s=gf.deadline_s, fn_key=gf.fid, **gf.config)
        if allocated:
            return allocated[0] if len(allocated) == 1 else tuple(allocated)
        return element

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        modes = ",".join(m.value for m in self.modes)
        return f"<GrFunction {self.name} fid={self.fid} modes=({modes})>"


# Every ``function()`` declaration registers here (weakly — a declaration
# dropped by user code disappears from lint sweeps with it).  The access-
# mode checker (``python -m repro.analysis lint``) audits this registry.
_DECLARATIONS: "weakref.WeakSet[GrFunction]" = weakref.WeakSet()


def declared_functions() -> List[GrFunction]:
    """Live ``function()`` declarations of this process, in fid order."""
    return sorted(_DECLARATIONS, key=lambda gf: gf.fid)


def function(fn: Optional[Callable],
             modes: Sequence[Union[str, AccessMode]], *,
             name: Optional[str] = None, outputs: Any = None,
             cost_s: float = 0.0, tune: Optional[dict] = None,
             scheduler: Optional[GrScheduler] = None,
             lint_shapes: Optional[Sequence] = None,
             **config) -> GrFunction:
    """Declare a kernel once; every later call is plain ``f(x, y)``.

    ``modes`` annotates the signature (``"const"``/``"out"``/``"inout"``,
    paper §IV-D) — the one place access intent is ever written.  ``outputs``
    optionally describes how to allocate omitted trailing ``out`` arguments
    (see :class:`GrFunction`); ``lint_shapes`` optionally gives the
    access-mode checker one ``(shape, dtype)`` shadow operand per argument.
    Remaining keyword arguments become the default launch config (e.g.
    ``parallel_fraction`` for the simulator).
    """
    gf = GrFunction(fn, modes, name=name, outputs=outputs, cost_s=cost_s,
                    tune=tune, scheduler=scheduler,
                    lint_shapes=lint_shapes, config=config)
    _DECLARATIONS.add(gf)
    return gf
