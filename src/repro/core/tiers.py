"""Backing tiers — the pluggable spill hierarchy behind the MemoryManager.

PR 5 hard-coded one answer to "where do evicted bytes go": the host, over
the D2H engine.  Multitasking under memory pressure wants a *hierarchy* of
backing stores (see "Towards Efficient and Practical GPU Multitasking in
the Era of LLM", PAPERS.md): an idle peer device over the fast D2D
interconnect first, then compressed host memory, then disk for truly huge
working sets.  This module defines the :class:`BackingTier` interface and
the three concrete tiers; the scheduler takes an *ordered stack* of them
(``GrScheduler(spill_tiers=[...])``) and the submission pipeline asks the
stack where each dirty victim should land — the first tier that
``can_accept`` the block wins, and a stack-wide miss falls back to the
flat PR 5 D2H spill, which is also the default when no stack is
configured (bit-identical behaviour).

Only *dirty* victims (device copy newer than host) consult the stack: a
clean victim's bytes already live in the host buffer, so dropping the
device copy is free and no tier could do better.

Division of labour (mirrors the location-bit rules in memory.py):

* **Logical** bookkeeping (which tier holds which block, resident byte
  sums, stats) happens at *schedule* time via the MemoryManager's
  ``note_spill``/``note_reload`` — the simulator never moves real bytes.
* **Physical** payloads (compress, write the spool file, device_put to
  the peer) happen at *execution* time on the real executor via
  ``tier.spill(block)`` / ``tier.reload(block)``.

Tier wiring into the rest of the runtime:

* ``PeerDeviceTier`` spills are ``EVICT`` elements with ``src_device``
  set — the simulator runs them on the point-to-point D2D link and the
  real executor device_puts the value onto the peer.  The block stays
  *device-resident* (on the peer), so the ordinary migrate stage brings
  it back with a plain D2D when next consumed — no new reload machinery.
* Host-side tiers (compressed / disk) produce ``EVICT`` elements on the
  D2H engine and later ``RELOAD`` elements on the H2D engine; the block's
  ``backing_tier`` attribute names the holder (part of capture slot
  state, so a replayed plan reloads from the right tier).
* ``DiskTier`` spool files are written tmp+rename (atomic, like
  checkpoint/manager.py) — which is also what lets checkpointing
  hard-link a clean spilled block instead of copying it a second time
  (snapshot-through-spill).
"""
from __future__ import annotations

import os
import shutil
import tempfile
import zlib
from typing import Any, Dict, List, Optional, Tuple

import numpy as np


def _nbytes(block: Any) -> int:
    try:
        return int(getattr(block, "nbytes", 0))
    except TypeError:  # pragma: no cover - exotic duck types
        return 0


def _block_value(block: Any) -> np.ndarray:
    """The newest physical value of a victim at spill time (real executor):
    the device copy when one is materialized, else the host buffer."""
    dev = getattr(block, "device", None)
    return np.asarray(dev if dev is not None else block.host)


class BackingTier:
    """One layer of the spill stack.

    Subclasses implement the capacity test, the physical payload
    movement and their own stats; the MemoryManager drives the logical
    (schedule-time) bookkeeping through ``note_spill``/``note_reload``/
    ``note_release`` so stats and residency stay exact on the simulator.
    """

    name = "base"
    #: "host" tiers hold the payload off-device (RELOAD brings it back);
    #: "device" tiers park the block on another device (plain D2D reload).
    location = "host"

    def __init__(self) -> None:
        self.mem = None                       # bound MemoryManager
        self._resident: Dict[int, int] = {}   # key -> logical nbytes
        self.spills = 0
        self.spill_bytes = 0                  # logical bytes spilled (total)
        self.wire_bytes = 0                   # bytes moved over the link
        self.reloads = 0
        self.reload_bytes = 0
        self.drops = 0

    # -- wiring --------------------------------------------------------
    def bind(self, mem: Any) -> None:
        self.mem = mem

    # -- capacity ------------------------------------------------------
    def can_accept(self, nbytes: int, src_device: Optional[int] = None) -> bool:
        raise NotImplementedError

    def plan_spill(self, block: Any) -> dict:
        """Schedule-time description of one spill of ``block``:
        ``transfer_bytes`` (what the copy engine moves), ``config`` extras
        for the EVICT element (frozen into capture plan signatures) and,
        for device tiers, the ``target`` device."""
        return {"transfer_bytes": _nbytes(block), "config": {}, "target": None}

    def reload_wire_bytes(self, block: Any) -> int:
        """Bytes a RELOAD of ``block`` moves over the H2D engine (a
        compressed tier uploads the narrow codes and widens device-side)."""
        return _nbytes(block)

    # -- logical bookkeeping (schedule time, manager lock held) --------
    def holds(self, key: int) -> bool:
        return key in self._resident

    @property
    def resident_bytes(self) -> int:
        return sum(self._resident.values())

    def note_spill(self, key: int, nbytes: int, wire_bytes: int) -> None:
        self._resident[key] = nbytes
        self.spills += 1
        self.spill_bytes += nbytes
        self.wire_bytes += wire_bytes

    def note_reload(self, key: int) -> None:
        nb = self._resident.pop(key, 0)
        self.reloads += 1
        self.reload_bytes += nb

    def note_release(self, key: int) -> None:
        """The block left the tier without a reload (GC, host overwrite)."""
        if self._resident.pop(key, None) is not None:
            self.drops += 1

    # -- physical payloads (real executor) -----------------------------
    def spill(self, block: Any) -> None:
        """Store ``block``'s current value in the tier (executor thread)."""

    def reload(self, block: Any) -> np.ndarray:
        """Return the stored value (and refresh ``block.host``); the caller
        uploads it.  Also used synchronously for host reads of a
        tier-resident block."""
        raise NotImplementedError

    def drop(self, key: int) -> None:
        """Release the physical payload for ``key`` (idempotent)."""

    def peek(self, block: Any):
        """Non-destructive read of the stored value (checkpoint snapshots
        read through the tier without releasing the payload), or None when
        the tier holds no payload for ``block``."""
        return None

    # -- reporting -----------------------------------------------------
    def stats(self) -> dict:
        return {"spills": self.spills,
                "spill_bytes": self.spill_bytes,
                "wire_bytes": self.wire_bytes,
                "reloads": self.reloads,
                "reload_bytes": self.reload_bytes,
                "drops": self.drops,
                "resident_blocks": len(self._resident),
                "spilled_bytes_resident": self.resident_bytes}

    def host_restore_seconds(self, nbytes: int) -> float:
        """Simulated cost of restoring a block host-side (host read path)."""
        return 0.0

    def close(self) -> None:
        """Scheduler shutdown: release every payload and backing resource."""
        self._resident.clear()


# ======================================================================
class PeerDeviceTier(BackingTier):
    """Spill to the least-pressured *other* device over the D2D link.

    The fast tier: NVLink/P2P bandwidth (``SimHardware.d2d_gbps``, default
    50 GB/s) beats the PCIe D2H+H2D round trip several times over, and the
    block stays device-resident — reloading it is the ordinary migrate-stage
    D2D the runtime already performs for cross-device reads.  A block is
    accepted only when some other device can hold it *without* evicting
    (free budget room), so spills never cascade."""

    name = "peer-device"
    location = "device"

    def __init__(self, headroom: float = 1.0) -> None:
        super().__init__()
        #: fraction of a peer's budget the tier may fill (1.0 = up to budget).
        self.headroom = headroom

    def _target_for(self, nbytes: int, src_device: Optional[int]) -> Optional[int]:
        mem = self.mem
        if mem is None or mem.num_devices <= 1:
            return None
        best, best_key = None, None
        for d in range(mem.num_devices):
            if d == (src_device if src_device is not None else 0):
                continue
            pool = mem.pools[d]
            if pool.budget_bytes is not None:
                room = pool.budget_bytes * self.headroom - pool.resident_bytes
                if nbytes > room:
                    continue
            key = (mem.pressure(d), d)
            if best_key is None or key < best_key:
                best, best_key = d, key
        return best

    def can_accept(self, nbytes: int, src_device: Optional[int] = None) -> bool:
        return self._target_for(nbytes, src_device) is not None

    def plan_spill(self, block: Any) -> dict:
        nb = _nbytes(block)
        target = self._target_for(nb, getattr(block, "device_id", None))
        return {"transfer_bytes": nb,
                "config": {"tier": self.name, "spill_target": target},
                "target": target}

    # Peer blocks stay in the device pools; per-tier residency here only
    # feeds the ``spilled_bytes_resident`` pressure stat.


# ======================================================================
class CompressedHostTier(BackingTier):
    """Spill to host memory through a compressor.

    Two codecs, selected by the ``lossy`` exactness flag:

    * ``lossy=False`` (default) — **lossless** ``zlib`` bytes.  The wire
      cost is the full D2H copy (compression happens host-side), the
      round trip is bit-exact, only host RAM is saved.
    * ``lossy=True`` — **bf16 demotion** for float32 blocks: the mantissa
      is rounded (nearest-even) to 8 bits and only the top halfword is
      kept, so both the wire transfer and the host payload are half size.
      This reuses the demote-and-track-the-residual idiom of
      ``repro.optim.compress`` — but where gradient compression *carries*
      the residual into the next step (the same tensor is re-compressed
      every step), a spilled block is re-spilled only after being
      overwritten with unrelated data, so the residual is reported as an
      error bound (``max_abs_error``) instead of fed back.  Non-float32
      blocks fall back to lossless bytes — exactness is only ever traded
      where the flag explicitly allows it.

    ``capacity_bytes`` bounds the tier (by *logical* block bytes) so a
    stack like ``[CompressedHostTier(capacity_bytes=...), DiskTier()]``
    overflows to disk instead of growing host memory without bound.
    """

    name = "compressed-host"
    location = "host"

    def __init__(self, lossy: bool = False,
                 capacity_bytes: Optional[int] = None) -> None:
        super().__init__()
        self.lossy = lossy
        self.capacity_bytes = capacity_bytes
        self.stored_bytes = 0                  # physical payload bytes held
        self.lossy_blocks = 0
        self.max_abs_error = 0.0
        self._payload: Dict[int, Tuple[str, bytes, tuple, str]] = {}

    def can_accept(self, nbytes: int, src_device: Optional[int] = None) -> bool:
        if self.capacity_bytes is None:
            return True
        return self.resident_bytes + nbytes <= self.capacity_bytes

    def _wire_bytes(self, block: Any) -> int:
        nb = _nbytes(block)
        if self.lossy and str(getattr(block, "dtype", "")) == "float32":
            return nb // 2         # demotion happens device-side: half wire
        return nb

    def plan_spill(self, block: Any) -> dict:
        return {"transfer_bytes": self._wire_bytes(block),
                "config": {"tier": self.name}, "target": None}

    def reload_wire_bytes(self, block: Any) -> int:
        return self._wire_bytes(block)

    # -- physical ------------------------------------------------------
    def spill(self, block: Any) -> None:
        from .element import dep_key
        arr = _block_value(block)
        key = dep_key(block)
        if self.lossy and arr.dtype == np.float32:
            # bf16 demotion with round-to-nearest-even on the dropped bits.
            u = np.ascontiguousarray(arr).view(np.uint32)
            rounded = u + 0x7FFF + ((u >> 16) & 1)
            codes = (rounded >> 16).astype(np.uint16)
            approx = (codes.astype(np.uint32) << 16).view(np.float32)
            err = float(np.max(np.abs(arr - approx))) if arr.size else 0.0
            self.max_abs_error = max(self.max_abs_error, err)
            self.lossy_blocks += 1
            payload = ("bf16", codes.tobytes(), arr.shape, "float32")
        else:
            payload = ("zlib", zlib.compress(
                np.ascontiguousarray(arr).tobytes(), 1),
                arr.shape, str(arr.dtype))
        prev = self._payload.get(key)
        if prev is not None:
            self.stored_bytes -= len(prev[1])
        self._payload[key] = payload
        self.stored_bytes += len(payload[1])

    def _decode(self, key: int) -> np.ndarray:
        codec, raw, shape, dtype = self._payload[key]
        if codec == "bf16":
            codes = np.frombuffer(raw, np.uint16).reshape(shape)
            return (codes.astype(np.uint32) << 16).view(np.float32)
        return np.frombuffer(zlib.decompress(raw), dtype).reshape(shape)

    def peek(self, block: Any):
        from .element import dep_key
        key = dep_key(block)
        return self._decode(key) if key in self._payload else None

    def reload(self, block: Any) -> np.ndarray:
        from .element import dep_key
        key = dep_key(block)
        val = self._decode(key)
        host = getattr(block, "host", None)
        if host is not None:
            np.copyto(host, val)
        self.drop(key)
        return val

    def drop(self, key: int) -> None:
        payload = self._payload.pop(key, None)
        if payload is not None:
            self.stored_bytes -= len(payload[1])

    def stats(self) -> dict:
        out = super().stats()
        out.update({"lossy": self.lossy, "stored_bytes": self.stored_bytes})
        if self.lossy:
            out.update({"lossy_blocks": self.lossy_blocks,
                        "max_abs_error": self.max_abs_error})
        return out

    def close(self) -> None:
        super().close()
        self._payload.clear()
        self.stored_bytes = 0


# ======================================================================
class DiskTier(BackingTier):
    """Spill to memory-mapped ``.npy`` files under a spool directory.

    The last-resort tier for working sets bounded by *aggregate* rather
    than device (or even host) memory.  Every spool write is atomic
    (``blk_<key>.tmp`` then ``os.rename``, the checkpoint/manager.py
    idiom), which makes published payload files immutable-by-inode: the
    checkpoint manager snapshots a disk-resident block by *hard-linking*
    the spool file instead of copying it (snapshot-through-spill) and a
    later re-spill replaces the inode without touching the link.

    Spool files are removed on block reload/GC (weakref finalizers in
    memory.py) and the whole directory on ``close()`` (scheduler
    shutdown) — nothing leaks.  ``gbps`` is the simulated disk bandwidth:
    the D2H/H2D engine stays occupied for the whole spill/reload but runs
    at the slower disk rate (the dominating stage of the pipe)."""

    name = "disk"
    location = "host"

    def __init__(self, spool_dir: Optional[str] = None,
                 gbps: float = 3.0) -> None:
        super().__init__()
        self.gbps = gbps
        self._own_dir = spool_dir is None
        self.spool_dir = spool_dir or tempfile.mkdtemp(prefix="grjax_spool_")
        os.makedirs(self.spool_dir, exist_ok=True)
        self.files_written = 0
        self._files: Dict[int, str] = {}

    def can_accept(self, nbytes: int, src_device: Optional[int] = None) -> bool:
        return True

    def plan_spill(self, block: Any) -> dict:
        return {"transfer_bytes": _nbytes(block),
                "config": {"tier": self.name, "tier_gbps": self.gbps},
                "target": None}

    def path_for(self, key: int) -> Optional[str]:
        """Published spool file for ``key`` (checkpoint hard-link source)."""
        return self._files.get(key)

    # -- physical ------------------------------------------------------
    def spill(self, block: Any) -> None:
        from .element import dep_key
        key = dep_key(block)
        arr = _block_value(block)
        final = os.path.join(self.spool_dir, f"blk_{abs(key)}.npy")
        tmp = final + ".tmp"
        with open(tmp, "wb") as f:
            np.save(f, arr)
        os.rename(tmp, final)                  # atomic publish
        self._files[key] = final
        self.files_written += 1

    def peek(self, block: Any):
        from .element import dep_key
        path = self._files.get(dep_key(block))
        return np.load(path) if path else None

    def reload(self, block: Any) -> np.ndarray:
        from .element import dep_key
        key = dep_key(block)
        val = np.load(self._files[key], mmap_mode="r")
        val = np.array(val)                    # materialize off the mmap
        host = getattr(block, "host", None)
        if host is not None:
            np.copyto(host, val)
        self.drop(key)
        return val

    def drop(self, key: int) -> None:
        path = self._files.pop(key, None)
        if path is not None:
            try:
                os.remove(path)
            except OSError:       # pragma: no cover - already gone
                pass

    def host_restore_seconds(self, nbytes: int) -> float:
        return nbytes / (self.gbps * 1e9)

    def stats(self) -> dict:
        return dict(super().stats(), gbps=self.gbps,
                    files_written=self.files_written,
                    files_resident=len(self._files))

    def close(self) -> None:
        super().close()
        self._files.clear()
        if self._own_dir:
            shutil.rmtree(self.spool_dir, ignore_errors=True)
        else:
            for f in os.listdir(self.spool_dir):
                if f.startswith("blk_"):
                    try:
                        os.remove(os.path.join(self.spool_dir, f))
                    except OSError:  # pragma: no cover
                        pass


# ======================================================================
TIER_TYPES = {t.name: t for t in (PeerDeviceTier, CompressedHostTier,
                                  DiskTier)}


def make_tiers(spec) -> List[BackingTier]:
    """Normalize a ``spill_tiers`` argument: a list of tier instances
    and/or names ("peer-device" / "compressed-host" / "disk")."""
    if spec is None:
        return []
    tiers: List[BackingTier] = []
    for item in spec:
        if isinstance(item, BackingTier):
            tiers.append(item)
        elif isinstance(item, str):
            try:
                tiers.append(TIER_TYPES[item]())
            except KeyError:
                raise ValueError(f"unknown spill tier {item!r}; choose from "
                                 f"{sorted(TIER_TYPES)}") from None
        else:
            raise TypeError(f"spill tier must be a BackingTier or a name, "
                            f"got {item!r}")
    return tiers
