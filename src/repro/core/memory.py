"""Per-device memory manager — budgeted placement + transparent spill/evict.

The paper's scheduler transparently inserts data transfers without advance
knowledge of the program (§IV); this module extends the same mechanism to
the *capacity* dimension: each device gets a :class:`MemoryPool` with a
configurable byte budget, the submission pipeline reserves an element's
working set before DAG insertion, and under pressure the runtime
synthesizes DAG-ordered ``EVICT`` transfer elements (async D2H + drop of
the device copy) for least-recently-used victims — out-of-core working
sets then run unmodified, they just spill.

Two design rules keep this sound:

* **Logical residency is flipped at schedule time**, exactly like the
  location bits on :class:`~repro.core.managed.ManagedArray` (see the NOTE
  in managed.py): the scheduling thread knows what each scheduled element
  will produce, and worker threads only install physical values.
* **This manager is the single source of truth for location-bit
  transitions.**  Every path that used to flip ``host_valid`` /
  ``device_valid`` / ``device_id`` by hand (eager prefetch, D2D migration,
  kernel-output updates, capture replay, host overwrites) now goes through
  one ``note_*`` method that updates the bits *and* the resident-set
  accounting atomically — the two can no longer diverge, whichever path
  (eager, replayed, or capture-demoted) scheduled the element.

Budgets are opt-in: ``memory_budget=None`` (the default) tracks residency
for stats but never evicts, refuses no placements, and inserts no
elements — the pre-budget behaviour, bit for bit.
"""
from __future__ import annotations

import threading
import weakref
from collections import OrderedDict
from typing import (Any, Callable, Dict, Iterable, List, Mapping, Optional,
                    Sequence, Tuple, Union)

from .element import dep_key
from .tiers import BackingTier, make_tiers

Budget = Union[None, int, Mapping[int, Optional[int]]]


class DeviceOutOfMemoryError(RuntimeError):
    """An element's working set cannot fit any device's byte budget (even
    after evicting everything else) — the workload is not merely
    out-of-core, a *single* computational element is over-budget."""


class DriftReport:
    """Structured logical-vs-physical residency reconciliation.

    ``problems`` are the ledger inconsistencies; ``logical`` is the
    per-device byte count the pools account against their budgets;
    ``physical`` (when the check ran with ``physical=True``) is the
    per-device byte count of actually-installed device values."""

    def __init__(self, problems: List[str], logical: Dict[int, int],
                 physical: Optional[Dict[int, int]] = None) -> None:
        self.problems = list(problems)
        self.logical = dict(logical)
        self.physical = dict(physical) if physical is not None else None

    @property
    def ok(self) -> bool:
        return not self.problems

    def to_json(self) -> dict:
        return {"ok": self.ok, "problems": list(self.problems),
                "logical_bytes": dict(self.logical),
                "physical_bytes": (dict(self.physical)
                                   if self.physical is not None else None)}

    def __str__(self) -> str:
        if self.ok:
            return "memory ledger consistent"
        lines = [f"{len(self.problems)} memory-ledger problem(s):"]
        lines += [f"  - {p}" for p in self.problems]
        lines.append(f"  logical bytes/device: {self.logical}")
        if self.physical is not None:
            lines.append(f"  physical bytes/device: {self.physical}")
        return "\n".join(lines)


class MemoryDriftError(RuntimeError):
    """Raised by :meth:`MemoryManager.verify` when the logical ledger and
    the tracked array/tier state disagree.  Carries the full
    :class:`DriftReport` for the daemon's drift alarm path."""

    def __init__(self, report: DriftReport) -> None:
        self.report = report
        super().__init__(str(report))


class MemoryPool:
    """Resident-set tracker for one device: byte budget, LRU ordering and
    spill statistics.

    ``budget_bytes=None`` means unlimited (tracking only).  Stats:

    * ``resident_bytes`` — bytes currently (logically) resident;
    * ``peak_bytes``     — high-water mark of ``resident_bytes``;
    * ``spills``         — dirty evictions (device copy newer than host →
      an async D2H write-back was scheduled);
    * ``spill_bytes``    — bytes moved by those write-backs;
    * ``evict_blocks``   — arrays evicted in total (dirty + clean drops);
    * ``reloads``/``reload_bytes`` — re-uploads of previously evicted
      blocks (the *return* traffic spilling causes; reported separately
      from ``spill_bytes`` so eviction-policy quality is visible: a policy
      that spills dead blocks moves the same spill bytes but reloads none).
    """

    def __init__(self, device_id: int,
                 budget_bytes: Optional[int] = None) -> None:
        self.device_id = device_id
        self.budget_bytes = budget_bytes
        self.resident_bytes = 0
        self.peak_bytes = 0
        self.spills = 0
        self.spill_bytes = 0
        self.evict_blocks = 0
        self.reloads = 0
        self.reload_bytes = 0
        # key -> nbytes, insertion order == LRU order (oldest first); touch
        # moves a key to the MRU end.
        self._resident: "OrderedDict[int, int]" = OrderedDict()

    # -- residency -----------------------------------------------------
    def __contains__(self, key: int) -> bool:
        return key in self._resident

    def add(self, key: int, nbytes: int) -> None:
        prev = self._resident.pop(key, None)
        if prev is not None:
            self.resident_bytes -= prev
        self._resident[key] = nbytes
        self.resident_bytes += nbytes
        self.peak_bytes = max(self.peak_bytes, self.resident_bytes)

    def touch(self, key: int) -> None:
        if key in self._resident:
            self._resident.move_to_end(key)

    def discard(self, key: int) -> int:
        nbytes = self._resident.pop(key, None)
        if nbytes is None:
            return 0
        self.resident_bytes -= nbytes
        return nbytes

    def fits(self, working_set_bytes: int) -> bool:
        return (self.budget_bytes is None
                or working_set_bytes <= self.budget_bytes)

    def lru_keys(self) -> List[int]:
        return list(self._resident)

    @property
    def occupancy(self) -> float:
        """Resident/budget fraction (0.0 when unlimited)."""
        if not self.budget_bytes:
            return 0.0
        return self.resident_bytes / self.budget_bytes

    def stats(self) -> dict:
        return {"resident_bytes": self.resident_bytes,
                "peak_bytes": self.peak_bytes,
                "occupancy": self.occupancy,
                "spills": self.spills,
                "spill_bytes": self.spill_bytes,
                "evict_blocks": self.evict_blocks,
                "reloads": self.reloads,
                "reload_bytes": self.reload_bytes}


def _nbytes(array: Any) -> int:
    try:
        return int(getattr(array, "nbytes", 0))
    except TypeError:  # pragma: no cover - exotic duck types
        return 0


class MemoryManager:
    """Per-device :class:`MemoryPool` set + the location-bit transitions.

    ``budget`` is ``None`` (unlimited everywhere), one int (same budget on
    every device) or a ``{device_id: bytes | None}`` mapping (missing
    devices unlimited).  All methods are thread-safe: scheduling threads
    hold the submission-pipeline lock, but array finalizers (GC) may fire
    anywhere, so pool mutations take a private lock.
    """

    def __init__(self, num_devices: int = 1, budget: Budget = None,
                 tiers: Optional[Sequence[Any]] = None) -> None:
        self.num_devices = max(1, num_devices)
        if isinstance(budget, Mapping):
            per_dev = [budget.get(d) for d in range(self.num_devices)]
        else:
            per_dev = [budget] * self.num_devices
        self.pools: List[MemoryPool] = [
            MemoryPool(d, per_dev[d]) for d in range(self.num_devices)]
        self._lock = threading.RLock()
        # key -> (device, weakref) for every resident array; the weakref's
        # finalizer drops residency when an array is GC'd mid-episode, so
        # long-running serving loops cannot leak pool accounting.
        self._where: Dict[int, Tuple[int, "weakref.ref"]] = {}
        # Ordered spill stack (tiers.py).  Empty stack == PR 5 flat D2H.
        self.tiers: List[BackingTier] = make_tiers(tiers)
        for t in self.tiers:
            t.bind(self)
        # key -> (tier, weakref|None) for blocks a tier currently tracks.
        # Host tiers hold the block's only valid copy (backing_tier set on
        # the array); the peer tier only tracks membership for stats — the
        # block stays an ordinary device-resident entry in the peer's pool.
        # The weakref finalizer drops physical tier payloads (compressed
        # bytes, spool files) when an array is GC'd while spilled.
        self._tier_of: Dict[int, Tuple[BackingTier, Any]] = {}
        # Keys evicted off-device at some point and not yet re-uploaded:
        # the next h2d/d2d/reload of such a key is *return traffic caused
        # by eviction*, counted under the pool's reload stats.  Cleared on
        # host overwrite (the re-upload then carries new data, not a
        # reload) and on GC.
        self._evicted_keys: set = set()
        # Scheduled (plan-carried Belady EVICT elements replayed from a
        # captured plan) vs reactive (LRU reserve under live pressure)
        # eviction split, for the planopt benchmarks.
        self.evicts_scheduled = 0
        self.evicts_reactive = 0

    # ------------------------------------------------------------------
    @property
    def bounded(self) -> bool:
        """True when at least one device has a finite budget."""
        return any(p.budget_bytes is not None for p in self.pools)

    def pool(self, device: int) -> MemoryPool:
        return self.pools[min(max(0, int(device)), self.num_devices - 1)]

    def _on_dead(self, key: int) -> None:
        with self._lock:
            entry = self._where.pop(key, None)
            if entry is not None:
                self.pools[entry[0]].discard(key)
            self._tier_release(key)
            self._evicted_keys.discard(key)

    # -- tier stack ----------------------------------------------------
    def tier_named(self, name: str) -> Optional[BackingTier]:
        for t in self.tiers:
            if t.name == name:
                return t
        return None

    def select_tier(self, ma: Any):
        """Ask the ordered stack where a *dirty* victim should land.

        Returns ``(tier, plan)`` from the first tier that accepts the block
        (``plan`` is the tier's schedule-time spill description, see
        ``BackingTier.plan_spill``) or ``(None, None)`` — the flat PR 5
        D2H spill.  Clean victims never reach here: their bytes already
        live in the host buffer, so dropping the device copy is free."""
        nb = _nbytes(ma)
        src = getattr(ma, "device_id", None)
        for tier in self.tiers:
            if not tier.can_accept(nb, src):
                continue
            plan = tier.plan_spill(ma)
            if tier.location == "device" and plan.get("target") is None:
                continue        # raced out of peer room; try the next tier
            return tier, plan
        return None, None

    def _tier_release(self, key: int, reload: bool = False) -> None:
        """A tier-tracked block left its tier (reload, overwrite, GC,
        re-eviction).  Must hold the manager lock."""
        entry = self._tier_of.pop(key, None)
        if entry is None:
            return
        tier = entry[0]
        if reload:
            tier.note_reload(key)
        else:
            tier.note_release(key)
            tier.drop(key)      # physical payload is garbage now

    def _make_resident(self, ma: Any, device: int) -> None:
        nb = _nbytes(ma)
        if nb <= 0:
            return      # ManagedValue / zero-size arrays are never tracked
        key = dep_key(ma)
        device = min(max(0, int(device)), self.num_devices - 1)
        with self._lock:
            prev = self._where.get(key)
            if prev is not None and prev[0] != device:
                self.pools[prev[0]].discard(key)
            if prev is None or prev[0] != device:
                try:
                    ref = weakref.ref(ma, lambda _r, k=key: self._on_dead(k))
                except TypeError:       # plain test doubles without __weakref__
                    ref = (lambda m: (lambda: m))(ma)
                self._where[key] = (device, ref)
                self.pools[device].add(key, nb)
            else:
                self.pools[device].touch(key)

    def _drop_residency(self, ma: Any) -> None:
        key = dep_key(ma)
        with self._lock:
            entry = self._where.pop(key, None)
            if entry is not None:
                self.pools[entry[0]].discard(key)

    # ------------------------------------------------------------------
    # Location-bit transitions (single source of truth).  Each mirrors one
    # schedule-time update the runtime used to perform inline; callers —
    # eager pipeline, capture replay, host-write path — may not flip the
    # bits themselves.
    # ------------------------------------------------------------------
    def _note_return(self, key: int, device: int, nbytes: int) -> None:
        """Count a re-upload of a previously evicted key as reload traffic.
        Must hold the manager lock."""
        if key in self._evicted_keys:
            self._evicted_keys.discard(key)
            pool = self.pool(device)
            pool.reloads += 1
            pool.reload_bytes += nbytes

    def note_h2d(self, ma: Any, device: int) -> None:
        """An H2D prefetch of ``ma`` onto ``device`` was scheduled."""
        ma.device_valid = True
        ma.device_id = device
        with self._lock:
            self._note_return(dep_key(ma), device, _nbytes(ma))
        self._make_resident(ma, device)

    def note_d2d(self, ma: Any, device: int) -> None:
        """A D2D migration of ``ma`` onto ``device`` was scheduled (or an
        unowned device copy was claimed): single-copy ownership moves.
        A peer-tier-parked block consumed this way counts as its reload."""
        ma.device_id = device
        with self._lock:
            self._tier_release(dep_key(ma), reload=True)
            self._note_return(dep_key(ma), device, _nbytes(ma))
        self._make_resident(ma, device)

    def note_device_write(self, ma: Any, device: int) -> None:
        """A kernel writing ``ma`` on ``device`` was scheduled: the device
        copy becomes the only valid one (any tier payload is stale)."""
        ma.device_valid = True
        ma.host_valid = False
        ma.device_id = device
        if getattr(ma, "backing_tier", None) is not None:
            ma.backing_tier = None
        with self._lock:
            self._tier_release(dep_key(ma))
            # A write-only kernel re-materializes an evicted block with new
            # data; no bytes came back over the link, so not a reload.
            self._evicted_keys.discard(dep_key(ma))
        self._make_resident(ma, device)

    def note_evict(self, ma: Any, scheduled: bool = False) -> bool:
        """An EVICT of ``ma`` was scheduled: the device copy is dropped
        (after an async D2H write-back when it was the only valid copy).
        Returns True when the eviction was dirty (write-back needed).
        ``scheduled=True`` marks a plan-carried (Belady) eviction rather
        than a reactive LRU one — the split is reported in stats()."""
        dirty = not getattr(ma, "host_valid", True)
        device = getattr(ma, "device_id", None)
        pool = self.pool(device if device is not None else 0)
        ma.host_valid = True
        ma.device_valid = False
        ma.device_id = None
        self._drop_residency(ma)
        with self._lock:
            self._tier_release(dep_key(ma))
            self._evicted_keys.add(dep_key(ma))
            pool.evict_blocks += 1
            if scheduled:
                self.evicts_scheduled += 1
            else:
                self.evicts_reactive += 1
            if dirty:
                pool.spills += 1
                pool.spill_bytes += _nbytes(ma)
        return dirty

    def note_spill(self, ma: Any, tier: BackingTier,
                   target: Optional[int] = None,
                   wire_bytes: Optional[int] = None,
                   scheduled: bool = False) -> None:
        """A tiered spill of dirty ``ma`` was scheduled.

        Peer tier (``location == "device"``): the block becomes an ordinary
        device-resident entry on ``target`` — its host copy stays stale and
        the migrate stage's plain D2D brings it back when next consumed.

        Host tiers (compressed / disk): the tier payload becomes the only
        valid copy — host *and* device bits clear and ``backing_tier``
        names the holder, so consumers synthesize a RELOAD and capture
        slot-state distinguishes tier residency."""
        nb = _nbytes(ma)
        key = dep_key(ma)
        src = getattr(ma, "device_id", None)
        pool = self.pool(src if src is not None else 0)
        with self._lock:
            self._tier_release(key)     # re-spill replaces any old entry
            self._evicted_keys.add(key)
            pool.evict_blocks += 1
            if scheduled:
                self.evicts_scheduled += 1
            else:
                self.evicts_reactive += 1
            pool.spills += 1
            pool.spill_bytes += nb
            tier.note_spill(key, nb, nb if wire_bytes is None else wire_bytes)
            if tier.location == "device":
                ma.device_valid = True
                ma.device_id = target
                self._tier_of[key] = (tier, None)
                self._make_resident(ma, target if target is not None else 0)
                return
            ma.host_valid = False
            ma.device_valid = False
            ma.device_id = None
            ma.backing_tier = tier.name
            self._drop_residency(ma)
            try:
                ref = weakref.ref(ma, lambda _r, k=key: self._on_dead(k))
            except TypeError:           # plain test doubles
                ref = None
            self._tier_of[key] = (tier, ref)

    def note_reload(self, ma: Any, device: int) -> None:
        """A RELOAD of ``ma`` from its host tier onto ``device`` was
        scheduled: the tier handler restores the host buffer and the copy
        engine uploads it, so both copies become valid."""
        with self._lock:
            self._tier_release(dep_key(ma), reload=True)
            self._note_return(dep_key(ma), device, _nbytes(ma))
        ma.backing_tier = None
        ma.host_valid = True
        ma.device_valid = True
        ma.device_id = device
        self._make_resident(ma, device)

    def note_tier_to_host(self, ma: Any) -> None:
        """The host read a tier-resident block (no device upload): the tier
        handler restored ``ma.host`` and the payload is released."""
        with self._lock:
            self._tier_release(dep_key(ma), reload=True)
        ma.backing_tier = None
        ma.host_valid = True
        ma.device_valid = False
        ma.device_id = None

    def note_host_overwrite(self, ma: Any) -> None:
        """The host mutated ``ma.host``: the device copy (if any) is stale
        and no device owns a valid copy anymore (see managed.py for why
        ``device_id`` must clear too).  Any tier payload is stale with it."""
        ma.host_valid = True
        if ma.device_valid or ma.device_id is not None:
            ma.device_valid = False
            ma.device_id = None
        if getattr(ma, "backing_tier", None) is not None:
            ma.backing_tier = None
        with self._lock:
            self._tier_release(dep_key(ma))
            # The next upload carries *new* host data — not reload traffic.
            self._evicted_keys.discard(dep_key(ma))
        self._drop_residency(ma)

    # ------------------------------------------------------------------
    # Budget planning (placement + the submission pipeline's reserve stage)
    # ------------------------------------------------------------------
    def _distinct_args(self, args: Sequence[Any], device: int):
        """Yield ``(key, nbytes, resident_on_device)`` per distinct sized
        argument — the one accounting rule behind working-set size,
        placement pressure and the reserve stage.  Callers needing the
        residency flag must hold the manager lock."""
        seen = set()
        for a in args:
            ma = a.array
            nb = _nbytes(ma)
            k = dep_key(ma)
            if nb <= 0 or k in seen:
                continue
            seen.add(k)
            entry = self._where.get(k)
            yield k, nb, (entry is not None and entry[0] == device)

    def working_set_bytes(self, args: Sequence[Any]) -> int:
        """Bytes that must be simultaneously resident to run one element:
        every distinct argument's nbytes (reads are uploaded/migrated,
        outputs materialize on-device)."""
        return sum(nb for _, nb, _ in self._distinct_args(args, -1))

    def device_fits(self, device: int, working_set_bytes: int) -> bool:
        return self.pool(device).fits(working_set_bytes)

    def pressure(self, device: int) -> float:
        """Occupancy fraction of the device's budget (0.0 when unlimited)."""
        pool = self.pool(device)
        if pool.budget_bytes is None or pool.budget_bytes <= 0:
            return 0.0
        return pool.resident_bytes / pool.budget_bytes

    def placement_pressure(self, device: int, args: Sequence[Any]) -> float:
        """Budget fraction the device would reach after hosting ``args``
        (incoming = argument bytes not already resident there)."""
        pool = self.pool(device)
        if pool.budget_bytes is None or pool.budget_bytes <= 0:
            return 0.0
        with self._lock:
            incoming = sum(nb for _, nb, here in
                           self._distinct_args(args, pool.device_id)
                           if not here)
        return (pool.resident_bytes + incoming) / pool.budget_bytes

    def plan_fits(self, device_mem: Iterable[Tuple[int, int]]) -> bool:
        """Whether a captured plan's recorded per-device peak bytes fit the
        current budgets (capture/replay gating)."""
        return all(self.pool(d).fits(peak) for d, peak in device_mem)

    def reserve(self, device: int, element: Any,
                is_frontier: Optional[Callable[[int], bool]] = None,
                extra_pinned: Optional[Iterable[int]] = None) -> List[Any]:
        """Reserve ``element``'s working set on ``device``; under pressure,
        pick LRU victims to evict (non-frontier arrays first — arrays still
        referenced by in-flight DAG work are spilled only as a last resort,
        the DAG ordering of the EVICT element keeps even that correct).

        ``extra_pinned`` keys are additionally exempt from eviction without
        counting toward the element's working set (the replay fast path
        pins every plan-bound array: a replayed episode may evict stale
        *foreign* leftovers, never its own schedule's data).

        Returns the victim arrays (the pipeline synthesizes one EVICT
        element per victim); raises :class:`DeviceOutOfMemoryError` when
        the element's own working set exceeds the budget outright."""
        pool = self.pool(device)
        if pool.budget_bytes is None:
            return []
        pinned: Dict[int, int] = {}
        incoming = 0
        with self._lock:
            for k, nb, here in self._distinct_args(element.args,
                                                   pool.device_id):
                pinned[k] = nb
                if here:
                    pool.touch(k)
                else:
                    incoming += nb
            ws = sum(pinned.values())
            if ws > pool.budget_bytes:
                raise DeviceOutOfMemoryError(
                    f"element {getattr(element, 'name', '?')!r} needs "
                    f"{ws} bytes resident at once on device "
                    f"{pool.device_id}, budget is {pool.budget_bytes}")
            need = pool.resident_bytes + incoming - pool.budget_bytes
            if need <= 0:
                return []
            no_evict = set(pinned)
            if extra_pinned is not None:
                no_evict.update(extra_pinned)
            victims: List[Any] = []
            # Two LRU passes: non-frontier arrays first, then (only if the
            # budget still cannot be met) arrays with live DAG readers.
            for frontier_pass in (False, True):
                if need <= 0:
                    break
                for k in pool.lru_keys():
                    if need <= 0:
                        break
                    if k in no_evict:
                        continue
                    if (not frontier_pass and is_frontier is not None
                            and is_frontier(k)):
                        continue
                    entry = self._where.get(k)
                    ma = entry[1]() if entry is not None else None
                    freed = pool.discard(k)
                    self._where.pop(k, None)
                    need -= freed
                    if ma is not None:
                        victims.append(ma)
            return victims

    def reserve_bytes(self, device: int, peak: int,
                      is_frontier: Optional[Callable[[int], bool]] = None,
                      extra_pinned: Optional[Iterable[int]] = None
                      ) -> List[Any]:
        """Make room for ``peak`` bytes on ``device`` up front (the whole-
        plan analogue of :meth:`reserve`): evict LRU victims — non-frontier
        first — until the *non-pinned* resident bytes fit beside ``peak``.

        Used by ``SubmissionPipeline.reserve_plan`` before replaying a
        Belady-scheduled plan: the plan's own slots are in ``extra_pinned``
        (their bytes are part of ``peak`` already), so only foreign
        leftovers from earlier episodes are evicted.  Returns the victim
        arrays; never raises — ``plan_fits`` gating already checked
        ``peak <= budget``."""
        pool = self.pool(device)
        if pool.budget_bytes is None:
            return []
        no_evict = set(extra_pinned) if extra_pinned is not None else set()
        with self._lock:
            pinned_res = sum(nb for k, nb in pool._resident.items()
                             if k in no_evict)
            need = (pool.resident_bytes - pinned_res) \
                - (pool.budget_bytes - peak)
            if need <= 0:
                return []
            victims: List[Any] = []
            for frontier_pass in (False, True):
                if need <= 0:
                    break
                for k in pool.lru_keys():
                    if need <= 0:
                        break
                    if k in no_evict:
                        continue
                    if (not frontier_pass and is_frontier is not None
                            and is_frontier(k)):
                        continue
                    entry = self._where.get(k)
                    ma = entry[1]() if entry is not None else None
                    freed = pool.discard(k)
                    self._where.pop(k, None)
                    need -= freed
                    if ma is not None:
                        victims.append(ma)
            return victims

    # ------------------------------------------------------------------
    def stats(self) -> dict:
        agg = {"resident_bytes": 0, "peak_bytes": 0, "spills": 0,
               "spill_bytes": 0, "evict_blocks": 0, "reloads": 0,
               "reload_bytes": 0}
        per = {}
        bounded_res = bounded_budget = 0
        for p in self.pools:
            s = p.stats()
            per[p.device_id] = dict(s, budget_bytes=p.budget_bytes)
            for k in agg:
                agg[k] += s[k]
            if p.budget_bytes:
                bounded_res += p.resident_bytes
                bounded_budget += p.budget_bytes
        out = {f"mem_{k}": v for k, v in agg.items()}
        out["mem_evicts_scheduled"] = self.evicts_scheduled
        out["mem_evicts_reactive"] = self.evicts_reactive
        # Pressure alarm input: resident/budget over the *bounded* pools
        # (0.0 when every pool is unlimited, like MemoryPool.occupancy).
        out["mem_occupancy"] = (bounded_res / bounded_budget
                                if bounded_budget else 0.0)
        if self.num_devices > 1:
            out["mem_per_device"] = per
        if self.tiers:
            out["mem_tiers"] = {t.name: t.stats() for t in self.tiers}
        return out

    def logical_resident_bytes(self) -> Dict[int, int]:
        """Per-device bytes the *logical* ledger says are resident —
        exactly what the pools account against their budgets."""
        with self._lock:
            return {p.device_id: p.resident_bytes for p in self.pools}

    def physical_resident_bytes(self) -> Dict[int, int]:
        """Per-device bytes *physically installed*: resident-tracked arrays
        whose device value object actually exists.  On the real executor at
        a quiescent point this must equal :meth:`logical_resident_bytes`
        (the daemon monitor's drift check); the simulator installs no
        physical values, and a mid-flight real run legitimately lags."""
        out: Dict[int, int] = {p.device_id: 0 for p in self.pools}
        with self._lock:
            for _k, (dev, ref) in self._where.items():
                ma = ref() if callable(ref) else None
                if ma is None or getattr(ma, "device", None) is None:
                    continue
                out[dev] = out.get(dev, 0) + _nbytes(ma)
        return out

    def verify(self, *, raise_on_drift: bool = True,
               physical: bool = False) -> DriftReport:
        """Reconcile logical residency (array location bits, tier
        membership) against the pool ledger; with ``physical=True`` also
        diff the logical byte counts against physically-installed device
        values (only meaningful at a quiescent point on the real
        executor).  Returns a :class:`DriftReport`; raises
        :class:`MemoryDriftError` on any problem unless
        ``raise_on_drift=False`` (the daemon monitor's alarm path reads
        the report instead of unwinding the sampler)."""
        problems: List[str] = []
        with self._lock:
            for p in self.pools:
                ledger = sum(p._resident.values())
                if ledger != p.resident_bytes:
                    problems.append(
                        f"pool {p.device_id}: resident_bytes="
                        f"{p.resident_bytes} but ledger sums to {ledger}")
                for k in p._resident:
                    entry = self._where.get(k)
                    if entry is None:
                        problems.append(f"pool {p.device_id}: key {k} "
                                        f"resident but untracked in _where")
                    elif entry[0] != p.device_id:
                        problems.append(
                            f"key {k} in pool {p.device_id} but _where says "
                            f"device {entry[0]}")
            for k, (dev, ref) in self._where.items():
                if k not in self.pools[dev]._resident:
                    problems.append(f"_where key {k} on device {dev} "
                                    f"missing from that pool's ledger")
                ma = ref() if callable(ref) else None
                if ma is None:
                    continue
                if not getattr(ma, "device_valid", True):
                    problems.append(f"{getattr(ma, 'name', k)}: resident on "
                                    f"device {dev} but device_valid is False")
                elif getattr(ma, "device_id", dev) != dev:
                    problems.append(
                        f"{getattr(ma, 'name', k)}: pool says device {dev}, "
                        f"array says {ma.device_id}")
            for k, (tier, ref) in self._tier_of.items():
                if not tier.holds(k):
                    problems.append(f"key {k} tracked by tier {tier.name} "
                                    f"but the tier's ledger dropped it")
                if tier.location == "device":
                    if k not in self._where:
                        problems.append(f"peer-tier key {k} not device-"
                                        f"resident anywhere")
                    continue
                if k in self._where:
                    problems.append(f"{tier.name}-tier key {k} still "
                                    f"device-resident")
                ma = ref() if callable(ref) else None
                if ma is not None and \
                        getattr(ma, "backing_tier", None) != tier.name:
                    problems.append(
                        f"{getattr(ma, 'name', k)}: tier ledger says "
                        f"{tier.name}, array says {ma.backing_tier!r}")
            for t in self.tiers:
                mine = {k for k, (tt, _r) in self._tier_of.items() if tt is t}
                for k in list(t._resident):
                    if k not in mine:
                        problems.append(f"tier {t.name} holds key {k} the "
                                        f"manager does not track")
        logical = self.logical_resident_bytes()
        phys: Optional[Dict[int, int]] = None
        if physical:
            phys = self.physical_resident_bytes()
            for dev, lb in sorted(logical.items()):
                pb = phys.get(dev, 0)
                if pb != lb:
                    problems.append(
                        f"device {dev}: logical ledger says {lb} resident "
                        f"bytes but {pb} bytes are physically installed")
        report = DriftReport(problems, logical, phys)
        if problems and raise_on_drift:
            raise MemoryDriftError(report)
        return report

    def close(self) -> None:
        """Release every tier's backing resources (spool directories,
        compressed payloads).  Called from ``GrScheduler.shutdown()``."""
        with self._lock:
            self._tier_of.clear()
        for t in self.tiers:
            t.close()
