"""Plan-time global optimization of captured execution plans.

The paper's scheduler is deliberately greedy: each computational element is
placed and ordered as it arrives, with no knowledge of the future DAG.
Capture (capture.py) changes the information available — at plan
finalization the runtime holds the *entire* episode: every dependency,
every array's full access order, every per-device byte footprint.  This
module spends that information once per recorded plan, in two stages:

**Stage 1 — placement.**  Kernels are vertices of a graph whose edge
weights are the bytes that would cross the D2D link if the endpoints land
on different devices (consecutive accesses of one array under the
single-copy ownership model drag the array along).  A KL/FM-style min-cut
refinement (pure Python — gain-ordered moves with per-pass rollback to the
best prefix, so the search can climb out of local minima) improves the
greedy assignment subject to a load-balance cap on per-device compute and
to user pins (``with_options(device=...)`` launches never move; replay
matching would reject the retarget).  Grounded in "A Graph-Partition-Based
Scheduling Policy for Heterogeneous Architectures" (PAPERS.md).

**Stage 2 — memory.**  For budgeted replays the reactive LRU reserve is
replaced with Belady's algorithm computed from the plan's exact future
access order: victims are the blocks whose next *read* is farthest away
(dead blocks first, clean before dirty), evictions carry only the victim's
own frontier as dependencies — so the DAG lets them run as early as the
buffer goes dead — and the re-upload of a previously evicted block is
issued as a ``reload_*`` transfer whose only dependency is the eviction's
write-back, so it overlaps earlier compute instead of stalling the
consuming kernel.

The rewritten plan is re-synthesized from scratch (movement elements,
dependencies, lanes, ``device_mem``) by replaying the same state machine
the eager pipeline runs, which guarantees DAG-equivalence by construction:
every RAW/WAR/WAW ordering between original kernels is re-derived from the
same access modes.  The optimizer is strictly conservative: if the rewrite
does not *strictly* reduce total moved bytes (D2D + spill write-backs +
re-uploads), or the plan contains structures it does not model (tiered
spills, library/host elements), the original plan object is returned
untouched — ``plan_optimize=False`` and eager execution stay bit-identical
by the same token.
"""
from __future__ import annotations

from bisect import bisect_right
from typing import Dict, List, Optional, Sequence, Set, Tuple

from .capture import (ExecutionPlan, PlanElement, _PLAN_IDS, _Draft,
                      _assign_plan_lanes, _plan_device_mem, freeze_config)
from .element import AccessMode, DEFAULT_TENANT, ElementKind

# Kinds the re-synthesis state machine models.  RELOAD is deliberately
# absent: it only appears in tiered-spill plans, which the optimizer skips
# (tier choice depends on runtime stack state the plan cannot re-derive).
_MODELED_KINDS = frozenset((ElementKind.KERNEL, ElementKind.TRANSFER,
                            ElementKind.D2D, ElementKind.EVICT))

_FM_PASSES = 8
_BALANCE_TOL = 0.25     # per-device compute may exceed the mean by 25%
_INF = float("inf")


# ======================================================================
# Entry point
# ======================================================================

def optimize_plan(sched, plan: ExecutionPlan) -> ExecutionPlan:
    """Rewrite ``plan`` with globally-optimized placement and memory
    scheduling.  Returns the *same object* when the plan is out of scope
    (tiered spills, host/library elements, already optimized) or when the
    rewrite is not strictly better — callers can rely on ``is`` to detect
    a no-op."""
    if not _eligible(plan):
        return plan
    kpos = plan.kernel_positions
    kernels = [plan.elements[i] for i in kpos]
    assign = [pe.device for pe in kernels]
    moved = False
    if sched.num_devices > 1 and len(kernels) > 1:
        refined = _refine_placement(plan, kernels, sched)
        if refined is not None:
            assign = refined
            moved = True
    bounded = sched.memory.bounded
    has_evict = any(pe.kind is ElementKind.EVICT for pe in plan.elements)
    if not moved and not (bounded and has_evict):
        return plan     # nothing the rewrite could improve
    new = _resynthesize(sched, plan, kernels, assign)
    if new is None:
        return plan
    if bounded and not sched.memory.plan_fits(new.device_mem):
        return plan     # safety net: never adopt an over-budget rewrite
    if _moved_bytes(new) >= _moved_bytes(plan):
        return plan     # strictly-better or keep the greedy trace
    if getattr(sched, "sanitize", False):
        # Sanitize mode: a rewrite must pass the happens-before/liveness
        # verifier before it can replace the (already verified) greedy
        # trace — a planopt bug must never reach the replay fast path.
        from ..analysis.verifier import PlanVerificationError, verify_plan
        violations = verify_plan(new)
        if violations:
            raise PlanVerificationError(new.name, violations)
    return new


def _eligible(plan: ExecutionPlan) -> bool:
    if not plan.kernel_positions or plan.optimized:
        return False
    for pe in plan.elements:
        if pe.kind not in _MODELED_KINDS:
            return False
        if pe.kind is ElementKind.EVICT and dict(pe.config).get("tier"):
            return False        # tiered spill: stack-state dependent
    return all(spec.tier is None for spec in plan.slots)


def _moved_bytes(plan: ExecutionPlan) -> int:
    """Total bytes the plan moves over any link (H2D uploads, D2D
    migrations, spill write-backs, tier reloads) — the objective the
    optimizer must strictly reduce before its rewrite is adopted."""
    return sum(pe.transfer_bytes for pe in plan.elements
               if pe.kind in (ElementKind.TRANSFER, ElementKind.D2D,
                              ElementKind.EVICT, ElementKind.RELOAD))


# ======================================================================
# Stage 1 — min-cut placement refinement (KL/FM style)
# ======================================================================

def _refine_placement(plan: ExecutionPlan, kernels: Sequence[PlanElement],
                      sched) -> Optional[List[int]]:
    """Return an improved device assignment for ``kernels`` (kernel-order
    list), or None when the greedy assignment is already minimal."""
    ndev = sched.num_devices
    # Adjacency: for each slot, consecutive distinct accessors form an edge
    # weighted by the slot's bytes — under single-copy ownership *any*
    # consecutive pair on different devices costs one migration of the
    # array (even read->read: the copy moves, it is not replicated).  A
    # slot captured device-resident contributes a fixed "pin" edge from its
    # holding device to the first accessor; host-resident slots cost the
    # same H2D wherever the first accessor lands, so they contribute no
    # edge at all.
    adj: List[List[Tuple[object, int]]] = [[] for _ in kernels]
    chains: Dict[int, List[int]] = {}
    for pos, pe in enumerate(kernels):
        seen: Set[int] = set()
        for slot, _mode in pe.arg_slots:
            if slot in seen:
                continue
            seen.add(slot)
            chain = chains.setdefault(slot, [])
            if not chain or chain[-1] != pos:
                chain.append(pos)
    for slot, chain in chains.items():
        spec = plan.slots[slot]
        nb = spec.nbytes
        if nb <= 0:
            continue
        prev: object = None
        if spec.device_valid:
            prev = ("pin", spec.device_id if spec.device_id is not None else 0)
        for pos in chain:
            if isinstance(prev, tuple):
                adj[pos].append((prev, nb))
            elif prev is not None:
                adj[pos].append((prev, nb))
                adj[prev].append((pos, nb))
            prev = pos

    assign = [pe.device for pe in kernels]
    locked = [pe.pinned for pe in kernels]
    costs = [max(float(pe.cost_s), 0.0) for pe in kernels]
    return _fm_refine(assign, adj, costs, locked, ndev)


def _cut(assign: List[int], adj: List[List[Tuple[object, int]]]) -> int:
    total = 0
    for i, edges in enumerate(adj):
        for nbr, w in edges:
            if isinstance(nbr, tuple):
                if assign[i] != nbr[1]:
                    total += w
            elif nbr > i and assign[i] != assign[nbr]:
                total += w          # symmetric edges stored twice, count once
    return total


def _gain(i: int, target: int, assign: List[int],
          adj: List[List[Tuple[object, int]]]) -> int:
    """Cut reduction from moving kernel ``i`` to ``target``."""
    here = assign[i]
    g = 0
    for nbr, w in adj[i]:
        nd = nbr[1] if isinstance(nbr, tuple) else assign[nbr]
        if nd == here:
            g -= w              # edge becomes cut
        elif nd == target:
            g += w              # edge becomes internal
    return g


def _fm_refine(assign: List[int], adj: List[List[Tuple[object, int]]],
               costs: List[float], locked: List[bool], ndev: int
               ) -> Optional[List[int]]:
    """Fiduccia–Mattheyses-style refinement generalized to ``ndev`` parts.

    Each pass greedily applies the single best-gain feasible move (possibly
    negative — that is what lets the search traverse ridges), freezing each
    moved vertex, then rolls back to the best prefix of the move sequence.
    Passes repeat until one fails to improve.  Feasibility = the balance
    cap: a device's summed kernel cost may not exceed the mean by more than
    ``_BALANCE_TOL`` (unless the move still leaves it lighter than the
    source — rebalancing toward the mean is always allowed)."""
    n = len(assign)
    total_cost = sum(costs)
    # Standard FM balance criterion: a device may exceed the mean by the
    # tolerance *or* by one maximal cell, whichever is larger — without the
    # one-cell slack, a perfectly balanced swap (A->B then B->A) could
    # never pass through its intermediate state on equal-cost kernels.
    mean = total_cost / ndev
    cap = mean + max(max(costs) if costs else 0.0, mean * _BALANCE_TOL)
    cur = list(assign)
    cur_cut = _cut(cur, adj)
    start_cut = cur_cut
    for _ in range(_FM_PASSES):
        loads = [0.0] * ndev
        for i, d in enumerate(cur):
            loads[d] += costs[i]
        frozen = list(locked)
        history: List[Tuple[int, int, int]] = []
        pass_cut = cur_cut
        best_cut, best_len = cur_cut, 0
        while True:
            pick = None
            for i in range(n):
                if frozen[i]:
                    continue
                src = cur[i]
                for dst in range(ndev):
                    if dst == src:
                        continue
                    after = loads[dst] + costs[i]
                    if after > cap and after > loads[src]:
                        continue        # would unbalance the target
                    g = _gain(i, dst, cur, adj)
                    if pick is None or g > pick[0]:
                        pick = (g, i, dst)
            if pick is None:
                break
            g, i, dst = pick
            src = cur[i]
            cur[i] = dst
            loads[src] -= costs[i]
            loads[dst] += costs[i]
            frozen[i] = True
            pass_cut -= g
            history.append((i, src, dst))
            if pass_cut < best_cut:
                best_cut, best_len = pass_cut, len(history)
        for i, src, _dst in reversed(history[best_len:]):
            cur[i] = src            # roll back past the best prefix
        if best_cut >= cur_cut:
            break                   # the pass found nothing better
        cur_cut = best_cut
    if cur_cut < start_cut:
        return cur
    return None


# ======================================================================
# Stage 2 — re-synthesis with Belady memory scheduling
# ======================================================================

def _resynthesize(sched, plan: ExecutionPlan,
                  kernels: Sequence[PlanElement], assign: Sequence[int]
                  ) -> Optional[ExecutionPlan]:
    """Rebuild the plan for the (possibly new) device assignment.

    Walks the kernels in original order through the same state machine the
    eager pipeline runs (reserve -> upload -> migrate -> kernel), with two
    substitutions: victims are chosen by Belady's farthest-next-read rule
    instead of LRU, and re-uploads of previously evicted blocks are named
    ``reload_*`` (they carry only the eviction's write-back as a
    dependency, so batch submission starts them as early as the DAG
    allows — the prefetch-ahead overlap).  Residency accounting mirrors
    ``_plan_device_mem``'s list-order walk exactly, so the rebuilt plan's
    recorded peak is over-budget only if a single kernel's working set is
    (in which case — or on any other unmodeled structure — None is
    returned and the greedy plan stands)."""
    slots = plan.slots
    nslots = len(slots)
    ndev = sched.num_devices
    auto_upload = sched.auto_prefetch or ndev > 1
    budgets = [p.budget_bytes for p in sched.memory.pools]

    # -- dynamic slot state ------------------------------------------------
    host_valid = [s.host_valid for s in slots]
    device_valid = [s.device_valid for s in slots]
    device_id: List[Optional[int]] = [
        (s.device_id if s.device_id is not None else 0) if s.device_valid
        else None for s in slots]
    last_writer: List[Optional[int]] = [None] * nslots
    readers: List[List[int]] = [[] for _ in range(nslots)]
    evicted_once: Set[int] = set()

    resident: Dict[int, int] = {}       # slot -> device (sized slots only)
    res_bytes = [0] * ndev
    for s in slots:
        if s.device_valid and s.nbytes > 0:
            d = s.device_id if s.device_id is not None else 0
            resident[s.index] = d
            res_bytes[d] += s.nbytes

    # Belady oracle: kernel-order positions at which each slot is *read*
    # (a future write-only access needs no reload, so it must not keep a
    # victim resident).
    reads_at: List[List[int]] = [[] for _ in range(nslots)]
    for pos, pe in enumerate(kernels):
        seen: Set[int] = set()
        for slot, mode in pe.arg_slots:
            if mode.reads and slot not in seen:
                seen.add(slot)
                reads_at[slot].append(pos)

    drafts: List[_Draft] = []

    def add_draft(kind, name, arg_slots, dep_modes, device, *,
                  src_device=None, transfer_bytes=0, raw=None, config=None,
                  cost_s=0.0, fn=None, priority=0, tenant=DEFAULT_TENANT,
                  deadline_s=None, fn_key=None, pinned=False) -> None:
        raw = {} if raw is None else raw
        idx = len(drafts)
        parents: Dict[int, None] = {}   # insertion-ordered de-dup
        for slot, mode in dep_modes:
            lw = last_writer[slot]
            if lw is not None:
                parents.setdefault(lw)
            if mode.writes:
                for r in readers[slot]:
                    parents.setdefault(r)
        drafts.append(_Draft(
            index=idx, kind=kind, name=name,
            config=freeze_config(raw) if config is None else config,
            cost_s=cost_s, transfer_bytes=transfer_bytes,
            arg_slots=tuple(arg_slots), device=device, src_device=src_device,
            parents=tuple(parents), fn=fn, raw_config=raw,
            priority=priority, tenant=tenant, deadline_s=deadline_s,
            fn_key=fn_key, pinned=pinned))
        for slot, mode in dep_modes:
            if mode.writes:
                last_writer[slot] = idx
                readers[slot] = []
            else:
                readers[slot].append(idx)

    for pos, pe in enumerate(kernels):
        d = assign[pos]
        orig = plan.kernel_positions[pos]
        # Merged strongest mode per distinct slot (element.arg_modes rule).
        merged: Dict[int, AccessMode] = {}
        for slot, mode in pe.arg_slots:
            prev = merged.get(slot)
            if prev is None or (mode.writes and not prev.writes):
                merged[slot] = mode
        for slot, mode in merged.items():
            if mode.reads and not host_valid[slot] and not device_valid[slot]:
                return None     # location state the machine does not model

        # ---- Belady reserve (budgeted target device only) ----
        budget = budgets[d] if d < len(budgets) else None
        if budget is not None:
            ws = incoming = 0
            ws_slots: Set[int] = set()
            for slot in merged:
                nb = slots[slot].nbytes
                if nb <= 0:
                    continue
                ws_slots.add(slot)
                ws += nb
                if resident.get(slot) != d:
                    incoming += nb
            if ws > budget:
                return None     # single-element OOM: greedy raises too
            need = res_bytes[d] + incoming - budget
            if need > 0:
                def victim_key(s: int, pos: int = pos) -> Tuple:
                    i = bisect_right(reads_at[s], pos)
                    nxt = reads_at[s][i] if i < len(reads_at[s]) else _INF
                    dirty = device_valid[s] and not host_valid[s]
                    return (-nxt, dirty, s)     # farthest first, clean first
                cands = sorted((s for s, dev in resident.items()
                                if dev == d and s not in ws_slots),
                               key=victim_key)
                for s in cands:
                    if need <= 0:
                        break
                    nb = slots[s].nbytes
                    dirty = device_valid[s] and not host_valid[s]
                    add_draft(ElementKind.EVICT, f"evict_{slots[s].name}",
                              ((s, AccessMode.INOUT),),
                              ((s, AccessMode.INOUT),), d,
                              transfer_bytes=nb if dirty else 0,
                              raw={"writeback": dirty},
                              priority=pe.priority, tenant=pe.tenant,
                              deadline_s=pe.deadline_s)
                    host_valid[s] = True
                    device_valid[s] = False
                    device_id[s] = None
                    del resident[s]
                    res_bytes[d] -= nb
                    evicted_once.add(s)
                    need -= nb
                if need > 0:
                    return None     # nothing evictable enough

        # ---- uploads & migrations for read slots ----
        for slot, mode in merged.items():
            if not mode.reads:
                continue
            nb = slots[slot].nbytes
            if host_valid[slot] and not device_valid[slot]:
                if not auto_upload:
                    continue        # fault-driven mode reads host in place
                name = (f"reload_{slots[slot].name}"
                        if slot in evicted_once
                        else f"h2d_{slots[slot].name}")
                add_draft(ElementKind.TRANSFER, name,
                          ((slot, AccessMode.INOUT),),
                          ((slot, AccessMode.INOUT),), d,
                          transfer_bytes=nb,
                          priority=pe.priority, tenant=pe.tenant,
                          deadline_s=pe.deadline_s)
                device_valid[slot] = True
                device_id[slot] = d
                if nb > 0:
                    resident[slot] = d
                    res_bytes[d] += nb
            elif device_valid[slot] and device_id[slot] != d:
                src = device_id[slot]
                add_draft(ElementKind.D2D, f"d2d_{slots[slot].name}",
                          ((slot, AccessMode.INOUT),),
                          ((slot, AccessMode.INOUT),), d,
                          src_device=src, transfer_bytes=nb,
                          priority=pe.priority, tenant=pe.tenant,
                          deadline_s=pe.deadline_s)
                device_id[slot] = d
                if nb > 0:
                    if resident.get(slot) == src:
                        res_bytes[src] -= nb
                    resident[slot] = d
                    res_bytes[d] += nb

        # ---- the kernel itself ----
        add_draft(ElementKind.KERNEL, pe.name, pe.arg_slots, merged.items(),
                  d, transfer_bytes=pe.transfer_bytes, config=pe.config,
                  raw=plan.configs[orig], cost_s=pe.cost_s,
                  fn=plan.fns[orig], priority=pe.priority, tenant=pe.tenant,
                  deadline_s=pe.deadline_s, fn_key=pe.fn_key, pinned=pe.pinned)
        for slot, mode in merged.items():
            if not mode.writes:
                continue
            nb = slots[slot].nbytes
            was = resident.get(slot)
            host_valid[slot] = False
            device_valid[slot] = True
            device_id[slot] = d
            if nb > 0 and was != d:
                if was is not None:
                    res_bytes[was] -= nb
                resident[slot] = d
                res_bytes[d] += nb

    placed, lane_devices = _assign_plan_lanes(drafts)
    elements = tuple(PlanElement(
        index=dr.index, kind=dr.kind, name=dr.name, config=dr.config,
        cost_s=dr.cost_s, transfer_bytes=dr.transfer_bytes,
        arg_slots=dr.arg_slots, lane=lane, device=dr.device,
        src_device=dr.src_device, parents=dr.parents, wait_events=events,
        priority=dr.priority, tenant=dr.tenant, deadline_s=dr.deadline_s,
        fn_key=dr.fn_key, pinned=dr.pinned)
        for dr, (lane, events) in zip(drafts, placed))
    return ExecutionPlan(
        name=plan.name, key=f"{plan.name}#{next(_PLAN_IDS)}",
        elements=elements, slots=slots,
        fns=tuple(dr.fn for dr in drafts),
        configs=tuple(dr.raw_config for dr in drafts),
        slot_arrays=plan.slot_arrays, lane_devices=lane_devices,
        kernel_positions=tuple(i for i, dr in enumerate(drafts)
                               if dr.kind is ElementKind.KERNEL),
        device_mem=_plan_device_mem(drafts, slots),
        optimized=True, mem_scheduled=sched.memory.bounded)
