"""Computational elements — the vertices of the runtime DAG (paper §IV-A).

A *computational element* is anything the scheduler must order: a device
kernel invocation, a host access to a managed array, a host-to-device
transfer (prefetch), or a pre-registered library call.  Each element carries
an explicit argument list; every argument is a handle to a `ManagedArray`
(GrCUDA's UM-backed device array analogue) annotated with an access mode.

The managed-object encapsulation is what makes automatic dependency
inference sound: arguments are opaque handles, so there is no pointer
aliasing (paper §IV-A, "removing the risk of pointer aliasing typical of
native languages").
"""
from __future__ import annotations

import enum
import itertools
from dataclasses import dataclass, field
from typing import Any, Callable, Optional, Tuple

_ELEMENT_IDS = itertools.count()

# Priority -> space-sharing weight mapping (multi-tenant QoS).  Each priority
# level doubles the element's claim on contended device capacity: the
# SimExecutor water-fill hands a kernel ``weight/Σweights`` of the device
# (still capped by its parallel fraction), so priority 3 work progresses 8x
# faster than priority 0 work *only while they contend* — an idle device runs
# everything at full rate regardless.
PRIORITY_WEIGHT_BASE = 2.0

DEFAULT_TENANT = "default"


def priority_weight(priority: int) -> float:
    """Capacity weight of a priority level (``base ** priority``).

    Negative priorities yield sub-unit weights: true background work that
    cedes the device to any default-priority tenant under contention."""
    return float(PRIORITY_WEIGHT_BASE ** priority)


class AccessMode(enum.Enum):
    """Argument annotations (paper §IV-D: ``input``/``const``/``output``).

    ``CONST`` arguments are read-only and get the special dependency rules of
    Fig. 3.  Un-annotated arguments are conservatively ``INOUT`` ("the
    scheduler treats them as modifiable by the kernel; not specifying
    arguments as read-only does not affect correctness").
    """

    CONST = "const"      # read-only
    OUT = "out"          # write-only (still ordered after prior readers/writer)
    INOUT = "inout"      # read-modify-write (default for unannotated args)

    @property
    def reads(self) -> bool:
        return self in (AccessMode.CONST, AccessMode.INOUT)

    @property
    def writes(self) -> bool:
        return self in (AccessMode.OUT, AccessMode.INOUT)

    def conflicts_with(self, other: "AccessMode") -> bool:
        """Two accesses to the same array conflict (must be ordered) unless
        both are read-only — the RAW/WAR/WAW rule the verifier and the
        runtime sanitizer share."""
        return self.writes or other.writes


def dep_key(array: Any) -> int:
    """Dependency-tracking key for an argument handle.

    Managed arrays carry a process-monotonic ``aid``; plain (test) objects
    fall back to ``id()``.  ``id()`` alone is unsound in long-running loops:
    CPython reuses addresses after GC, so a fresh array could inherit the
    stale ``last_writer``/``readers`` frontier of a dead one.  ``aid`` keys
    are mapped to negative ints so the two namespaces can never collide
    (``id()`` is a non-negative address)."""
    aid = getattr(array, "aid", None)
    return id(array) if aid is None else -1 - aid


@dataclass(frozen=True)
class Arg:
    """One argument of a computational element: a managed handle + mode."""

    array: Any               # ManagedArray (duck-typed; keyed via dep_key)
    mode: AccessMode

    @property
    def key(self) -> int:
        return dep_key(self.array)


class ElementKind(enum.Enum):
    KERNEL = "kernel"            # device computation
    HOST_ACCESS = "host_access"  # CPU read/write of a managed array (§IV-A)
    TRANSFER = "transfer"        # H2D prefetch / D2H copy (scheduled by runtime)
    D2D = "d2d"                  # device-to-device copy (multi-device runtime)
    EVICT = "evict"              # budget spill: async D2H + drop device copy
    RELOAD = "reload"            # bring a tier-spilled block back on-device
    LIBRARY = "library"          # pre-registered library call (§IV-A)
    SYNC = "sync"                # explicit barrier requested by the host


class ElementState(enum.Enum):
    """Element lifecycle, as the executors see it.

    ``PAUSED`` is the element-boundary preemption state: a queued (never
    started) element whose lane yields to deadline-urgent work.  A paused
    element stays exactly where it is in its lane's FIFO — pausing blocks
    the lane in place, it never reorders it, because same-lane children
    rely on queue order instead of completion events.  Running work is
    never interrupted (no mid-kernel preemption)."""

    PENDING = "pending"    # constructed, not yet handed to an executor
    QUEUED = "queued"      # submitted, waiting for lane/parents
    PAUSED = "paused"      # queued but yielding to at-risk deadline work
    RUNNING = "running"    # on the device (or worker thread)
    DONE = "done"          # completed


@dataclass
class ComputationalElement:
    """A vertex of the computation DAG.

    Tracks its configuration, input arguments and whether the computation is
    *active* (paper: "computations are considered active until the CPU
    requires their result or one of their children" — plus the dependency-set
    emptiness rule).
    """

    fn: Optional[Callable]
    args: Tuple[Arg, ...]
    kind: ElementKind = ElementKind.KERNEL
    name: str = ""
    # launch configuration (block-size analogue; used by history heuristics)
    config: dict = field(default_factory=dict)
    # estimated costs for the simulator (seconds / bytes); populated by the
    # benchsuite or measured by the history tracker.
    cost_s: float = 0.0
    transfer_bytes: int = 0
    # Multi-tenant QoS: who issued this element and how urgent it is.
    # Auto-inserted TRANSFER/D2D elements inherit both from the kernel that
    # triggered them; ``priority`` feeds the weighted water-fill and the
    # priority-aware lane fallback, ``tenant`` feeds per-tenant accounting
    # and (optional) lane quotas.
    priority: int = 0
    tenant: str = DEFAULT_TENANT
    # Deadline/SLO-aware scheduling (EDF): ``deadline_s`` is the declared
    # per-launch latency budget (seconds from submission; None = no
    # deadline); ``deadline_t`` is the absolute deadline stamped at
    # submission time (host clock).  Auto-inserted TRANSFER/D2D/EVICT
    # children inherit both from the kernel that triggered them so the
    # whole urgent frontier carries one EDF rank.
    deadline_s: Optional[float] = None
    deadline_t: Optional[float] = None
    # Declared-function identity (GrFunction frontend): launches issued
    # through the same declared ``GrFunction`` share one ``fn_key`` even when
    # the underlying Python callable is re-created per episode, and two
    # different declarations never share one.  ``None`` for legacy
    # ``scheduler.launch`` call sites; capture/replay keys plans by it.
    fn_key: Optional[int] = None

    # Backing tier driving this EVICT/RELOAD (runtime object, not part of
    # the structural signature — capture encodes the tier *name* in
    # ``config`` and replay re-resolves it against the scheduler's stack).
    tier: Any = None

    # -- filled in by the scheduler --
    uid: int = field(default_factory=lambda: next(_ELEMENT_IDS))
    stream: Optional[int] = None       # lane id assigned by the StreamManager
    device: Optional[int] = None       # device chosen by the placement policy
    # True when ``device`` was pinned by the caller (GrFunction
    # ``with_options(device=...)``) rather than chosen by the placement
    # policy.  Capture records it so the plan optimizer never moves a
    # user-pinned kernel (replay matching rejects device retargets).
    device_pinned: bool = False
    src_device: Optional[int] = None   # D2D only: device the copy reads from
    parents: list = field(default_factory=list)    # list[ComputationalElement]
    children: list = field(default_factory=list)
    # dependency set: argument keys that can still introduce dependencies
    dep_set: set = field(default_factory=set)
    active: bool = False
    state: ElementState = ElementState.PENDING
    done_event: Any = None             # executor-specific completion handle
    pause_gate: Any = None             # threading.Event (real executor only):
    #                                    cleared = paused, worker blocks on it
    # timeline bookkeeping (filled by executors)
    t_issue: float = float("nan")      # submission time (queueing-delay base)
    t_start: float = float("nan")
    t_end: float = float("nan")

    @property
    def weight(self) -> float:
        """Space-sharing weight derived from ``priority``."""
        return priority_weight(self.priority)

    @property
    def effective_deadline(self) -> float:
        """EDF sort key: absolute deadline, or +inf for deadline-free work.

        Comparisons between two deadline-free elements are always vacuous
        (``inf > inf`` is False), which is what keeps every EDF tie-break a
        no-op — and the schedule bit-identical — when no deadlines are in
        play."""
        return float("inf") if self.deadline_t is None else self.deadline_t

    def __post_init__(self) -> None:
        if not self.name:
            self.name = f"{self.kind.value}_{self.uid}"
        # The dependency set initially contains all arguments (§IV-A).
        self.dep_set = {a.key for a in self.args}

    # ------------------------------------------------------------------
    def arg_modes(self):
        """Yield (key, mode) merged per distinct array.

        If the same array appears twice with different modes the strongest
        (writing) mode wins — matching the conservative GrCUDA behaviour.
        """
        merged: dict = {}
        for a in self.args:
            prev = merged.get(a.key)
            if prev is None or (a.mode.writes and not prev.writes):
                merged[a.key] = a.mode
        return merged.items()

    @property
    def is_host(self) -> bool:
        return self.kind in (ElementKind.HOST_ACCESS, ElementKind.SYNC)

    def __hash__(self) -> int:
        return self.uid

    def __eq__(self, other) -> bool:
        return isinstance(other, ComputationalElement) and other.uid == self.uid

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return (f"<CE {self.name} uid={self.uid} stream={self.stream} "
                f"parents={[p.name for p in self.parents]}>")


def kernel(fn: Callable, *args: Arg, name: str = "", cost_s: float = 0.0,
           transfer_bytes: int = 0, priority: int = 0,
           tenant: str = DEFAULT_TENANT, **config) -> ComputationalElement:
    """Convenience constructor for a device kernel element."""
    return ComputationalElement(fn=fn, args=tuple(args), kind=ElementKind.KERNEL,
                                name=name, config=config, cost_s=cost_s,
                                transfer_bytes=transfer_bytes,
                                priority=priority, tenant=tenant)


def const(array: Any) -> Arg:
    return Arg(array, AccessMode.CONST)


def out(array: Any) -> Arg:
    return Arg(array, AccessMode.OUT)


def inout(array: Any) -> Arg:
    return Arg(array, AccessMode.INOUT)
