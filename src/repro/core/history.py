"""Per-kernel historical performance tracking (paper §IV-A).

"We track each kernel's historical performance and scheduling to allow the
creation of heuristics that guide future scheduling of the same kernel."

GrJAX uses the history for three things:
* cost estimates for the discrete-event simulator / oracle scheduler;
* straggler detection (an execution slower than ``factor`` × the running
  median is flagged; the distributed trainer uses this to re-dispatch);
* block-size / config heuristics (best-performing config per kernel).
"""
from __future__ import annotations

import statistics
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple


def _config_key(config: dict) -> Tuple:
    return tuple(sorted((k, str(v)) for k, v in config.items()))


@dataclass
class KernelHistory:
    straggler_factor: float = 3.0
    min_samples: int = 3
    _durations: Dict[Tuple[str, Tuple], List[float]] = field(
        default_factory=lambda: defaultdict(list))
    stragglers_seen: int = 0

    def record(self, name: str, config: dict, duration_s: float) -> bool:
        """Record an execution; returns True if it was a straggler."""
        key = (name, _config_key(config))
        hist = self._durations[key]
        straggler = False
        if len(hist) >= self.min_samples:
            med = statistics.median(hist)
            if med > 0 and duration_s > self.straggler_factor * med:
                straggler = True
                self.stragglers_seen += 1
        hist.append(duration_s)
        if len(hist) > 256:          # sliding window
            del hist[0]
        return straggler

    def estimate(self, name: str, config: dict) -> Optional[float]:
        hist = self._durations.get((name, _config_key(config)))
        if not hist:
            # fall back to any config of the same kernel
            pool = [d for (n, _), ds in self._durations.items() if n == name
                    for d in ds]
            return statistics.median(pool) if pool else None
        return statistics.median(hist)

    def is_straggler(self, name: str, config: dict, duration_s: float) -> bool:
        est = self.estimate(name, config)
        return est is not None and est > 0 and duration_s > self.straggler_factor * est

    def best_config(self, name: str) -> Optional[dict]:
        """Config with the lowest median duration for this kernel (§VI:
        'estimating the ideal block size based on previous executions')."""
        best, best_t = None, float("inf")
        for (n, ckey), ds in self._durations.items():
            if n == name and ds:
                m = statistics.median(ds)
                if m < best_t:
                    best, best_t = dict((k, v) for k, v in ckey), m
        return best

    def stats(self) -> dict:
        return {"kernels_tracked": len(self._durations),
                "stragglers_seen": self.stragglers_seen}
