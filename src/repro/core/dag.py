"""Incremental computation DAG + dependency sets (paper §IV-A, Fig. 3).

The DAG is built **at run time**: elements are appended as the host program
issues them, and only the *frontier* of active computations is consulted.
Dependency inference follows the paper's rules exactly:

* each element starts with a dependency set containing all its arguments;
* a **reader** (``const`` argument) depends on the *last writer* of that
  argument only — it never depends on other readers, and it does **not**
  consume the writer's dependency-set entry (Fig. 3 case C: "the dependency
  set of the parent kernel K1 is not updated");
* a **writer** depends on *all readers since the last write* (write-after-read
  anti-dependencies, Fig. 3 case B) — transitively covering the previous
  writer — or, if there are no readers, on the last writer directly
  (write-after-write).  The write *consumes* the entry: the argument is
  removed from the dependency sets of the previous writer and all readers
  ("all dependency sets will be updated");
* an element whose dependency set is empty can no longer introduce
  dependencies (§IV-B) and leaves the frontier;
* elements also leave the frontier when the host observes their completion
  (§IV-B: "active until the CPU requires their result or one of their
  children").
"""
from __future__ import annotations

from dataclasses import dataclass, field
from types import MappingProxyType
from typing import Dict, List, Mapping, Optional, Set, Tuple

from .element import AccessMode, ComputationalElement

# Prune a state's reader list once it grows past this many entries (a
# long-lived const array — e.g. serving weights — otherwise accumulates
# every retired reader ever issued).
_READER_PRUNE = 64


@dataclass
class _ArrayState:
    """Frontier bookkeeping for one managed array (keyed by handle id)."""

    last_writer: Optional[ComputationalElement] = None
    readers: List[ComputationalElement] = field(default_factory=list)


@dataclass(frozen=True)
class DAGSnapshot:
    """Immutable point-in-time view of the DAG frontier.

    ``writers``/``readers`` map argument keys to the *live* (active) elements
    that could still introduce a dependency on that array; retired elements
    are excluded.  A debugging/introspection surface (the replay fast path
    itself uses the targeted :meth:`ComputationDAG.live_deps`) — mutating
    the returned mappings raises."""

    writers: Mapping[int, ComputationalElement]
    readers: Mapping[int, Tuple[ComputationalElement, ...]]
    frontier: frozenset
    num_elements: int
    num_edges: int


class ComputationDAG:
    """Runtime-built dependency DAG over computational elements."""

    def __init__(self) -> None:
        self._state: Dict[int, _ArrayState] = {}
        self.frontier: Set[ComputationalElement] = set()
        self.num_elements = 0
        self.num_edges = 0
        # Amortized eviction threshold for dead per-array state (see _sweep).
        self._sweep_at = 256

    # ------------------------------------------------------------------
    def _eligible(self, e: Optional[ComputationalElement], key: int) -> bool:
        """An element can be a parent only while it is active *and* the
        argument is still in its dependency set."""
        return e is not None and e.active and key in e.dep_set

    def add(self, element: ComputationalElement) -> List[ComputationalElement]:
        """Insert ``element``, inferring parents.  Returns the parent list."""
        parents: List[ComputationalElement] = []
        seen: Set[int] = set()

        def add_parent(p: ComputationalElement) -> None:
            if p.uid not in seen and p is not element:
                seen.add(p.uid)
                parents.append(p)

        for key, mode in element.arg_modes():
            st = self._state.get(key)
            if st is not None:
                if mode.writes:
                    # WAR: depend on every active reader since the last
                    # write; they transitively cover the last writer
                    # (Fig. 3 case B).
                    live_readers = [r for r in st.readers
                                    if self._eligible(r, key)]
                    if live_readers:
                        for r in live_readers:
                            add_parent(r)
                    elif self._eligible(st.last_writer, key):
                        add_parent(st.last_writer)  # WAW / RAW for inout
                elif self._eligible(st.last_writer, key):
                    add_parent(st.last_writer)  # RAW; writer's set NOT updated
            self._transition(key, mode, element)

        element.parents = parents
        self._install(element)
        return parents

    # ------------------------------------------------------------------
    def _transition(self, key: int, mode: AccessMode,
                    element: ComputationalElement) -> None:
        """Per-array frontier transition shared by :meth:`add` and
        :meth:`adopt`: a write consumes the previous frontier's
        dependency-set entries for this argument ("all dependency sets will
        be updated") and becomes the last writer; a read joins the reader
        list (the writer's set is NOT updated, Fig. 3 case C)."""
        st = self._state.get(key)
        if st is None:
            st = self._state[key] = _ArrayState()
        if mode.writes:
            if st.last_writer is not None:
                st.last_writer.dep_set.discard(key)
                self._maybe_retire(st.last_writer)
            for r in st.readers:
                r.dep_set.discard(key)
                self._maybe_retire(r)
            st.last_writer = element
            st.readers = []
        else:
            if len(st.readers) >= _READER_PRUNE:
                st.readers = [r for r in st.readers if r.active]
            st.readers.append(element)

    def _install(self, element: ComputationalElement) -> None:
        """Common bookkeeping once parents are final: edges, counters,
        frontier membership and the dependency-set emptiness rule."""
        for p in element.parents:
            p.children.append(element)
        self.num_edges += len(element.parents)
        self.num_elements += 1
        element.active = True
        self.frontier.add(element)
        self._maybe_retire(element)

    # ------------------------------------------------------------------
    def adopt(self, element: ComputationalElement) -> None:
        """Fast-path insert for a replayed element with **pre-resolved**
        parents (``element.parents`` set by the caller from an
        :class:`~repro.core.capture.ExecutionPlan`).

        Per-array frontier state is transitioned exactly as :meth:`add` would
        (writes consume the previous frontier's dependency-set entries, reads
        join the reader list) but the O(frontier) parent inference is
        skipped — that is the capture/replay fast path."""
        for key, mode in element.arg_modes():
            self._transition(key, mode, element)
        self._install(element)

    def live_deps(self, key: int, writes: bool) -> List[ComputationalElement]:
        """Elements the host (or a replayed episode) must order against
        before accessing the array ``key``: for a write, every active reader
        since the last write (WAR) or, failing that, the live writer; for a
        read, the live writer only (RAW)."""
        st = self._state.get(key)
        if st is None:
            return []
        if writes:
            deps = [r for r in st.readers if self._eligible(r, key)]
            if not deps and st.last_writer is not None and st.last_writer.active:
                deps = [st.last_writer]
            return deps
        if st.last_writer is not None and st.last_writer.active:
            return [st.last_writer]
        return []

    def has_device_frontier(self, key: int, writes: bool = True) -> bool:
        """Whether any live *device-side* element could still order against
        the array — the one definition of "in-flight" shared by host-access
        re-validation and evict victim selection."""
        return any(not d.is_host for d in self.live_deps(key, writes))

    def snapshot(self) -> DAGSnapshot:
        """Frozen view of the live frontier state (read-only mappings)."""
        writers = {k: st.last_writer for k, st in self._state.items()
                   if st.last_writer is not None and st.last_writer.active}
        readers = {k: tuple(r for r in st.readers if r.active)
                   for k, st in self._state.items()
                   if any(r.active for r in st.readers)}
        return DAGSnapshot(writers=MappingProxyType(writers),
                           readers=MappingProxyType(readers),
                           frontier=frozenset(self.frontier),
                           num_elements=self.num_elements,
                           num_edges=self.num_edges)

    # ------------------------------------------------------------------
    def _sweep(self) -> None:
        """Amortized eviction of dead per-array state.

        Long-running loops (serving) create fresh arrays per episode; their
        ``_ArrayState`` entries outlive the arrays and — before aid-based
        keying — a recycled ``id()`` could even alias a dead entry.  Once the
        table grows past the high-water mark, drop every entry with no active
        element and prune retired readers/writers from the survivors.  The
        threshold doubles with the live size, so the cost is O(1) amortized."""
        if len(self._state) < self._sweep_at:
            return
        alive: Dict[int, _ArrayState] = {}
        for k, st in self._state.items():
            w = st.last_writer
            if w is not None and not w.active:
                w = None
            rs = [r for r in st.readers if r.active]
            if w is not None or rs:
                st.last_writer, st.readers = w, rs
                alive[k] = st
        self._state = alive
        self._sweep_at = max(256, 2 * len(alive))

    def _maybe_retire(self, e: ComputationalElement) -> None:
        """Drop an element from the frontier once its dependency set is empty
        — it can no longer be a parent (§IV-B)."""
        if e.active and not e.dep_set:
            e.active = False
            self.frontier.discard(e)

    def retire(self, e: ComputationalElement) -> None:
        """Host observed completion of ``e`` (and hence of its ancestors)."""
        stack = [e]
        while stack:
            cur = stack.pop()
            if not cur.active:
                continue
            cur.active = False
            self.frontier.discard(cur)
            stack.extend(cur.parents)
        self._sweep()

    def retire_all(self) -> None:
        for e in list(self.frontier):
            e.active = False
        self.frontier.clear()
        # A full barrier retires *everything*: sweep unconditionally so no
        # dead ``_ArrayState`` pins retired elements (and through their args,
        # the arrays — a tier-spilled block must become collectable here for
        # its GC finalizer to release the spool payload).
        self._sweep_at = 0
        self._sweep()

    # ------------------------------------------------------------------
    def validate(self) -> List[str]:
        """Structural invariant check used by ``repro.analysis``: returns
        human-readable problems (empty = consistent).

        Invariants: frontier membership equals the ``active`` flag with a
        non-empty dependency set; dependency sets never grow beyond the
        element's own argument keys (writes only ever *consume* entries);
        per-array frontier state points only at elements that could still
        legally become parents under :meth:`_eligible`."""
        problems: List[str] = []
        for e in self.frontier:
            if not e.active:
                problems.append(
                    f"retired element {e.name}(uid {e.uid}) still on the "
                    f"frontier")
            elif not e.dep_set:
                problems.append(
                    f"frontier element {e.name}(uid {e.uid}) has an empty "
                    f"dependency set — §IV-B says it must retire")
        for key, st in self._state.items():
            for r in st.readers:
                if r.active and key not in r.dep_set:
                    problems.append(
                        f"active reader {r.name}(uid {r.uid}) listed for "
                        f"key {key} without a dependency-set entry")
        for e in self.frontier:
            keys = {a.key for a in e.args}
            extra = set(e.dep_set) - keys
            if extra:
                problems.append(
                    f"element {e.name}(uid {e.uid}) tracks dependency keys "
                    f"{sorted(extra)} outside its argument list")
        return problems

    # ------------------------------------------------------------------
    def ancestors(self, e: ComputationalElement) -> Set[ComputationalElement]:
        out: Set[ComputationalElement] = set()
        stack = list(e.parents)
        while stack:
            cur = stack.pop()
            if cur not in out:
                out.add(cur)
                stack.extend(cur.parents)
        return out

    def writers_of(self, key: int) -> Optional[ComputationalElement]:
        st = self._state.get(key)
        return st.last_writer if st else None

    def readers_of(self, key: int) -> List[ComputationalElement]:
        st = self._state.get(key)
        return list(st.readers) if st else []
