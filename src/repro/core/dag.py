"""Incremental computation DAG + dependency sets (paper §IV-A, Fig. 3).

The DAG is built **at run time**: elements are appended as the host program
issues them, and only the *frontier* of active computations is consulted.
Dependency inference follows the paper's rules exactly:

* each element starts with a dependency set containing all its arguments;
* a **reader** (``const`` argument) depends on the *last writer* of that
  argument only — it never depends on other readers, and it does **not**
  consume the writer's dependency-set entry (Fig. 3 case C: "the dependency
  set of the parent kernel K1 is not updated");
* a **writer** depends on *all readers since the last write* (write-after-read
  anti-dependencies, Fig. 3 case B) — transitively covering the previous
  writer — or, if there are no readers, on the last writer directly
  (write-after-write).  The write *consumes* the entry: the argument is
  removed from the dependency sets of the previous writer and all readers
  ("all dependency sets will be updated");
* an element whose dependency set is empty can no longer introduce
  dependencies (§IV-B) and leaves the frontier;
* elements also leave the frontier when the host observes their completion
  (§IV-B: "active until the CPU requires their result or one of their
  children").
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Set

from .element import AccessMode, ComputationalElement


@dataclass
class _ArrayState:
    """Frontier bookkeeping for one managed array (keyed by handle id)."""

    last_writer: Optional[ComputationalElement] = None
    readers: List[ComputationalElement] = field(default_factory=list)

    def live(self) -> bool:
        return self.last_writer is not None or bool(self.readers)


class ComputationDAG:
    """Runtime-built dependency DAG over computational elements."""

    def __init__(self) -> None:
        self._state: Dict[int, _ArrayState] = {}
        self.frontier: Set[ComputationalElement] = set()
        self.num_elements = 0
        self.num_edges = 0

    # ------------------------------------------------------------------
    def _eligible(self, e: Optional[ComputationalElement], key: int) -> bool:
        """An element can be a parent only while it is active *and* the
        argument is still in its dependency set."""
        return e is not None and e.active and key in e.dep_set

    def add(self, element: ComputationalElement) -> List[ComputationalElement]:
        """Insert ``element``, inferring parents.  Returns the parent list."""
        parents: List[ComputationalElement] = []
        seen: Set[int] = set()

        def add_parent(p: ComputationalElement) -> None:
            if p.uid not in seen and p is not element:
                seen.add(p.uid)
                parents.append(p)

        for key, mode in element.arg_modes():
            st = self._state.get(key)
            if st is None:
                st = self._state[key] = _ArrayState()

            if mode.writes:
                # WAR: depend on every active reader since the last write;
                # they transitively cover the last writer (Fig. 3 case B).
                live_readers = [r for r in st.readers if self._eligible(r, key)]
                if live_readers:
                    for r in live_readers:
                        add_parent(r)
                elif self._eligible(st.last_writer, key):
                    add_parent(st.last_writer)  # WAW / RAW for inout
                # The write consumes the dependency-set entries of the
                # previous frontier for this argument.
                if st.last_writer is not None:
                    st.last_writer.dep_set.discard(key)
                    self._maybe_retire(st.last_writer)
                for r in st.readers:
                    r.dep_set.discard(key)
                    self._maybe_retire(r)
                st.last_writer = element
                st.readers = []
            else:  # CONST read
                if self._eligible(st.last_writer, key):
                    add_parent(st.last_writer)  # RAW; writer's set NOT updated
                st.readers.append(element)

        element.parents = parents
        for p in parents:
            p.children.append(element)
        self.num_edges += len(parents)
        self.num_elements += 1
        element.active = True
        self.frontier.add(element)
        self._maybe_retire(element)
        return parents

    # ------------------------------------------------------------------
    def _maybe_retire(self, e: ComputationalElement) -> None:
        """Drop an element from the frontier once its dependency set is empty
        — it can no longer be a parent (§IV-B)."""
        if e.active and not e.dep_set:
            e.active = False
            self.frontier.discard(e)

    def retire(self, e: ComputationalElement) -> None:
        """Host observed completion of ``e`` (and hence of its ancestors)."""
        stack = [e]
        while stack:
            cur = stack.pop()
            if not cur.active:
                continue
            cur.active = False
            self.frontier.discard(cur)
            stack.extend(cur.parents)

    def retire_all(self) -> None:
        for e in list(self.frontier):
            e.active = False
        self.frontier.clear()

    # ------------------------------------------------------------------
    def ancestors(self, e: ComputationalElement) -> Set[ComputationalElement]:
        out: Set[ComputationalElement] = set()
        stack = list(e.parents)
        while stack:
            cur = stack.pop()
            if cur not in out:
                out.add(cur)
                stack.extend(cur.parents)
        return out

    def writers_of(self, key: int) -> Optional[ComputationalElement]:
        st = self._state.get(key)
        return st.last_writer if st else None

    def readers_of(self, key: int) -> List[ComputationalElement]:
        st = self._state.get(key)
        return list(st.readers) if st else []
