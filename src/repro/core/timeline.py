"""Execution timeline + overlap accounting (paper Fig. 10 / §V-F).

Every executor records (element, lane, kind, t_start, t_end) intervals.  From
the timeline we compute the paper's four overlap metrics:

* **CT** — fraction of kernel-computation time overlapped with any transfer;
* **TC** — fraction of transfer time overlapped with any computation;
* **CC** — fraction of computation time overlapped with other computation;
* **TOT** — fraction of device-busy time where ≥2 device tasks overlap,
  overlap intervals counted once (union semantics).
"""
from __future__ import annotations

import threading
from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Optional, Tuple


_NAN = float("nan")


@dataclass(frozen=True)
class Span:
    uid: int
    name: str
    kind: str          # "compute" | "h2d" | "d2h" | "d2d" | "host"
    lane: Optional[int]
    t0: float
    t1: float
    # Multi-tenant QoS attribution (None/defaults for untagged spans).
    tenant: Optional[str] = None
    priority: int = 0
    t_issue: float = _NAN              # submission time; t0 - t_issue is the
    #                                    span's queueing delay
    deadline: Optional[float] = None   # absolute deadline (None = no SLO);
    #                                    met iff t1 <= deadline

    @property
    def dur(self) -> float:
        return self.t1 - self.t0

    @property
    def queue_delay(self) -> float:
        return self.t0 - self.t_issue   # nan when t_issue was not recorded

    @property
    def latency(self) -> float:
        return self.t1 - self.t_issue   # submit-to-completion (nan likewise)

    @property
    def met_deadline(self) -> Optional[bool]:
        """True/False for deadline'd spans, None for deadline-free ones."""
        if self.deadline is None:
            return None
        return self.t1 <= self.deadline + 1e-12


def _union(intervals: Iterable[Tuple[float, float]]) -> List[Tuple[float, float]]:
    ivs = sorted((a, b) for a, b in intervals if b > a)
    out: List[Tuple[float, float]] = []
    for a, b in ivs:
        if out and a <= out[-1][1]:
            out[-1] = (out[-1][0], max(out[-1][1], b))
        else:
            out.append((a, b))
    return out


def _measure(ivs: List[Tuple[float, float]]) -> float:
    return sum(b - a for a, b in ivs)


def _intersect(xs: List[Tuple[float, float]], ys: List[Tuple[float, float]]
               ) -> List[Tuple[float, float]]:
    out, i, j = [], 0, 0
    while i < len(xs) and j < len(ys):
        a = max(xs[i][0], ys[j][0])
        b = min(xs[i][1], ys[j][1])
        if b > a:
            out.append((a, b))
        if xs[i][1] < ys[j][1]:
            i += 1
        else:
            j += 1
    return out


def _sorted_percentile(ys: List[float], q: float) -> float:
    """Linear-interpolated percentile of an already-sorted ``ys``."""
    if not ys:
        return 0.0
    k = (len(ys) - 1) * q
    lo = int(k)
    hi = min(lo + 1, len(ys) - 1)
    return ys[lo] + (ys[hi] - ys[lo]) * (k - lo)


def _percentile(xs: List[float], q: float) -> float:
    """Linear-interpolated percentile of ``xs`` (q in [0, 1])."""
    return _sorted_percentile(sorted(xs), q)


def _k_overlap(spans: List[Tuple[float, float]], k: int = 2
               ) -> List[Tuple[float, float]]:
    """Intervals where at least ``k`` of the given spans are active."""
    pts = []
    for a, b in spans:
        pts.append((a, 1))
        pts.append((b, -1))
    pts.sort()
    out, depth, start = [], 0, None
    for t, d in pts:
        prev = depth
        depth += d
        if prev < k <= depth:
            start = t
        elif prev >= k > depth and start is not None:
            out.append((start, t))
            start = None
    return _union(out)


@dataclass
class Timeline:
    spans: List[Span] = field(default_factory=list)
    # Per-tenant append-only buffers of device spans, filled by record():
    # tenant_stats() reads these instead of rescanning (and re-sorting) the
    # full span list on every call.  ``_tenant_cache`` memoizes one stats
    # epoch per tenant — keyed by buffer length, so stats are recomputed
    # (and the percentile arrays re-sorted) at most once per query epoch,
    # however often serving polls per flush.
    _per_tenant: Dict[str, List[Span]] = field(default_factory=dict)
    _tenant_cache: Dict[str, tuple] = field(default_factory=dict)
    # Recorders (real-executor lane workers, host-span paths) and readers
    # (tenant_stats, the daemon monitor) run on different threads and are
    # NOT all under the scheduler's pipeline lock — a timeline-internal lock
    # keeps each record and each stats pass internally consistent.
    _lock: threading.Lock = field(default_factory=threading.Lock, repr=False)

    _DEVICE_KINDS = ("compute", "h2d", "d2h", "d2d")

    def record(self, uid: int, name: str, kind: str, lane: Optional[int],
               t0: float, t1: float, *, tenant: Optional[str] = None,
               priority: int = 0, t_issue: float = _NAN,
               deadline: Optional[float] = None) -> None:
        s = Span(uid, name, kind, lane, t0, t1,
                 tenant=tenant, priority=priority,
                 t_issue=t_issue, deadline=deadline)
        with self._lock:
            self.spans.append(s)
            if tenant is not None and kind in self._DEVICE_KINDS:
                self._per_tenant.setdefault(tenant, []).append(s)

    def device_busy_since(self, idx: int) -> Tuple[int, float]:
        """Sum of device-span durations recorded at or after span index
        ``idx``; returns ``(new_idx, busy_seconds)`` so callers (the daemon
        monitor's utilization gauge) can walk the timeline incrementally."""
        with self._lock:
            n = len(self.spans)
            busy = sum(s.dur for s in self.spans[idx:n]
                       if s.kind in self._DEVICE_KINDS)
        return n, busy

    # ------------------------------------------------------------------
    def device_spans(self) -> List[Span]:
        return [s for s in self.spans
                if s.kind in ("compute", "h2d", "d2h", "d2d")]

    @property
    def makespan(self) -> float:
        dev = self.device_spans()
        if not dev:
            return 0.0
        return max(s.t1 for s in dev) - min(s.t0 for s in dev)

    def overlap_metrics(self) -> Dict[str, float]:
        comp = [(s.t0, s.t1) for s in self.spans if s.kind == "compute"]
        xfer = [(s.t0, s.t1) for s in self.spans
                if s.kind in ("h2d", "d2h", "d2d")]
        u_comp, u_xfer = _union(comp), _union(xfer)
        t_comp, t_xfer = _measure(u_comp), _measure(u_xfer)

        ct = _measure(_intersect(u_comp, u_xfer)) / t_comp if t_comp else 0.0
        tc = _measure(_intersect(u_comp, u_xfer)) / t_xfer if t_xfer else 0.0
        cc = _measure(_k_overlap(comp, 2)) / t_comp if t_comp else 0.0
        allspans = comp + xfer
        u_all = _union(allspans)
        tot = _measure(_k_overlap(allspans, 2)) / _measure(u_all) if allspans else 0.0
        return {"CT": ct, "TC": tc, "CC": cc, "TOT": tot}

    def tenant_stats(self) -> Dict[str, Dict[str, float]]:
        """Per-tenant QoS metrics over the device spans.

        For each tenant that appears on the timeline: element count,
        makespan (first start to last end of its spans), device-busy time,
        mean/p99 queueing delay (span start minus submission) and p50/p99
        submit-to-completion latency.  Tenants with deadline'd spans
        additionally report ``deadlined`` (count of deadline'd compute
        launches) and ``slo_attainment`` (fraction that finished by their
        deadline).  Spans recorded without a tenant tag (host spans,
        pre-QoS callers) are excluded.

        Incremental: spans accumulate in per-tenant append-only buffers and
        the percentile arrays are extended + re-sorted once per query epoch
        (timsort is near-linear on the mostly-sorted extension); repeated
        queries with no new spans return the cached epoch.

        Thread-safe: the whole pass runs under the timeline lock, so a
        monitor polling stats never sees a tenant buffer mid-append (torn
        counters) from a lane worker recording concurrently."""
        with self._lock:
            return self._tenant_stats_locked()

    def _tenant_stats_locked(self) -> Dict[str, Dict[str, float]]:
        out: Dict[str, Dict[str, float]] = {}
        for tenant, spans in self._per_tenant.items():
            cached = self._tenant_cache.get(tenant)
            if cached is not None and cached[0] == len(spans):
                out[tenant] = dict(cached[1])
                continue
            n0, _, lats, qds = cached if cached is not None else (0, None, [], [])
            fresh = spans[n0:]
            lats = lats + [s.latency for s in fresh if s.latency == s.latency]
            qds = qds + [s.queue_delay for s in fresh
                         if s.queue_delay == s.queue_delay]
            lats.sort()
            qds.sort()
            stats = {
                "elements": float(len(spans)),
                "makespan_s": max(s.t1 for s in spans) - min(s.t0 for s in spans),
                "busy_s": _measure(_union([(s.t0, s.t1) for s in spans])),
                "queue_delay_mean_s": (sum(qds) / len(qds)) if qds else 0.0,
                "queue_delay_p99_s": _sorted_percentile(qds, 0.99),
                "latency_p50_s": _sorted_percentile(lats, 0.50),
                "latency_p99_s": _sorted_percentile(lats, 0.99),
            }
            # SLO attainment over deadline'd *compute* spans only: inherited
            # transfer children carry the same deadline and would otherwise
            # triple-count each launch.
            ded = [s for s in spans
                   if s.deadline is not None and s.kind == "compute"]
            if ded:
                met = sum(1 for s in ded if s.met_deadline)
                stats["deadlined"] = float(len(ded))
                stats["slo_attainment"] = met / len(ded)
            self._tenant_cache[tenant] = (len(spans), stats, lats, qds)
            out[tenant] = dict(stats)
        return out

    def busy_time(self, kind: str) -> float:
        return _measure(_union([(s.t0, s.t1) for s in self.spans if s.kind == kind]))

    def reload_spans(self) -> List[Span]:
        """H2D spans that re-upload a previously uploaded block.

        Two sources: explicitly named ``reload_*`` transfers (Belady plans,
        tier reloads) and *repeat* H2D uploads of a name already seen on
        the H2D engine — the eager LRU path names every upload ``h2d_*``,
        so a second upload of the same array is spill-return traffic."""
        out: List[Span] = []
        seen: set = set()
        for s in sorted((s for s in self.spans if s.kind == "h2d"),
                        key=lambda s: s.t0):
            if s.name.startswith("reload_") or s.name in seen:
                out.append(s)
            seen.add(s.name)
        return out

    def reload_stall_s(self) -> float:
        """Reload time *not* hidden behind compute — the stall a smarter
        eviction/prefetch schedule can actually remove (reload bytes alone
        conflate overlapped and blocking traffic)."""
        ru = _union([(s.t0, s.t1) for s in self.reload_spans()])
        if not ru:
            return 0.0
        comp = _union([(s.t0, s.t1) for s in self.spans
                       if s.kind == "compute"])
        return _measure(ru) - _measure(_intersect(ru, comp))

    def per_lane(self) -> Dict[int, List[Span]]:
        lanes: Dict[int, List[Span]] = {}
        for s in self.device_spans():
            lanes.setdefault(s.lane if s.lane is not None else -1, []).append(s)
        return lanes

    def critical_path(self) -> float:
        """Longest chain end-to-end (lower bound on any schedule)."""
        return self.makespan  # refined bound computed by benchmarks from DAG

    def to_rows(self) -> List[dict]:
        return [s.__dict__ | {"dur": s.dur} for s in self.spans]

    def to_chrome_trace(self, path: str) -> None:
        """Export as a Chrome trace (chrome://tracing / Perfetto): one row
        per lane plus H2D/D2H/host rows — the paper's Fig. 10 timeline,
        inspectable."""
        import json
        events = []
        for s in self.spans:
            tid = {"h2d": -1, "d2h": -2, "host": -3, "d2d": -5}.get(
                s.kind, s.lane if s.lane is not None else -4)
            events.append({
                "name": s.name, "cat": s.kind, "ph": "X",
                "ts": s.t0 * 1e6, "dur": max(0.01, s.dur * 1e6),
                "pid": 0, "tid": tid,
            })
        meta = [{"name": "thread_name", "ph": "M", "pid": 0, "tid": t,
                 "args": {"name": n}} for t, n in
                [(-1, "H2D engine"), (-2, "D2H engine"), (-3, "host"),
                 (-5, "D2D link")]]
        with open(path, "w") as f:
            json.dump({"traceEvents": meta + events}, f)
