"""ManagedArray — GrCUDA's UM-backed polyglot array, adapted to JAX.

GrCUDA arrays live in CUDA Unified Memory: the host reads/writes them like
normal arrays while the runtime tracks every access and orders it against GPU
work (§IV-A).  TPUs have no page-fault UM, so GrJAX keeps an explicit
host/device pair with validity bits and lets the scheduler insert
*asynchronous prefetch* transfers (the paper's recommended mode — §V-C shows
prefetching strictly dominates fault-driven migration).

Host accesses go through ``read()`` / ``write()`` (or ``np.asarray(ma)`` /
indexing), which notify the scheduler: accesses that introduce a data
dependency on in-flight device work become HOST_ACCESS computational
elements; accesses that cannot introduce dependencies are executed
immediately without touching the DAG (§IV-A, low-overhead path).
"""
from __future__ import annotations

import itertools
from typing import Any, Optional, Tuple

import numpy as np

_ARRAY_IDS = itertools.count()


class ManagedArray:
    """A host+device array pair managed by a GrScheduler."""

    def __init__(self, scheduler: Any, host: Optional[np.ndarray] = None, *,
                 shape: Optional[Tuple[int, ...]] = None, dtype=np.float32,
                 name: str = "") -> None:
        if host is None:
            host = np.zeros(shape, dtype=dtype)
        self._scheduler = scheduler
        self.host: np.ndarray = np.asarray(host)
        self.device: Any = None            # jax.Array once transferred
        self.host_valid = True
        self.device_valid = False
        # Which device owns the current device copy (single-copy model: a
        # cross-device consumer triggers a D2D element that moves ownership).
        self.device_id: Optional[int] = None
        # Name of the backing tier (tiers.py) currently holding the only
        # valid copy off-device, or None.  Set/cleared exclusively by the
        # MemoryManager's note_spill/note_reload transitions; part of the
        # capture slot state so replayed plans reload from the right tier.
        self.backing_tier: Optional[str] = None
        self.aid = next(_ARRAY_IDS)
        self.name = name or f"arr{self.aid}"

    # -- geometry ------------------------------------------------------
    @property
    def shape(self):
        return self.host.shape

    @property
    def dtype(self):
        return self.host.dtype

    @property
    def nbytes(self) -> int:
        return int(self.host.nbytes)

    # -- device-side value used by executors ---------------------------
    # NOTE on concurrency: ``host_valid``/``device_valid`` are *logical*
    # location bits owned by the scheduling thread and flipped at SCHEDULE
    # time (the scheduler knows what each scheduled element will produce).
    # Worker threads only install the physical ``device`` value.  Reading
    # stale flags from workers caused mis-scheduled prefetches otherwise.
    def device_value(self):
        if self.device is not None:
            return self.device
        return self.host

    def set_physical_device(self, value) -> None:
        """Called by executors when a kernel/transfer materializes a value."""
        self.device = value

    # -- host access API (triggers scheduling) --------------------------
    def read(self) -> np.ndarray:
        self._scheduler.host_read(self)
        return self.host

    def _host_overwrote(self) -> None:
        """Location-bit update after the host mutated ``self.host``.

        The device copy (if any) is now stale, and — crucially — no device
        *owns* a valid copy anymore, so ``device_id`` must be cleared too.
        Leaving it behind (the old behaviour) meant a write after a D2D
        migration kept pointing at the last owning device: capture plans then
        spuriously mismatched fresh arrays (whose ``device_id`` is None) and
        the multi-device migrate stage could treat the dead copy as claimable
        state.  On a never-transferred array this is a no-op: neither
        ``device_valid`` nor ``device_id`` flips.

        The transition routes through the scheduler's MemoryManager (the
        single owner of location-bit flips) so the device pool's resident-set
        accounting drops the stale copy in the same step — bits and residency
        cannot diverge.  Duck-typed test schedulers without a ``memory``
        attribute fall back to the inline flip.
        """
        mem = getattr(self._scheduler, "memory", None)
        if mem is not None:
            mem.note_host_overwrite(self)
            return
        self.host_valid = True
        if self.device_valid or self.device_id is not None:
            self.device_valid = False
            self.device_id = None

    def write(self, value) -> None:
        self._scheduler.host_write(self)
        self.host[...] = value
        self._host_overwrote()

    def __array__(self, dtype=None):
        out = self.read()
        return out.astype(dtype) if dtype is not None else out

    def __getitem__(self, idx):
        return self.read()[idx]

    def __setitem__(self, idx, value):
        self._scheduler.host_write(self)
        self.host[idx] = value
        self._host_overwrote()

    def __repr__(self) -> str:  # pragma: no cover
        loc = "D" if self.device_valid else "-"
        loc += "H" if self.host_valid else "-"
        return f"<ManagedArray {self.name} {self.shape} {self.dtype} [{loc}]>"


class ManagedValue:
    """Device-resident opaque value (e.g. a TrainState pytree) under the
    scheduler's dependency tracking.  No host mirror: it is produced and
    consumed by device kernels; ``get()`` synchronizes the owning lanes and
    returns the pytree (used for checkpointing/metrics)."""

    def __init__(self, scheduler: Any, value: Any = None, name: str = "") -> None:
        self._scheduler = scheduler
        self.device: Any = value
        self.host = None
        self.host_valid = False
        self.device_valid = value is not None
        self.device_id: Optional[int] = 0 if value is not None else None
        self.aid = next(_ARRAY_IDS)
        self.name = name or f"val{self.aid}"

    @property
    def nbytes(self) -> int:
        return 0

    def device_value(self):
        return self.device

    def set_physical_device(self, value) -> None:
        self.device = value

    def get(self):
        self._scheduler._sync_against(self, writes=False)
        return self.device
