"""Deadline/SLO-aware scheduling: EDF risk tracking + element-boundary
preemption (ROADMAP "Deadline/SLO-aware scheduling with preemption").

The paper's scheduler optimizes makespan; PR 3's priority weights shape
*capacity*.  Neither bounds *latency*: under contention a latency-critical
element can queue behind an arbitrarily long bulk tail, so p99 is unbounded.
This module adds the missing piece — per-launch deadlines
(``gr.with_options(deadline_s=...)``) and per-tenant SLO targets
(``GrScheduler(slo_targets={tenant: seconds})``) — and makes the runtime act
on them in three stages:

1. **EDF ordering.**  Elements carry an *effective deadline* (absolute
   deadline, or +inf when deadline-free).  Lane fallback prefers lanes whose
   queues hold equal-or-earlier deadlines, and the SimExecutor's water-fill
   hands device capacity to deadline'd kernels in earliest-deadline order
   before any deadline-free kernel sees it.  Deadline-free work sorts last
   everywhere, so a run with no deadlines is bit-identical to the pre-EDF
   scheduler.

2. **Deadline-risk signal.**  ``slack = deadline − now − critical-path cost``
   where the critical path is the element's own declared ``cost_s`` plus the
   max over its unfinished parents' remaining paths, plus the unfinished
   work queued ahead of it on its lane (FIFO lanes make that wait
   unavoidable).  Computed at submission and re-checked at every element
   completion boundary; an element is *at risk* when slack drops under a
   safety margin (a fraction of its deadline window).

3. **Element-boundary preemption.**  When a deadline is at risk, queued
   (never started) deadline-free elements on the affected devices are
   PAUSED — their lanes yield.  Pausing blocks a lane *in place*: same-lane
   children depend on FIFO order instead of events, so the queue must never
   be reordered.  Running work is never interrupted (no mid-kernel
   preemption), and lanes holding deadline'd work — or work the urgent
   frontier transitively depends on — are never stalled.  Paused elements
   resume when no at-risk work remains, when the urgent frontier drains, or
   when a host wait would otherwise block on them (deadlock guard).
"""
from __future__ import annotations

import threading
from typing import Dict, Optional

from .element import ComputationalElement, ElementState


class DeadlineMonitor:
    """Owns deadline stamping, the slack estimator and pause/resume.

    Thread-safety: the monitor has its own lock and never acquires the
    scheduler's submission-pipeline lock.  Full risk checks (which walk
    scheduler lane state) run only from contexts that already hold the
    pipeline lock — submission, and the SimExecutor's completion boundaries
    (the sim clock only advances inside locked scheduler calls).  Boundaries
    raised from real-executor worker threads take the *light* path: prune
    finished work, resume when the urgent frontier has drained — touching
    only monitor-owned state and per-element gates.
    """

    def __init__(self, scheduler, slo_targets: Optional[Dict[str, float]] = None,
                 slack_margin: float = 0.25) -> None:
        self.sched = scheduler
        self.slo_targets: Dict[str, float] = dict(slo_targets or {})
        # Risk fires when slack < slack_margin * deadline_s: the margin
        # absorbs costs the critical-path estimator cannot see (copy-engine
        # backlogs, host overhead) before the deadline is already lost.
        self.slack_margin = float(slack_margin)
        self._lock = threading.RLock()
        # Live (not yet completed) deadline'd elements, by uid.
        self._live: Dict[int, ComputationalElement] = {}
        # Currently paused elements, by uid.
        self._paused: Dict[int, ComputationalElement] = {}
        # Flips True at the first deadline'd launch; every hook early-outs
        # while False, keeping deadline-free runs at zero overhead.
        self.enabled = bool(self.slo_targets)
        # True when completion boundaries may run the full risk check (the
        # boundary fires under the pipeline lock — SimExecutor); False for
        # real worker threads (light path only).  Set by the scheduler.
        self.full_boundary_checks = True
        self.deadline_elements = 0   # elements stamped with a deadline
        self.preemptions = 0         # elements paused
        self.preempt_events = 0      # risk sweeps that paused something
        self.resumes = 0             # elements resumed

    # ------------------------------------------------------------------
    # Deadline stamping
    # ------------------------------------------------------------------
    def tag(self, element: ComputationalElement) -> None:
        """Stamp ``element``'s absolute deadline and register it.

        Applies the tenant SLO target when no explicit ``deadline_s`` was
        declared; stamps ``deadline_t = host_now + deadline_s`` exactly once
        (inherited children arrive with ``deadline_t`` pre-set and keep it).
        Idempotent — safe to call from both launch and schedule paths."""
        if element.deadline_s is None:
            if element.is_host:
                return
            slo = self.slo_targets.get(element.tenant)
            if slo is None:
                return
            element.deadline_s = float(slo)
        if element.deadline_t is None:
            element.deadline_t = (self.sched.executor.host_now()
                                  + float(element.deadline_s))
        self.enabled = True
        with self._lock:
            if element.uid not in self._live:
                self._live[element.uid] = element
                self.deadline_elements += 1

    # ------------------------------------------------------------------
    # Completion predicate
    # ------------------------------------------------------------------
    def _done(self, e: ComputationalElement) -> bool:
        """Device-side completion.  Executor ``is_done`` answers the *host's*
        question (has the host observed completion — false while the host
        clock lags the sim's device clock mid-drain); risk tracking must see
        an element as finished the moment it retires on the device, or every
        completed deadline would read as eternally at-risk and keep the
        bulk lanes paused."""
        return (e.state is ElementState.DONE
                or self.sched.executor.is_done(e))

    # ------------------------------------------------------------------
    # Slack estimation
    # ------------------------------------------------------------------
    def _remaining_path(self, e: ComputationalElement, is_done,
                        memo: Dict[int, float]) -> float:
        """Critical-path seconds still between ``e``'s completion and now:
        own declared cost plus the deepest unfinished ancestor chain.
        Iterative (serving lanes chain thousands of elements deep)."""
        stack = [(e, False)]
        while stack:
            x, expanded = stack.pop()
            if x.uid in memo:
                continue
            if is_done(x):
                memo[x.uid] = 0.0
                continue
            if expanded:
                best = 0.0
                for p in x.parents:
                    v = memo.get(p.uid, 0.0)
                    if v > best:
                        best = v
                memo[x.uid] = best + max(x.cost_s, 0.0)
            else:
                stack.append((x, True))
                for p in x.parents:
                    if p.uid not in memo:
                        stack.append((p, False))
        return memo.get(e.uid, 0.0)

    def _lane_wait(self, e: ComputationalElement, is_done) -> float:
        """Unfinished work queued ahead of ``e`` on its FIFO lane."""
        if e.stream is None:
            return 0.0
        lane = self.sched.streams.lanes.get(e.stream)
        if lane is None:
            return 0.0
        w = 0.0
        for q in lane.in_flight:
            if q is e:
                break
            if not is_done(q):
                w += max(q.cost_s, 0.0)
        return w

    def slack(self, e: ComputationalElement, now: float, is_done,
              memo: Optional[Dict[int, float]] = None) -> float:
        memo = {} if memo is None else memo
        return (e.deadline_t - now
                - self._remaining_path(e, is_done, memo)
                - self._lane_wait(e, is_done))

    # ------------------------------------------------------------------
    # Risk check + preemption
    # ------------------------------------------------------------------
    def check(self, now: Optional[float] = None) -> None:
        """Full risk sweep.  Caller must hold the pipeline lock (or be the
        sim event loop, which only runs under it)."""
        if not self.enabled:
            return
        ex = self.sched.executor
        is_done = self._done
        if now is None:
            now = ex.device_now()
        with self._lock:
            for uid in [u for u, e in self._live.items() if is_done(e)]:
                del self._live[uid]
            memo: Dict[int, float] = {}
            risky = [e for e in self._live.values()
                     if (self.slack(e, now, is_done, memo)
                         < self.slack_margin * (e.deadline_s or 0.0))]
            if risky:
                self._preempt_locked(risky, is_done)
            elif self._paused:
                # No at-risk deadline remains: the urgent frontier has
                # drained (or caught up) — give the device back.
                self._resume_locked()

    def _preempt_locked(self, risky, is_done) -> None:
        # Work the urgent frontier transitively depends on must keep
        # flowing: collect the unfinished ancestor closure of every live
        # deadline'd element (not just the risky ones — pausing a comfy
        # deadline's parent would manufacture the next at-risk element).
        needed = set()
        stack = [e for e in self._live.values()]
        while stack:
            x = stack.pop()
            if x.uid in needed:
                continue
            needed.add(x.uid)
            for p in x.parents:
                if p.uid not in needed and not is_done(p):
                    stack.append(p)
        devices = {e.device for e in risky}
        paused_any = False
        for lane in self.sched.streams.lanes.values():
            if lane.device_id not in devices and None not in devices:
                continue
            stall = True
            for q in lane.in_flight:
                if is_done(q):
                    continue
                if q.deadline_t is not None or q.uid in needed:
                    stall = False     # lane carries (or feeds) urgent work
                    break
            if not stall:
                continue
            for q in lane.in_flight:
                if q.state is ElementState.QUEUED and not is_done(q):
                    self._pause(q)
                    paused_any = True
        if paused_any:
            self.preempt_events += 1

    def _pause(self, q: ComputationalElement) -> None:
        if self.sched.executor.pause_via_gates:
            # Publish a cleared gate *before* flipping state: the lane
            # worker checks the gate right before running.  If the worker
            # already passed the check the element simply runs — that is
            # the no-mid-kernel-preemption contract, not an error.
            gate = threading.Event()
            q.pause_gate = gate
        q.state = ElementState.PAUSED
        self._paused[q.uid] = q
        self.preemptions += 1

    def _resume_locked(self) -> None:
        for q in self._paused.values():
            if q.state is ElementState.PAUSED:
                q.state = ElementState.QUEUED
                self.resumes += 1
            gate = q.pause_gate
            if gate is not None:
                q.pause_gate = None
                gate.set()
        self._paused.clear()

    def resume_all(self) -> None:
        if not self._paused:
            return
        with self._lock:
            self._resume_locked()

    # ------------------------------------------------------------------
    # Hooks wired into the executors / pipeline
    # ------------------------------------------------------------------
    def on_submit(self, element: ComputationalElement) -> None:
        """Submission-time risk check (pipeline lock held)."""
        if not self.enabled:
            return
        if element.deadline_t is not None:
            if self._paused:
                # A new urgent element must never end up gated behind
                # paused ancestors; the subsequent check() re-pauses
                # anything that is still safely stallable.
                with self._lock:
                    for p in element.parents:
                        if p.uid in self._paused:
                            self._resume_locked()
                            break
            self.check()

    def on_boundary(self, element: ComputationalElement) -> None:
        """Element-completion hook (both executors)."""
        if not self.enabled:
            return
        if self.full_boundary_checks:
            self.check()
            return
        # Worker-thread context: never walk scheduler lane state here.
        is_done = self._done
        with self._lock:
            self._live.pop(element.uid, None)
            for uid in [u for u, e in self._live.items() if is_done(e)]:
                del self._live[uid]
            if self._paused and not self._live:
                self._resume_locked()

    def ensure_progress(self, element: Optional[ComputationalElement] = None
                        ) -> bool:
        """Stalled-host hook: a wait that cannot complete resumes paused
        work.  Returns True when anything was resumed."""
        if not self._paused:
            return False
        with self._lock:
            if not self._paused:
                return False
            self._resume_locked()
        return True

    # ------------------------------------------------------------------
    def stats(self) -> dict:
        if not (self.enabled or self.deadline_elements):
            return {}
        out = {
            "deadline_elements": self.deadline_elements,
            "edf_preemptions": self.preemptions,
            "edf_preempt_events": self.preempt_events,
            "edf_resumes": self.resumes,
        }
        rounds = getattr(self.sched.executor, "edf_fill_rounds", 0)
        if rounds:
            out["edf_fill_rounds"] = rounds
        return out
