"""SubmissionPipeline — the explicit, lock-protected submission path.

Historically the stages of issuing one computational element (device
placement, argument prefetch, cross-device migration, DAG insertion, lane
assignment, executor submission) were inlined across ``GrScheduler.launch``
and ``GrScheduler._schedule``; correct only when a single host thread talked
to the scheduler.  Multi-tenant serving has *concurrent* submitters, so the
pipeline is now an explicit object with one re-entrant lock guarding every
stage:

    place -> reserve (EVICT) -> prefetch (H2D) -> migrate (D2D) -> DAG-add
          -> lane-assign -> submit

The lock is held across the whole pipeline for one element (plus the host
synchronization paths), which keeps the paper's dependency inference sound
under concurrency: the DAG frontier, the stream manager's lane table and the
executor's clocks are only ever mutated by the lock holder.  Submissions from
different threads serialize at the pipeline; the *executors* still overlap
device work freely (that is the whole point of lanes).

The pipeline is deliberately a thin, orderable object: each stage is a
method, so subclasses (or tests) can instrument/override individual stages
without re-implementing ``launch``.
"""
from __future__ import annotations

import threading
from typing import TYPE_CHECKING, Iterable, Optional, Sequence, Set

from .element import (Arg, ComputationalElement, DEFAULT_TENANT, ElementKind,
                      inout)

if TYPE_CHECKING:  # pragma: no cover
    from .scheduler import GrScheduler


class SubmissionPipeline:
    """Serializes concurrent submitters onto one scheduler instance."""

    def __init__(self, sched: "GrScheduler") -> None:
        self.sched = sched
        # RLock: host-access synchronization can nest inside a launch (e.g.
        # a ManagedValue.get() issued from a tuning callback) and the public
        # entry points wrap each other freely.
        self._lock = threading.RLock()
        self.submissions = 0
        self._seen_threads: Set[int] = set()

    # -- critical section ------------------------------------------------
    def __enter__(self) -> "SubmissionPipeline":
        self._lock.acquire()
        self._seen_threads.add(threading.get_ident())
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        self._lock.release()
        return False

    # -- stages ----------------------------------------------------------
    def run(self, e: ComputationalElement) -> None:
        """Full pipeline for a kernel element under the parallel policy.

        Caller must hold the pipeline lock (``with sched.pipeline:``)."""
        sched = self.sched
        # Placement first: prefetches land on the consuming device and
        # cross-device inputs get D2D copies before the kernel is added.
        # A caller-pinned device (GrFunction ``with_options(device=...)``)
        # bypasses the placement policy but is clamped to the device count.
        if e.device is None:
            e.device = sched.streams.place(e, sched.executor.is_done)
        else:
            e.device = min(max(0, int(e.device)), sched.num_devices - 1)
        # Reserve the element's working set before anything lands on the
        # device: under budget pressure this synthesizes DAG-ordered EVICT
        # elements for LRU victims (spill D2H first, reload H2D after — the
        # copy engines see them in that order).
        self.reserve(e)
        # Tier-resident read args (spilled to compressed host / disk) must
        # come back regardless of ``auto_prefetch``: the fault-driven
        # single-device mode can read a *host-valid* array in place, but a
        # tier payload is not host-addressable until its RELOAD runs.
        self.reload(e.args, e.device, priority=e.priority, tenant=e.tenant,
                    deadline_s=e.deadline_s, deadline_t=e.deadline_t)
        # Host-resident read args must reach the device ahead of the kernel.
        # With auto_prefetch off on a single device the executor reads the
        # host copy in place (GrCUDA's fault-driven mode), but on multiple
        # devices skipping the H2D would leave cross-device host-only reads
        # never localized (migrate() only moves device-owned copies), so the
        # upload is forced regardless of the flag.
        if sched.auto_prefetch or sched.num_devices > 1:
            self.prefetch(e.args, e.device, priority=e.priority,
                          tenant=e.tenant, deadline_s=e.deadline_s,
                          deadline_t=e.deadline_t)
        if sched.num_devices > 1:
            self.migrate(e.args, e.device, priority=e.priority,
                         tenant=e.tenant, deadline_s=e.deadline_s,
                         deadline_t=e.deadline_t)
        self.schedule(e)

    def reserve(self, e: ComputationalElement,
                extra_pinned: Optional[Iterable[int]] = None) -> None:
        """Budget stage: make room for ``e``'s working set on its device.

        No-op under unlimited budgets.  Victims are chosen LRU-first among
        non-frontier arrays (no live DAG readers/writer); each victim gets
        one EVICT element — an async D2H write-back (clean copies just
        drop) ordered *after* the victim's last reader by the ordinary
        dependency rules, exactly like the paper's transparent H2D/D2D
        insertion.  Evictions inherit the triggering element's priority and
        tenant: making room is work done on that element's behalf.

        ``extra_pinned`` forwards to :meth:`MemoryManager.reserve` — the
        replay fast path pins its plan-bound arrays so only foreign
        leftovers are evicted under a replay."""
        sched = self.sched
        mem = sched.memory
        if not mem.bounded:
            return
        for ma in mem.reserve(e.device, e, sched.dag.has_device_frontier,
                              extra_pinned):
            self.evict(ma, priority=e.priority, tenant=e.tenant,
                       deadline_s=e.deadline_s, deadline_t=e.deadline_t)

    def reserve_plan(self, plan, extra_pinned: Optional[Iterable[int]] = None
                     ) -> None:
        """Budget stage for a Belady-scheduled plan replay: make room for
        the plan's recorded per-device peaks once, up front.

        A ``mem_scheduled`` plan carries its own EVICT elements — its
        element order *is* the memory schedule — so the only possible
        victims here are *foreign* leftovers from earlier episodes still
        holding bytes the plan's peak needs.  Plan gating
        (``plan_fits``) already guaranteed peak <= budget."""
        sched = self.sched
        mem = sched.memory
        if not mem.bounded:
            return
        for device, peak in plan.device_mem:
            for ma in mem.reserve_bytes(device, peak,
                                        sched.dag.has_device_frontier,
                                        extra_pinned):
                self.evict(ma)

    def evict(self, ma, *, priority: int = 0,
              tenant: str = DEFAULT_TENANT,
              deadline_s: Optional[float] = None,
              deadline_t: Optional[float] = None) -> ComputationalElement:
        """Synthesize and schedule one EVICT element for ``ma``.

        ``inout`` access makes the DAG order it after every in-flight
        reader and the last writer of the array; the device copy is dropped
        at schedule time (logical bits + residency via the MemoryManager),
        the executors perform the physical write-back/release.  A clean
        copy (host still valid) is dropped without moving bytes.

        Dirty victims consult the spill-tier stack (``memory.select_tier``):
        a peer-device spill becomes a device-to-device transfer (the EVICT
        runs on the D2D link, ``src_device`` set like any D2D element), a
        host-tier spill stays on the D2H engine but the tier stores/encodes
        the payload instead of the plain host write-back.  A stack-wide
        miss — or no stack at all — is the flat PR 5 D2H spill."""
        sched = self.sched
        dirty = not getattr(ma, "host_valid", True)
        tier = plan = None
        if dirty:
            tier, plan = sched.memory.select_tier(ma)
        if tier is None:
            t = ComputationalElement(
                fn=None, args=(inout(ma),), kind=ElementKind.EVICT,
                name=f"evict_{ma.name}",
                transfer_bytes=ma.nbytes if dirty else 0,
                config={"writeback": dirty}, priority=priority, tenant=tenant,
                deadline_s=deadline_s, deadline_t=deadline_t)
            t.device = ma.device_id if ma.device_id is not None else 0
            if sched.policy == "parallel":
                self.schedule(t)
            else:
                self.serial(t)
            sched.memory.note_evict(ma)
            return t
        src = ma.device_id if ma.device_id is not None else 0
        target = plan.get("target")
        wire = int(plan.get("transfer_bytes", ma.nbytes))
        t = ComputationalElement(
            fn=None, args=(inout(ma),), kind=ElementKind.EVICT,
            name=f"evict_{ma.name}", transfer_bytes=wire,
            config=dict({"writeback": True}, **plan.get("config", {})),
            priority=priority, tenant=tenant,
            deadline_s=deadline_s, deadline_t=deadline_t)
        t.tier = tier
        if tier.location == "device":
            t.device = target       # runs on the (src -> target) D2D link
            t.src_device = src
            sched.d2d_transfers += 1
        else:
            t.device = src          # runs on the source's D2H engine
        if sched.policy == "parallel":
            self.schedule(t)
        else:
            self.serial(t)
        sched.memory.note_spill(ma, tier, target, wire)
        return t

    def reload(self, args: Sequence[Arg], device: int, *,
               priority: int = 0, tenant: str = DEFAULT_TENANT,
               deadline_s: Optional[float] = None,
               deadline_t: Optional[float] = None) -> None:
        """Insert RELOAD elements for read args parked in a host-side tier
        (``ma.backing_tier`` set).  The tier handler restores the host
        payload and the H2D engine uploads it; the DAG orders the RELOAD
        after the spill's write via the ordinary ``inout`` rules.  Peer-tier
        blocks never reach here — they are device-resident and come back
        through the migrate stage's plain D2D."""
        sched = self.sched
        for a in args:
            ma = a.array
            tname = getattr(ma, "backing_tier", None)
            if tname is None or not a.mode.reads:
                continue
            tier = sched.memory.tier_named(tname)
            if tier is None:        # stack reconfigured under a live block
                continue
            cfg = {"tier": tier.name}
            gbps = getattr(tier, "gbps", None)
            if gbps is not None:
                cfg["tier_gbps"] = gbps
            t = ComputationalElement(
                fn=None, args=(inout(ma),), kind=ElementKind.RELOAD,
                name=f"reload_{ma.name}",
                transfer_bytes=tier.reload_wire_bytes(ma),
                config=cfg, priority=priority, tenant=tenant,
                deadline_s=deadline_s, deadline_t=deadline_t)
            t.tier = tier
            t.device = device
            if sched.policy == "parallel":
                self.schedule(t)
            else:
                self.serial(t)
            sched.memory.note_reload(ma, device)

    def prefetch(self, args: Sequence[Arg], device: int = 0, *,
                 priority: int = 0, tenant: str = DEFAULT_TENANT,
                 deadline_s: Optional[float] = None,
                 deadline_t: Optional[float] = None) -> None:
        """Insert asynchronous H2D transfers for host-resident read args.

        The transfers inherit the consuming kernel's priority/tenant (and
        deadline): a latency-critical kernel's input upload must not be
        accounted (or de-prioritized) as someone else's work."""
        sched = self.sched
        for a in args:
            ma = a.array
            if a.mode.reads and ma.host_valid and not ma.device_valid:
                t = ComputationalElement(
                    fn=None, args=(inout(ma),), kind=ElementKind.TRANSFER,
                    name=f"h2d_{ma.name}", transfer_bytes=ma.nbytes,
                    priority=priority, tenant=tenant,
                    deadline_s=deadline_s, deadline_t=deadline_t)
                t.device = device
                if sched.policy == "parallel":
                    self.schedule(t)
                else:
                    self.serial(t)
                # Logical location update at schedule time (see managed.py),
                # via the MemoryManager so residency tracks the bits.
                sched.memory.note_h2d(ma, device)

    def migrate(self, args: Sequence[Arg], device: int, *,
                priority: int = 0, tenant: str = DEFAULT_TENANT,
                deadline_s: Optional[float] = None,
                deadline_t: Optional[float] = None) -> None:
        """Move device-resident read args owned by *other* devices onto
        ``device`` via D2D transfer elements (single-copy ownership model:
        the copy migrates, it is not replicated)."""
        sched = self.sched
        for a in args:
            ma = a.array
            if not a.mode.reads or not getattr(ma, "device_valid", False):
                continue
            src = getattr(ma, "device_id", None)
            if src is None:
                sched.memory.note_d2d(ma, device)  # claim unowned copies
                continue
            if src == device:
                continue
            t = ComputationalElement(
                fn=None, args=(inout(ma),), kind=ElementKind.D2D,
                name=f"d2d_{ma.name}", transfer_bytes=getattr(ma, "nbytes", 0),
                priority=priority, tenant=tenant,
                deadline_s=deadline_s, deadline_t=deadline_t)
            t.device = device
            t.src_device = src
            self.schedule(t)
            sched.memory.note_d2d(ma, device)
            sched.d2d_transfers += 1

    def schedule(self, e: ComputationalElement) -> None:
        """DAG insert + lane assignment + submission (parallel policy)."""
        sched = self.sched
        # Idempotent deadline stamp: kernels arrive tagged from _launch,
        # auto children carry inherited deadline_t; both still register
        # with the monitor here (direct schedule() callers get stamped).
        sched.deadlines.tag(e)
        sched.executor.host_overhead(sched.launch_overhead_s)
        if sched.sanitizer is not None:
            sched.sanitizer.on_schedule(e)
        sched.dag.add(e)
        lane, events = sched.streams.assign(e, sched.executor.is_done)
        sched.executor.submit(e, lane.lane_id, events)
        sched._elements.append(e)
        self.submissions += 1
        # Submission-time deadline-risk check (may preempt queued bulk work).
        sched.deadlines.on_submit(e)
        if sched._capture is not None:
            sched._capture.trace(e)

    def serial(self, e: ComputationalElement) -> None:
        """Original GrCUDA behaviour: blocking, in-order, single lane, no
        dependency computation (overheads even smaller, §V-C)."""
        sched = self.sched
        sched.executor.host_overhead(sched.launch_overhead_s)
        e.parents = []
        sched.executor.submit(e, 0, [])
        sched.executor.wait(e)
        sched._elements.append(e)
        self.submissions += 1

    # -- stats -----------------------------------------------------------
    def stats(self) -> dict:
        return {"pipeline_submissions": self.submissions,
                "pipeline_threads_seen": len(self._seen_threads)}
