"""SubmissionPipeline — the explicit, lock-protected submission path.

Historically the stages of issuing one computational element (device
placement, argument prefetch, cross-device migration, DAG insertion, lane
assignment, executor submission) were inlined across ``GrScheduler.launch``
and ``GrScheduler._schedule``; correct only when a single host thread talked
to the scheduler.  Multi-tenant serving has *concurrent* submitters, so the
pipeline is now an explicit object with one re-entrant lock guarding every
stage:

    place -> prefetch (H2D) -> migrate (D2D) -> DAG-add -> lane-assign -> submit

The lock is held across the whole pipeline for one element (plus the host
synchronization paths), which keeps the paper's dependency inference sound
under concurrency: the DAG frontier, the stream manager's lane table and the
executor's clocks are only ever mutated by the lock holder.  Submissions from
different threads serialize at the pipeline; the *executors* still overlap
device work freely (that is the whole point of lanes).

The pipeline is deliberately a thin, orderable object: each stage is a
method, so subclasses (or tests) can instrument/override individual stages
without re-implementing ``launch``.
"""
from __future__ import annotations

import threading
from typing import TYPE_CHECKING, Sequence, Set

from .element import (Arg, ComputationalElement, DEFAULT_TENANT, ElementKind,
                      inout)

if TYPE_CHECKING:  # pragma: no cover
    from .scheduler import GrScheduler


class SubmissionPipeline:
    """Serializes concurrent submitters onto one scheduler instance."""

    def __init__(self, sched: "GrScheduler") -> None:
        self.sched = sched
        # RLock: host-access synchronization can nest inside a launch (e.g.
        # a ManagedValue.get() issued from a tuning callback) and the public
        # entry points wrap each other freely.
        self._lock = threading.RLock()
        self.submissions = 0
        self._seen_threads: Set[int] = set()

    # -- critical section ------------------------------------------------
    def __enter__(self) -> "SubmissionPipeline":
        self._lock.acquire()
        self._seen_threads.add(threading.get_ident())
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        self._lock.release()
        return False

    # -- stages ----------------------------------------------------------
    def run(self, e: ComputationalElement) -> None:
        """Full pipeline for a kernel element under the parallel policy.

        Caller must hold the pipeline lock (``with sched.pipeline:``)."""
        sched = self.sched
        # Placement first: prefetches land on the consuming device and
        # cross-device inputs get D2D copies before the kernel is added.
        # A caller-pinned device (GrFunction ``with_options(device=...)``)
        # bypasses the placement policy but is clamped to the device count.
        if e.device is None:
            e.device = sched.streams.place(e, sched.executor.is_done)
        else:
            e.device = min(max(0, int(e.device)), sched.num_devices - 1)
        if sched.auto_prefetch:
            self.prefetch(e.args, e.device, priority=e.priority,
                          tenant=e.tenant)
        if sched.num_devices > 1:
            self.migrate(e.args, e.device, priority=e.priority,
                         tenant=e.tenant)
        self.schedule(e)

    def prefetch(self, args: Sequence[Arg], device: int = 0, *,
                 priority: int = 0, tenant: str = DEFAULT_TENANT) -> None:
        """Insert asynchronous H2D transfers for host-resident read args.

        The transfers inherit the consuming kernel's priority/tenant: a
        latency-critical kernel's input upload must not be accounted (or
        de-prioritized) as someone else's work."""
        sched = self.sched
        for a in args:
            ma = a.array
            if a.mode.reads and ma.host_valid and not ma.device_valid:
                t = ComputationalElement(
                    fn=None, args=(inout(ma),), kind=ElementKind.TRANSFER,
                    name=f"h2d_{ma.name}", transfer_bytes=ma.nbytes,
                    priority=priority, tenant=tenant)
                t.device = device
                if sched.policy == "parallel":
                    self.schedule(t)
                else:
                    self.serial(t)
                # Logical location update at schedule time (see managed.py).
                ma.device_valid = True
                ma.device_id = device

    def migrate(self, args: Sequence[Arg], device: int, *,
                priority: int = 0, tenant: str = DEFAULT_TENANT) -> None:
        """Move device-resident read args owned by *other* devices onto
        ``device`` via D2D transfer elements (single-copy ownership model:
        the copy migrates, it is not replicated)."""
        sched = self.sched
        for a in args:
            ma = a.array
            if not a.mode.reads or not getattr(ma, "device_valid", False):
                continue
            src = getattr(ma, "device_id", None)
            if src is None:
                ma.device_id = device      # claim unowned device copies
                continue
            if src == device:
                continue
            t = ComputationalElement(
                fn=None, args=(inout(ma),), kind=ElementKind.D2D,
                name=f"d2d_{ma.name}", transfer_bytes=getattr(ma, "nbytes", 0),
                priority=priority, tenant=tenant)
            t.device = device
            t.src_device = src
            self.schedule(t)
            ma.device_id = device
            sched.d2d_transfers += 1

    def schedule(self, e: ComputationalElement) -> None:
        """DAG insert + lane assignment + submission (parallel policy)."""
        sched = self.sched
        sched.executor.host_overhead(sched.launch_overhead_s)
        sched.dag.add(e)
        lane, events = sched.streams.assign(e, sched.executor.is_done)
        sched.executor.submit(e, lane.lane_id, events)
        sched._elements.append(e)
        self.submissions += 1
        if sched._capture is not None:
            sched._capture.trace(e)

    def serial(self, e: ComputationalElement) -> None:
        """Original GrCUDA behaviour: blocking, in-order, single lane, no
        dependency computation (overheads even smaller, §V-C)."""
        sched = self.sched
        sched.executor.host_overhead(sched.launch_overhead_s)
        e.parents = []
        sched.executor.submit(e, 0, [])
        sched.executor.wait(e)
        sched._elements.append(e)
        self.submissions += 1

    # -- stats -----------------------------------------------------------
    def stats(self) -> dict:
        return {"pipeline_submissions": self.submissions,
                "pipeline_threads_seen": len(self._seen_threads)}
