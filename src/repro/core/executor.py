"""Executors: how lanes actually run computational elements.

Two implementations behind one interface:

* ``ThreadLaneExecutor`` — real execution.  Each lane is a worker thread with
  an ordered queue (CUDA-stream semantics: in-order per lane, lanes
  independent).  Cross-lane dependencies wait on per-element events — the
  CUDA-event analogue; the host is never blocked by device work (§IV-B).
  Kernels are (jitted) JAX callables; transfers are ``jax.device_put``.

* ``SimExecutor`` — a discrete-event simulator that replays the *same* DAG +
  lane assignment under a calibrated hardware model: processor-sharing
  compute with a per-kernel *parallel fraction* (space-sharing contention,
  Fig. 9), one copy engine per transfer direction with fair bandwidth
  sharing, and host scheduling overhead.  This is how speedup numbers are
  produced on a machine that is not an Nvidia GPU: the scheduling algorithm
  is identical, only the clock is simulated.
"""
from __future__ import annotations

import queue
import threading
import time
from collections import deque
from dataclasses import dataclass
from typing import Deque, Dict, List, Optional

import numpy as np

from .element import ComputationalElement, ElementKind, ElementState
from .history import KernelHistory
from .timeline import Timeline


class Executor:
    """Interface shared by real and simulated executors."""

    timeline: Timeline
    history: KernelHistory
    # True when per-element wait() only blocks on a completion handle and
    # touches no shared executor state — the scheduler may then drop its
    # submission-pipeline lock while waiting, so one tenant's host read
    # cannot stall other tenants' launches (priority-inversion guard).
    # The simulator advances a shared clock in wait(), so it stays False.
    concurrent_waits = False
    # True when pausing a queued element requires a pause_gate event the
    # lane worker blocks on (real threads); the simulator pauses purely
    # via ElementState.PAUSED.
    pause_via_gates = False
    # Deadline-monitor hooks (installed by GrScheduler; None = no-op).
    # ``on_boundary(element)`` fires at every element completion — the
    # deadline-risk re-check point.  ``on_stall(element_or_None) -> bool``
    # fires when a host wait cannot make progress; it resumes paused work
    # and returns True when it changed anything.
    on_boundary = None
    on_stall = None
    # Sanitizer hooks (installed by ``GrScheduler(sanitize=True)``; None =
    # no-op).  ``pre_exec(element)`` fires when the element actually starts
    # executing (after its waits/gates resolved), ``post_exec(element)``
    # when its body finished but *before* the completion event is
    # published — so correctly-ordered children can never appear to
    # overlap their parent.
    pre_exec = None
    post_exec = None

    def _notify_boundary(self, element: ComputationalElement) -> None:
        cb = self.on_boundary
        if cb is not None:
            cb(element)

    def device_now(self) -> float:
        """Clock deadline-risk checks compare deadlines against: the sim
        clock mid-advance, the host clock on real executors."""
        return self.host_now()

    def submit(self, element: ComputationalElement, lane_id: int,
               wait_parents: List[ComputationalElement]) -> None:
        raise NotImplementedError

    def submit_batch(self, items) -> None:
        """Submit a pre-scheduled batch (capture/replay fast path).

        ``items`` is a sequence of ``(element, lane_id, wait_parents)``
        triples in topological order.  Subclasses override to pre-materialize
        completion events / start the whole batch at once."""
        for element, lane_id, waits in items:
            self.submit(element, lane_id, waits)

    def is_done(self, element: ComputationalElement) -> bool:
        raise NotImplementedError

    def wait(self, element: ComputationalElement) -> None:
        raise NotImplementedError

    def wait_all(self) -> None:
        raise NotImplementedError

    def host_overhead(self, seconds: float) -> None:
        """Host-side scheduling cost (only the simulator advances a clock)."""

    def host_now(self) -> float:
        raise NotImplementedError

    def record_host_span(self, element: ComputationalElement, t0: float,
                         t1: float) -> None:
        self.timeline.record(element.uid, element.name, "host", None, t0, t1)

    def shutdown(self) -> None:
        pass


# ======================================================================
# Real execution: threads as lanes, JAX async dispatch underneath
# ======================================================================

def _run_device_element(e: ComputationalElement, jdev=None):
    """Execute a kernel/transfer element against its ManagedArray args.

    ``jdev`` is the JAX device the element's lane is pinned to (None when a
    single device is visible — the pre-multi-device behaviour)."""
    import jax

    if e.kind is ElementKind.TRANSFER:
        ma = e.args[0].array
        val = jax.device_put(np.asarray(ma.host), jdev)
        val.block_until_ready()
        ma.set_physical_device(val)
        return

    if e.kind is ElementKind.D2D:
        ma = e.args[0].array
        val = jax.device_put(ma.device_value(), jdev)
        if hasattr(val, "block_until_ready"):
            val.block_until_ready()
        ma.set_physical_device(val)
        return

    if e.kind is ElementKind.EVICT:
        ma = e.args[0].array
        tier = e.tier
        if tier is not None and tier.location == "device":
            # Peer-device spill: a D2D copy onto the tier's target device
            # (the lane — and jdev — belong to the target, like any D2D).
            val = jax.device_put(ma.device_value(), jdev)
            if hasattr(val, "block_until_ready"):
                val.block_until_ready()
            ma.set_physical_device(val)
            return
        if tier is not None:
            # Host-side tier: store/encode the payload (compressed bytes,
            # spool file), then release the device buffer.
            tier.spill(ma)
            ma.set_physical_device(None)
            return
        # Flat budget spill: write the device copy back to the host buffer
        # when it was the only valid one, then actually release the device
        # buffer (dropping the reference frees the backing device memory).
        if e.config.get("writeback", True) and ma.device is not None:
            np.copyto(ma.host, np.asarray(ma.device))
        ma.set_physical_device(None)
        return

    if e.kind is ElementKind.RELOAD:
        # Bring a tier-spilled block back: the tier decodes/reads the
        # payload (refreshing ma.host) and the copy engine uploads it.
        ma = e.args[0].array
        val = jax.device_put(np.asarray(e.tier.reload(ma)), jdev)
        val.block_until_ready()
        ma.set_physical_device(val)
        return

    inputs = [a.array.device_value() for a in e.args]
    if jdev is not None:
        # Commit every input to the lane's device so XLA runs the kernel
        # there (device_put is a no-op for values already resident).
        inputs = [jax.device_put(x, jdev) for x in inputs]
    result = e.fn(*inputs)
    writable = [a.array for a in e.args if a.mode.writes]
    if writable:
        outs = result if isinstance(result, (tuple, list)) else (result,)
        if len(outs) != len(writable):
            raise ValueError(
                f"kernel {e.name}: returned {len(outs)} outputs for "
                f"{len(writable)} writable args")
        for ma, val in zip(writable, outs):
            if hasattr(val, "block_until_ready"):
                val.block_until_ready()
            ma.set_physical_device(val)
    elif result is not None and hasattr(result, "block_until_ready"):
        result.block_until_ready()


class _LaneWorker(threading.Thread):
    def __init__(self, lane_id: int, executor: "ThreadLaneExecutor") -> None:
        super().__init__(name=f"lane-{lane_id}", daemon=True)
        self.lane_id = lane_id
        self.executor = executor
        self.q: "queue.Queue" = queue.Queue()
        self.start()

    def run(self) -> None:
        while True:
            item = self.q.get()
            if item is None:
                return
            element, waits = item
            try:
                while waits:        # pop: no loop variable may outlive the
                    waits.pop().done_event.wait()   # wait (see finally below)
                # Element-boundary preemption: a paused element blocks its
                # lane *in place* (FIFO order is a dependency carrier — the
                # queue must never be reordered) until the deadline monitor
                # resumes it.  A gate published after this check simply
                # means the element already started: running work is never
                # interrupted.
                gate = element.pause_gate
                if gate is not None:
                    gate.wait()
                element.state = ElementState.RUNNING
                pre = self.executor.pre_exec
                if pre is not None:
                    pre(element)
                t0 = self.executor.host_now()
                _run_device_element(element,
                                    self.executor.jax_device_for(element))
                t1 = self.executor.host_now()
                post = self.executor.post_exec
                if post is not None:
                    post(element)
                element.t_start, element.t_end = t0, t1
                kind = ("h2d" if element.kind in (ElementKind.TRANSFER,
                                                 ElementKind.RELOAD)
                        else "d2d" if (element.kind is ElementKind.D2D
                                       or (element.kind is ElementKind.EVICT
                                           and element.src_device is not None))
                        else "d2h" if element.kind is ElementKind.EVICT
                        else "compute")
                self.executor.timeline.record(
                    element.uid, element.name, kind, self.lane_id, t0, t1,
                    tenant=element.tenant, priority=element.priority,
                    t_issue=element.t_issue, deadline=element.deadline_t)
                if element.kind is ElementKind.KERNEL:
                    self.executor.history.record(
                        element.name, element.config, t1 - t0)
            except BaseException as exc:  # surfaced on wait()
                element.error = exc
            finally:
                element.state = ElementState.DONE
                element.done_event.set()
                self.executor._notify_boundary(element)
                self.q.task_done()
                # An idle worker blocked on q.get must not keep its last
                # element's graph (and, through the args, the arrays)
                # reachable: tier-spilled blocks rely on GC finalizers to
                # release their spool payloads.
                del item, element, waits


class ThreadLaneExecutor(Executor):
    concurrent_waits = True     # wait() is a pure event wait
    pause_via_gates = True      # paused elements block their lane worker

    def __init__(self, num_devices: int = 1) -> None:
        self.timeline = Timeline()
        self.history = KernelHistory()
        self.num_devices = max(1, num_devices)
        self._jax_devices = None           # resolved lazily (jax.devices())
        self._lanes: Dict[int, _LaneWorker] = {}
        self._submitted: List[ComputationalElement] = []
        self._epoch = time.perf_counter()

    def jax_device_for(self, element: ComputationalElement):
        """JAX device backing the element's lane; None when single-device
        (scheduling still works, D2D copies degrade to no-ops)."""
        if self.num_devices <= 1:
            return None
        if self._jax_devices is None:
            import jax
            self._jax_devices = jax.devices()
        if len(self._jax_devices) <= 1:
            return None
        dev = element.device if element.device is not None else 0
        return self._jax_devices[dev % len(self._jax_devices)]

    def host_now(self) -> float:
        return time.perf_counter() - self._epoch

    def _worker(self, lane_id: int) -> _LaneWorker:
        worker = self._lanes.get(lane_id)
        if worker is None:
            worker = self._lanes[lane_id] = _LaneWorker(lane_id, self)
        return worker

    def submit(self, element, lane_id, wait_parents) -> None:
        element.done_event = threading.Event()
        element.error = None
        element.state = ElementState.QUEUED
        element.t_issue = self.host_now()
        self._submitted.append(element)
        self._worker(lane_id).q.put((element, list(wait_parents)))

    def submit_batch(self, items) -> None:
        # Pre-materialize every completion event before anything is
        # enqueued: a worker may dequeue a child and wait on a sibling-lane
        # parent that has not been individually submitted yet.
        for element, _, _ in items:
            element.done_event = threading.Event()
            element.error = None
            element.state = ElementState.QUEUED
            element.t_issue = self.host_now()
        for element, lane_id, waits in items:
            self._submitted.append(element)
            self._worker(lane_id).q.put((element, list(waits)))

    def is_done(self, element) -> bool:
        ev = element.done_event
        return ev is not None and ev.is_set()

    def wait(self, element) -> None:
        ev = element.done_event
        if ev is None:
            return
        stall = self.on_stall
        if stall is None:
            ev.wait()
        else:
            # A host wait must never deadlock on paused (preempted) work:
            # poll, giving the deadline monitor a chance to resume anything
            # the host is now blocked on.  Event.wait returns as soon as the
            # event is set, so completed elements pay no extra latency.
            while not ev.wait(0.02):
                stall(element)
        if getattr(element, "error", None) is not None:
            raise element.error

    def wait_all(self) -> None:
        for e in self._submitted:
            self.wait(e)
        self._submitted.clear()

    def shutdown(self) -> None:
        """Idempotent: stop every lane worker and *join* it.  Relying on
        daemon-thread teardown leaked running workers into interpreter exit
        (and kept spool-file finalizers from running deterministically);
        after shutdown returns, no lane thread is alive."""
        if self.on_stall is not None:
            self.on_stall(None)   # resume paused work so workers can drain
        workers = list(self._lanes.values())
        self._lanes.clear()
        for w in workers:
            w.q.put(None)         # sentinel after any queued work: drain
        for w in workers:
            w.join(timeout=5.0)


# ======================================================================
# Discrete-event simulation
# ======================================================================

@dataclass
class SimHardware:
    """Cost model of the target device + host link.

    * ``cost_s`` of a kernel is its *solo* execution time; a kernel's
      ``parallel_fraction`` (pf) is the fraction of device resources it
      occupies while running solo (SM occupancy / bandwidth analogue).
    * Space-sharing: concurrent kernels water-fill the device's unit
      capacity — a kernel receives allocation ``a ≤ pf`` and progresses at
      rate ``a / pf`` (≤ 1).  Two pf=0.75 kernels therefore run at 0.67×
      each — the ~70 %-of-contention-free-bound regime of Fig. 9 — while
      low-occupancy kernels overlap for free (the ML benchmark's low-IPC
      kernel, Fig. 12).
    * Transfers: one copy engine per direction, FIFO order, full bandwidth —
      CUDA DMA semantics (no fair-sharing of a single engine).

    Defaults approximate the paper's PCIe-3.0 testbeds; the benchsuite
    calibrates per-kernel costs, so only *relative* magnitudes matter for the
    scheduling comparison.
    """

    h2d_gbps: float = 12.0          # effective PCIe 3.0 x16 H2D bandwidth
    d2h_gbps: float = 12.0
    default_parallel_fraction: float = 0.75
    launch_overhead_s: float = 5e-6
    # Multi-device: N identical devices, each with unit compute capacity and
    # its own H2D/D2H copy engines; device pairs are connected by a
    # point-to-point link (NVLink / PCIe P2P analogue) used by D2D elements.
    num_devices: int = 1
    d2d_gbps: float = 50.0


@dataclass
class _SimTask:
    element: ComputationalElement
    kind: str                   # compute | h2d | d2h | d2d
    work: float                 # seconds (compute) or bytes (transfer)
    remaining: float
    pf: float
    lane: int
    issue_t: float
    device: int = 0             # executing device (D2D: destination)
    src_device: int = 0         # D2D only: device the copy reads from
    rate: float = 0.0
    t_start: float = float("nan")
    weight: float = 1.0         # priority weight for the capacity water-fill
    # Per-tier bandwidth override (GB/s): a disk-tier spill occupies its
    # copy engine at disk rate, not at link rate.  None = engine default.
    gbps: Optional[float] = None


class SimExecutor(Executor):
    """Event-driven replay of the scheduled DAG under `SimHardware`."""

    def __init__(self, hw: Optional[SimHardware] = None) -> None:
        self.hw = hw or SimHardware()
        self.timeline = Timeline()
        self.history = KernelHistory()
        self.now = 0.0                    # device/simulation clock
        self.host_time = 0.0              # host program clock
        self.edf_fill_rounds = 0          # rate recomputes where the EDF
        #                                   layer handed capacity out first
        self._pending: List[_SimTask] = []
        self._running: List[_SimTask] = []
        self._end: Dict[int, float] = {}   # uid -> completion time
        # Lane queues complete strictly in head order (_try_start admits only
        # the head), so a deque with popleft keeps completion O(1) instead of
        # list.remove's O(n) — O(n^2) per episode on long serving lanes.
        self._lane_q: Dict[int, Deque[int]] = {}  # lane -> uid queue (order)

    # -- host clock ----------------------------------------------------
    def host_now(self) -> float:
        return self.host_time

    def device_now(self) -> float:
        return max(self.now, self.host_time)

    def host_overhead(self, seconds: float) -> None:
        self.host_time += seconds
        self._advance_to(self.host_time)

    # -- submission ------------------------------------------------------
    def submit(self, element, lane_id, wait_parents) -> None:
        self._enqueue(element, lane_id)
        self._try_start()

    def submit_batch(self, items) -> None:
        # Replay fast path: enqueue the whole pre-scheduled episode, then
        # run a single readiness scan instead of one per element.
        for element, lane_id, _ in items:
            self._enqueue(element, lane_id)
        self._try_start()

    def _enqueue(self, element, lane_id) -> None:
        if element.kind is ElementKind.TRANSFER:
            kind = "h2d"
            work = float(element.transfer_bytes)
        elif element.kind is ElementKind.D2D:
            kind = "d2d"
            work = float(element.transfer_bytes)
        elif element.kind is ElementKind.EVICT:
            # Spill write-back occupies the D2H engine for its byte count;
            # clean drops (transfer_bytes == 0) complete instantly.  A
            # peer-tier spill (src_device set) runs on the D2D link instead.
            kind = "d2d" if element.src_device is not None else "d2h"
            work = float(element.transfer_bytes)
        elif element.kind is ElementKind.RELOAD:
            # Tier reload: the H2D engine is occupied for the upload (at
            # the tier's bandwidth when it is the slower stage of the pipe).
            kind = "h2d"
            work = float(element.transfer_bytes)
        else:
            kind = "compute"
            est = element.cost_s
            if not est:
                h = self.history.estimate(element.name, element.config)
                est = h if h is not None else 1e-4
            work = float(est)
        pf = float(element.config.get(
            "parallel_fraction", self.hw.default_parallel_fraction))
        # The hardware model is authoritative: a schedule that names more
        # devices than the hw has folds onto the last physical device.
        top = max(0, self.hw.num_devices - 1)
        task = _SimTask(element=element, kind=kind, work=work, remaining=work,
                        pf=pf, lane=lane_id, issue_t=self.host_time,
                        device=min(element.device or 0, top),
                        src_device=min(element.src_device or 0, top),
                        weight=element.weight,
                        gbps=element.config.get("tier_gbps"))
        element.t_issue = self.host_time
        element.state = ElementState.QUEUED
        self._pending.append(task)
        self._lane_q.setdefault(lane_id, deque()).append(element.uid)

    # -- readiness & rates ---------------------------------------------
    def _parents_done(self, e: ComputationalElement) -> bool:
        return all(p.uid in self._end and self._end[p.uid] <= self.now
                   for p in e.parents)

    def _lane_head(self, t: _SimTask) -> bool:
        q = self._lane_q[t.lane]
        return q and q[0] == t.element.uid

    def _try_start(self) -> None:
        started = True
        while started:
            started = False
            for t in list(self._pending):
                # A PAUSED lane head yields without reordering: it simply
                # blocks its lane until the deadline monitor resumes it.
                if (t.issue_t <= self.now + 1e-18 and self._lane_head(t)
                        and t.element.state is not ElementState.PAUSED
                        and self._parents_done(t.element)):
                    self._pending.remove(t)
                    t.t_start = self.now
                    t.element.state = ElementState.RUNNING
                    if self.pre_exec is not None:
                        self.pre_exec(t.element)
                    self._running.append(t)
                    started = True
        self._recompute_rates()

    def _recompute_rates(self) -> None:
        # Priority-weighted water-fill of each device's unit capacity: a
        # kernel's fair share is ``remaining * w/W`` (weight over total
        # outstanding weight), still capped by its parallel fraction ``pf``;
        # it progresses at a/pf (solo rate 1.0).  Kernels are visited in
        # ascending pf/weight order so capacity a capped kernel cannot use
        # spills to the rest — with equal weights this reduces exactly to the
        # original unweighted progressive fill (ascending pf, share 1/n).
        by_dev: Dict[int, List[_SimTask]] = {}
        for t in self._running:
            if t.kind == "compute":
                by_dev.setdefault(t.device, []).append(t)
        for comp in by_dev.values():
            remaining = 1.0
            # EDF layer: deadline'd kernels take their full parallel
            # fraction in earliest-deadline order *before* any deadline-free
            # kernel sees capacity; deadline-free work then water-fills the
            # leftovers exactly as before.  With no deadlines in flight
            # ``urgent`` is empty and the fill below is bit-identical to the
            # pre-EDF scheduler.
            urgent = [t for t in comp if t.element.deadline_t is not None]
            if urgent:
                self.edf_fill_rounds += 1
                urgent.sort(key=lambda t: (t.element.deadline_t,
                                           t.element.uid))
                for t in urgent:
                    a = min(t.pf, remaining)
                    t.rate = (a / t.pf) if t.pf > 0 else 1.0
                    remaining -= a
                comp = [t for t in comp if t.element.deadline_t is None]
            todo = sorted(comp, key=lambda t: t.pf / max(t.weight, 1e-12))
            total_w = sum(t.weight for t in todo)
            for t in todo:
                share = remaining * t.weight / total_w if total_w > 0 else 0.0
                a = min(t.pf, share)
                t.rate = (a / t.pf) if t.pf > 0 else 1.0
                remaining -= a
                total_w -= t.weight
        # One DMA engine per direction *per device*, FIFO at full bandwidth.
        for direction, bw in (("h2d", self.hw.h2d_gbps),
                              ("d2h", self.hw.d2h_gbps)):
            engines: Dict[int, List[_SimTask]] = {}
            for t in self._running:
                if t.kind == direction:
                    engines.setdefault(t.device, []).append(t)
            for xs in engines.values():
                xs.sort(key=lambda t: (t.t_start, t.element.uid))
                for i, t in enumerate(xs):
                    t.rate = (t.gbps or bw) * 1e9 if i == 0 else 0.0
        # One point-to-point link per ordered (src, dst) device pair.
        links: Dict[tuple, List[_SimTask]] = {}
        for t in self._running:
            if t.kind == "d2d":
                links.setdefault((t.src_device, t.device), []).append(t)
        for xs in links.values():
            xs.sort(key=lambda t: (t.t_start, t.element.uid))
            for i, t in enumerate(xs):
                t.rate = (t.gbps or self.hw.d2d_gbps) * 1e9 if i == 0 else 0.0

    # -- event loop ------------------------------------------------------
    def _advance_to(self, target: float) -> None:
        inf = float("inf")
        guard = 0
        while True:
            guard += 1
            if guard > 5_000_000:  # pragma: no cover
                raise RuntimeError("simulation runaway")
            self._try_start()
            if not self._running:
                # Nothing executing: jump to the next issue time (if any)
                # or to the host target.
                future = [t.issue_t for t in self._pending
                          if t.issue_t > self.now + 1e-18]
                if future and (target == inf or min(future) <= target):
                    self.now = min(future)
                    continue
                if target != inf and self.now < target:
                    self.now = target
                    self._try_start()
                    if self._running:
                        continue
                return
            nxt = min(self.now + (t.remaining / t.rate if t.rate > 0 else inf)
                      for t in self._running)
            if nxt == inf:  # pragma: no cover
                raise RuntimeError("simulation deadlock: zero-rate tasks")
            step_to = nxt if target == inf else min(nxt, target)
            dt = step_to - self.now
            if dt > 0:
                for t in self._running:
                    t.remaining -= t.rate * dt
                self.now = step_to
            finished = [t for t in self._running
                        if t.remaining <= max(1e-12, 1e-9 * t.work)]
            for t in finished:
                self._running.remove(t)
                self._finish(t)
            if not finished and target != inf and self.now >= target:
                return
            if not finished and dt <= 0:
                return

    def _finish(self, t: _SimTask) -> None:
        e = t.element
        if self.post_exec is not None:
            self.post_exec(e)
        self._end[e.uid] = self.now
        e.t_start, e.t_end = t.t_start, self.now
        e.state = ElementState.DONE
        # Only the lane head may run, so the finishing task IS the head.
        self._lane_q[t.lane].popleft()
        self.timeline.record(e.uid, e.name, t.kind, t.lane, t.t_start, self.now,
                             tenant=e.tenant, priority=e.priority,
                             t_issue=t.issue_t, deadline=e.deadline_t)
        if t.kind == "compute":
            self.history.record(e.name, e.config, self.now - t.t_start)
        # Logical array-location bits are owned by the scheduler and were
        # already flipped at schedule time; nothing to do here.
        # Element boundary: the deadline monitor re-checks slack here and
        # may pause/resume queued work before the next _try_start scan.
        self._notify_boundary(e)

    # -- waiting -----------------------------------------------------------
    def is_done(self, element) -> bool:
        return element.uid in self._end and self._end[element.uid] <= self.host_time

    def wait(self, element) -> None:
        if element.uid not in self._end:
            self._advance_to(float("inf"))
        if element.uid not in self._end and self.on_stall is not None:
            # Everything runnable ran; if the target is (transitively)
            # behind paused/preempted work, resume it and advance again.
            while self.on_stall(element):
                self._advance_to(float("inf"))
                if element.uid in self._end:
                    break
        if element.uid not in self._end:
            raise RuntimeError(
                f"simulation deadlock waiting for {element.name}; "
                f"pending={[(t.element.name, t.lane) for t in self._pending]}")
        self.host_time = max(self.host_time, self._end[element.uid])

    def wait_all(self) -> None:
        self._advance_to(float("inf"))
        if (self._pending or self._running) and self.on_stall is not None:
            while self.on_stall(None):
                self._advance_to(float("inf"))
                if not (self._pending or self._running):
                    break
        if self._pending or self._running:
            raise RuntimeError("simulation finished with unrunnable tasks "
                               f"{[t.element.name for t in self._pending]}")
        self.host_time = max(self.host_time, self.now)
