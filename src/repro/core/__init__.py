"""GrJAX core: the paper's runtime DAG scheduler (see DESIGN.md §1-2)."""
from .element import (AccessMode, Arg, ComputationalElement, DEFAULT_TENANT,
                      ElementKind, ElementState, PRIORITY_WEIGHT_BASE, const,
                      dep_key, inout, kernel, out, priority_weight)
from .deadlines import DeadlineMonitor
from .dag import ComputationDAG, DAGSnapshot
from .capture import (CaptureContext, ExecutionPlan, PlanCache, PlanElement,
                      SlotSpec)
from .streams import (DataAffinityPlacement, Lane, MinLoadPlacement,
                      MinPressurePlacement, NewStreamPolicy,
                      ParentStreamPolicy, PlacementPolicy,
                      PLACEMENT_POLICIES, RoundRobinPlacement, StreamManager)
from .managed import ManagedArray
from .memory import DeviceOutOfMemoryError, MemoryManager, MemoryPool
from .tiers import (BackingTier, CompressedHostTier, DiskTier,
                    PeerDeviceTier, make_tiers)
from .submission import SubmissionPipeline
from .timeline import Timeline, Span
from .history import KernelHistory
from .executor import (Executor, SimExecutor, SimHardware,
                       ThreadLaneExecutor)
from .scheduler import GrScheduler, make_scheduler
from .frontend import (GrFunction, NoActiveRuntimeError, current_runtime,
                       function, get_runtime, runtime, set_runtime)

__all__ = [
    "AccessMode", "Arg", "ComputationalElement", "DEFAULT_TENANT",
    "ElementKind", "ElementState", "PRIORITY_WEIGHT_BASE",
    "DeadlineMonitor",
    "const", "dep_key", "inout", "kernel", "out", "priority_weight",
    "SubmissionPipeline",
    "ComputationDAG", "DAGSnapshot",
    "CaptureContext", "ExecutionPlan", "PlanCache", "PlanElement", "SlotSpec",
    "NewStreamPolicy", "ParentStreamPolicy", "StreamManager",
    "Lane", "PlacementPolicy", "PLACEMENT_POLICIES", "RoundRobinPlacement",
    "MinLoadPlacement", "DataAffinityPlacement", "MinPressurePlacement",
    "DeviceOutOfMemoryError", "MemoryManager", "MemoryPool",
    "BackingTier", "CompressedHostTier", "DiskTier", "PeerDeviceTier",
    "make_tiers",
    "ManagedArray", "Timeline", "Span", "KernelHistory",
    "Executor", "SimExecutor", "SimHardware", "ThreadLaneExecutor",
    "GrScheduler", "make_scheduler",
    "GrFunction", "NoActiveRuntimeError", "current_runtime", "function",
    "get_runtime", "runtime", "set_runtime",
]
