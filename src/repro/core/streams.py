"""Stream (execution-lane) management (paper §IV-C).

CUDA streams map to GrJAX *lanes*: ordered dispatch queues that serialize the
elements assigned to them while different lanes proceed independently.  On a
real TPU deployment a lane is a per-device async dispatch queue or a submesh
(see DESIGN.md §2); the assignment algorithm below is the paper's, verbatim:

* lanes are reused in FIFO order; a new lane is created **only** when no
  currently-empty lane exists;
* the **first child** of a computation is scheduled on its parent's lane
  (sequential lane order makes the dependency free — no event needed);
  **following children** are scheduled on other lanes to guarantee
  concurrency, synchronizing with an event;
* the manager tracks which computations are in flight on each lane and which
  managed arrays each lane currently *owns*, so a host access synchronizes
  only the lanes operating on that data (§IV-B).
"""
from __future__ import annotations

import enum
from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Set, Tuple

from .element import ComputationalElement


class NewStreamPolicy(enum.Enum):
    """How to obtain a lane when none can be inherited from a parent."""

    FIFO_REUSE = "fifo"          # reuse an empty lane in FIFO order (default)
    ALWAYS_NEW = "always-new"    # create a fresh lane every time


class ParentStreamPolicy(enum.Enum):
    """How children relate to their parents' lanes."""

    FIRST_CHILD_INHERITS = "disjoint"      # paper default (§IV-C)
    SAME_AS_PARENT = "same-as-parent"      # all children share parent's lane


@dataclass
class Lane:
    lane_id: int
    in_flight: List[ComputationalElement] = field(default_factory=list)
    last: Optional[ComputationalElement] = None   # tail of the lane's queue

    def pending(self, is_done: Callable[[ComputationalElement], bool]) -> int:
        self.in_flight = [e for e in self.in_flight if not is_done(e)]
        return len(self.in_flight)


class StreamManager:
    """Assigns computational elements to lanes and decides event insertion."""

    def __init__(self,
                 new_stream_policy: NewStreamPolicy = NewStreamPolicy.FIFO_REUSE,
                 parent_stream_policy: ParentStreamPolicy = ParentStreamPolicy.FIRST_CHILD_INHERITS,
                 max_lanes: Optional[int] = None) -> None:
        self.new_stream_policy = new_stream_policy
        self.parent_stream_policy = parent_stream_policy
        self.max_lanes = max_lanes
        self.lanes: Dict[int, Lane] = {}
        self._free: deque = deque()          # FIFO of idle lane ids
        self.lanes_created = 0
        self.events_created = 0

    # ------------------------------------------------------------------
    def _new_lane(self) -> Lane:
        lane = Lane(self.lanes_created)
        self.lanes[lane.lane_id] = lane
        self.lanes_created += 1
        return lane

    def _acquire_free_lane(self, is_done) -> Lane:
        if self.new_stream_policy is NewStreamPolicy.FIFO_REUSE:
            # Reclaim lanes whose queues drained (FIFO order, §IV-C).
            for _ in range(len(self._free)):
                lane_id = self._free.popleft()
                lane = self.lanes[lane_id]
                if lane.pending(is_done) == 0:
                    return lane
                self._free.append(lane_id)
            # Lazily scan for drained lanes not yet returned to the pool.
            for lane in self.lanes.values():
                if lane.pending(is_done) == 0 and lane.lane_id not in self._free:
                    return lane
        if self.max_lanes is not None and len(self.lanes) >= self.max_lanes:
            # Saturated: fall back to the least-loaded lane.
            return min(self.lanes.values(), key=lambda l: l.pending(is_done))
        return self._new_lane()

    # ------------------------------------------------------------------
    def assign(self, element: ComputationalElement,
               is_done: Callable[[ComputationalElement], bool]
               ) -> Tuple[Lane, List[ComputationalElement]]:
        """Pick a lane for ``element``; return (lane, parents needing events).

        A parent needs no event when it is the lane's current tail (lane
        order guarantees completion) — the "first child inherits" rule; every
        other *unfinished* parent contributes one synchronization event.
        """
        parents = element.parents
        lane: Optional[Lane] = None

        if parents and self.parent_stream_policy is ParentStreamPolicy.SAME_AS_PARENT:
            lane = self.lanes[parents[0].stream]
        elif parents:
            # First child inherits: find a parent that (a) sits at the tail of
            # its lane and (b) has no scheduled child yet on that lane.
            for p in sorted(parents, key=lambda q: -q.cost_s):
                if p.stream is None:
                    continue
                plane = self.lanes[p.stream]
                if plane.last is p and not is_done(p):
                    lane = plane
                    break

        if lane is None:
            lane = self._acquire_free_lane(is_done)

        element.stream = lane.lane_id
        lane.in_flight.append(element)
        inherited_tail = lane.last
        lane.last = element

        # Events: every unfinished parent on a *different* lane, plus parents
        # on this lane that are not the immediate tail (queue order already
        # covers the tail and everything before it).
        events = []
        for p in parents:
            if is_done(p):
                continue
            if p.stream == lane.lane_id and (p is inherited_tail or self._precedes(lane, p)):
                continue  # ordered by the lane queue
            events.append(p)
        self.events_created += len(events)
        return lane, events

    @staticmethod
    def _precedes(lane: Lane, p: ComputationalElement) -> bool:
        # p scheduled earlier on the same lane => ordered without an event.
        return p.stream == lane.lane_id

    # ------------------------------------------------------------------
    def release(self, element: ComputationalElement) -> None:
        """Called when the host has synchronized with ``element``."""
        lane = self.lanes.get(element.stream) if element.stream is not None else None
        if lane is None:
            return
        if element in lane.in_flight:
            lane.in_flight.remove(element)
        if not lane.in_flight and lane.lane_id not in self._free:
            self._free.append(lane.lane_id)

    def stats(self) -> dict:
        return {"lanes_created": self.lanes_created,
                "events_created": self.events_created}
