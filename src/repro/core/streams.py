"""Stream (execution-lane) management (paper §IV-C) + device placement.

CUDA streams map to GrJAX *lanes*: ordered dispatch queues that serialize the
elements assigned to them while different lanes proceed independently.  On a
real TPU deployment a lane is a per-device async dispatch queue or a submesh
(see DESIGN.md §2); the assignment algorithm below is the paper's, verbatim:

* lanes are reused in FIFO order; a new lane is created **only** when no
  currently-empty lane exists;
* the **first child** of a computation is scheduled on its parent's lane
  (sequential lane order makes the dependency free — no event needed);
  **following children** are scheduled on other lanes to guarantee
  concurrency, synchronizing with an event;
* the manager tracks which computations are in flight on each lane and which
  managed arrays each lane currently *owns*, so a host access synchronizes
  only the lanes operating on that data (§IV-B).

Multi-device extension: every lane is pinned to one ``device_id`` and a
pluggable :class:`PlacementPolicy` picks the device for each new element
*before* lane assignment.  Lane reuse, first-child inheritance and event
insertion then all happen within the chosen device; the scheduler inserts
``D2D`` transfer elements when an input's owning device disagrees with the
placement (see scheduler.py).
"""
from __future__ import annotations

import enum
from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple, Union

from .element import ComputationalElement


class NewStreamPolicy(enum.Enum):
    """How to obtain a lane when none can be inherited from a parent."""

    FIFO_REUSE = "fifo"          # reuse an empty lane in FIFO order (default)
    ALWAYS_NEW = "always-new"    # create a fresh lane every time


class ParentStreamPolicy(enum.Enum):
    """How children relate to their parents' lanes."""

    FIRST_CHILD_INHERITS = "disjoint"      # paper default (§IV-C)
    SAME_AS_PARENT = "same-as-parent"      # all children share parent's lane


@dataclass
class Lane:
    lane_id: int
    device_id: int = 0
    in_flight: List[ComputationalElement] = field(default_factory=list)
    last: Optional[ComputationalElement] = None   # tail of the lane's queue
    # Lanes pre-reserved for an execution plan (capture/replay) are excluded
    # from the eager scheduler's FIFO-reuse pool, so interleaved eager work
    # cannot serialize into a replayed episode's queues.
    reserved: bool = False
    # Incremental per-tenant occupancy (count of in-flight elements per
    # tenant) maintained on every add/prune/release, so quota checks do not
    # rescan ``in_flight``.  ``manager`` (set by StreamManager._new_lane)
    # receives busy-lane transitions (0 -> >0 and back) per tenant.
    manager: Optional["StreamManager"] = None
    _tenant_counts: Dict[str, int] = field(default_factory=dict)

    def _note_add(self, e: ComputationalElement) -> None:
        n = self._tenant_counts.get(e.tenant, 0)
        self._tenant_counts[e.tenant] = n + 1
        if n == 0 and self.manager is not None:
            self.manager._busy_transition(self, e.tenant, +1)

    def _note_remove(self, e: ComputationalElement) -> None:
        n = self._tenant_counts.get(e.tenant, 0)
        if n <= 1:
            self._tenant_counts.pop(e.tenant, None)
            if n == 1 and self.manager is not None:
                self.manager._busy_transition(self, e.tenant, -1)
        else:
            self._tenant_counts[e.tenant] = n - 1

    def add(self, e: ComputationalElement) -> None:
        self.in_flight.append(e)
        self._note_add(e)

    def pending(self, is_done: Callable[[ComputationalElement], bool]) -> int:
        alive: List[ComputationalElement] = []
        for e in self.in_flight:
            if is_done(e):
                self._note_remove(e)
            else:
                alive.append(e)
        self.in_flight = alive
        return len(alive)

    def serves(self, tenant: str) -> bool:
        """Whether any in-flight element belongs to ``tenant`` (per-tenant
        lane quotas count a shared lane for every tenant queued on it)."""
        return self._tenant_counts.get(tenant, 0) > 0

    def load(self, is_done) -> float:
        """Cost-weighted outstanding work (used by min-load placement)."""
        self.pending(is_done)
        return sum(max(e.cost_s, 1e-6) for e in self.in_flight)

    def min_priority(self) -> Optional[int]:
        """Lowest priority currently queued on this lane (None when idle)."""
        if not self.in_flight:
            return None
        return min(e.priority for e in self.in_flight)

    def min_deadline(self) -> float:
        """Earliest effective deadline queued on this lane (inf when idle or
        when every queued element is deadline-free)."""
        if not self.in_flight:
            return float("inf")
        return min(e.effective_deadline for e in self.in_flight)


# ======================================================================
# Device placement policies
# ======================================================================

class PlacementPolicy:
    """Picks the device for an element before lane assignment."""

    name = "base"

    def choose(self, element: ComputationalElement, manager: "StreamManager",
               is_done) -> int:
        raise NotImplementedError


class RoundRobinPlacement(PlacementPolicy):
    """Cycle devices per launch — maximal spreading, ignores data location."""

    name = "round-robin"

    def __init__(self) -> None:
        self._next = 0

    def choose(self, element, manager, is_done) -> int:
        d = self._next % manager.num_devices
        self._next += 1
        return d


class MinLoadPlacement(PlacementPolicy):
    """Least outstanding (cost-weighted) work across each device's lanes."""

    name = "min-load"

    def choose(self, element, manager, is_done) -> int:
        return min(range(manager.num_devices),
                   key=lambda d: (manager.device_load(d, is_done), d))


class DataAffinityPlacement(PlacementPolicy):
    """Device that already owns the most input bytes; falls back to min-load
    for elements with no device-resident inputs.  Minimizes D2D traffic on
    locality-heavy DAGs."""

    name = "affinity"

    def __init__(self) -> None:
        self._fallback = MinLoadPlacement()

    def choose(self, element, manager, is_done) -> int:
        bytes_on: Dict[int, int] = {}
        for a in element.args:
            ma = a.array
            dev = getattr(ma, "device_id", None)
            if (a.mode.reads and getattr(ma, "device_valid", False)
                    and dev is not None and dev < manager.num_devices):
                bytes_on[dev] = bytes_on.get(dev, 0) + getattr(ma, "nbytes", 0)
        if bytes_on:
            return max(sorted(bytes_on), key=lambda d: bytes_on[d])
        return self._fallback.choose(element, manager, is_done)


class MinPressurePlacement(PlacementPolicy):
    """Memory-aware placement: the device whose budget occupancy after
    hosting the element's arguments is lowest; ties break by outstanding
    load, then device id.  With unlimited budgets every device reports
    zero pressure and the policy degrades to min-load."""

    name = "min-pressure"

    def __init__(self) -> None:
        self._fallback = MinLoadPlacement()

    def choose(self, element, manager, is_done) -> int:
        mem = getattr(manager, "memory", None)
        if mem is None or not mem.bounded:
            return self._fallback.choose(element, manager, is_done)
        return min(range(manager.num_devices),
                   key=lambda d: (mem.placement_pressure(d, element.args),
                                  manager.device_load(d, is_done), d))


PLACEMENT_POLICIES = {p.name: p for p in
                      (RoundRobinPlacement, MinLoadPlacement,
                       DataAffinityPlacement, MinPressurePlacement)}


def make_placement(policy: Union[str, PlacementPolicy, None]
                   ) -> PlacementPolicy:
    if policy is None:
        return RoundRobinPlacement()
    if isinstance(policy, PlacementPolicy):
        return policy
    try:
        return PLACEMENT_POLICIES[policy]()
    except KeyError:
        raise ValueError(f"unknown placement policy {policy!r}; "
                         f"choose from "
                         f"{sorted(PLACEMENT_POLICIES)}") from None


# ======================================================================
class StreamManager:
    """Assigns computational elements to (device, lane) and decides event
    insertion.  ``max_lanes`` caps lanes *per device*."""

    def __init__(self,
                 new_stream_policy: NewStreamPolicy = NewStreamPolicy.FIFO_REUSE,
                 parent_stream_policy: ParentStreamPolicy = ParentStreamPolicy.FIRST_CHILD_INHERITS,
                 max_lanes: Optional[int] = None,
                 num_devices: int = 1,
                 placement: Union[str, PlacementPolicy, None] = None,
                 tenant_quotas: Optional[Dict[str, int]] = None) -> None:
        self.new_stream_policy = new_stream_policy
        self.parent_stream_policy = parent_stream_policy
        self.max_lanes = max_lanes
        self.num_devices = max(1, num_devices)
        self.placement = make_placement(placement)
        # Optional per-tenant cap on concurrently *busy* lanes per device: a
        # bulk tenant with a quota of 2 can keep at most 2 queues of work
        # outstanding per device, however many elements it submits.
        self.tenant_quotas: Dict[str, int] = dict(tenant_quotas or {})
        # MemoryManager installed by the owning scheduler; placement uses it
        # to refuse devices whose byte budget the element cannot fit and to
        # drive the min-pressure policy.  None for standalone managers.
        self.memory = None
        self.lanes: Dict[int, Lane] = {}
        self._free: Dict[int, deque] = {}    # device -> FIFO of idle lane ids
        self.lanes_created = 0
        self.events_created = 0
        self.events_cross_device = 0
        self.priority_bypasses = 0   # saturated fallbacks that dodged a
        #                              lower-priority lane tail
        self.edf_bypasses = 0        # saturated fallbacks that dodged a
        #                              later-deadline lane tail (EDF)
        self.quota_fallbacks = 0     # submissions folded onto a tenant's own
        #                              lanes because its quota was reached
        # Incremental (device, tenant) -> busy-lane count, maintained by the
        # lanes' _note_add/_note_remove transitions.  An *upper bound*: a
        # lane leaves the count only when its finished elements are pruned
        # (pending()/release()), so ``count < quota`` proves the precise scan
        # would pass and the scan is skipped; ``count >= quota`` falls back
        # to the pruning scan for the exact answer.
        self._tenant_busy: Dict[Tuple[int, str], int] = {}
        # plan key -> list of reserved lane-set instances, each mapping the
        # plan-local lane id to a real lane id (capture/replay, §V-D oracle).
        self._plan_lanes: Dict[str, List[Dict[int, int]]] = {}
        self._plan_rr = 0
        self.max_plan_instances = 4

    # ------------------------------------------------------------------
    def device_lanes(self, device: int) -> List[Lane]:
        return [l for l in self.lanes.values() if l.device_id == device]

    def device_load(self, device: int, is_done) -> float:
        return sum(l.load(is_done) for l in self.device_lanes(device))

    def place(self, element: ComputationalElement, is_done) -> int:
        """Pick the device for ``element`` (0 when single-device).

        Whatever the policy chose, a device whose byte budget is smaller
        than the element's working set is refused — no amount of eviction
        could make the element fit there.  The least-pressured fitting
        device is substituted; when *no* device fits, the policy's choice
        stands and the pipeline's reserve stage raises the descriptive
        :class:`~repro.core.memory.DeviceOutOfMemoryError`."""
        if self.num_devices <= 1:
            return 0
        d = self.placement.choose(element, self, is_done)
        d = min(max(0, int(d)), self.num_devices - 1)
        mem = self.memory
        if mem is not None and mem.bounded:
            ws = mem.working_set_bytes(element.args)
            if not mem.device_fits(d, ws):
                fitting = [x for x in range(self.num_devices)
                           if mem.device_fits(x, ws)]
                if fitting:
                    d = min(fitting, key=lambda x: (mem.pressure(x), x))
        return d

    # ------------------------------------------------------------------
    def _busy_transition(self, lane: Lane, tenant: str, delta: int) -> None:
        key = (lane.device_id, tenant)
        n = self._tenant_busy.get(key, 0) + delta
        if n <= 0:
            self._tenant_busy.pop(key, None)
        else:
            self._tenant_busy[key] = n

    def busy_lanes(self, device: int, tenant: str) -> int:
        """Upper bound on ``tenant``'s busy lanes on ``device`` (see
        ``_tenant_busy``)."""
        return self._tenant_busy.get((device, tenant), 0)

    def _new_lane(self, device: int) -> Lane:
        lane = Lane(self.lanes_created, device_id=device, manager=self)
        self.lanes[lane.lane_id] = lane
        self.lanes_created += 1
        return lane

    def _acquire_free_lane(self, is_done, device: int,
                           element: Optional[ComputationalElement] = None
                           ) -> Lane:
        # Per-tenant quota: once the tenant occupies its full allowance of
        # busy lanes on this device, fold the element onto the least-loaded
        # of its *own* lanes instead of taking a free/new one — other
        # tenants' concurrency is protected from a flooding submitter.
        if element is not None and self.tenant_quotas:
            quota = self.tenant_quotas.get(element.tenant)
            if quota is not None:
                # A lane counts toward the quota while ANY of the tenant's
                # work is queued on it (not just the latest assignee — a
                # shared lane must not silently drop out of the count).
                # The incremental busy-lane count is an upper bound, so
                # ``count < quota`` skips the per-lane pruning scan entirely
                # (provably the same decision); only at/over quota do we pay
                # for the precise scan.
                if (self.busy_lanes(device, element.tenant)
                        >= max(1, quota)):
                    own = [l for l in self.device_lanes(device)
                           if not l.reserved and l.pending(is_done) > 0
                           and l.serves(element.tenant)]
                    if len(own) >= max(1, quota):
                        self.quota_fallbacks += 1
                        return self._fallback_lane(own, element, is_done)
        free = self._free.setdefault(device, deque())
        if self.new_stream_policy is NewStreamPolicy.FIFO_REUSE:
            # Reclaim lanes whose queues drained (FIFO order, §IV-C).
            for _ in range(len(free)):
                lane_id = free.popleft()
                lane = self.lanes[lane_id]
                if lane.pending(is_done) == 0:
                    return lane
                free.append(lane_id)
            # Lazily scan for drained lanes not yet returned to the pool.
            for lane in self.lanes.values():
                if (lane.device_id == device and not lane.reserved
                        and lane.pending(is_done) == 0
                        and lane.lane_id not in free):
                    return lane
        # Reserved plan lanes neither count toward nor satisfy the eager cap.
        dev_lanes = [l for l in self.device_lanes(device) if not l.reserved]
        if (self.max_lanes is not None and dev_lanes
                and len(dev_lanes) >= self.max_lanes):
            # Saturated: fall back to a lane on this device, priority-aware.
            return self._fallback_lane(dev_lanes, element, is_done)
        return self._new_lane(device)

    def _fallback_lane(self, lanes: List[Lane],
                       element: Optional[ComputationalElement],
                       is_done) -> Lane:
        """Pick an existing lane to queue on when no fresh lane is allowed.

        Priority-aware: a lane whose queue holds *lower-priority* work would
        make the element wait behind it (lane order is FIFO), so such lanes
        are only chosen when every alternative is equally blocked; ties break
        by shortest queue.  This is what keeps a latency-critical element
        from parking behind a bulk tenant's queue under ``max_lanes``
        saturation.

        EDF-aware: a lane whose queue holds only *later-deadline* (or
        deadline-free) work would likewise delay a deadline'd element past
        its EDF rank, so such lanes sort after lanes already serving an
        equal-or-earlier deadline.  For deadline-free elements the EDF term
        is vacuously False everywhere (``inf < x`` never holds), preserving
        today's ordering bit-for-bit."""
        prio = element.priority if element is not None else 0
        edl = (element.effective_deadline if element is not None
               else float("inf"))

        def key(lane: Lane):
            n = lane.pending(is_done)       # prunes finished elements first
            mp = lane.min_priority()
            blocked = mp is not None and mp < prio
            edf_blocked = n > 0 and edl < lane.min_deadline()
            return (blocked, edf_blocked, n, lane.lane_id)

        keyed = sorted(((key(lane), lane) for lane in lanes),
                       key=lambda kl: kl[0])
        best_key, best = keyed[0]
        bmp = best.min_priority()
        if any(l.min_priority() is not None and l.min_priority() < prio
               for l in lanes) and not (bmp is not None and bmp < prio):
            self.priority_bypasses += 1
        if (edl != float("inf") and not best_key[1]
                and any(k[1] for k, _ in keyed[1:])):
            self.edf_bypasses += 1
        return best

    # ------------------------------------------------------------------
    def assign(self, element: ComputationalElement,
               is_done: Callable[[ComputationalElement], bool]
               ) -> Tuple[Lane, List[ComputationalElement]]:
        """Pick a lane for ``element``; return (lane, parents needing events).

        A parent needs no event when it is the lane's current tail (lane
        order guarantees completion) — the "first child inherits" rule; every
        other *unfinished* parent contributes one synchronization event.
        ``element.device`` (set by :meth:`place`) constrains inheritance: a
        parent's lane is only inherited when it lives on the same device.
        """
        parents = element.parents
        device = element.device if element.device is not None else 0
        lane: Optional[Lane] = None

        if parents and self.parent_stream_policy is ParentStreamPolicy.SAME_AS_PARENT:
            plane = self.lanes.get(parents[0].stream)
            if (plane is not None and plane.device_id == device
                    and not plane.reserved):
                lane = plane
        elif parents:
            # First child inherits: find a parent that (a) sits at the tail of
            # its lane, (b) lives on the chosen device, and (c) has no
            # scheduled child yet on that lane.  Reserved plan lanes are
            # never inherited — eager children of replayed elements must not
            # serialize into a plan's queues.
            for p in sorted(parents, key=lambda q: -q.cost_s):
                if p.stream is None:
                    continue
                plane = self.lanes[p.stream]
                if plane.device_id != device or plane.reserved:
                    continue
                if plane.last is p and not is_done(p):
                    lane = plane
                    break

        if lane is None:
            lane = self._acquire_free_lane(is_done, device, element)

        element.stream = lane.lane_id
        element.device = lane.device_id
        lane.add(element)
        lane.last = element

        # Events: every unfinished parent on a *different* lane.  Same-lane
        # parents — tail or not — were enqueued earlier on this FIFO lane,
        # so queue order already covers them and no event is needed.
        events = []
        for p in parents:
            if is_done(p) or p.stream == lane.lane_id:
                continue
            events.append(p)
            if p.device is not None and p.device != lane.device_id:
                self.events_cross_device += 1
        self.events_created += len(events)
        return lane, events

    # ------------------------------------------------------------------
    # Capture/replay: pre-reserved lane sets for execution plans (§V-D).
    # ------------------------------------------------------------------
    def reserve(self, plan_key: str, lane_devices, is_done) -> Dict[int, Lane]:
        """Pre-reserve a dedicated lane set for one replay of an execution
        plan.  ``lane_devices`` is the plan's (plan-local lane id -> device)
        mapping.  The first idle instance is reused (mirroring
        ``cudaGraphLaunch`` re-submitting into the same streams); while all
        instances are busy, up to ``max_plan_instances`` fresh sets are
        created so concurrent replays of the same plan keep space-sharing,
        after which instances are handed out round-robin (lane FIFO order
        keeps overlapping replays correct — they merely serialize)."""
        instances = self._plan_lanes.setdefault(plan_key, [])
        for inst in instances:
            lanes = {c: self.lanes[lid] for c, lid in inst.items()}
            if all(l.pending(is_done) == 0 for l in lanes.values()):
                return lanes
        if len(instances) < self.max_plan_instances:
            inst = {}
            for cap_id, dev in sorted(lane_devices):
                # Recycle a drained eager lane when one exists — plan churn
                # (record/invalidate cycles) must not grow the lane table
                # (= worker threads on the real executor) without bound.
                lane = self._reclaim_idle_lane(dev, is_done) or self._new_lane(dev)
                lane.reserved = True
                inst[cap_id] = lane.lane_id
            instances.append(inst)
            return {c: self.lanes[lid] for c, lid in inst.items()}
        inst = instances[self._plan_rr % len(instances)]
        self._plan_rr += 1
        return {c: self.lanes[lid] for c, lid in inst.items()}

    def _reclaim_idle_lane(self, device: int, is_done) -> Optional[Lane]:
        free = self._free.get(device)
        if not free:
            return None
        for _ in range(len(free)):
            lid = free.popleft()
            lane = self.lanes[lid]
            if lane.pending(is_done) == 0:
                return lane
            free.append(lid)
        return None

    def unreserve(self, plan_key: str) -> None:
        """Return a dropped plan's lanes to the eager pool (called when a
        plan is invalidated or evicted from the cache — without this, every
        divergence in a long-running loop would leak a reserved lane set).
        The lanes may still hold in-flight replayed work, so they are only
        un-flagged here; the eager FIFO-reuse scan reclaims them once
        drained."""
        for inst in self._plan_lanes.pop(plan_key, []):
            for lid in inst.values():
                lane = self.lanes.get(lid)
                if lane is not None:
                    lane.reserved = False

    def bind_to_lane(self, lane: Lane, element: ComputationalElement) -> None:
        """Replay fast path: place ``element`` on a pre-reserved lane,
        skipping placement and the assignment algorithm entirely."""
        element.stream = lane.lane_id
        element.device = lane.device_id
        lane.add(element)
        lane.last = element

    # ------------------------------------------------------------------
    def release(self, element: ComputationalElement) -> None:
        """Called when the host has synchronized with ``element``."""
        lane = self.lanes.get(element.stream) if element.stream is not None else None
        if lane is None:
            return
        if element in lane.in_flight:
            lane.in_flight.remove(element)
            lane._note_remove(element)
        if not lane.in_flight and lane.last is not None and not lane.last.active:
            # A drained lane's retired tail can never be inherited again,
            # but through parents/children lists it would pin the whole
            # episode graph — and, transitively, its arrays — in memory for
            # as long as the lane idles.
            lane.last = None
        if lane.reserved:
            return    # plan lanes are recycled via reserve(), not the pool
        free = self._free.setdefault(lane.device_id, deque())
        if not lane.in_flight and lane.lane_id not in free:
            free.append(lane.lane_id)

    def stats(self) -> dict:
        out = {"lanes_created": self.lanes_created,
               "events_created": self.events_created}
        if self.priority_bypasses:
            out["priority_bypasses"] = self.priority_bypasses
        if self.edf_bypasses:
            out["edf_bypasses"] = self.edf_bypasses
        if self.tenant_quotas:
            out["quota_fallbacks"] = self.quota_fallbacks
        if self._plan_lanes:
            out["plan_lane_sets"] = sum(len(v) for v in
                                        self._plan_lanes.values())
        if self.num_devices > 1:
            out.update({
                "num_devices": self.num_devices,
                "placement": self.placement.name,
                "events_cross_device": self.events_cross_device,
                "lanes_per_device": {
                    d: len(self.device_lanes(d))
                    for d in range(self.num_devices)},
            })
        return out
