"""Graph capture & replay — reusable execution plans (CUDA-Graphs analogue).

The paper's strongest baseline (§V-D) is a hand-written CUDA-Graphs
schedule: the full DAG is known in advance, so launching it costs a single
``cudaGraphLaunch`` instead of per-kernel dependency inference, stream
assignment and launch overhead.  This module closes that gap *without*
giving up the paper's core premise (no upfront program structure):

* ``with scheduler.capture(name):`` — a transparent recording context.  The
  first episode under a given ``name``/signature runs eagerly while its
  launches are traced into an immutable :class:`ExecutionPlan`; later
  episodes are matched launch-by-launch against the cached plan and replayed
  through a fast path that skips ``ComputationDAG.add``,
  ``StreamManager.place``/``assign`` and the per-element launch overhead
  (one reduced plan-launch overhead is charged instead).
* ``scheduler.replay(plan, bindings)`` — explicit re-submission of a whole
  plan with fresh arrays bound by slot.

Plans are keyed by (name + structural signature: argument shapes/dtypes,
access modes, kernel configs, logical data locations).  When a traced
episode diverges from its plan mid-way, the plan is invalidated and the
episode continues eagerly, so capture is always semantics-preserving.

Lane assignment is *re-planned* at capture finalization: the eager episode's
lane choices are an artifact of host pacing (a slow host drains every lane
between launches), so the plan re-runs the paper's §IV-C assignment rules
structurally — nothing assumed complete, first child inherits, fresh lane
otherwise — which is exactly the schedule the zero-overhead oracle produces.
Replays then run on a lane set pre-reserved via ``StreamManager.reserve``.
"""
from __future__ import annotations

import itertools
import weakref
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import (Any, Callable, Dict, List, Mapping, Optional, Sequence,
                    Tuple)

from .element import (AccessMode, Arg, ComputationalElement, DEFAULT_TENANT,
                      ElementKind, dep_key)

_PLAN_IDS = itertools.count()


def _freeze(v: Any) -> Any:
    """Hashable stand-in for a launch-config value (plan signatures are
    dict keys).  Containers freeze recursively; anything else unhashable
    degrades to its repr — two values with equal reprs then match, which is
    the right conservatism for cache keying."""
    if isinstance(v, (list, tuple)):
        return tuple(_freeze(x) for x in v)
    if isinstance(v, dict):
        return tuple(sorted((k, _freeze(x)) for k, x in v.items()))
    if isinstance(v, (set, frozenset)):
        return tuple(sorted((_freeze(x) for x in v), key=repr))
    try:
        hash(v)
    except TypeError:
        # Array-likes compare by exact content (repr would truncate large
        # arrays and let different values collide); anything else degrades
        # to repr, which is conservative for cache keying.
        tobytes = getattr(v, "tobytes", None)
        if callable(tobytes):
            return ("array", getattr(v, "shape", None),
                    str(getattr(v, "dtype", "")), tobytes())
        return repr(v)
    return v


def freeze_config(config: dict) -> Tuple[Tuple[str, Any], ...]:
    return tuple(sorted((k, _freeze(v)) for k, v in config.items()))


class _PlanSignature:
    """Structural cache key with a memoized hash.

    The signature tuple nests every element/slot of the plan; tuples do not
    cache their hash, so keying the cache on the raw tuple re-walked the
    whole plan on *every* probe (each transparent episode probes at least
    once).  Hashing once at plan finalization makes the probe O(1); equality
    short-circuits on the stored hash before falling back to the tuple
    compare dict collisions require."""

    __slots__ = ("data", "_hash")

    def __init__(self, data: Tuple) -> None:
        self.data = data
        self._hash = hash(data)

    def __hash__(self) -> int:
        return self._hash

    def __eq__(self, other: Any) -> bool:
        if isinstance(other, _PlanSignature):
            return self._hash == other._hash and self.data == other.data
        return self.data == other

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"<_PlanSignature {self._hash:#x}>"


# ======================================================================
# Immutable plan structures
# ======================================================================

@dataclass(frozen=True)
class SlotSpec:
    """One array-binding slot of an execution plan.

    Captures the array's geometry and its *logical location bits* at first
    use — a replay binding must present the same shape/dtype and the same
    location state, otherwise the recorded transfer structure would be wrong
    for the new array (e.g. a recorded H2D prefetch re-run against an array
    that only lives on the device)."""

    index: int
    name: str
    shape: Optional[Tuple[int, ...]]
    dtype: Optional[str]
    nbytes: int
    host_valid: bool
    device_valid: bool
    device_id: Optional[int]
    # Backing tier holding the array at capture time (None = not spilled).
    # Part of the slot state: a plan recorded against a disk-resident array
    # replays a disk RELOAD, which would read the wrong payload for an
    # array parked in (say) the compressed tier.
    tier: Optional[str] = None

    def geometry_matches(self, array: Any) -> bool:
        shape = getattr(array, "shape", None)
        dtype = getattr(array, "dtype", None)
        return ((tuple(shape) if shape is not None else None) == self.shape
                and (str(dtype) if dtype is not None else None) == self.dtype)

    def state_matches(self, array: Any) -> bool:
        return (bool(getattr(array, "host_valid", False)) == self.host_valid
                and bool(getattr(array, "device_valid", False)) == self.device_valid
                and getattr(array, "device_id", None) == self.device_id
                and getattr(array, "backing_tier", None) == self.tier)


def _slot_spec(index: int, array: Any) -> SlotSpec:
    shape = getattr(array, "shape", None)
    dtype = getattr(array, "dtype", None)
    return SlotSpec(
        index=index,
        name=getattr(array, "name", f"slot{index}"),
        shape=tuple(shape) if shape is not None else None,
        dtype=str(dtype) if dtype is not None else None,
        nbytes=int(getattr(array, "nbytes", 0)),
        host_valid=bool(getattr(array, "host_valid", False)),
        device_valid=bool(getattr(array, "device_valid", False)),
        device_id=getattr(array, "device_id", None),
        tier=getattr(array, "backing_tier", None))


@dataclass(frozen=True)
class PlanElement:
    """One topologically-ordered vertex of an execution plan."""

    index: int
    kind: ElementKind
    name: str
    config: Tuple[Tuple[str, Any], ...]       # frozen launch-config items
    cost_s: float
    transfer_bytes: int
    arg_slots: Tuple[Tuple[int, AccessMode], ...]
    lane: int                                  # plan-local lane id
    device: int
    src_device: Optional[int]
    parents: Tuple[int, ...]                   # plan indices (in-trace only)
    wait_events: Tuple[int, ...]               # cross-lane parents -> events
    # QoS tags are part of the structural signature: an episode re-issued at
    # a different priority (or by a different tenant) records its own plan,
    # so replay always reproduces the captured capacity weighting.
    priority: int = 0
    tenant: str = DEFAULT_TENANT
    # Relative deadline window (seconds).  Part of the signature — a plan
    # captured without deadlines must not replay a deadline'd episode (EDF
    # ordering and preemption eligibility differ).  The *absolute* deadline
    # is never captured: replay re-stamps ``deadline_t`` at submission time.
    deadline_s: Optional[float] = None
    # Declared-function identity (GrFunction frontend).  Part of the
    # signature: two declarations that happen to share a kernel name never
    # alias each other's plans, while one declaration whose Python closure
    # is re-created per episode keeps replaying the same plan.
    fn_key: Optional[int] = None
    # The caller pinned this element's device explicitly; the plan-time
    # optimizer (planopt.py) must keep it in place — replay matching
    # rejects a device retarget of a pinned launch.
    pinned: bool = False


@dataclass(frozen=True)
class ExecutionPlan:
    """Immutable, replayable trace of one episode.

    ``signature`` is the structural cache key (everything except the
    callables and the default array bindings); ``key`` is a process-unique
    id used to reserve lane sets."""

    name: str
    key: str
    elements: Tuple[PlanElement, ...]
    slots: Tuple[SlotSpec, ...]
    fns: Tuple[Optional[Callable], ...]        # captured callables
    configs: Tuple[dict, ...]                  # original (unfrozen) configs
    # Default bindings are held *weakly*: the transparent match path always
    # rebinds the episode's current arrays, so a cached plan must not pin a
    # retired episode's batch tensors in memory.  Explicit replay of an
    # unbound slot raises if the captured array has been collected.
    slot_arrays: Tuple["weakref.ref", ...]
    lane_devices: Tuple[Tuple[int, int], ...]  # (plan-local lane, device)
    kernel_positions: Tuple[int, ...]
    # Per-device peak resident bytes of one replay, computed structurally
    # from the trace (slot geometry + transfer/evict/write transitions).
    # Part of the signature: replay is gated on the peak still fitting the
    # current budgets — a shrunk budget re-records a spill-aware plan
    # instead of silently blowing the device's memory.
    device_mem: Tuple[Tuple[int, int], ...] = ()
    # Set by the plan-time optimizer (planopt.py): ``optimized`` marks a
    # rewritten plan; ``mem_scheduled`` means the plan carries its own
    # Belady evict/reload schedule, so replay honors it instead of the
    # reactive per-element LRU reserve.  Neither is part of the structural
    # signature — an optimized plan *replaces* its greedy original in the
    # cache rather than coexisting with it.
    optimized: bool = False
    mem_scheduled: bool = False

    @property
    def signature(self) -> "_PlanSignature":
        # Memoized: hashed once at first use (finalization stores the plan,
        # which probes the cache), O(1) on every later probe.  The plan is
        # immutable, so the cached value can never go stale; optimize/retag
        # build a *new* plan object with its own signature.
        sig = self.__dict__.get("_signature")
        if sig is None:
            sig = _PlanSignature((self.elements, self.slots, self.device_mem))
            object.__setattr__(self, "_signature", sig)
        return sig

    @property
    def num_kernels(self) -> int:
        return len(self.kernel_positions)

    def optimize(self, sched) -> "ExecutionPlan":
        """Run the plan-time global optimizer on this plan (see
        :func:`repro.core.planopt.optimize_plan`); returns the rewritten
        plan, or ``self`` when no strict improvement is possible.  Does not
        touch the scheduler's plan cache — use
        :meth:`GrScheduler.optimize_plan` for cached plans."""
        from .planopt import optimize_plan
        return optimize_plan(sched, self)

    def __len__(self) -> int:
        return len(self.elements)


# ======================================================================
# Plan cache
# ======================================================================

class PlanCache:
    """Plans keyed by name + structural signature, with LRU-bounded storage
    per name and explicit invalidation when a traced episode diverges."""

    def __init__(self, max_plans_per_name: int = 8) -> None:
        self.max_plans_per_name = max_plans_per_name
        self._plans: Dict[str, "OrderedDict[Tuple, ExecutionPlan]"] = {}
        self.records = 0
        self.replacements = 0
        self.hits = 0
        self.invalidations = 0

    def candidates(self, name: str) -> List[ExecutionPlan]:
        return list(self._plans.get(name, {}).values())

    def all_plans(self) -> List[ExecutionPlan]:
        """Every cached plan across all names (verifier/introspection)."""
        return [p for by_sig in self._plans.values()
                for p in by_sig.values()]

    def store(self, plan: ExecutionPlan) -> List[ExecutionPlan]:
        """Cache ``plan``; returns the plans displaced by it (same signature
        or LRU overflow) so the caller can release their lane reservations.

        ``records`` counts net-new signatures only; a same-signature
        replacement displaces the previous plan and counts under
        ``replacements`` instead (it used to inflate ``records``, hiding
        record/replace churn from the stats)."""
        displaced: List[ExecutionPlan] = []
        by_sig = self._plans.setdefault(plan.name, OrderedDict())
        prev = by_sig.pop(plan.signature, None)
        if prev is not None:
            displaced.append(prev)
            self.replacements += 1
        else:
            self.records += 1
        by_sig[plan.signature] = plan
        while len(by_sig) > self.max_plans_per_name:
            displaced.append(by_sig.popitem(last=False)[1])
        return displaced

    def invalidate(self, plan: ExecutionPlan) -> None:
        by_sig = self._plans.get(plan.name)
        if by_sig is not None and by_sig.pop(plan.signature, None) is not None:
            self.invalidations += 1

    def touch(self, plan: ExecutionPlan) -> None:
        """Refresh a plan's recency on a replay hit, so LRU eviction drops
        cold signatures rather than the hot, constantly-replayed one."""
        by_sig = self._plans.get(plan.name)
        if by_sig is not None and plan.signature in by_sig:
            by_sig.move_to_end(plan.signature)

    def __len__(self) -> int:
        return sum(len(v) for v in self._plans.values())

    def stats(self) -> dict:
        return {"plans_cached": len(self),
                "plan_records": self.records,
                "plan_replacements": self.replacements,
                "plan_replays": self.hits,
                "plan_invalidations": self.invalidations}


# ======================================================================
# Recording
# ======================================================================

@dataclass
class _Draft:
    """Mutable per-element record collected while the episode runs eagerly."""

    index: int
    kind: ElementKind
    name: str
    config: Tuple[Tuple[str, Any], ...]
    cost_s: float
    transfer_bytes: int
    arg_slots: Tuple[Tuple[int, AccessMode], ...]
    device: int
    src_device: Optional[int]
    parents: Tuple[int, ...]
    fn: Optional[Callable] = None
    raw_config: dict = field(default_factory=dict)
    priority: int = 0
    tenant: str = DEFAULT_TENANT
    deadline_s: Optional[float] = None
    fn_key: Optional[int] = None
    pinned: bool = False


def _assign_plan_lanes(drafts: Sequence[_Draft]):
    """Structural lane assignment for the finalized plan.

    Re-runs the paper's §IV-C rules with *nothing assumed complete* (the
    zero-overhead oracle regime): the most expensive parent sitting at its
    lane's tail on the same device is inherited, every other element opens a
    fresh plan-local lane, and each cross-lane parent costs one event.  The
    eager episode's actual lane choices are deliberately discarded — they
    encode host pacing (a slow host drains every lane between launches),
    which would serialize the replayed episode."""
    lane_of: Dict[int, int] = {}
    tails: Dict[int, int] = {}
    lane_dev: List[int] = []
    placed = []
    for d in drafts:
        lane = None
        for p in sorted(d.parents, key=lambda j: -drafts[j].cost_s):
            pl = lane_of[p]
            if tails[pl] == p and lane_dev[pl] == d.device:
                lane = pl
                break
        if lane is None:
            lane = len(lane_dev)
            lane_dev.append(d.device)
        events = tuple(p for p in d.parents if lane_of.get(p) != lane)
        lane_of[d.index] = lane
        tails[lane] = d.index
        placed.append((lane, events))
    return placed, tuple(enumerate(lane_dev))


def _plan_device_mem(drafts: Sequence[_Draft], slots: Sequence[SlotSpec]
                     ) -> Tuple[Tuple[int, int], ...]:
    """Per-device peak resident bytes of one replay of the trace.

    Replays the logical residency transitions structurally: slots captured
    device-resident start on their device; TRANSFER/D2D place a slot on the
    element's device, EVICT drops it, and a kernel's writable slots
    materialize on its device.  The running per-device byte sums' maxima
    are the plan's memory demand — what replay gating checks against the
    current budgets."""
    loc: Dict[int, int] = {}            # slot -> device currently holding it
    cur: Dict[int, int] = {}            # device -> resident bytes
    peak: Dict[int, int] = {}

    def move(slot: int, dev: Optional[int]) -> None:
        nb = slots[slot].nbytes
        if nb <= 0:
            return
        old = loc.pop(slot, None)
        if old is not None:
            cur[old] -= nb
        if dev is not None:
            loc[slot] = dev
            cur[dev] = cur.get(dev, 0) + nb
            peak[dev] = max(peak.get(dev, 0), cur[dev])

    for s in slots:
        if s.device_valid:
            move(s.index, s.device_id if s.device_id is not None else 0)
    for d in drafts:
        if d.kind in (ElementKind.TRANSFER, ElementKind.D2D,
                      ElementKind.RELOAD):
            move(d.arg_slots[0][0], d.device)
        elif d.kind is ElementKind.EVICT:
            # A peer-tier spill keeps the block device-resident on the spill
            # target (its budget is gated too); other evictions drop it.
            move(d.arg_slots[0][0], d.raw_config.get("spill_target"))
        else:
            for slot, mode in d.arg_slots:
                if mode.writes:
                    move(slot, d.device)
    return tuple(sorted((dv, pk) for dv, pk in peak.items() if pk > 0))


class _Recorder:
    def __init__(self) -> None:
        self.slots: List[SlotSpec] = []
        self.slot_arrays: List[Any] = []
        self._slot_of: Dict[int, int] = {}
        self.drafts: List[_Draft] = []
        self._idx_of_uid: Dict[int, int] = {}
        # Set when a host access retired part of the trace: any *further*
        # launch would record with the retired RAW/WAR edges missing (the
        # retire cleared them before inference), producing a racy plan.
        self.blocked = False

    def traced(self, e: ComputationalElement) -> bool:
        return e.uid in self._idx_of_uid

    def knows(self, array: Any) -> bool:
        """Whether ``array`` is already a slot of this recording."""
        return dep_key(array) in self._slot_of

    def _slot_for(self, array: Any) -> int:
        k = dep_key(array)
        s = self._slot_of.get(k)
        if s is None:
            s = len(self.slots)
            self._slot_of[k] = s
            self.slots.append(_slot_spec(s, array))
            self.slot_arrays.append(array)
        return s

    def seed_from_replay(self, r: "_ReplayState") -> None:
        """Adopt an already-submitted replay prefix as the head of a new
        trace (mid-episode divergence): the prefix matched its old plan, so
        its fresh elements become drafts verbatim and the bound arrays keep
        their capture-time slot specs (their *current* location bits have
        already advanced past episode start)."""
        plan = r.plan
        for slot_idx in sorted({s for pe in plan.elements[:r.flushed]
                                for s, _ in pe.arg_slots}):
            arr = r.bound[slot_idx]
            spec = plan.slots[slot_idx]
            new_idx = len(self.slots)
            self._slot_of[dep_key(arr)] = new_idx
            self.slots.append(SlotSpec(
                index=new_idx, name=spec.name, shape=spec.shape,
                dtype=spec.dtype, nbytes=spec.nbytes,
                host_valid=spec.host_valid, device_valid=spec.device_valid,
                device_id=spec.device_id, tier=spec.tier))
            self.slot_arrays.append(arr)
        for ce in r.new_elements:
            self.record(ce)

    def record(self, e: ComputationalElement) -> None:
        """Trace one scheduled element (called from ``GrScheduler._schedule``
        after DAG insertion, before the scheduler flips location bits)."""
        arg_slots = tuple((self._slot_for(a.array), a.mode) for a in e.args)
        parents = tuple(self._idx_of_uid[p.uid] for p in e.parents
                        if p.uid in self._idx_of_uid)
        idx = len(self.drafts)
        self._idx_of_uid[e.uid] = idx
        self.drafts.append(_Draft(
            index=idx, kind=e.kind, name=e.name,
            config=freeze_config(e.config),
            cost_s=e.cost_s, transfer_bytes=e.transfer_bytes,
            arg_slots=arg_slots,
            device=e.device if e.device is not None else 0,
            src_device=e.src_device, parents=parents, fn=e.fn,
            raw_config=dict(e.config),
            priority=e.priority, tenant=e.tenant,
            deadline_s=e.deadline_s, fn_key=e.fn_key,
            pinned=bool(getattr(e, "device_pinned", False))))

    def build(self, name: str) -> Optional[ExecutionPlan]:
        if not any(d.kind is ElementKind.KERNEL for d in self.drafts):
            return None
        placed, lane_devices = _assign_plan_lanes(self.drafts)
        elements = tuple(PlanElement(
            index=d.index, kind=d.kind, name=d.name, config=d.config,
            cost_s=d.cost_s, transfer_bytes=d.transfer_bytes,
            arg_slots=d.arg_slots, lane=lane, device=d.device,
            src_device=d.src_device, parents=d.parents, wait_events=events,
            priority=d.priority, tenant=d.tenant, deadline_s=d.deadline_s,
            fn_key=d.fn_key, pinned=d.pinned)
            for d, (lane, events) in zip(self.drafts, placed))
        return ExecutionPlan(
            name=name, key=f"{name}#{next(_PLAN_IDS)}",
            elements=elements, slots=tuple(self.slots),
            fns=tuple(d.fn for d in self.drafts),
            configs=tuple(d.raw_config for d in self.drafts),
            slot_arrays=tuple(weakref.ref(a) for a in self.slot_arrays),
            lane_devices=lane_devices,
            kernel_positions=tuple(i for i, d in enumerate(self.drafts)
                                   if d.kind is ElementKind.KERNEL),
            device_mem=_plan_device_mem(self.drafts, self.slots))


# ======================================================================
# Replay
# ======================================================================

class _ReplayState:
    """Bookkeeping for one in-flight replay of a plan."""

    def __init__(self, sched, plan: ExecutionPlan) -> None:
        self.plan = plan
        self.bound: List[Any] = [None] * len(plan.slots)
        self.bound_keys: Dict[int, int] = {}   # dep_key(array) -> slot
        self.new_elements: List[ComputationalElement] = []
        self.flushed = 0                       # next plan index to submit
        self.kpos = 0                          # next kernel to match
        self.written: set = set()              # slots written in-session
        self.started = False
        self.lanes = sched.streams.reserve(plan.key, plan.lane_devices,
                                           sched.executor.is_done)
        # The plan's captured default arrays (persistent weights etc.) are
        # pinned against replay-time eviction even before the episode binds
        # them: evicting one would flip its location bits and guarantee a
        # state mismatch — and hence divergence — at its first use, so a
        # replay under sustained pressure would never stick.
        self.pinned: set = {dep_key(a) for ref in plan.slot_arrays
                            if (a := ref()) is not None}

    @property
    def completed(self) -> bool:
        return self.flushed == len(self.plan.elements)


def _match_kernel(plan: ExecutionPlan, kpos: int, bound: List[Any],
                  bound_keys: Dict[int, int], args: Sequence[Arg],
                  name: str, cfg_items: Tuple, cost_s: float,
                  priority: int = 0, tenant: str = DEFAULT_TENANT,
                  device: Optional[int] = None,
                  fn_key: Optional[int] = None,
                  deadline_s: Optional[float] = None
                  ) -> Optional[Dict[int, Any]]:
    """Check one user launch against the plan's next kernel.  Returns the
    new slot bindings on a match, None on any mismatch."""
    pe = plan.elements[plan.kernel_positions[kpos]]
    if pe.name != name or pe.config != cfg_items or pe.cost_s != cost_s:
        return None
    if pe.priority != priority or pe.tenant != tenant:
        return None     # QoS retag: record a fresh plan with the new weights
    if pe.deadline_s != deadline_s:
        return None     # deadline retag: EDF rank/preemption eligibility
        #                 differ — record a fresh plan
    if pe.fn_key != fn_key:
        return None     # a different declared GrFunction (or legacy launch)
    if device is not None and pe.device != device:
        return None     # explicit device retarget: the recorded placement,
        #                 lanes and D2D structure would all be wrong
    if len(args) != len(pe.arg_slots):
        return None
    new_bind: Dict[int, Any] = {}
    new_keys: Dict[int, int] = {}
    for a, (slot, mode) in zip(args, pe.arg_slots):
        if a.mode is not mode:
            return None
        k = dep_key(a.array)
        cur = bound_keys.get(k, new_keys.get(k))
        if cur is not None:                 # array already bound to a slot
            if cur != slot:
                return None                 # aliasing the capture didn't have
            continue
        if bound[slot] is not None:
            if dep_key(bound[slot]) != k:
                return None                 # slot already holds another array
            continue
        if slot in new_bind:
            return None                     # two arrays for one slot
        spec = plan.slots[slot]
        if not spec.geometry_matches(a.array) or not spec.state_matches(a.array):
            return None
        new_bind[slot] = a.array
        new_keys[k] = slot
    return new_bind


def _apply_location_bits(sched, pe: PlanElement, bound: List[Any]) -> None:
    """Logical data-location updates at schedule time — the same
    MemoryManager transitions the eager pipeline performs, so a replayed
    (or capture-demoted) episode keeps location bits and resident-set
    accounting in lockstep with the eager path."""
    mem = sched.memory
    if pe.kind is ElementKind.TRANSFER:
        mem.note_h2d(bound[pe.arg_slots[0][0]], pe.device)
    elif pe.kind is ElementKind.D2D:
        mem.note_d2d(bound[pe.arg_slots[0][0]], pe.device)
    elif pe.kind is ElementKind.EVICT:
        # Plan-carried evictions are *scheduled* (part of the captured —
        # possibly Belady-rewritten — memory schedule), not reactive.
        cfg = dict(pe.config)
        tier = mem.tier_named(cfg["tier"]) if cfg.get("tier") else None
        if tier is not None:
            mem.note_spill(bound[pe.arg_slots[0][0]], tier,
                           cfg.get("spill_target"), pe.transfer_bytes,
                           scheduled=True)
        else:
            mem.note_evict(bound[pe.arg_slots[0][0]], scheduled=True)
    elif pe.kind is ElementKind.RELOAD:
        mem.note_reload(bound[pe.arg_slots[0][0]], pe.device)
    else:
        for slot, mode in pe.arg_slots:
            if mode.writes:
                mem.note_device_write(bound[slot], pe.device)


def _flush_range(sched, r: _ReplayState, hi_inclusive: int,
                 kernel_fn: Optional[Callable] = None,
                 use_plan_fns: bool = False) -> ComputationalElement:
    """Materialize and batch-submit plan elements ``r.flushed .. hi``.

    Fresh ``ComputationalElement``s are created with slot-bound arrays and
    pre-resolved parents; the DAG adopts them without inference, the
    pre-reserved lanes receive them without assignment, and the executor
    gets one batch with pre-materialized event lists.  Only the *first* use
    of each slot consults the live frontier (entry dependencies) so that
    replays chain correctly behind earlier eager/replayed work touching the
    same arrays."""
    plan = r.plan
    bounded = sched.memory.bounded
    if not r.started:
        # The whole episode costs one reduced plan-launch overhead
        # (cudaGraphLaunch analogue) instead of one overhead per element.
        sched.executor.host_overhead(sched.plan_launch_overhead_s)
        r.started = True
        if bounded and plan.mem_scheduled:
            # The plan carries its own Belady evict/reload schedule: make
            # room for its recorded per-device peak once, up front (stale
            # foreign leftovers are the only possible victims), then let
            # the plan's own EVICT elements manage its working set.
            sched.pipeline.reserve_plan(
                plan, extra_pinned=r.pinned.union(r.bound_keys))
    is_done = sched.executor.is_done
    items = []
    for idx in range(r.flushed, hi_inclusive + 1):
        pe = plan.elements[idx]
        if pe.kind is ElementKind.KERNEL:
            fn = plan.fns[idx] if use_plan_fns else kernel_fn
        else:
            fn = plan.fns[idx]
        args = tuple(Arg(r.bound[s], m) for s, m in pe.arg_slots)
        ce = ComputationalElement(
            fn=fn, args=args, kind=pe.kind, name=pe.name,
            config=dict(plan.configs[idx]), cost_s=pe.cost_s,
            transfer_bytes=pe.transfer_bytes,
            priority=pe.priority, tenant=pe.tenant,
            deadline_s=pe.deadline_s, fn_key=pe.fn_key)
        # Re-stamp the absolute deadline at *replay* submission time (the
        # capture-time deadline_t would be long expired) and register with
        # the monitor for EDF/risk tracking.
        sched.deadlines.tag(ce)
        ce.device = pe.device
        ce.src_device = pe.src_device
        ce.device_pinned = pe.pinned    # survives a seed_from_replay re-trace
        if pe.kind in (ElementKind.EVICT, ElementKind.RELOAD):
            # Re-resolve the tier by name against the *current* stack: the
            # plan records only the tier name (part of the frozen config),
            # never the runtime object.
            tname = plan.configs[idx].get("tier")
            if tname:
                ce.tier = sched.memory.tier_named(tname)
        if bounded and not plan.mem_scheduled \
                and pe.kind is not ElementKind.EVICT:
            # Replays reserve dynamically too: plan gating guarantees the
            # plan's *own* peak fits the budget, but stale foreign arrays
            # (earlier episodes' leftovers) may still hold bytes — evict
            # those eagerly, never an array the plan has bound (or will
            # bind by default).  The synthesized evicts bypass the replay
            # lanes entirely.  (Belady-scheduled plans did this once, up
            # front, in reserve_plan — their element order *is* the
            # schedule, so the reactive reserve must not interleave.)
            sched.pipeline.reserve(
                ce, extra_pinned=r.pinned.union(r.bound_keys))
        parents = [r.new_elements[p] for p in pe.parents]
        seen = {p.uid for p in parents}
        entry: List[ComputationalElement] = []
        for s, m in pe.arg_slots:
            if s in r.written:
                continue    # session already owns this slot's frontier
            for d in sched.dag.live_deps(dep_key(r.bound[s]), writes=m.writes):
                if d.uid not in seen and d is not ce and not d.is_host:
                    seen.add(d.uid)
                    entry.append(d)
        ce.parents = parents + entry
        sched.dag.adopt(ce)
        for s, m in pe.arg_slots:
            if m.writes:
                r.written.add(s)
        lane = r.lanes[pe.lane]
        sched.streams.bind_to_lane(lane, ce)
        events = [r.new_elements[w] for w in pe.wait_events
                  if not is_done(r.new_elements[w])]
        events += [d for d in entry if not is_done(d)]
        items.append((ce, lane.lane_id, events))
        r.new_elements.append(ce)
        sched._elements.append(ce)
        if pe.kind is ElementKind.D2D:
            sched.d2d_transfers += 1
        _apply_location_bits(sched, pe, r.bound)
    sched.executor.submit_batch(items)
    if items and items[-1][0].deadline_t is not None:
        # Deadline'd replay flush: run the submission-time risk check once
        # per batch (the caller holds the pipeline lock on this path).
        sched.deadlines.on_submit(items[-1][0])
    r.flushed = hi_inclusive + 1
    return r.new_elements[hi_inclusive]


def replay_plan(sched, plan: ExecutionPlan,
                bindings: Optional[Mapping] = None
                ) -> List[ComputationalElement]:
    """Explicit whole-plan replay (``scheduler.replay``).  ``bindings`` maps
    slot names or indices to fresh arrays; unbound slots reuse the arrays
    captured with the plan (CUDA-graph buffer-reuse semantics)."""
    r = _ReplayState(sched, plan)
    arrays = [ref() for ref in plan.slot_arrays]
    by_name = {s.name: s.index for s in plan.slots}
    for ref, arr in (bindings or {}).items():
        if isinstance(ref, int):
            if not 0 <= ref < len(arrays):
                raise ValueError(f"no slot {ref} in plan {plan.name!r}")
            idx = ref
        else:
            if ref not in by_name:
                raise ValueError(f"no slot named {ref!r} in plan {plan.name!r}; "
                                 f"slots: {sorted(by_name)}")
            idx = by_name[ref]
        spec = plan.slots[idx]
        if not spec.geometry_matches(arr):
            raise ValueError(
                f"binding for slot {spec.name!r} has shape/dtype "
                f"{getattr(arr, 'shape', None)}/{getattr(arr, 'dtype', None)}, "
                f"plan expects {spec.shape}/{spec.dtype}")
        arrays[idx] = arr
    # Location-state validation for every slot, bound or default: a recorded
    # H2D prefetch re-uploads the array's *host* copy, so replaying it over
    # an array whose newest value lives on the device would silently clobber
    # it; likewise a slot captured device-resident (no transfer recorded)
    # needs a valid device copy to read.
    transfer_slots = {pe.arg_slots[0][0] for pe in plan.elements
                      if pe.kind is ElementKind.TRANSFER}
    for spec, arr in zip(plan.slots, arrays):
        if arr is None:
            raise ValueError(
                f"slot {spec.name!r}: the captured default array has been "
                f"garbage-collected; bind a fresh array explicitly")
        if (spec.index in transfer_slots
                and not getattr(arr, "host_valid", True)
                and getattr(arr, "device_valid", False)):
            raise ValueError(
                f"slot {spec.name!r}: the plan replays a host->device "
                f"transfer but the array's host copy is stale "
                f"(host_valid=False); read it back or rebind before replay")
        if spec.tier != getattr(arr, "backing_tier", None):
            raise ValueError(
                f"slot {spec.name!r} was captured "
                f"{'in tier ' + repr(spec.tier) if spec.tier else 'untiered'}"
                f" but the bound array is "
                f"{'in tier ' + repr(arr.backing_tier) if getattr(arr, 'backing_tier', None) else 'not tier-resident'};"
                f" the recorded reload structure would read the wrong payload")
        if spec.device_valid:
            if not getattr(arr, "device_valid", False):
                raise ValueError(
                    f"slot {spec.name!r} was captured device-resident but "
                    f"the bound array has no valid device copy")
            if getattr(arr, "device_id", None) != spec.device_id:
                raise ValueError(
                    f"slot {spec.name!r} is resident on device "
                    f"{getattr(arr, 'device_id', None)}, plan expects "
                    f"device {spec.device_id} (rebind or migrate first)")
    r.bound = arrays
    for i, a in enumerate(arrays):
        k = dep_key(a)
        if k in r.bound_keys:
            # Eager execution would serialize the aliased writes (WAW/WAR);
            # a plan captured from distinct arrays has no such edges, so the
            # aliasing must be rejected (the transparent match path rejects
            # it the same way).
            raise ValueError(
                f"array {getattr(a, 'name', a)!r} is bound to both slot "
                f"{plan.slots[r.bound_keys[k]].name!r} and "
                f"{plan.slots[i].name!r}; replay bindings must be distinct")
        r.bound_keys[k] = i
    _flush_range(sched, r, len(plan.elements) - 1, use_plan_fns=True)
    sched.plan_cache.hits += 1
    sched.plan_cache.touch(plan)
    return list(r.new_elements)


# ======================================================================
# The transparent context manager
# ======================================================================

class CaptureContext:
    """``with scheduler.capture(name):`` — record on first sight, replay on
    structural match, fall back to eager on divergence.

    Modes:

    * ``match``  — a cached plan for ``name`` exists; user launches are
      matched positionally against its kernels and submitted through the
      replay fast path (intervening transfer/D2D plan elements ride along);
    * ``record`` — no (matching) plan; the episode runs eagerly while
      ``_schedule`` traces it, and a plan is stored on clean exit;
    * ``eager``  — divergence was detected (plan invalidated) or the
      scheduler policy is serial; pure passthrough.
    """

    def __init__(self, sched, name: str) -> None:
        self.sched = sched
        self.name = name
        self.mode = "idle"
        self.recorder: Optional[_Recorder] = None
        self.replay: Optional[_ReplayState] = None
        self.candidates: List[ExecutionPlan] = []

    # -- context protocol ----------------------------------------------
    def __enter__(self) -> "CaptureContext":
        if self.sched._capture is not None:
            raise RuntimeError("capture contexts cannot nest")
        self.sched._capture = self
        if self.sched.policy != "parallel":
            self.mode = "eager"
            return self
        self.candidates = self.sched.plan_cache.candidates(self.name)
        if self.sched.memory.bounded:
            # Budget gating: a plan whose recorded per-device peak no longer
            # fits the current budgets must not replay (its transfer/evict
            # structure was recorded for a roomier device).  The episode
            # falls back to eager execution — and re-records, so the next
            # episode replays a spill-aware plan under the new budget.
            self.candidates = [p for p in self.candidates
                               if self.sched.memory.plan_fits(p.device_mem)]
        if self.candidates:
            self.mode = "match"
        else:
            self.mode = "record"
            self.recorder = _Recorder()
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        self.sched._capture = None
        if exc_type is not None:
            return False            # abandoned episode: keep cache untouched
        if self.mode == "match" and self.replay is not None:
            if self.replay.completed:
                self.sched.plan_cache.hits += 1
                self.sched.plan_cache.touch(self.replay.plan)
            else:
                # Episode ended before the plan did: structural divergence.
                # The replayed prefix *is* this shorter episode — transplant
                # it into a recording so the new shape is cached immediately.
                self._diverge(self.replay)
        if self.mode == "record" and self.recorder is not None:
            plan = self.recorder.build(self.name)
            if plan is not None:
                if getattr(self.sched, "plan_optimize", False):
                    # Plan-time global optimization (planopt.py): min-cut
                    # placement + Belady memory scheduling.  Returns the
                    # same object when no strict improvement exists, so
                    # disabled/unimprovable plans cache the greedy trace
                    # bit for bit.
                    from .planopt import optimize_plan
                    with self.sched.pipeline:
                        plan = optimize_plan(self.sched, plan)
                if getattr(self.sched, "sanitize", False):
                    # Sanitize mode: never cache a plan that fails the
                    # happens-before/liveness verifier.
                    from ..analysis.verifier import (PlanVerificationError,
                                                     verify_plan)
                    violations = verify_plan(plan)
                    if violations:
                        raise PlanVerificationError(plan.name, violations)
                for displaced in self.sched.plan_cache.store(plan):
                    self.sched.streams.unreserve(displaced.key)
        return False

    def _drop(self, plan: ExecutionPlan) -> None:
        """Invalidate a diverged plan and free its reserved lane sets."""
        self.sched.plan_cache.invalidate(plan)
        self.sched.streams.unreserve(plan.key)

    def _diverge(self, r: _ReplayState) -> None:
        """Mid-episode divergence: the already-replayed prefix matched (and
        therefore executed correctly).  Drop the stale plan and transplant
        the prefix into a fresh recording, so the *new* episode shape gets
        cached without waiting for another full eager episode.  (Distinct
        episode shapes are still best given distinct capture names —
        alternating shapes under one name re-record every switch.)"""
        self._drop(r.plan)
        self.recorder = _Recorder()
        self.recorder.seed_from_replay(r)
        self.replay = None
        self.mode = "record"

    def note_host_write(self, ma: Any) -> None:
        """A host write to a plan-bound array mid-replay changes its logical
        location behind the plan's back (the eager path would insert a fresh
        prefetch the plan does not contain).  Demote the rest of the episode
        to eager execution; the plan stays cached — episodes without the
        mid-episode write keep replaying."""
        if self.mode != "match" or self.replay is None:
            return
        if dep_key(ma) in self.replay.bound_keys:
            self.replay = None
            self.mode = "eager"

    # -- scheduler hooks -----------------------------------------------
    @property
    def recording(self) -> bool:
        return self.mode == "record"

    def trace(self, e: ComputationalElement) -> None:
        """Trace one eagerly-scheduled element (record mode only)."""
        if self.mode != "record" or self.recorder is None:
            return
        if self.recorder.blocked:
            # A host sync retired part of the trace before this launch; its
            # inferred parents are missing the retired edges, so a plan
            # containing it would replay without them (a data race when the
            # episode is later re-issued without the host access).  Abandon
            # the recording; the episode itself stays correct and eager.
            self.recorder = None
            self.mode = "eager"
            return
        if (e.kind is ElementKind.EVICT
                and not self.recorder.knows(e.args[0].array)):
            # Budget eviction of an array *foreign* to this episode (a
            # previous episode's leftover): purely environment-dependent —
            # baking it into the plan would tie the plan's slots (and its
            # replayability) to whatever happened to be resident this time.
            # Episode-local evictions (the victim is already a slot) stay
            # in the trace: they manage the plan's own working set.
            return
        self.recorder.record(e)

    def note_host_sync(self, deps: Optional[Sequence] = None) -> None:
        """Called when a host access synchronizes (and retires) in-flight
        work: ``deps`` are the waited elements, None means a full barrier.
        Recording stays valid only while no *traced* element is retired
        before further launches (trailing reads/syncs are harmless)."""
        if self.mode != "record" or self.recorder is None:
            return
        if not self.recorder.drafts:
            return
        if deps is None or any(self.recorder.traced(p) for p in deps):
            self.recorder.blocked = True

    def offer(self, fn: Optional[Callable], args: Sequence[Arg], name: str,
              config: dict, cost_s: float, priority: int = 0,
              tenant: str = DEFAULT_TENANT, device: Optional[int] = None,
              fn_key: Optional[int] = None,
              deadline_s: Optional[float] = None
              ) -> Optional[ComputationalElement]:
        """Called by ``GrScheduler._launch`` before the eager path.  Returns
        the replayed element on a plan hit, or None to fall through (the
        eager path then records when in record mode)."""
        if self.mode != "match":
            return None
        cfg_items = freeze_config(config)
        r = self.replay
        if r is None:
            # Candidate selection happens at the first kernel: the cache may
            # hold several signatures under one name (e.g. batch shapes).
            for plan in self.candidates:
                bind = _match_kernel(plan, 0, [None] * len(plan.slots), {},
                                     args, name, cfg_items, cost_s,
                                     priority, tenant, device, fn_key,
                                     deadline_s)
                if bind is not None:
                    self.replay = r = _ReplayState(self.sched, plan)
                    return self._commit(r, bind, fn)
            # No plan starts with this launch: trace a new episode instead.
            self.mode = "record"
            self.recorder = _Recorder()
            return None
        if r.kpos >= r.plan.num_kernels:
            bind = None             # plan exhausted but episode continues
        else:
            bind = _match_kernel(r.plan, r.kpos, r.bound, r.bound_keys,
                                 args, name, cfg_items, cost_s,
                                 priority, tenant, device, fn_key,
                                 deadline_s)
        if bind is None:
            # Divergence: drop the stale plan, transplant the replayed
            # prefix into a recording, and let the eager path trace the
            # rest of this (new-shape) episode.
            self._diverge(r)
            return None
        return self._commit(r, bind, fn)

    def _commit(self, r: _ReplayState, bind: Dict[int, Any],
                fn: Optional[Callable]) -> ComputationalElement:
        for slot, arr in bind.items():
            r.bound[slot] = arr
            r.bound_keys[dep_key(arr)] = slot
        j = r.plan.kernel_positions[r.kpos]
        r.kpos += 1
        # The matched launch's *current* callable is used (closures are
        # routinely re-created per episode); only the schedule is reused.
        return _flush_range(self.sched, r, j, kernel_fn=fn)
