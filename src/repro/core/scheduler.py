"""GrScheduler — the user-facing runtime (paper §IV-B, Fig. 5).

The *GPU execution context* of the paper: tracks declarations/invocations of
computational elements, updates the DAG with inferred dependencies, asks the
stream manager for a lane, and submits to an executor.  Two policies:

* ``serial``  — the original GrCUDA scheduler: synchronous, in-order, no
  overlap, no dependency computation (baseline of Fig. 7);
* ``parallel`` — this paper: asynchronous, dependency-driven, lanes + events,
  automatic prefetch of host-resident arguments.

Host reads/writes of managed arrays synchronize only against the in-flight
computations that actually touch the data (§IV-B), then retire the observed
sub-DAG from the frontier.
"""
from __future__ import annotations

import itertools
import threading
import warnings
from typing import Callable, List, Mapping, Optional, Sequence

import numpy as np

from .capture import CaptureContext, ExecutionPlan, PlanCache, replay_plan
from .dag import ComputationDAG
from .deadlines import DeadlineMonitor
from .element import (Arg, ComputationalElement, DEFAULT_TENANT, ElementKind,
                      ElementState, const, dep_key, inout, out)
from .executor import Executor, SimExecutor, SimHardware, ThreadLaneExecutor
from .managed import ManagedArray
from .memory import Budget, MemoryManager
from .streams import NewStreamPolicy, ParentStreamPolicy, StreamManager
from .submission import SubmissionPipeline
from .timeline import Timeline

# A replayed plan is submitted with a single reduced launch overhead — the
# cudaGraphLaunch analogue: roughly one hardware kernel-launch, however many
# elements the plan contains.
_PLAN_LAUNCH_OVERHEAD_S = 5e-6


class GrScheduler:
    def __init__(self,
                 policy: str = "parallel",
                 executor: Optional[Executor] = None,
                 new_stream_policy: NewStreamPolicy = NewStreamPolicy.FIFO_REUSE,
                 parent_stream_policy: ParentStreamPolicy = ParentStreamPolicy.FIRST_CHILD_INHERITS,
                 auto_prefetch: bool = True,
                 launch_overhead_s: Optional[float] = None,
                 plan_launch_overhead_s: Optional[float] = None,
                 max_lanes: Optional[int] = None,
                 num_devices: int = 1,
                 placement: str = "round-robin",
                 tenant_quotas: Optional[Mapping[str, int]] = None,
                 memory_budget: Budget = None,
                 spill_tiers: Optional[Sequence] = None,
                 plan_optimize: bool = True,
                 slo_targets: Optional[Mapping[str, float]] = None,
                 sanitize: bool = False) -> None:
        assert policy in ("serial", "parallel")
        self.policy = policy
        self.num_devices = max(1, num_devices)
        self.executor = executor or ThreadLaneExecutor(
            num_devices=self.num_devices)
        self.dag = ComputationDAG()
        # Per-device byte budgets (None = unlimited): the MemoryManager owns
        # resident-set accounting and every logical location-bit flip; the
        # pipeline's reserve stage spills LRU victims when a budget is hit.
        # ``spill_tiers`` is the ordered backing-tier stack (tiers.py) dirty
        # victims fall through; empty/None keeps the flat D2H spill of PR 5
        # bit for bit.
        self.memory = MemoryManager(self.num_devices, memory_budget,
                                    tiers=spill_tiers)
        self.streams = StreamManager(new_stream_policy, parent_stream_policy,
                                     max_lanes=max_lanes,
                                     num_devices=self.num_devices,
                                     placement=placement,
                                     tenant_quotas=tenant_quotas)
        self.streams.memory = self.memory
        self.auto_prefetch = auto_prefetch
        if launch_overhead_s is None:
            launch_overhead_s = 5e-6 if policy == "parallel" else 1e-6
        self.launch_overhead_s = launch_overhead_s
        if plan_launch_overhead_s is None:
            plan_launch_overhead_s = min(launch_overhead_s,
                                         _PLAN_LAUNCH_OVERHEAD_S)
        self.plan_launch_overhead_s = plan_launch_overhead_s
        self.d2d_transfers = 0
        self._elements: List[ComputationalElement] = []
        self._tune_counts: dict = {}
        # Explicit, lock-protected submission path (place -> prefetch/D2D ->
        # DAG-add -> lane-assign -> submit): multiple client threads may
        # call launch()/host_read()/host_write()/sync() concurrently.
        self.pipeline = SubmissionPipeline(self)
        # Graph capture & replay (capture.py): cached execution plans plus
        # the at-most-one active capture context.  ``plan_optimize`` runs the
        # plan-time global optimizer (planopt.py: min-cut placement + Belady
        # memory scheduling) once at capture finalization; False keeps the
        # greedy trace bit for bit.
        self.plan_cache = PlanCache()
        self.plan_optimize = plan_optimize
        self._capture: Optional[CaptureContext] = None
        # Deadline/SLO-aware scheduling (deadlines.py): per-tenant SLO
        # targets auto-stamp deadlines on launches; the monitor owns the
        # slack estimator and element-boundary preemption.  All hooks
        # early-out while no deadline exists, so deadline-free schedules
        # stay bit-identical.
        self.deadlines = DeadlineMonitor(self, slo_targets)
        self.deadlines.full_boundary_checks = not self.executor.concurrent_waits
        self.executor.on_boundary = self.deadlines.on_boundary
        self.executor.on_stall = self.deadlines.ensure_progress
        # Host-access ordering log for the happens-before verifier: each
        # entry is ``(position, host_element)`` recorded once the host wait
        # completed — the host element orders after its parents and before
        # everything submitted from ``position`` on.  Cleared with
        # ``_elements`` at every full sync; cheap enough to keep always-on.
        self._host_log: List[tuple] = []
        # Sanitizer runtime mode (repro.analysis): version-vector race
        # detection at element boundaries.  Off by default — with
        # ``sanitize=False`` no hook is installed and scheduling is
        # bit-identical.
        self.sanitize = bool(sanitize)
        self.sanitizer = None
        if self.sanitize:
            from ..analysis.sanitizer import Sanitizer
            self.sanitizer = Sanitizer(
                checksums=not isinstance(self.executor, SimExecutor))
            self.executor.pre_exec = self.sanitizer.pre_exec
            self.executor.post_exec = self.sanitizer.post_exec
        self._closed = False

    # ------------------------------------------------------------------
    def array(self, data=None, *, shape=None, dtype=np.float32,
              name: str = "") -> ManagedArray:
        return ManagedArray(self, data, shape=shape, dtype=dtype, name=name)

    # ------------------------------------------------------------------
    def _mark_host_done(self, e: ComputationalElement) -> None:
        if isinstance(self.executor, SimExecutor):
            self.executor._end[e.uid] = self.executor.host_time
        else:
            ev = threading.Event()
            ev.set()
            e.done_event = ev
        e.state = ElementState.DONE
        e.t_start = e.t_end = self.executor.host_now()

    def _schedule(self, e: ComputationalElement) -> None:
        """DAG insert + lane assignment + submission (parallel policy).

        Thin alias kept for backward compatibility; the staged path lives in
        :class:`~repro.core.submission.SubmissionPipeline`."""
        self.pipeline.schedule(e)

    # ------------------------------------------------------------------
    def launch(self, fn: Optional[Callable], args: Sequence[Arg], *,
               name: str = "", cost_s: float = 0.0,
               tune: Optional[dict] = None,
               priority: int = 0, tenant: str = DEFAULT_TENANT,
               deadline_s: Optional[float] = None,
               **config) -> ComputationalElement:
        """Deprecated shim over the submission engine (:meth:`_launch`).

        Per-call ``const/out/inout`` annotation is exactly the expert burden
        the paper's polyglot API removes — declare a :class:`GrFunction`
        once via ``repro.api.function`` (access modes, cost model and tuning
        space live with the declaration) and call it like a plain function.
        The shim stays for at least two more releases so downstream callers
        and the tier-1 tests keep working; see README "Migrating from
        ``s.launch``".
        """
        warnings.warn(
            "GrScheduler.launch is deprecated: declare the kernel once with "
            "repro.api.function(fn, modes=...) and call the GrFunction "
            "directly", DeprecationWarning, stacklevel=2)
        return self._launch(fn, args, name=name, cost_s=cost_s, tune=tune,
                            priority=priority, tenant=tenant,
                            deadline_s=deadline_s, **config)

    def _launch(self, fn: Optional[Callable], args: Sequence[Arg], *,
                name: str = "", cost_s: float = 0.0,
                tune: Optional[dict] = None,
                priority: int = 0, tenant: str = DEFAULT_TENANT,
                device: Optional[int] = None,
                fn_key: Optional[int] = None,
                deadline_s: Optional[float] = None,
                **config) -> ComputationalElement:
        """Submission engine: issue one kernel, dependencies & lane inferred.

        This is the single path behind ``GrFunction.__call__`` (and the
        deprecated ``launch`` shim).  ``tune={"param": [candidates...]}``
        enables the paper's §VI heuristic: explore each candidate launch
        config round-robin, then exploit the historically fastest
        (per-kernel history, §IV-A).

        ``priority``/``tenant`` tag the element (and its auto-inserted
        transfers) for multi-tenant QoS: priority weights contended device
        capacity and steers lane selection; tenant drives per-tenant stats
        and optional lane quotas.  ``device`` pins placement to one device
        (bypassing the placement policy); ``fn_key`` is the declared-function
        identity capture plans are keyed by.  Thread-safe — concurrent
        submitters serialize on the scheduler's submission pipeline.
        """
        with self.pipeline:
            if tune:
                config = dict(config, **self._tune(name, tune))
            if device is not None:
                # Clamp before capture matching: plans record the *clamped*
                # placement, so an out-of-range pin must present the same
                # value or identical episodes would re-record forever.
                device = min(max(0, int(device)), self.num_devices - 1)
            cap = self._capture
            if cap is not None:
                replayed = cap.offer(fn, tuple(args), name, config, cost_s,
                                     priority=priority, tenant=tenant,
                                     device=device, fn_key=fn_key,
                                     deadline_s=deadline_s)
                if replayed is not None:
                    return replayed     # plan hit: submitted via the fast path
            e = ComputationalElement(fn=fn, args=tuple(args),
                                     kind=ElementKind.KERNEL, name=name,
                                     config=config, cost_s=cost_s,
                                     priority=priority, tenant=tenant,
                                     fn_key=fn_key, deadline_s=deadline_s)
            if device is not None:
                e.device = device       # clamped by the pipeline's run stage
                e.device_pinned = True  # plan optimizer must not move it
            # Stamp the absolute deadline (explicit or tenant-SLO) before
            # the pipeline runs, so auto-inserted transfer children inherit
            # the same EDF rank.
            self.deadlines.tag(e)
            if self.policy == "parallel":
                self.pipeline.run(e)
            else:
                e.device = 0 if e.device is None else e.device
                self.pipeline.reserve(e)
                if self.auto_prefetch:
                    self.pipeline.prefetch(e.args, priority=priority,
                                           tenant=tenant)
                self.pipeline.serial(e)
            # Logical location update at schedule time: the kernel's writable
            # outputs will live on device; host copies become stale.  Routed
            # through the MemoryManager so residency tracks the bits.
            dev = e.device if e.device is not None else 0
            for a in e.args:
                if a.mode.writes:
                    self.memory.note_device_write(a.array, dev)
            return e

    def _tune(self, name: str, tune: dict) -> dict:
        counts = self._tune_counts.setdefault(name, 0)
        keys = sorted(tune)
        grid = [dict(zip(keys, vals)) for vals in
                itertools.product(*(tune[k] for k in keys))]
        if counts < 2 * len(grid):      # exploration phase
            choice = grid[counts % len(grid)]
        else:                           # exploitation: fastest median config
            choice = self._coerce_best_config(name, keys, grid)
        self._tune_counts[name] = counts + 1
        return choice

    def _coerce_best_config(self, name: str, keys, grid) -> dict:
        """History stores config values stringified; coerce them back to the
        candidate types, falling back to the first grid point when history
        is empty or a value no longer parses as the candidate type."""
        best = self.executor.history.best_config(name)
        if not best:
            return grid[0]
        choice = {}
        for k, v in best.items():
            if k not in keys:
                continue
            try:
                choice[k] = type(grid[0][k])(v)
            except (TypeError, ValueError):
                return grid[0]
        return choice or grid[0]

    # ------------------------------------------------------------------
    # Host accesses (ManagedArray callbacks) — paper §IV-A/B
    # ------------------------------------------------------------------
    def _sync_against(self, ma: ManagedArray, writes: bool) -> None:
        with self.pipeline:
            deps = [d for d in self.dag.live_deps(dep_key(ma), writes)
                    if not d.is_host]
            if deps and self._capture is not None:
                self._capture.note_host_sync(deps)
            if not deps:
                return  # fast path: host access introduces no dependency (§IV-A)
            e = ComputationalElement(
                fn=None, args=(inout(ma) if writes else const(ma),),
                kind=ElementKind.HOST_ACCESS, name=f"host_{ma.name}")
            self.dag.add(e)
            t0 = self.executor.host_now()
            waits = [p for p in e.parents if not p.is_host]
            if not self.executor.concurrent_waits:
                for p in waits:     # sync only the lanes owning this data
                    self.executor.wait(p)
                waits = []
        # Real executor: block OUTSIDE the pipeline lock — a tenant waiting
        # on its own slow kernel must not stall other tenants' launches
        # (priority inversion).  wait() is a pure completion-event wait and
        # the post-wait retire/release below are idempotent under the
        # re-acquired lock, so a concurrent sync() racing us is harmless.
        for p in waits:
            self.executor.wait(p)
        with self.pipeline:
            self.dag.retire(e)
            for p in e.parents:
                self.streams.release(p)
            self._mark_host_done(e)
            # Verifier ordering log: this host access completed before any
            # element at position >= len(_elements) was submitted.
            self._host_log.append((len(self._elements), e))
            self.executor.record_host_span(e, t0, self.executor.host_now())

    def _sync_and_localize(self, ma: ManagedArray, writes: bool) -> None:
        """Synchronize against the array's frontier, then (under the lock)
        refresh its host copy.  Because _sync_against may wait with the lock
        released, another tenant can slip a new writer in before the D2H —
        copying then would tear the host buffer and mask the newer device
        data behind host_valid=True, an outcome no serialization of the two
        accesses could produce.  Re-validate the frontier under the lock and
        re-sync until the gap stays clean."""
        while True:
            self._sync_against(ma, writes=writes)
            with self.pipeline:
                if self.dag.has_device_frontier(dep_key(ma), writes):
                    continue    # a racing launch re-dirtied the array
                if ma.device_valid and not ma.host_valid:
                    self._d2h(ma)
                elif getattr(ma, "backing_tier", None) is not None:
                    self._tier_restore(ma)
                return

    def host_read(self, ma: ManagedArray) -> None:
        self._sync_and_localize(ma, writes=False)

    def host_write(self, ma: ManagedArray) -> None:
        with self.pipeline:
            if self._capture is not None:
                # A host write flips the array's logical location in a way a
                # replaying plan cannot see (eager would re-prefetch the new
                # host data); the capture context demotes the rest of the
                # episode to eager execution when the array is plan-bound.
                self._capture.note_host_write(ma)
        # D2H before the write: read-modify-write safety for partial updates.
        self._sync_and_localize(ma, writes=True)

    def _d2h(self, ma: ManagedArray) -> None:
        ex = self.executor
        if isinstance(ex, SimExecutor):
            t0 = ex.host_time
            ex.host_time += ma.nbytes / (ex.hw.d2h_gbps * 1e9)
            ex._advance_to(ex.host_time)
            ex.timeline.record(-1, f"d2h_{ma.name}", "d2h", None, t0, ex.host_time)
        else:
            t0 = ex.host_now()
            ma.host = np.asarray(ma.device)
            ex.timeline.record(-1, f"d2h_{ma.name}", "d2h", None, t0, ex.host_now())
        ma.host_valid = True

    def _tier_restore(self, ma: ManagedArray) -> None:
        """Host access to a block parked in a host-side tier: restore the
        host buffer synchronously (decompress / read the spool file) —
        no device hop.  The simulator charges the tier's restore cost."""
        tier = self.memory.tier_named(ma.backing_tier)
        if tier is None:        # stack reconfigured under a live block
            self.memory.note_tier_to_host(ma)
            return
        ex = self.executor
        if isinstance(ex, SimExecutor):
            t0 = ex.host_time
            ex.host_time += tier.host_restore_seconds(ma.nbytes)
            ex._advance_to(ex.host_time)
            ex.timeline.record(-1, f"tier_{tier.name}_{ma.name}", "d2h",
                               None, t0, ex.host_time)
        else:
            t0 = ex.host_now()
            tier.reload(ma)     # refreshes ma.host, drops the payload
            ex.timeline.record(-1, f"tier_{tier.name}_{ma.name}", "d2h",
                               None, t0, ex.host_now())
        self.memory.note_tier_to_host(ma)

    # ------------------------------------------------------------------
    # Graph capture & replay (capture.py, §V-D CUDA-Graphs analogue)
    # ------------------------------------------------------------------
    def capture(self, name: str) -> CaptureContext:
        """Enter a transparent capture/replay context.

        The first episode under ``name`` (per structural signature) runs
        eagerly and is traced into an :class:`ExecutionPlan`; later episodes
        that issue the identical launch sequence are replayed through the
        fast path, skipping DAG inference, lane assignment and per-element
        launch overhead.  Divergence invalidates the plan and the episode
        continues eagerly — capture never changes program semantics.  Under
        the serial policy the context is a no-op passthrough."""
        return CaptureContext(self, name)

    def replay(self, plan: ExecutionPlan,
               bindings: Optional[Mapping] = None
               ) -> List[ComputationalElement]:
        """Explicitly re-submit a captured plan with fresh arrays bound by
        slot name or index; unbound slots reuse the captured arrays."""
        if self.policy != "parallel":
            raise RuntimeError("replay requires the parallel policy")
        with self.pipeline:
            if self._capture is not None:
                raise RuntimeError("cannot replay inside a capture context")
            if not self.memory.plan_fits(plan.device_mem):
                from .memory import DeviceOutOfMemoryError
                raise DeviceOutOfMemoryError(
                    f"plan {plan.name!r} needs per-device peak bytes "
                    f"{dict(plan.device_mem)} but the current budgets are "
                    f"smaller; re-capture under the new budget instead")
            return replay_plan(self, plan, bindings)

    def optimize_plan(self, plan: ExecutionPlan) -> ExecutionPlan:
        """Explicitly re-run the plan-time global optimizer on a captured
        plan (``planopt.py``): min-cut placement refinement plus Belady
        memory scheduling.  Returns the rewritten plan (re-cached in place
        of the original) or ``plan`` itself when no strict improvement is
        possible.  Capture finalization already does this automatically
        when ``plan_optimize`` is on."""
        from .planopt import optimize_plan as _optimize
        with self.pipeline:
            new = _optimize(self, plan)
            if new is not plan:
                self.plan_cache.invalidate(plan)
                self.streams.unreserve(plan.key)
                for displaced in self.plan_cache.store(new):
                    self.streams.unreserve(displaced.key)
            return new

    # ------------------------------------------------------------------
    def sync(self) -> None:
        """Full barrier: host waits for every in-flight computation."""
        if self.executor.concurrent_waits:
            # Drain outside the pipeline lock (same priority-inversion guard
            # as _sync_against): one tenant's barrier must not freeze other
            # tenants' launches while device work finishes.  The locked
            # wait_all afterwards is near-instant unless new work raced in
            # during the drain — which the barrier then also covers.
            with self.pipeline:
                if self._capture is not None:
                    self._capture.note_host_sync(None)
                pending = list(self._elements)
            for e in pending:
                self.executor.wait(e)
        with self.pipeline:
            if self._capture is not None and not self.executor.concurrent_waits:
                self._capture.note_host_sync(None)
            self.executor.wait_all()
            self.dag.retire_all()
            for e in self._elements:
                self.streams.release(e)
            # Retired elements can never need another release; keeping them
            # made every later sync re-walk (and re-release) the whole
            # history — unbounded memory and O(n^2) cost in long-running
            # serving loops.
            self._elements.clear()
            self._host_log.clear()

    def verify(self, plans: bool = True) -> None:
        """Run the happens-before verifier (``repro.analysis``) over the
        live element window, the DAG bookkeeping invariants and every
        cached plan; raises :class:`PlanVerificationError` on any
        violation."""
        from ..analysis.verifier import PlanVerificationError, verify_scheduler
        violations = verify_scheduler(self, plans=plans)
        if violations:
            raise PlanVerificationError("scheduler", violations)

    @property
    def timeline(self) -> Timeline:
        return self.executor.timeline

    def stats(self) -> dict:
        """One consistent counter snapshot, taken under the submission lock
        so a concurrent submitter (or the daemon's monitor loop) never reads
        torn values — e.g. an element counted in ``elements`` whose bytes
        have not yet landed in ``mem_resident``."""
        with self.pipeline:
            return {"policy": self.policy,
                    "elements": self.dag.num_elements,
                    "edges": self.dag.num_edges,
                    "d2d_transfers": self.d2d_transfers,
                    **self.pipeline.stats(),
                    **self.streams.stats(),
                    **self.executor.history.stats(),
                    **self.plan_cache.stats(),
                    **self.memory.stats(),
                    **self.deadlines.stats(),
                    **(self.sanitizer.stats() if self.sanitizer is not None
                       else {})}

    def tenant_stats(self) -> dict:
        """Per-tenant QoS metrics (makespan, queueing delay, completion
        latency p50/p99, and — for deadline'd tenants — SLO attainment)
        computed from the execution timeline.  Consistent under concurrent
        launches: the pipeline lock serializes against submitters, the
        timeline's own lock against lane workers recording completions."""
        with self.pipeline:
            return self.timeline.tenant_stats()

    # ------------------------------------------------------------------
    def close(self) -> None:
        """Idempotent full shutdown: resume paused work, drain every
        in-flight computation, join the executor's worker threads, release
        spill-tier backing resources (spool directories, compressed
        payloads).  After close the scheduler must not be used."""
        if self._closed:
            return
        self._closed = True
        # Paused (preempted) work must drain before workers are stopped.
        self.deadlines.resume_all()
        try:
            self.sync()
        except Exception:
            pass            # best effort: close from an except path anyway
        self.executor.shutdown()
        self.memory.close()

    def shutdown(self) -> None:
        """Backward-compatible alias for :meth:`close`."""
        self.close()

    def __enter__(self) -> "GrScheduler":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


# ----------------------------------------------------------------------
def make_scheduler(policy: str = "parallel", *, simulate: bool = False,
                   hw: Optional[SimHardware] = None,
                   oracle: bool = False, num_devices: int = 1,
                   placement: str = "round-robin", **kw) -> GrScheduler:
    """Factory: real vs simulated executor; ``oracle=True`` emulates the
    hand-optimized CUDA-Graphs baseline of §V-D (full DAG known in advance →
    zero runtime scheduling overhead, unlimited dedicated streams).

    ``num_devices=N`` enables the multi-device runtime: the ``placement``
    policy ("round-robin" / "min-load" / "affinity") spreads kernels across
    devices and the scheduler inserts D2D copies for cross-device inputs.
    """
    num_devices = max(1, num_devices)
    if simulate:
        if hw is None:
            hw = SimHardware(num_devices=num_devices)
        elif hw.num_devices < num_devices:
            from dataclasses import replace
            hw = replace(hw, num_devices=num_devices)
        ex: Executor = SimExecutor(hw)
    else:
        ex = ThreadLaneExecutor(num_devices=num_devices)
    if oracle:
        kw.setdefault("new_stream_policy", NewStreamPolicy.ALWAYS_NEW)
        kw.setdefault("launch_overhead_s", 0.0)
    return GrScheduler(policy=policy, executor=ex, num_devices=num_devices,
                       placement=placement, **kw)
