"""Pure-jnp oracle for the WKV6 recurrence (scan form)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def wkv6_ref(r, k, v, w, u, s0):
    """r/k/v/w: (BH, T, hd); u: (BH, hd); s0: (BH, hd, hd) fp32."""
    rf = r.astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    wf = w.astype(jnp.float32)
    uf = u.astype(jnp.float32)

    def step(S, xs):
        r_t, k_t, v_t, w_t = xs                       # (BH, hd) each
        kv = k_t[:, :, None] * v_t[:, None, :]
        y = jnp.einsum("bi,bij->bj", r_t, S + uf[:, :, None] * kv)
        S = w_t[:, :, None] * S + kv
        return S, y

    xs = tuple(jnp.moveaxis(t, 1, 0) for t in (rf, kf, vf, wf))
    S_final, ys = jax.lax.scan(step, s0, xs)
    return jnp.moveaxis(ys, 0, 1).astype(r.dtype), S_final
