"""RWKV6 WKV recurrence as a Pallas TPU kernel.

The GPU reference (RWKV's CUDA wkv6 kernel) assigns one thread per channel
with shared-memory staging of r/k/v/w — a warp-level pattern with no direct
TPU analogue.  The TPU-native re-think (DESIGN.md §2): one grid row per
(batch x head), the per-head state S (hd x hd, fp32) lives in VMEM scratch
and persists across the sequential time-chunk grid dimension; each grid step
streams a (chunk x hd) tile of r/k/v/w from HBM and walks it with a
``fori_loop`` of rank-1 updates (outer products on the VPU/MXU).

State is carried in/out explicitly so decode and chunked prefill compose.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _wkv_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref, o_ref, sT_ref,
                state_ref, *, chunk: int, n_chunks: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _load_state():
        state_ref[...] = s0_ref[0]

    u = u_ref[0].astype(jnp.float32)                    # (hd,)

    def step(t, _):
        r = r_ref[0, t].astype(jnp.float32)             # (hd,)
        k = k_ref[0, t].astype(jnp.float32)
        v = v_ref[0, t].astype(jnp.float32)
        w = w_ref[0, t].astype(jnp.float32)
        S = state_ref[...]                              # (hd, hd) fp32
        kv = k[:, None] * v[None, :]
        y = jnp.sum(r[:, None] * (S + u[:, None] * kv), axis=0)
        state_ref[...] = w[:, None] * S + kv
        o_ref[0, t] = y.astype(o_ref.dtype)
        return 0

    jax.lax.fori_loop(0, chunk, step, 0)

    @pl.when(ci == n_chunks - 1)
    def _store_state():
        sT_ref[0] = state_ref[...]


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def wkv6_bh(r, k, v, w, u, s0, *, chunk: int = 128, interpret: bool = False):
    """r/k/v/w: (BH, T, hd); u: (BH, hd); s0: (BH, hd, hd) fp32.
    Returns (y (BH, T, hd) in r.dtype, s_final (BH, hd, hd) fp32)."""
    BH, T, hd = r.shape
    chunk = min(chunk, T)
    while T % chunk:
        chunk //= 2
    n_chunks = T // chunk

    kernel = functools.partial(_wkv_kernel, chunk=chunk, n_chunks=n_chunks)
    seq_spec = pl.BlockSpec((1, chunk, hd), lambda bh, ci: (bh, ci, 0))
    return pl.pallas_call(
        kernel,
        grid=(BH, n_chunks),
        in_specs=[seq_spec, seq_spec, seq_spec, seq_spec,
                  pl.BlockSpec((1, hd), lambda bh, ci: (bh, 0)),
                  pl.BlockSpec((1, hd, hd), lambda bh, ci: (bh, 0, 0))],
        out_specs=[seq_spec,
                   pl.BlockSpec((1, hd, hd), lambda bh, ci: (bh, 0, 0))],
        out_shape=[jax.ShapeDtypeStruct((BH, T, hd), r.dtype),
                   jax.ShapeDtypeStruct((BH, hd, hd), jnp.float32)],
        scratch_shapes=[pltpu.VMEM((hd, hd), jnp.float32)],
        interpret=interpret,
    )(r, k, v, w, u, s0)
