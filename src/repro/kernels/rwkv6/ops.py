"""jit'd public wrapper for the WKV6 kernel: (B, T, H, hd) layout with
interpret-mode fallback off-TPU."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import wkv6_bh


@functools.partial(jax.jit, static_argnames=("chunk",))
def wkv6(r, k, v, w, u, s0, *, chunk: int = 128):
    """r/k/v/w: (B, T, H, hd); u: (H, hd); s0: (B, H, hd, hd) fp32.
    Returns (y (B, T, H*hd), s_final (B, H, hd, hd))."""
    B, T, H, hd = r.shape
    flat = lambda x: jnp.swapaxes(x, 1, 2).reshape(B * H, T, hd)
    uf = jnp.tile(u[None], (B, 1, 1)).reshape(B * H, hd)
    s0f = s0.reshape(B * H, hd, hd)
    interpret = jax.default_backend() != "tpu"
    y, sT = wkv6_bh(flat(r), flat(k), flat(v), flat(w), uf, s0f,
                    chunk=chunk, interpret=interpret)
    y = jnp.swapaxes(y.reshape(B, H, T, hd), 1, 2).reshape(B, T, H * hd)
    return y, sT.reshape(B, H, hd, hd)
