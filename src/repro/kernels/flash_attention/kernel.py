"""Blocked causal flash attention as a Pallas TPU kernel.

TPU-native design (HARDWARE ADAPTATION, DESIGN.md §2):
* HBM→VMEM tiling via BlockSpec: one (block_q x hd) query tile and one
  (block_k x hd) key/value tile resident per grid step; the score block
  (block_q x block_k) lives only in VMEM/VREGs — it never round-trips HBM
  (the XLA fallback in models/attention.py pays that traffic).
* Online-softmax state (m, l, acc) in VMEM scratch, persisting across the
  sequential minor grid dimension (k blocks) — the TPU's in-order grid
  replaces the CUDA thread-block reduction of the GPU original.
* Default blocks 256x256: multiples of the 128-wide MXU systolic array and
  the (8,128) VREG tile.
* GQA via the index map: query head h reads kv head h // group.

Validated against ref.py in interpret mode (tests/test_kernels.py sweeps
shapes/dtypes/window/softcap).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  scale: float, block_q: int, block_k: int, causal: bool,
                  window: int, softcap: float, n_k_blocks: int):
    qi = pl.program_id(1)
    kj = pl.program_id(2)

    @pl.when(kj == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)                     # (bq, hd)
    k = k_ref[0].astype(jnp.float32)                     # (bk, hd)
    s = jax.lax.dot_general(q * scale, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)
    if softcap > 0:
        s = jnp.tanh(s / softcap) * softcap

    q_pos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32,
                                                    (block_q, block_k), 0)
    k_pos = kj * block_k + jax.lax.broadcasted_iota(jnp.int32,
                                                    (block_q, block_k), 1)
    if causal:
        mask = k_pos <= q_pos
        if window > 0:
            mask &= (q_pos - k_pos) < window
        s = jnp.where(mask, s, NEG_INF)
    elif window > 0:
        s = jnp.where((q_pos - k_pos) < window, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1)
    v = v_ref[0].astype(jnp.float32)
    pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    acc_ref[...] = acc_ref[...] * corr[:, None] + pv
    m_ref[...] = m_new

    @pl.when(kj == n_k_blocks - 1)
    def _finalize():
        denom = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / denom[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "softcap", "block_q", "block_k",
                     "interpret", "num_q_heads"))
def flash_attention_bhsd(q, k, v, *, causal: bool = True, window: int = 0,
                         softcap: float = 0.0, block_q: int = 256,
                         block_k: int = 256, interpret: bool = False,
                         num_q_heads: int = 0):
    """q: (B*H, Sq, hd); k/v: (B*Hkv, Sk, hd) flattened batch*head layout.
    ``num_q_heads`` (=H) is required when H != Hkv (GQA head mapping)."""
    BH, Sq, hd = q.shape
    BHkv, Sk, _ = k.shape
    if not num_q_heads:
        raise ValueError("num_q_heads is required (GQA head mapping)")
    H = num_q_heads
    B = BH // H
    Hkv = BHkv // B
    g = H // Hkv

    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)
    while Sq % block_q:
        block_q //= 2
    while Sk % block_k:
        block_k //= 2
    nq, nk = Sq // block_q, Sk // block_k

    def kv_index(bh, i, j):
        b = bh // H
        h = bh % H
        return (b * Hkv + h // g, j, 0)

    kernel = functools.partial(
        _flash_kernel, scale=hd ** -0.5, block_q=block_q, block_k=block_k,
        causal=causal, window=window, softcap=softcap, n_k_blocks=nk)

    return pl.pallas_call(
        kernel,
        grid=(BH, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, hd), lambda bh, i, j: (bh, i, 0)),
            pl.BlockSpec((1, block_k, hd), kv_index),
            pl.BlockSpec((1, block_k, hd), kv_index),
        ],
        out_specs=pl.BlockSpec((1, block_q, hd), lambda bh, i, j: (bh, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
