"""Pure-jnp oracle for the flash-attention kernel: materialized-softmax
attention in f32 with identical masking semantics."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def attention_ref(q, k, v, *, causal: bool = True, window: int = 0,
                  softcap: float = 0.0):
    """q: (B, Sq, H, hd); k/v: (B, Sk, Hkv, hd) -> (B, Sq, H, hd)."""
    B, Sq, H, hd = q.shape
    Sk, Hkv = k.shape[1], k.shape[2]
    g = H // Hkv
    qg = q.reshape(B, Sq, Hkv, g, hd).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg * hd ** -0.5, kf)
    if softcap > 0:
        s = jnp.tanh(s / softcap) * softcap
    q_pos = jnp.arange(Sq)[:, None]
    k_pos = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= k_pos <= q_pos
    if window > 0:
        mask &= (q_pos - k_pos) < window
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", w, vf)
    return o.reshape(B, Sq, H, hd).astype(q.dtype)
