"""jit'd public wrapper: (B, S, H, hd) layout, TPU kernel with interpret-mode
fallback on other backends."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import flash_attention_bhsd


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "window", "softcap",
                                             "block_q", "block_k"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    softcap: float = 0.0, block_q: int = 256,
                    block_k: int = 256):
    """q: (B, Sq, H, hd); k/v: (B, Sk, Hkv, hd) -> (B, Sq, H, hd)."""
    B, Sq, H, hd = q.shape
    Sk, Hkv = k.shape[1], k.shape[2]
    qf = jnp.swapaxes(q, 1, 2).reshape(B * H, Sq, hd)
    kf = jnp.swapaxes(k, 1, 2).reshape(B * Hkv, Sk, hd)
    vf = jnp.swapaxes(v, 1, 2).reshape(B * Hkv, Sk, hd)
    o = flash_attention_bhsd(qf, kf, vf, causal=causal, window=window,
                             softcap=softcap, block_q=block_q,
                             block_k=block_k, interpret=not _on_tpu(),
                             num_q_heads=H)
    return jnp.swapaxes(o.reshape(B, H, Sq, hd), 1, 2)
