"""Fused RMSNorm (+ optional residual add) row kernel.

One VMEM tile of (block_rows x d) per grid step; mean-of-squares, rsqrt and
scale fuse into a single HBM read + write (XLA often emits separate
reduce + multiply passes).  d is padded by the caller to a 128 multiple.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, scale_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(ms + eps) * scale_ref[...].astype(jnp.float32)
    o_ref[...] = y.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("eps", "block_rows",
                                             "interpret"))
def rmsnorm_2d(x, scale, *, eps: float = 1e-6, block_rows: int = 256,
               interpret: bool = False):
    """x: (N, d); scale: (d,)."""
    N, d = x.shape
    block_rows = min(block_rows, N)
    while N % block_rows:
        block_rows //= 2
    kernel = functools.partial(_rmsnorm_kernel, eps=eps)
    return pl.pallas_call(
        kernel,
        grid=(N // block_rows,),
        in_specs=[pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
                  pl.BlockSpec((d,), lambda i: (0,))],
        out_specs=pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((N, d), x.dtype),
        interpret=interpret,
    )(x, scale)
