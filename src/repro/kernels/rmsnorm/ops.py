"""jit'd wrapper: any leading shape, interpret fallback off-TPU."""
from __future__ import annotations

import functools

import jax

from .kernel import rmsnorm_2d


@functools.partial(jax.jit, static_argnames=("eps",))
def rmsnorm(x, scale, *, eps: float = 1e-6):
    shape = x.shape
    x2 = x.reshape(-1, shape[-1])
    y = rmsnorm_2d(x2, scale, eps=eps,
                   interpret=jax.default_backend() != "tpu")
    return y.reshape(shape)
