"""Pallas TPU kernels for the framework's compute hot-spots.

The paper itself has no kernel-level contribution (its kernels come from
open-source suites); these are the perf-critical layers of the *framework*:
flash_attention (blocked online softmax), rwkv6 (WKV recurrence), rmsnorm.
Each package has kernel.py (pl.pallas_call + BlockSpec), ops.py (jit'd
wrapper, interpret-mode fallback off-TPU) and ref.py (pure-jnp oracle).
"""
