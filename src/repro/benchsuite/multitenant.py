"""Multi-tenant contention scenarios (benchsuite companions to suite.py).

The QoS question the priority-weighted runtime must answer: when a small
**latency-sensitive** tenant shares devices with a **bulk throughput**
tenant, does priority weighting protect the former's tail latency without
wrecking aggregate throughput?

:func:`build_contention` constructs exactly that workload:

* the *bulk* tenant issues ``bulk_kernels`` independent, long,
  full-occupancy kernels (priority 0) — enough outstanding work to keep
  every device saturated for the whole episode;
* the *latency* tenant issues ``latency_streams`` sequential chains of
  ``per_stream`` short kernels (one chain ~ one interactive request
  pipeline), tagged ``latency_priority`` when ``use_priority`` is set, else
  priority 0 (the priority-blind baseline).

With weighting on, each latency kernel receives ``w/(w+B)`` of a device
(w = 2**priority, B = concurrent bulk weight) instead of ``1/(1+B)`` —
the chain completes several times sooner while the bulk tenant, which only
cares about aggregate makespan, finishes at essentially the same time
(total work is conserved; the water-fill always hands out full capacity).

Both builders issue plain sequential host code through declared GrFunctions
(the paper's Fig. 4 programming model); tenants, priorities and devices are
call-scoped options — entirely the runtime's business.
"""
from __future__ import annotations

from typing import List

import numpy as np

from ..core import GrScheduler
from ..core.frontend import function

BULK_TENANT = "bulk"
LATENCY_TENANT = "latency"

# Declared once: a full-occupancy in-place bulk kernel and a full-occupancy
# streaming stage; QoS tags/cost attach per call via with_options.
BULK_STAGE = function(None, modes=("inout",), name="mt_bulk",
                      parallel_fraction=1.0)
LATENCY_STAGE = function(None, modes=("const", "out"), name="mt_lat",
                         parallel_fraction=1.0)


def build_contention(sched: GrScheduler, *, bulk_kernels: int = 6,
                     latency_streams: int = 2, per_stream: int = 6,
                     bulk_cost: float = 4e-3, lat_cost: float = 2e-4,
                     n: int = 1 << 16, latency_priority: int = 3,
                     use_priority: bool = True) -> List:
    """Issue the bulk flood first, then the latency tenant's chains."""
    lp = latency_priority if use_priority else 0
    bulk = BULK_STAGE.with_options(scheduler=sched, cost_s=bulk_cost,
                                   priority=0, tenant=BULK_TENANT)
    lat = LATENCY_STAGE.with_options(scheduler=sched, cost_s=lat_cost,
                                     priority=lp, tenant=LATENCY_TENANT)
    outs = []
    for b in range(bulk_kernels):
        x = sched.array(np.zeros(n, np.float32), name=f"mt_bulk{b}")
        bulk.with_options(name=f"mt_bulk_k{b}")(x)
        outs.append(x)
    for s in range(latency_streams):
        x = sched.array(np.zeros(n, np.float32), name=f"mt_lat{s}")
        for k in range(per_stream):
            y = sched.array(shape=(n,), dtype=np.float32,
                            name=f"mt_lat{s}_{k}")
            lat.with_options(name=f"mt_lat_k{s}_{k}")(x, y)
            x = y
        outs.append(x)
    return outs
