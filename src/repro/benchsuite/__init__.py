"""The paper's benchmark suite (§V-B): 6 task-parallel GPU workloads."""
from .costmodel import GPUS, GPUSpec, GTX960, GTX1660S, P100, kernel_cost, occupancy
from .suite import BENCHMARKS, Benchmark, BS, DL, HITS, IMG, ML, VEC

__all__ = ["BENCHMARKS", "Benchmark", "VEC", "BS", "IMG", "ML", "HITS", "DL",
           "GPUS", "GPUSpec", "P100", "GTX1660S", "GTX960", "kernel_cost",
           "occupancy"]
