"""The paper's benchmark suite (§V-B): 6 task-parallel GPU workloads, plus
multi-device scheduling scenarios (multidevice.py)."""
from .costmodel import GPUS, GPUSpec, GTX960, GTX1660S, P100, kernel_cost, occupancy
from .suite import BENCHMARKS, Benchmark, BS, DL, HITS, IMG, ML, VEC
from .multidevice import build_locality_heavy, build_task_parallel
from .outofcore import build_outofcore, verify_outofcore, working_set_bytes
from .slo import build_slo_workload

__all__ = ["BENCHMARKS", "Benchmark", "VEC", "BS", "IMG", "ML", "HITS", "DL",
           "GPUS", "GPUSpec", "P100", "GTX1660S", "GTX960", "kernel_cost",
           "occupancy", "build_task_parallel", "build_locality_heavy",
           "build_outofcore", "verify_outofcore", "working_set_bytes",
           "build_slo_workload"]
