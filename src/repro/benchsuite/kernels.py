"""JAX implementations of the 33 benchmark kernels (paper §V-B).

Kernels are pure functions of the device values of their argument list (in
argument order, including output placeholders) and return the new values of
their writable arguments — the executor installs results into the
ManagedArray handles.  Taken/derived from the open-source suites the paper
cites (CUDA samples, LightSpMV, cuda-gaussian-blur, Kepler reduction post).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax


# ---------------------------------------------------------------- VEC ----
@jax.jit
def k_square(x, _y):
    return x * x


@jax.jit
def k_reduce_diff(y1, y2, _z):
    return jnp.sum(y1 - y2)[None]


# ---------------------------------------------------------------- B&S ----
def _ndtr(x):
    return 0.5 * (1.0 + lax.erf(x / jnp.sqrt(jnp.asarray(2.0, x.dtype))))


@jax.jit
def k_black_scholes(s, _out):
    """European call, CUDA-samples parameterization (double precision)."""
    dt = s.dtype
    K = jnp.asarray(60.0, dt)
    r = jnp.asarray(0.035, dt)
    sigma = jnp.asarray(0.2, dt)
    T = jnp.asarray(1.0, dt)
    sqrt_t = jnp.sqrt(T)
    d1 = (jnp.log(s / K) + (r + 0.5 * sigma * sigma) * T) / (sigma * sqrt_t)
    d2 = d1 - sigma * sqrt_t
    return s * _ndtr(d1) - K * jnp.exp(-r * T) * _ndtr(d2)


# ---------------------------------------------------------------- IMG ----
def _gauss_kernel(ksize: int, sigma: float) -> np.ndarray:
    ax = np.arange(ksize) - (ksize - 1) / 2.0
    g = np.exp(-(ax ** 2) / (2.0 * sigma ** 2))
    k2 = np.outer(g, g)
    return (k2 / k2.sum()).astype(np.float32)


def _conv2d_same(img, kern):
    """img: (H, W); kern: (k, k) — SAME padding, NCHW conv underneath."""
    x = img[None, None]
    w = kern[None, None]
    y = lax.conv_general_dilated(x, w, window_strides=(1, 1), padding="SAME")
    return y[0, 0]


@functools.partial(jax.jit, static_argnames=("ksize", "sigma"))
def k_gaussian_blur(img, _out, *, ksize: int, sigma: float):
    kern = jnp.asarray(_gauss_kernel(ksize, sigma))
    return _conv2d_same(img, kern)


@jax.jit
def k_sobel(img, _out):
    gx = jnp.asarray([[-1, 0, 1], [-2, 0, 2], [-1, 0, 1]], jnp.float32)
    gy = gx.T
    ex = _conv2d_same(img, gx)
    ey = _conv2d_same(img, gy)
    g = jnp.sqrt(ex * ex + ey * ey)
    return g / (jnp.max(g) + 1e-6)


@jax.jit
def k_extend_mask(mask, _out):
    """Dilate + normalize the edge mask (paper's `extend` kernel)."""
    m = lax.reduce_window(mask, -jnp.inf, lax.max, (5, 5), (1, 1), "SAME")
    lo, hi = jnp.min(m), jnp.max(m)
    return (m - lo) / (hi - lo + 1e-6)


@jax.jit
def k_unsharpen(img, blur, _out):
    return jnp.clip(img + 0.5 * (img - blur), 0.0, 1.0)


@jax.jit
def k_combine(sharp, blur_med, mask, _out):
    return sharp * mask + blur_med * (1.0 - mask)


@jax.jit
def k_combine_low(comb, blur_low, mask, _out):
    return comb * mask + blur_low * (1.0 - mask)


# ----------------------------------------------------------------- ML ----
@jax.jit
def k_nb_scores(x, feat_logprob, class_logprior, _out):
    """Categorical Naive-Bayes log-posteriors — the tall-matrix low-IPC
    kernel of §V-F (rows >> classes)."""
    return x @ feat_logprob.T + class_logprior[None, :]


@jax.jit
def k_ridge_scores(x, w, b, _out):
    return x @ w.T + b[None, :]


@jax.jit
def k_softmax_norm(scores, _out):
    m = jnp.max(scores, axis=1, keepdims=True)
    e = jnp.exp(scores - m)
    return e / jnp.sum(e, axis=1, keepdims=True)


@jax.jit
def k_ensemble_avg(p1, p2, _out):
    return jnp.argmax(0.5 * (p1 + p2), axis=1).astype(jnp.int32)


# --------------------------------------------------------------- HITS ----
@jax.jit
def k_spmv(vals, cols, rows, x, _y):
    """CSR-ish SpMV (COO row index + segment_sum), LightSpMV-derived."""
    n = _y.shape[0]
    return jax.ops.segment_sum(vals * x[cols], rows, num_segments=n)


@jax.jit
def k_l2_norm(x, _out):
    return jnp.sqrt(jnp.sum(x * x))[None]


@jax.jit
def k_divide(x, norm, _out):
    return x / (norm[0] + 1e-12)


# ----------------------------------------------------------------- DL ----
@functools.partial(jax.jit, static_argnames=("stride",))
def k_conv_relu_pool(x, w, _out, *, stride: int = 1):
    """x: (N,C,H,W), w: (O,C,k,k) -> conv + relu + 2x2 maxpool."""
    y = lax.conv_general_dilated(x, w, (stride, stride), "SAME")
    y = jnp.maximum(y, 0.0)
    return lax.reduce_window(y, -jnp.inf, lax.max, (1, 1, 2, 2),
                             (1, 1, 2, 2), "VALID")


@jax.jit
def k_dense_embed(x, w, _out):
    flat = x.reshape((x.shape[0], -1))
    return jnp.tanh(flat @ w)


@jax.jit
def k_concat_dense(e1, e2, w, _out):
    h = jnp.concatenate([e1, e2], axis=1) @ w
    return 1.0 / (1.0 + jnp.exp(-h))


# ======================================================================
# Declared GrFunctions (the polyglot frontend surface, paper §III-IV)
# ======================================================================
# Access modes are declared exactly once, here with the kernel; the
# benchmark builders then call these like plain functions — per-call
# const/out annotation boilerplate is gone.  ``with_options`` attaches the
# per-call cost model / occupancy / display name without forking identity.
from ..core.frontend import function as _gr_function

SQUARE = _gr_function(k_square, modes=("const", "out"), outputs=0,
                      name="SQ")
REDUCE_DIFF = _gr_function(k_reduce_diff, modes=("const", "const", "out"),
                           name="RED")
BLACK_SCHOLES = _gr_function(k_black_scholes, modes=("const", "out"),
                             outputs=0, name="BS")
BLUR_S = _gr_function(functools.partial(k_gaussian_blur, ksize=3, sigma=1.0),
                      modes=("const", "out"), name="BLUR_S")
BLUR_M = _gr_function(functools.partial(k_gaussian_blur, ksize=7, sigma=2.5),
                      modes=("const", "out"), name="BLUR_M")
BLUR_L = _gr_function(functools.partial(k_gaussian_blur, ksize=13, sigma=5.0),
                      modes=("const", "out"), name="BLUR_L")
SOBEL = _gr_function(k_sobel, modes=("const", "out"), name="SOBEL")
EXTEND_MASK = _gr_function(k_extend_mask, modes=("const", "out"),
                           name="EXTEND")
UNSHARPEN = _gr_function(k_unsharpen, modes=("const", "const", "out"),
                         name="UNSHARP")
COMBINE = _gr_function(k_combine, modes=("const", "const", "const", "out"),
                       name="COMBINE")
COMBINE_LOW = _gr_function(k_combine_low,
                           modes=("const", "const", "const", "out"),
                           name="COMBINE_LOW")
NB_SCORES = _gr_function(k_nb_scores,
                         modes=("const", "const", "const", "out"), name="NB")
RIDGE_SCORES = _gr_function(k_ridge_scores,
                            modes=("const", "const", "const", "out"),
                            name="RIDGE")
SOFTMAX_NORM = _gr_function(k_softmax_norm, modes=("const", "out"),
                            name="SOFTMAX")
ENSEMBLE_AVG = _gr_function(k_ensemble_avg, modes=("const", "const", "out"),
                            name="ARGMAX")
SPMV = _gr_function(k_spmv,
                    modes=("const", "const", "const", "const", "out"),
                    name="SPMV",
                    lint_shapes=(((8,), np.float32), ((8,), np.int32),
                                 ((8,), np.int32), ((8,), np.float32),
                                 ((8,), np.float32)))
L2_NORM = _gr_function(k_l2_norm, modes=("const", "out"), name="NORM")
# DIVIDE never reads the prior value of its destination (pure x/norm
# store); ``inout`` here forced a spurious prefetch of dead data.  The
# WAR edges against this iteration's SpMV readers come from the *write*
# and are identical under ``out``.
DIVIDE = _gr_function(k_divide, modes=("const", "const", "out"),
                      name="DIV")
CONV_RELU_POOL = _gr_function(k_conv_relu_pool,
                              modes=("const", "const", "out"), name="CONV",
                              lint_shapes=(((1, 1, 8, 8), np.float32),
                                           ((1, 1, 3, 3), np.float32),
                                           ((1, 1, 4, 4), np.float32)))
DENSE_EMBED = _gr_function(k_dense_embed, modes=("const", "const", "out"),
                           name="DENSE")
CONCAT_DENSE = _gr_function(k_concat_dense,
                            modes=("const", "const", "const", "out"),
                            name="HEAD",
                            lint_shapes=(((8, 4), np.float32),
                                         ((8, 4), np.float32),
                                         ((8, 1), np.float32),
                                         ((8, 1), np.float32)))
