"""Deadline/SLO contention scenario (benchsuite companion to
multitenant.py).

The tail-latency question the deadline-aware runtime must answer: when a
**latency tenant** with a per-launch deadline shares one device — compute
capacity *and* the H2D copy engine — with a quota-folded **bulk tenant**
whose lanes hold deep queues of large uploads and long kernels, do EDF
ordering and element-boundary preemption bound the latency tenant's p99
without wrecking the bulk tenant's makespan?

:func:`build_slo_workload` constructs exactly that adversarial mix:

* the *bulk* tenant issues ``bulk_units`` upload+process stages (a fresh
  ``bulk_mb``-sized host array H2D'd then consumed by a long full-occupancy
  kernel).  Run it under a ``tenant_quotas={"bulk": 2}`` scheduler: the
  flood folds onto two lanes, so at any instant two bulk tasks are started
  (holding the copy engine / device) while the rest sit *queued* — exactly
  the state element-boundary preemption can act on;
* the *latency* tenant then issues ``latency_chains`` sequential chains of
  ``per_chain`` short kernels, each chain fed by a small host upload.  With
  ``use_deadlines`` every latency launch carries ``deadline_s``; without it
  the chains are plain priority-0 work (the PR 7 baseline — both tenants
  equal priority, so priority weighting cannot help).

Without deadlines a chain's first upload queues behind the bulk uploads
already handed to the FIFO copy engine and its kernels water-fill against
the running bulk kernels — p99 is set by the bulk tenant's queue depth.
With deadlines the chain EDF-ranks first for device capacity, and when its
slack runs low the monitor pauses the bulk lanes' *queued* elements at the
next element boundary, so the engine and device drain to the urgent
frontier.  Total bulk work is conserved (the paused elements would have
received no capacity anyway), so the bulk makespan moves by at most the
pause windows where its lanes sit idle.

Both tenants are priority 0 throughout: every improvement measured on this
workload is attributable to the deadline machinery alone.
"""
from __future__ import annotations

from typing import List, Optional

import numpy as np

from ..core import GrScheduler
from ..core.frontend import function

BULK_TENANT = "bulk"
LATENCY_TENANT = "latency"

# Declared once: a full-occupancy bulk consumer and a full-occupancy latency
# stage; cost, tenant and deadline attach per call via with_options.
SLO_BULK = function(None, modes=("inout",), name="slo_bulk",
                    parallel_fraction=1.0)
SLO_LAT = function(None, modes=("const", "out"), name="slo_lat",
                   parallel_fraction=1.0)


def build_slo_workload(sched: GrScheduler, *, bulk_units: int = 32,
                       latency_chains: int = 2, per_chain: int = 4,
                       bulk_mb: float = 2.0, bulk_cost: float = 1e-3,
                       lat_cost: float = 1.5e-4, lat_kb: int = 64,
                       deadline_s: Optional[float] = 2.5e-3,
                       use_deadlines: bool = True) -> List:
    """Issue the bulk flood, then the latency tenant's deadline'd chains.

    ``deadline_s`` applies to every latency launch when ``use_deadlines``
    is set; pass ``use_deadlines=False`` for the deadline-blind baseline
    (identical workload, no deadline tags).  Returns the output arrays so
    callers can extend the episode or force a drain."""
    bulk_n = max(1, int(bulk_mb * (1 << 20)) // 4)
    lat_n = max(1, (lat_kb << 10) // 4)
    bulk = SLO_BULK.with_options(scheduler=sched, cost_s=bulk_cost,
                                 priority=0, tenant=BULK_TENANT)
    lat = SLO_LAT.with_options(scheduler=sched, cost_s=lat_cost,
                               priority=0, tenant=LATENCY_TENANT)
    if use_deadlines and deadline_s is not None:
        lat = lat.with_options(deadline_s=float(deadline_s))
    outs = []
    for b in range(bulk_units):
        # Fresh host-resident input per unit: each stage costs one large
        # H2D on the FIFO copy engine before its kernel can run.
        x = sched.array(np.zeros(bulk_n, np.float32), name=f"slo_bulk{b}")
        bulk.with_options(name=f"slo_bulk_k{b}")(x)
        outs.append(x)
    for s in range(latency_chains):
        x = sched.array(np.zeros(lat_n, np.float32), name=f"slo_lat{s}")
        for k in range(per_chain):
            y = sched.array(shape=(lat_n,), dtype=np.float32,
                            name=f"slo_lat{s}_{k}")
            lat.with_options(name=f"slo_lat_k{s}_{k}")(x, y)
            x = y
        outs.append(x)
    return outs
