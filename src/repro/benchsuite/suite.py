"""The paper's 6 task-parallel benchmarks (§V-B, Fig. 6) as GrJAX programs.

Each benchmark issues plain sequential host code through the declared
GrFunctions in ``kernels.py`` — no streams, no events, no per-call access
annotations — exactly the programming model of Fig. 4.  The runtime infers
the DAG; the per-call cost model (sim mode) rides along via
``with_options``.

Benchmarks run in two modes:
* **real** (``gpu=None``): kernels execute on the local JAX backend; used by
  correctness tests (parallel scheduling must equal sequential semantics);
* **simulated** (``gpu=GPUSpec``): per-kernel solo costs/occupancies from the
  analytic roofline in `costmodel.py` drive the discrete-event executor to
  produce Fig. 7/8/9/11-style numbers for the paper's three testbed GPUs.
"""
from __future__ import annotations

from typing import Dict, Optional

import numpy as np

from ..core import GrScheduler
from ..core.frontend import GrFunction
from . import kernels as K
from .costmodel import GPUSpec, kernel_cost, occupancy


class Benchmark:
    name: str = "base"
    fp64: bool = False

    # -- helpers --------------------------------------------------------
    def _launch(self, sched: GrScheduler, gf: GrFunction, arrays, name: str,
                *, flops: float, bytes_moved: float, gpu: Optional[GPUSpec],
                fp64: bool = False, parallelism: float = 1.0):
        """Call one declared GrFunction (access modes live with the
        declaration); in sim mode the analytic cost model is attached as a
        call-scoped option."""
        opts = {"scheduler": sched, "name": name}
        if gpu is not None:
            opts["cost_s"] = kernel_cost(gpu, flops, bytes_moved, fp64)
            opts["parallel_fraction"] = occupancy(gpu, flops, bytes_moved,
                                                  fp64, parallelism)
        return gf.with_options(**opts)(*arrays)

    # -- interface -------------------------------------------------------
    def sizes(self, scale: float) -> dict:
        raise NotImplementedError

    def make_data(self, scale: float, seed: int = 0) -> Dict[str, np.ndarray]:
        raise NotImplementedError

    def build(self, sched: GrScheduler, data, gpu: Optional[GPUSpec] = None,
              iters: int = 2) -> Dict[str, np.ndarray]:
        raise NotImplementedError

    def run_reference(self, data, iters: int = 2) -> Dict[str, np.ndarray]:
        raise NotImplementedError

    def footprint_bytes(self, scale: float) -> int:
        data = self.make_data(scale)
        return sum(v.nbytes for v in data.values())


# ======================================================================
class VEC(Benchmark):
    """Vector Squares: sum of differences of two squared vectors; fresh
    input every iteration (streaming) — speedup comes purely from
    transfer/compute overlap (Fig. 11)."""

    name = "VEC"

    def sizes(self, scale):
        return {"n": max(64, int(25_000_000 * scale))}

    def make_data(self, scale, seed=0):
        n = self.sizes(scale)["n"]
        rng = np.random.RandomState(seed)
        return {"x1": rng.rand(n).astype(np.float32) + 0.5,
                "x2": rng.rand(n).astype(np.float32) + 0.5}

    def build(self, sched, data, gpu=None, iters=2):
        n = data["x1"].shape[0]
        zs = []
        for it in range(iters):
            x1 = sched.array(np.roll(data["x1"], it), name=f"x1_{it}")
            x2 = sched.array(np.roll(data["x2"], it), name=f"x2_{it}")
            y1 = sched.array(shape=(n,), dtype=np.float32, name=f"y1_{it}")
            y2 = sched.array(shape=(n,), dtype=np.float32, name=f"y2_{it}")
            z = sched.array(shape=(1,), dtype=np.float32, name=f"z_{it}")
            self._launch(sched, K.SQUARE, [x1, y1], "SQ1",
                         flops=n, bytes_moved=8 * n, gpu=gpu)
            self._launch(sched, K.SQUARE, [x2, y2], "SQ2",
                         flops=n, bytes_moved=8 * n, gpu=gpu)
            self._launch(sched, K.REDUCE_DIFF, [y1, y2, z], "RED",
                         flops=2 * n, bytes_moved=8 * n, gpu=gpu,
                         parallelism=0.5)
            zs.append(float(z[0]) if gpu is None else 0.0)
        sched.sync()
        return {"z": np.asarray(zs, np.float32)}

    def run_reference(self, data, iters=2):
        zs = []
        for it in range(iters):
            x1, x2 = np.roll(data["x1"], it), np.roll(data["x2"], it)
            zs.append(np.sum(x1.astype(np.float64) ** 2
                             - x2.astype(np.float64) ** 2))
        return {"z": np.asarray(zs, np.float32)}


# ======================================================================
class BS(Benchmark):
    """Black & Scholes on 10 independent price vectors (double precision);
    many independent kernels -> space-sharing + transfer pipelining."""

    name = "B&S"
    fp64 = True
    n_stocks = 10

    def sizes(self, scale):
        return {"n": max(64, int(2_500_000 * scale)), "stocks": self.n_stocks}

    def make_data(self, scale, seed=0):
        n = self.sizes(scale)["n"]
        rng = np.random.RandomState(seed)
        return {f"s{i}": (rng.rand(n) * 100 + 20).astype(np.float64)
                for i in range(self.n_stocks)}

    def build(self, sched, data, gpu=None, iters=2):
        outs = {}
        for it in range(iters):
            res = []
            for i in range(self.n_stocks):
                n = data[f"s{i}"].shape[0]
                s = sched.array(data[f"s{i}"] + it, name=f"s{i}_{it}")
                o = sched.array(shape=(n,), dtype=np.float64, name=f"c{i}_{it}")
                self._launch(sched, K.BLACK_SCHOLES, [s, o],
                             f"BS{i}", flops=150 * n, bytes_moved=16 * n,
                             gpu=gpu, fp64=True)
                res.append(o)
            outs = {f"c{i}": np.asarray(res[i]).copy() if gpu is None
                    else np.zeros(1) for i in range(self.n_stocks)}
        sched.sync()
        return outs

    def run_reference(self, data, iters=2):
        import jax.numpy as jnp
        outs = {}
        it = iters - 1
        for i in range(self.n_stocks):
            s = jnp.asarray(data[f"s{i}"] + it)
            outs[f"c{i}"] = np.asarray(K.k_black_scholes(s, None))
        return outs


# ======================================================================
class IMG(Benchmark):
    """Image pipeline: sharpened picture combined with low/medium-frequency
    blurs through an edge mask — complex DAG on 4 streams (Fig. 6)."""

    name = "IMG"

    def sizes(self, scale):
        side = max(32, int(np.sqrt(6_000_000 * scale)) * 4)
        return {"h": side, "w": side}

    def make_data(self, scale, seed=0):
        s = self.sizes(scale)
        rng = np.random.RandomState(seed)
        return {"img": rng.rand(s["h"], s["w"]).astype(np.float32)}

    def build(self, sched, data, gpu=None, iters=2):
        h, w = data["img"].shape
        hw = h * w
        result = None
        for it in range(iters):
            img = sched.array(data["img"], name=f"img_{it}")
            def mk(nm, it=it):
                return sched.array(shape=(h, w), dtype=np.float32,
                                   name=f"{nm}_{it}")
            b_s, b_m, b_l = mk("bs"), mk("bm"), mk("bl")
            sharp, edges, mask, comb, outp = (mk("sharp"), mk("edges"),
                                              mk("mask"), mk("comb"),
                                              mk("out"))
            self._launch(sched, K.BLUR_S, [img, b_s], "BLUR_S",
                         flops=2 * 9 * hw, bytes_moved=8 * hw, gpu=gpu,
                         parallelism=0.55)
            self._launch(sched, K.BLUR_M, [img, b_m], "BLUR_M",
                         flops=2 * 49 * hw, bytes_moved=8 * hw, gpu=gpu,
                         parallelism=0.55)
            self._launch(sched, K.BLUR_L, [img, b_l], "BLUR_L",
                         flops=2 * 169 * hw, bytes_moved=8 * hw, gpu=gpu,
                         parallelism=0.55)
            self._launch(sched, K.UNSHARPEN, [img, b_s, sharp], "UNSHARP",
                         flops=4 * hw, bytes_moved=12 * hw, gpu=gpu)
            self._launch(sched, K.SOBEL, [sharp, edges], "SOBEL",
                         flops=24 * hw, bytes_moved=8 * hw, gpu=gpu,
                         parallelism=0.55)
            self._launch(sched, K.EXTEND_MASK, [edges, mask],
                         "EXTEND", flops=30 * hw, bytes_moved=8 * hw, gpu=gpu,
                         parallelism=0.55)
            self._launch(sched, K.COMBINE, [sharp, b_m, mask, comb],
                         "COMBINE", flops=5 * hw, bytes_moved=16 * hw, gpu=gpu)
            self._launch(sched, K.COMBINE_LOW, [comb, b_l, mask, outp],
                         "COMBINE_LOW", flops=5 * hw, bytes_moved=16 * hw,
                         gpu=gpu)
            result = outp
        final = np.asarray(result).copy() if gpu is None else np.zeros(1)
        sched.sync()
        return {"out": final}

    def run_reference(self, data, iters=2):
        import jax.numpy as jnp
        img = jnp.asarray(data["img"])
        b_s = K.k_gaussian_blur(img, None, ksize=3, sigma=1.0)
        b_m = K.k_gaussian_blur(img, None, ksize=7, sigma=2.5)
        b_l = K.k_gaussian_blur(img, None, ksize=13, sigma=5.0)
        sharp = K.k_unsharpen(img, b_s, None)
        edges = K.k_sobel(sharp, None)
        mask = K.k_extend_mask(edges, None)
        comb = K.k_combine(sharp, b_m, mask, None)
        outp = K.k_combine_low(comb, b_l, mask, None)
        return {"out": np.asarray(outp)}


# ======================================================================
class ML(Benchmark):
    """NB + Ridge ensemble on a shared read-only input matrix: branch
    imbalance (NB is a slow tall-matrix kernel) + const-argument sharing."""

    name = "ML"
    n_features = 200
    n_classes = 10

    def sizes(self, scale):
        return {"rows": max(32, int(1_200_000 * scale)),
                "features": self.n_features, "classes": self.n_classes}

    def make_data(self, scale, seed=0):
        s = self.sizes(scale)
        rng = np.random.RandomState(seed)
        return {
            "x": rng.rand(s["rows"], s["features"]).astype(np.float32),
            "feat_logprob": rng.randn(s["classes"], s["features"]).astype(np.float32) * 0.1,
            "logprior": rng.randn(s["classes"]).astype(np.float32) * 0.1,
            "w": rng.randn(s["classes"], s["features"]).astype(np.float32) * 0.1,
            "b": rng.randn(s["classes"]).astype(np.float32) * 0.1,
        }

    def build(self, sched, data, gpu=None, iters=2):
        n, f = data["x"].shape
        c = data["w"].shape[0]
        res = None
        for it in range(iters):
            x = sched.array(data["x"], name=f"x_{it}")
            flp = sched.array(data["feat_logprob"], name=f"flp_{it}")
            lp = sched.array(data["logprior"], name=f"lp_{it}")
            wr = sched.array(data["w"], name=f"w_{it}")
            br = sched.array(data["b"], name=f"b_{it}")
            s1 = sched.array(shape=(n, c), dtype=np.float32, name=f"s1_{it}")
            s2 = sched.array(shape=(n, c), dtype=np.float32, name=f"s2_{it}")
            p1 = sched.array(shape=(n, c), dtype=np.float32, name=f"p1_{it}")
            p2 = sched.array(shape=(n, c), dtype=np.float32, name=f"p2_{it}")
            pred = sched.array(shape=(n,), dtype=np.int32, name=f"pred_{it}")
            mm_fl, mm_by = 2 * n * f * c, 4 * (n * f + f * c + n * c)
            # NB: tall-matrix low-occupancy kernel (low IPC, §V-F) — slower.
            self._launch(sched, K.NB_SCORES, [x, flp, lp, s1], "NB",
                         flops=4 * mm_fl, bytes_moved=2 * mm_by, gpu=gpu,
                         parallelism=0.25)
            self._launch(sched, K.RIDGE_SCORES, [x, wr, br, s2], "RIDGE",
                         flops=mm_fl, bytes_moved=mm_by, gpu=gpu,
                         parallelism=0.8)
            self._launch(sched, K.SOFTMAX_NORM, [s1, p1],
                         "SOFTMAX1", flops=5 * n * c, bytes_moved=8 * n * c,
                         gpu=gpu, parallelism=0.7)
            self._launch(sched, K.SOFTMAX_NORM, [s2, p2],
                         "SOFTMAX2", flops=5 * n * c, bytes_moved=8 * n * c,
                         gpu=gpu, parallelism=0.7)
            self._launch(sched, K.ENSEMBLE_AVG, [p1, p2, pred], "ARGMAX",
                         flops=3 * n * c, bytes_moved=4 * n * c + 4 * n,
                         gpu=gpu)
            res = pred
        final = np.asarray(res).copy() if gpu is None else np.zeros(1)
        sched.sync()
        return {"pred": final}

    def run_reference(self, data, iters=2):
        import jax.numpy as jnp
        x = jnp.asarray(data["x"])
        s1 = K.k_nb_scores(x, jnp.asarray(data["feat_logprob"]),
                           jnp.asarray(data["logprior"]), None)
        s2 = K.k_ridge_scores(x, jnp.asarray(data["w"]),
                              jnp.asarray(data["b"]), None)
        p1 = K.k_softmax_norm(s1, None)
        p2 = K.k_softmax_norm(s2, None)
        return {"pred": np.asarray(K.k_ensemble_avg(p1, p2, None))}


# ======================================================================
class HITS(Benchmark):
    """HITS on a random graph via repeated SpMV on A and A^T, double-buffered
    — the two chains cross-synchronize every iteration (Fig. 6)."""

    name = "HITS"

    def sizes(self, scale):
        n = max(64, int(1_300_000 * scale))
        return {"n": n, "nnz": 20 * n}

    def make_data(self, scale, seed=0):
        s = self.sizes(scale)
        rng = np.random.RandomState(seed)
        n, nnz = s["n"], s["nnz"]
        rows = np.sort(rng.randint(0, n, size=nnz)).astype(np.int32)
        cols = rng.randint(0, n, size=nnz).astype(np.int32)
        vals = np.ones(nnz, np.float32)
        # transpose: swap row/col, sort by new row
        order = np.argsort(cols, kind="stable")
        return {"rows": rows, "cols": cols, "vals": vals,
                "t_rows": cols[order].copy(), "t_cols": rows[order].copy(),
                "t_vals": vals[order].copy()}

    def build(self, sched, data, gpu=None, iters=2):
        n = int(max(data["rows"].max(), data["cols"].max())) + 1
        nnz = data["vals"].shape[0]
        g = {k: sched.array(v, name=k) for k, v in data.items()}
        hub = sched.array(np.ones(n, np.float32), name="hub")
        auth = sched.array(np.ones(n, np.float32), name="auth")
        a_new = sched.array(shape=(n,), dtype=np.float32, name="a_new")
        h_new = sched.array(shape=(n,), dtype=np.float32, name="h_new")
        a_nrm = sched.array(shape=(1,), dtype=np.float32, name="a_nrm")
        h_nrm = sched.array(shape=(1,), dtype=np.float32, name="h_nrm")
        spmv_fl, spmv_by = 2 * nnz, 12 * nnz + 8 * n
        for _it in range(iters):
            # a' = A^T h ; h' = A a   (read previous iterates concurrently)
            self._launch(sched, K.SPMV,
                         [g["t_vals"], g["t_cols"], g["t_rows"], hub, a_new],
                         "SPMV_AT", flops=spmv_fl, bytes_moved=spmv_by,
                         gpu=gpu, parallelism=0.6)
            self._launch(sched, K.SPMV,
                         [g["vals"], g["cols"], g["rows"], auth, h_new],
                         "SPMV_A", flops=spmv_fl, bytes_moved=spmv_by,
                         gpu=gpu, parallelism=0.6)
            self._launch(sched, K.L2_NORM, [a_new, a_nrm],
                         "NORM_A", flops=2 * n, bytes_moved=4 * n, gpu=gpu,
                         parallelism=0.4)
            self._launch(sched, K.L2_NORM, [h_new, h_nrm],
                         "NORM_H", flops=2 * n, bytes_moved=4 * n, gpu=gpu,
                         parallelism=0.4)
            # writes back into `auth`/`hub` (declared out on DIVIDE — the
            # destination's prior value is never read): WAR with this
            # iteration's SpMVs
            self._launch(sched, K.DIVIDE, [a_new, a_nrm, auth], "DIV_A",
                         flops=n, bytes_moved=8 * n, gpu=gpu)
            self._launch(sched, K.DIVIDE, [h_new, h_nrm, hub], "DIV_H",
                         flops=n, bytes_moved=8 * n, gpu=gpu)
        outs = {"auth": np.asarray(auth).copy() if gpu is None else np.zeros(1),
                "hub": np.asarray(hub).copy() if gpu is None else np.zeros(1)}
        sched.sync()
        return outs

    def run_reference(self, data, iters=2):
        import jax.numpy as jnp
        n = int(max(data["rows"].max(), data["cols"].max())) + 1
        hub = jnp.ones(n, jnp.float32)
        auth = jnp.ones(n, jnp.float32)
        for _ in range(iters):
            a_new = K.k_spmv(jnp.asarray(data["t_vals"]),
                             jnp.asarray(data["t_cols"]),
                             jnp.asarray(data["t_rows"]), hub,
                             jnp.zeros(n, jnp.float32))
            h_new = K.k_spmv(jnp.asarray(data["vals"]),
                             jnp.asarray(data["cols"]),
                             jnp.asarray(data["rows"]), auth,
                             jnp.zeros(n, jnp.float32))
            auth = K.k_divide(a_new, K.k_l2_norm(a_new, None), None)
            hub = K.k_divide(h_new, K.k_l2_norm(h_new, None), None)
        return {"auth": np.asarray(auth), "hub": np.asarray(hub)}


# ======================================================================
class DL(Benchmark):
    """Siamese CNN: two conv towers with shared (read-only) weights project
    two images to embeddings combined by a dense layer."""

    name = "DL"
    c1, c2, emb = 8, 16, 32

    def sizes(self, scale):
        side = max(16, int(np.sqrt(2_000_000 * scale)) * 2)
        return {"side": side, "batch": 4}

    def make_data(self, scale, seed=0):
        s = self.sizes(scale)
        rng = np.random.RandomState(seed)
        side, b = s["side"], s["batch"]
        flat = self.c2 * (side // 4) * (side // 4)
        return {
            "img1": rng.rand(b, 1, side, side).astype(np.float32),
            "img2": rng.rand(b, 1, side, side).astype(np.float32),
            "w1": (rng.randn(self.c1, 1, 3, 3) * 0.2).astype(np.float32),
            "w2": (rng.randn(self.c2, self.c1, 3, 3) * 0.1).astype(np.float32),
            "wd": (rng.randn(flat, self.emb) * 0.05).astype(np.float32),
            "wo": (rng.randn(2 * self.emb, 1) * 0.2).astype(np.float32),
        }

    def build(self, sched, data, gpu=None, iters=2):
        b, _, side, _ = data["img1"].shape
        flat = self.c2 * (side // 4) * (side // 4)
        res = None
        for it in range(iters):
            w1 = sched.array(data["w1"], name=f"w1_{it}")
            w2 = sched.array(data["w2"], name=f"w2_{it}")
            wd = sched.array(data["wd"], name=f"wd_{it}")
            wo = sched.array(data["wo"], name=f"wo_{it}")
            embs = []
            for t in (1, 2):
                x = sched.array(data[f"img{t}"], name=f"img{t}_{it}")
                h1 = sched.array(shape=(b, self.c1, side // 2, side // 2),
                                 dtype=np.float32, name=f"h1_{t}_{it}")
                h2 = sched.array(shape=(b, self.c2, side // 4, side // 4),
                                 dtype=np.float32, name=f"h2_{t}_{it}")
                e = sched.array(shape=(b, self.emb), dtype=np.float32,
                                name=f"e{t}_{it}")
                hw = side * side
                self._launch(sched, K.CONV_RELU_POOL,
                             [x, w1, h1], f"CONV1_{t}",
                             flops=2 * b * self.c1 * 9 * hw,
                             bytes_moved=4 * b * (hw + self.c1 * hw // 4),
                             gpu=gpu, parallelism=0.65)
                self._launch(sched, K.CONV_RELU_POOL,
                             [h1, w2, h2], f"CONV2_{t}",
                             flops=2 * b * self.c2 * self.c1 * 9 * hw // 4,
                             bytes_moved=4 * b * self.c1 * hw // 2, gpu=gpu,
                             parallelism=0.65)
                self._launch(sched, K.DENSE_EMBED,
                             [h2, wd, e], f"DENSE_{t}",
                             flops=2 * b * flat * self.emb,
                             bytes_moved=4 * (b * flat + flat * self.emb),
                             gpu=gpu, parallelism=0.4)
                embs.append(e)
            p = sched.array(shape=(b, 1), dtype=np.float32, name=f"p_{it}")
            self._launch(sched, K.CONCAT_DENSE,
                         [embs[0], embs[1], wo, p],
                         "HEAD", flops=2 * b * 2 * self.emb,
                         bytes_moved=4 * b * 2 * self.emb, gpu=gpu,
                         parallelism=0.2)
            res = p
        final = np.asarray(res).copy() if gpu is None else np.zeros(1)
        sched.sync()
        return {"p": final}

    def run_reference(self, data, iters=2):
        import jax.numpy as jnp
        embs = []
        for t in (1, 2):
            x = jnp.asarray(data[f"img{t}"])
            h1 = K.k_conv_relu_pool(x, jnp.asarray(data["w1"]), None)
            h2 = K.k_conv_relu_pool(h1, jnp.asarray(data["w2"]), None)
            embs.append(K.k_dense_embed(h2, jnp.asarray(data["wd"]), None))
        p = K.k_concat_dense(embs[0], embs[1], jnp.asarray(data["wo"]), None)
        return {"p": np.asarray(p)}


BENCHMARKS = {b.name: b for b in (VEC(), BS(), IMG(), ML(), HITS(), DL())}
