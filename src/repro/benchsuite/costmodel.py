"""Analytic kernel cost model for the discrete-event simulator.

Per-kernel solo times are derived from a roofline over the paper's three
testbed GPUs (§V-A): ``t = max(flops/peak, bytes/bw) + launch latency``.
The per-kernel ``parallel_fraction`` (device occupancy while running solo)
determines how much head-room space-sharing can exploit (Fig. 9/12).

The simulator compares *schedules*, so what matters is the relative magnitude
of transfer vs. compute and the dependency structure — both of which come
from the benchmark definitions, not from this table.
"""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class GPUSpec:
    name: str
    fp32_tflops: float
    fp64_tflops: float
    mem_gbps: float            # device memory bandwidth
    pcie_gbps: float           # effective host link bandwidth (per direction)
    mem_gb: float
    launch_latency_s: float = 3e-6
    # Effective UM demand-migration bandwidth.  Pascal+ GPUs serve UM through
    # the page-fault controller when data is not prefetched — the serial
    # GrCUDA scheduler (no prefetching) pays this price (§V-C); pre-Pascal
    # GPUs (GTX 960) always transfer explicitly at full PCIe bandwidth.
    um_fault_gbps: float = 0.0     # 0 -> no page-fault mechanism

    @property
    def page_faults(self) -> bool:
        return self.um_fault_gbps > 0


# The paper's three testbeds (§V-A).
P100 = GPUSpec("P100", fp32_tflops=9.3, fp64_tflops=4.7, mem_gbps=732.0,
               pcie_gbps=12.0, mem_gb=12.0, um_fault_gbps=7.6)
GTX1660S = GPUSpec("GTX1660Super", fp32_tflops=5.0, fp64_tflops=0.157,
                   mem_gbps=336.0, pcie_gbps=12.0, mem_gb=6.0,
                   um_fault_gbps=9.5)
GTX960 = GPUSpec("GTX960", fp32_tflops=2.4, fp64_tflops=0.075, mem_gbps=112.0,
                 pcie_gbps=12.0, mem_gb=2.0)

GPUS = {g.name: g for g in (P100, GTX1660S, GTX960)}


def kernel_cost(gpu: GPUSpec, flops: float, bytes_moved: float,
                fp64: bool = False) -> float:
    peak = (gpu.fp64_tflops if fp64 else gpu.fp32_tflops) * 1e12
    t_compute = flops / peak
    t_memory = bytes_moved / (gpu.mem_gbps * 1e9)
    return max(t_compute, t_memory) + gpu.launch_latency_s


# Global occupancy multiplier: benchmarks set this to ~0 to simulate the
# contention-free bound of Fig. 9 (every kernel computes at solo speed even
# when overlapped).
OCCUPANCY_SCALE = 1.0


def occupancy(gpu: GPUSpec, flops: float, bytes_moved: float,
              fp64: bool = False, parallelism: float = 1.0) -> float:
    """Estimate the device fraction a kernel occupies while running solo.

    A kernel *saturating* its bottleneck resource (bandwidth or FLOPs) cannot
    space-share for free — concurrent saturating kernels merely time-slice
    (Fig. 9: B&S at 15-20 % of the contention-free bound).  Head-room exists
    when a kernel underutilizes its bottleneck: ``parallelism`` < 1 encodes
    structural underutilization (tall matrices / low IPC, shared-memory-tiled
    stencils, irregular SpMV, tiny launches — §V-F), and launch latency makes
    very small kernels nearly free to overlap.  Clamped to [0.1, 1.0].
    """
    peak = (gpu.fp64_tflops if fp64 else gpu.fp32_tflops) * 1e12
    t_c = flops / peak
    t_m = bytes_moved / (gpu.mem_gbps * 1e9)
    t_busy = max(t_c, t_m)
    frac = t_busy / (t_busy + gpu.launch_latency_s)
    frac *= parallelism * OCCUPANCY_SCALE
    return float(min(1.0, max(0.01, frac)))


def sim_hardware(gpu: GPUSpec, policy: str, prefetch: bool = True):
    """Host-link model for a policy: the parallel scheduler prefetches at
    full PCIe bandwidth; the serial scheduler on page-fault GPUs pays
    demand-migration bandwidth (§V-C).  ``prefetch=False`` reproduces the
    paper's prefetch-disabled ablation (page-fault controller becomes the
    bottleneck)."""
    from ..core import SimHardware
    demand = gpu.page_faults and (policy == "serial" or not prefetch)
    bw = gpu.um_fault_gbps if demand else gpu.pcie_gbps
    return SimHardware(h2d_gbps=bw, d2h_gbps=gpu.pcie_gbps)
