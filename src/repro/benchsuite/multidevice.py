"""Multi-device scheduling scenarios (benchsuite companions to suite.py).

Two synthetic DAG shapes that isolate the two questions the multi-device
runtime must answer:

* :func:`build_task_parallel` — independent kernel chains with no shared
  data.  An N-device scheduler should approach N× speedup over one device;
  any placement policy works because there is nothing to misplace *within*
  a chain once it starts (affinity keeps each chain pinned, the others pay
  D2D migrations on every hop they scatter).
* :func:`build_locality_heavy` — groups of kernels that repeatedly update
  their own group's arrays.  Placement that ignores data location
  (round-robin) bounces every array between devices — one D2D per scattered
  hop — while data-affinity placement keeps each group on the device that
  owns its arrays and inserts (almost) no D2D traffic.

Both builders issue plain sequential host code through declared GrFunctions,
the programming model of the paper's Fig. 4 — devices, lanes and D2D copies
are entirely the runtime's business.
"""
from __future__ import annotations

from typing import List

import numpy as np

from ..core import GrScheduler
from ..core.frontend import function

# Declared once; cost and display names attach per call.
CHAIN_STAGE = function(None, modes=("const", "out"), name="td_k",
                       parallel_fraction=1.0)
INPLACE_STAGE = function(None, modes=("inout",), name="loc_k",
                         parallel_fraction=1.0)


def build_task_parallel(sched: GrScheduler, *, branches: int = 4,
                        chain: int = 4, n: int = 1 << 20,
                        cost_s: float = 1e-3) -> List:
    """``branches`` independent chains of ``chain`` kernels each.

    Each kernel fully occupies its device (``parallel_fraction=1.0``) so
    intra-device space-sharing cannot hide the serialization — speedup must
    come from using more devices.
    """
    stage = CHAIN_STAGE.with_options(scheduler=sched, cost_s=cost_s)
    outs = []
    for b in range(branches):
        x = sched.array(np.zeros(n, np.float32), name=f"td_x{b}")
        for k in range(chain):
            y = sched.array(shape=(n,), dtype=np.float32,
                            name=f"td_y{b}_{k}")
            stage.with_options(name=f"td_k{b}_{k}")(x, y)
            x = y
        outs.append(x)
    return outs


def build_locality_heavy(sched: GrScheduler, *, groups: int = 4,
                         iters: int = 6, n: int = 1 << 20,
                         cost_s: float = 5e-4) -> List:
    """``groups`` arrays, each updated in place ``iters`` times.

    Every kernel reads and writes only its group's array, so the DAG is
    ``groups`` independent sequential chains over *persistent* data — the
    worst case for location-blind placement (each scattered hop drags the
    array across the link) and the best case for data affinity.
    """
    stage = INPLACE_STAGE.with_options(scheduler=sched, cost_s=cost_s)
    outs = []
    for g in range(groups):
        x = sched.array(np.zeros(n, np.float32), name=f"loc_x{g}")
        for it in range(iters):
            stage.with_options(name=f"loc_k{g}_{it}")(x)
        outs.append(x)
    return outs
