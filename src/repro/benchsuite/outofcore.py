"""Out-of-core scenario (benchsuite companion to suite.py).

The memory question the budgeted runtime must answer: when a workload's
working set exceeds the device's byte budget, does the transparent
spill/evict machinery keep it *running correctly* at a bounded slowdown —
instead of the unhandled-OOM it used to be?

:func:`build_outofcore` constructs a two-pass streaming pipeline over
``chunks`` independent data chunks:

* pass 1 maps every input chunk ``x[i]`` to an intermediate ``y[i]``
  (device-only output — spilling it later costs a real D2H write-back);
* pass 2 maps every ``y[i]`` to a final ``z[i]``, re-touching the
  intermediates in order, so chunks evicted under pressure must be
  reloaded (H2D after the spill's D2H — the thrash pattern an LRU policy
  must survive).

Total allocated bytes are ``3 * chunks * chunk_bytes``; running with
``budget = working_set_bytes(...) // 2`` (the ISSUE's working set ≈ 2×
budget point) forces evictions while every single element's own working
set (2 chunks) stays far below the budget.  Kernel cost is set so compute
dominates the spill traffic: the acceptance criterion is makespan ≤ 2×
the unlimited-budget run with ≥ 1 recorded spill.

Like every benchsuite scenario, the host code is plain sequential calls
through one declared GrFunction — budgets, spills and reloads are
entirely the runtime's business.
"""
from __future__ import annotations

from typing import Dict, List

import numpy as np

from ..core import GrScheduler
from ..core.frontend import function


def _stage_fn(x, o):
    return x * 2.0 + 1.0


# Declared once: an elementwise streaming stage, full occupancy; per-call
# cost (sim mode) attaches via with_options.
OOC_STAGE = function(_stage_fn, modes=("const", "out"), name="ooc_stage",
                     outputs=0, parallel_fraction=1.0)


def working_set_bytes(chunks: int = 8, n: int = 1 << 14) -> int:
    """Total bytes the scenario keeps live (x + y + z chunk sets)."""
    return 3 * chunks * 4 * n


def build_outofcore(sched: GrScheduler, *, chunks: int = 8, n: int = 1 << 14,
                    cost_s: float = 1e-3, seed: int = 0,
                    device: int = None) -> Dict[str, List]:
    """Issue the two-pass pipeline; returns the chunk arrays for
    verification (``z[i] == 4*x[i] + 3`` elementwise).

    ``device`` pins every stage to one device (bypassing placement) — the
    tiered-spill benchmark uses it to keep the *compute* on the budgeted
    device so a peer-device tier competes on spill placement alone, not on
    work stealing."""
    rng = np.random.RandomState(seed)
    stage = OOC_STAGE.with_options(scheduler=sched, cost_s=cost_s)
    if device is not None:
        stage = stage.with_options(device=device)
    xs = [sched.array(rng.rand(n).astype(np.float32), name=f"ooc_x{i}")
          for i in range(chunks)]
    ys = [stage.with_options(name=f"ooc_p1_{i}")(x)
          for i, x in enumerate(xs)]
    zs = [stage.with_options(name=f"ooc_p2_{i}")(y)
          for i, y in enumerate(ys)]
    return {"x": xs, "y": ys, "z": zs}


def verify_outofcore(arrays: Dict[str, List]) -> bool:
    """Host-side correctness check (real executor): reads every final
    chunk back — through any spilled host copies — and compares against
    the closed form."""
    for x, z in zip(arrays["x"], arrays["z"]):
        expect = np.asarray(x.host, np.float32) * 4.0 + 3.0
        if not np.allclose(np.asarray(z), expect, rtol=1e-5, atol=1e-5):
            return False
    return True
