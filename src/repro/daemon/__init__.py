"""Runtime daemon — out-of-process job service over the GrJAX scheduler.

Every frontend so far links :class:`~repro.core.scheduler.GrScheduler`
in-process; a resident runtime that many client *processes* submit to needs
a service boundary.  This package is that boundary:

* :mod:`~repro.daemon.server` — a Unix-domain-socket server speaking
  length-prefixed JSON, dispatching jobs onto one shared scheduler through
  the thread-safe SubmissionPipeline;
* :mod:`~repro.daemon.client` / :mod:`~repro.daemon.cli` — the client
  library and the ``repro-daemon`` command line
  (``serve | submit | status | wait | cancel | stats | drain | shutdown``);
* :mod:`~repro.daemon.store` — an append-only JSONL journal: the job table
  survives daemon restarts and QUEUED work is replayed exactly once;
* :mod:`~repro.daemon.lifecycle` — the strict job state machine
  (QUEUED -> ADMITTED -> RUNNING -> PAUSED -> FINISHED/FAILED/CANCELLED)
  with an explicit legal-transition table and per-transition timestamps;
* :mod:`~repro.daemon.monitor` / :mod:`~repro.daemon.policy` — an EWMA
  monitoring loop (queue depth, lane utilization, memory occupancy, spike
  detection with cooldown windows, logical-vs-physical residency drift)
  driving admission control: jobs are shed or deferred under pressure
  instead of admitted blindly.
"""
from .client import DaemonClient, DaemonError
from .lifecycle import (IllegalTransitionError, JobRecord, JobState,
                        LEGAL_TRANSITIONS, TERMINAL_STATES)
from .monitor import Ewma, MonitorSnapshot, RuntimeMonitor, SpikeDetector
from .policy import AdmissionPolicy, Decision
from .server import DaemonServer
from .store import JobStore

__all__ = [
    "AdmissionPolicy", "DaemonClient", "DaemonError", "DaemonServer",
    "Decision", "Ewma", "IllegalTransitionError", "JobRecord", "JobState",
    "JobStore", "LEGAL_TRANSITIONS", "MonitorSnapshot", "RuntimeMonitor",
    "SpikeDetector", "TERMINAL_STATES",
]
