"""Persistent job store — an append-only JSONL journal.

Durability model: every job mutation appends one full-snapshot record
(``{"t": wall, "job": {...}}``) to the journal and flushes it to the OS, so
a killed daemon (SIGKILL included) loses at most the mutation in flight.
Reopening the journal replays it last-record-wins into the job table; no
tombstones, no partial-update ambiguity.  A trailing partially-written line
(the crash frontier) is ignored.

:meth:`JobStore.recover` implements the restart contract:

* QUEUED jobs are returned for re-enqueue — they were accepted but never
  claimed, so running them after a restart is exactly-once;
* ADMITTED / RUNNING / PAUSED jobs may have had side effects and are marked
  FAILED (``reason="daemon restart"``) — the legal table has an edge to
  FAILED from each of these states precisely for this;
* terminal jobs are kept for status queries.

``path=None`` gives a memory-only store with the same interface (tests,
benchmarks that do not care about restarts).
"""
from __future__ import annotations

import json
import os
import threading
import time
from typing import Dict, List, Optional, Tuple

from .lifecycle import JobRecord, JobState


class JobStore:
    def __init__(self, path: Optional[str] = None, *,
                 fsync: bool = False) -> None:
        self.path = path
        self.fsync = fsync
        self._lock = threading.Lock()
        self._jobs: Dict[str, JobRecord] = {}
        self._fh = None
        self.appends = 0
        self.replayed = 0
        self.truncated_tail = 0
        if path is not None:
            self._replay(path)
            self._fh = open(path, "a", encoding="utf-8")

    # ------------------------------------------------------------------
    def _replay(self, path: str) -> None:
        if not os.path.exists(path):
            return
        good_end = 0            # byte offset after the last intact record
        with open(path, "rb") as fh:
            for raw in fh:
                try:
                    rec = json.loads(raw.decode().strip() or "null")
                    job = JobRecord.from_json(rec["job"])
                except (ValueError, KeyError, TypeError, AttributeError):
                    # Crash frontier: a half-written trailing record.  Only
                    # the tail can be torn (appends are sequential), so we
                    # drop it and keep everything before it.
                    self.truncated_tail += 1
                    continue
                good_end += len(raw)
                self._jobs[job.job_id] = job
                self.replayed += 1
        if self.truncated_tail:
            # Physically cut the torn tail before reopening for append —
            # otherwise the next record would be glued onto the partial
            # line and *both* would be lost at the following replay.
            with open(path, "rb+") as fh:
                fh.truncate(good_end)

    # ------------------------------------------------------------------
    def _append_locked(self, job: JobRecord) -> None:
        if self._fh is not None:
            self._fh.write(json.dumps({"t": time.time(),
                                       "job": job.to_json()}) + "\n")
            self._fh.flush()
            if self.fsync:
                os.fsync(self._fh.fileno())
        self.appends += 1

    def put(self, job: JobRecord) -> None:
        """Insert a new job (or persist an update — same journal shape)."""
        with self._lock:
            self._jobs[job.job_id] = job
            self._append_locked(job)

    # ``update`` is an alias that reads better at transition sites.
    update = put

    def get(self, job_id: str) -> Optional[JobRecord]:
        with self._lock:
            return self._jobs.get(job_id)

    def jobs(self) -> List[JobRecord]:
        with self._lock:
            return list(self._jobs.values())

    def by_state(self, state: JobState) -> List[JobRecord]:
        with self._lock:
            return [j for j in self._jobs.values() if j.state is state]

    def __len__(self) -> int:
        with self._lock:
            return len(self._jobs)

    # ------------------------------------------------------------------
    def recover(self) -> Tuple[List[JobRecord], List[JobRecord]]:
        """Apply the restart contract; returns ``(requeued, failed)``.

        ``requeued`` are the QUEUED jobs to re-enqueue (exactly once: the
        table holds one record per job however many journal lines it has);
        ``failed`` are the jobs that were in flight when the previous
        daemon died, now FAILED."""
        requeued: List[JobRecord] = []
        failed: List[JobRecord] = []
        with self._lock:
            for job in self._jobs.values():
                if job.state is JobState.QUEUED:
                    requeued.append(job)
                elif job.state in (JobState.ADMITTED, JobState.RUNNING,
                                   JobState.PAUSED):
                    job.transition(JobState.FAILED, reason="daemon restart")
                    self._append_locked(job)
                    failed.append(job)
        # Stable re-enqueue order: original submission order.
        requeued.sort(key=lambda j: j.submit_t)
        return requeued, failed

    # ------------------------------------------------------------------
    def compact(self) -> None:
        """Rewrite the journal with one snapshot per job (atomic rename).

        Called on clean shutdown so restart replay stays O(jobs), not
        O(transitions ever recorded)."""
        if self.path is None:
            return
        with self._lock:
            tmp = self.path + ".tmp"
            with open(tmp, "w", encoding="utf-8") as fh:
                for job in self._jobs.values():
                    fh.write(json.dumps({"t": time.time(),
                                         "job": job.to_json()}) + "\n")
                fh.flush()
                os.fsync(fh.fileno())
            if self._fh is not None:
                self._fh.close()
            os.replace(tmp, self.path)
            self._fh = open(self.path, "a", encoding="utf-8")

    def audit(self):
        """Replay the journal through the lifecycle auditor
        (:func:`repro.analysis.journal.audit_journal`) without mutating it.
        Flushes pending appends first so the audit sees the live tail.
        Returns the :class:`JournalAudit`; raises when the store is
        in-memory only (nothing on disk to audit)."""
        if self.path is None:
            raise ValueError("in-memory JobStore has no journal to audit")
        with self._lock:
            if self._fh is not None:
                self._fh.flush()
        from ..analysis.journal import audit_journal
        return audit_journal(self.path)

    def close(self, *, compact: bool = True) -> None:
        if compact:
            self.compact()
        with self._lock:
            if self._fh is not None:
                self._fh.close()
                self._fh = None

    def stats(self) -> dict:
        with self._lock:
            by_state: Dict[str, int] = {}
            for j in self._jobs.values():
                by_state[j.state.value] = by_state.get(j.state.value, 0) + 1
            return {"jobs": len(self._jobs), "appends": self.appends,
                    "replayed": self.replayed,
                    "truncated_tail": self.truncated_tail,
                    "by_state": by_state}
