"""``repro-daemon`` command line: serve | submit | status | wait | cancel |
pause | resume | jobs | stats | drain | shutdown.

The default socket and store live under the system temp dir so two shells
on one machine talk to the same daemon with zero flags:

    python -m repro.daemon serve &
    python -m repro.daemon submit chain -p n=4 -p size=1024 --wait
    python -m repro.daemon stats
    python -m repro.daemon shutdown
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import tempfile
from typing import Optional


def default_socket_path() -> str:
    return os.environ.get(
        "REPRO_DAEMON_SOCKET",
        os.path.join(tempfile.gettempdir(), f"repro-daemon-{os.getuid()}.sock"))


def default_store_path() -> str:
    return os.environ.get(
        "REPRO_DAEMON_STORE",
        os.path.join(tempfile.gettempdir(),
                     f"repro-daemon-{os.getuid()}.jobs.jsonl"))


def _parse_params(pairs) -> dict:
    """``-p key=value`` with JSON-decoded values (bare words stay strings)."""
    out = {}
    for pair in pairs or []:
        if "=" not in pair:
            raise SystemExit(f"bad -p {pair!r}: expected key=value")
        k, v = pair.split("=", 1)
        try:
            out[k] = json.loads(v)
        except json.JSONDecodeError:
            out[k] = v
    return out


def build_parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(
        prog="repro-daemon",
        description="Out-of-process job service for the GrScheduler runtime.")
    p.add_argument("--socket", default=default_socket_path(),
                   help="Unix domain socket path (env REPRO_DAEMON_SOCKET)")
    sub = p.add_subparsers(dest="cmd", required=True)

    serve = sub.add_parser("serve", help="run the daemon in the foreground")
    serve.add_argument("--store", default=default_store_path(),
                       help="job journal path (env REPRO_DAEMON_STORE)")
    serve.add_argument("--workers", type=int, default=2,
                       help="dispatcher threads")
    serve.add_argument("--devices", type=int, default=2,
                       help="scheduler device lanes")
    serve.add_argument("--executor", default="threads",
                       choices=["threads", "sim"], help="scheduler executor")
    serve.add_argument("--mem-budget", type=float, default=None,
                       help="per-device memory budget in bytes")
    serve.add_argument("--monitor-interval", type=float, default=0.05,
                       help="monitor sample period (s)")
    serve.add_argument("--max-queue-depth", type=int, default=64)
    serve.add_argument("--spike-shed-depth", type=int, default=8)
    serve.add_argument("--shed-below-priority", type=int, default=1)
    serve.add_argument("--max-running", type=int, default=8)
    serve.add_argument("--mem-high-watermark", type=float, default=0.97)
    serve.add_argument("--spike-factor", type=float, default=3.0)
    serve.add_argument("--spike-floor", type=float, default=4.0,
                       help="queue-depth spike floor (jobs)")
    serve.add_argument("--rate-floor", type=float, default=None,
                       help="arrival-rate spike floor (jobs/s; "
                            "default 4x the depth floor)")
    serve.add_argument("--cooldown", type=float, default=0.5,
                       help="cooldown window after a spike (s)")

    sb = sub.add_parser("submit", help="submit one job")
    sb.add_argument("kind", help="registered job kind (chain, sleep, ...)")
    sb.add_argument("-p", "--param", action="append", dest="params",
                    metavar="KEY=VALUE", help="job parameter (JSON value)")
    sb.add_argument("--tenant", default="default")
    sb.add_argument("--priority", type=int, default=0)
    sb.add_argument("--deadline", type=float, default=None,
                    help="deadline in seconds from submission")
    sb.add_argument("--wait", action="store_true",
                    help="block until the job is terminal, print the result")
    sb.add_argument("--timeout", type=float, default=120.0)

    for name, hlp in [("status", "print one job record"),
                      ("wait", "block until a job is terminal"),
                      ("cancel", "cancel a queued or running job"),
                      ("pause", "pause a running job at its next checkpoint"),
                      ("resume", "resume a paused job")]:
        q = sub.add_parser(name, help=hlp)
        q.add_argument("job_id")
        if name == "wait":
            q.add_argument("--timeout", type=float, default=120.0)

    jb = sub.add_parser("jobs", help="list all jobs in the store")
    jb.add_argument("--audit", action="store_true",
                    help="audit the job journal offline (no daemon needed): "
                         "replay it through the lifecycle state machine and "
                         "exit non-zero on any illegal history")
    jb.add_argument("--store", default=default_store_path(),
                    help="journal path for --audit (env REPRO_DAEMON_STORE)")
    st = sub.add_parser("stats", help="print daemon + scheduler stats")
    st.add_argument("--no-scheduler", action="store_true",
                    help="skip the scheduler stats block")
    dr = sub.add_parser("drain", help="stop dispatching, wait for running")
    dr.add_argument("--timeout", type=float, default=30.0)
    sd = sub.add_parser("shutdown", help="stop the daemon")
    sd.add_argument("--no-drain", action="store_true",
                    help="do not wait for running jobs")
    sub.add_parser("ping", help="liveness check")
    return p


def _serve(args) -> int:
    from .monitor import RuntimeMonitor
    from .policy import AdmissionPolicy
    from .server import DaemonServer

    sched_kw = {"num_devices": args.devices,
                "simulate": args.executor == "sim"}
    if args.mem_budget is not None:
        sched_kw["memory_budget"] = args.mem_budget
    policy = AdmissionPolicy(
        max_queue_depth=args.max_queue_depth,
        spike_shed_depth=args.spike_shed_depth,
        shed_below_priority=args.shed_below_priority,
        max_running=args.max_running,
        mem_high_watermark=args.mem_high_watermark)
    server = DaemonServer(
        args.socket, store_path=args.store, sched_kw=sched_kw, policy=policy,
        workers=args.workers,
        monitor=RuntimeMonitor(interval_s=args.monitor_interval,
                               spike_factor=args.spike_factor,
                               spike_floor=args.spike_floor,
                               rate_floor=args.rate_floor,
                               cooldown_s=args.cooldown),
        monitor_interval_s=args.monitor_interval)
    print(f"repro-daemon: serving on {args.socket} "
          f"(store {args.store}, pid {os.getpid()})", flush=True)
    server.serve_forever()
    return 0


def _emit(obj) -> None:
    json.dump(obj, sys.stdout, indent=2, sort_keys=True)
    sys.stdout.write("\n")


def main(argv: Optional[list] = None) -> int:
    args = build_parser().parse_args(argv)
    if args.cmd == "serve":
        return _serve(args)
    if args.cmd == "jobs" and args.audit:
        # Offline journal audit: reads the JSONL directly, never connects.
        from repro.analysis.journal import audit_journal
        audit = audit_journal(args.store)
        _emit(audit.to_json())
        return 0 if audit.ok else 1

    from .client import DaemonClient, DaemonError
    client = DaemonClient(args.socket)
    try:
        if args.cmd == "submit":
            resp = client.submit(args.kind, _parse_params(args.params),
                                 tenant=args.tenant, priority=args.priority,
                                 deadline_s=args.deadline)
            if resp.get("shed"):
                _emit(resp)
                return 3
            if args.wait:
                _emit(client.wait(resp["job_id"], timeout=args.timeout))
            else:
                _emit(resp)
        elif args.cmd == "status":
            _emit(client.status(args.job_id))
        elif args.cmd == "wait":
            _emit(client.wait(args.job_id, timeout=args.timeout))
        elif args.cmd == "cancel":
            _emit(client.cancel(args.job_id))
        elif args.cmd == "pause":
            _emit(client.pause(args.job_id))
        elif args.cmd == "resume":
            _emit(client.resume(args.job_id))
        elif args.cmd == "jobs":
            _emit(client.jobs())
        elif args.cmd == "stats":
            _emit(client.stats(scheduler=not args.no_scheduler))
        elif args.cmd == "drain":
            _emit(client.drain(timeout=args.timeout))
        elif args.cmd == "shutdown":
            _emit(client.shutdown(drain=not args.no_drain))
        elif args.cmd == "ping":
            _emit(client.ping())
        return 0
    except DaemonError as exc:
        print(f"repro-daemon: error: {exc}", file=sys.stderr)
        return 2
    finally:
        client.close()


if __name__ == "__main__":
    raise SystemExit(main())
