"""Wire framing shared by the daemon server and client.

Length-prefixed JSON over a stream socket: 4-byte big-endian unsigned
payload length, then UTF-8 JSON.  One request -> one response; connections
are long-lived (a client may pipeline many request/response pairs over one
socket).
"""
from __future__ import annotations

import json
import socket
import struct
from typing import Optional

_HDR = struct.Struct(">I")
MAX_MESSAGE_BYTES = 64 * 1024 * 1024


class ProtocolError(RuntimeError):
    pass


def send_msg(sock: socket.socket, obj) -> None:
    payload = json.dumps(obj).encode()
    if len(payload) > MAX_MESSAGE_BYTES:
        raise ProtocolError(f"message of {len(payload)} bytes exceeds the "
                            f"{MAX_MESSAGE_BYTES}-byte frame limit")
    sock.sendall(_HDR.pack(len(payload)) + payload)


def _recv_exact(sock: socket.socket, n: int) -> Optional[bytes]:
    buf = bytearray()
    while len(buf) < n:
        chunk = sock.recv(n - len(buf))
        if not chunk:
            return None          # orderly EOF
        buf.extend(chunk)
    return bytes(buf)


def recv_msg(sock: socket.socket):
    """Read one frame; returns the decoded object or None on clean EOF."""
    hdr = _recv_exact(sock, _HDR.size)
    if hdr is None:
        return None
    (n,) = _HDR.unpack(hdr)
    if n > MAX_MESSAGE_BYTES:
        raise ProtocolError(f"frame of {n} bytes exceeds the "
                            f"{MAX_MESSAGE_BYTES}-byte limit")
    payload = _recv_exact(sock, n)
    if payload is None:
        raise ProtocolError("connection closed mid-frame")
    return json.loads(payload.decode())
