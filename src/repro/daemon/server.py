"""DaemonServer — the resident runtime behind a Unix domain socket.

One process owns one :class:`~repro.core.scheduler.GrScheduler`; any number
of client processes submit jobs over length-prefixed JSON (``wire.py``).
Jobs are journaled to a persistent :class:`~repro.daemon.store.JobStore`,
walked through the strict lifecycle state machine, admission-controlled by
an EWMA monitor + policy pair, and executed by dispatcher threads on the
shared scheduler through the thread-safe SubmissionPipeline — the
multi-tenant QoS, deadline and memory machinery all apply across *process*
boundaries exactly as they do across threads.

Request ops: ``ping, submit, status, wait, cancel, pause, resume, jobs,
stats, drain, resume_admission, shutdown``.
"""
from __future__ import annotations

import heapq
import itertools
import os
import socket
import threading
import time
import traceback
import uuid
from typing import Dict, List, Optional

from ..core.scheduler import GrScheduler, make_scheduler
from . import jobs as jobs_mod
from .jobs import JobCancelled, JobContext
from .lifecycle import JobRecord, JobState, TERMINAL_STATES
from .monitor import RuntimeMonitor
from .policy import AdmissionPolicy
from .store import JobStore
from .wire import recv_msg, send_msg


def _json_safe(obj):
    """Best-effort conversion of a stats tree to JSON-serializable types."""
    if isinstance(obj, dict):
        return {str(k): _json_safe(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple, set, frozenset)):
        return [_json_safe(v) for v in obj]
    if isinstance(obj, (str, int, float, bool)) or obj is None:
        return obj
    return str(obj)


class DaemonServer:
    def __init__(self, socket_path: str, *,
                 store: Optional[JobStore] = None,
                 store_path: Optional[str] = None,
                 scheduler: Optional[GrScheduler] = None,
                 sched_kw: Optional[dict] = None,
                 policy: Optional[AdmissionPolicy] = None,
                 monitor: Optional[RuntimeMonitor] = None,
                 workers: int = 2,
                 monitor_interval_s: Optional[float] = 0.05) -> None:
        self.socket_path = socket_path
        self.store = store if store is not None else JobStore(store_path)
        self._owns_scheduler = scheduler is None
        self.scheduler = scheduler or make_scheduler(**(sched_kw or {}))
        self.policy = policy or AdmissionPolicy()
        self.workers = max(1, int(workers))
        self._lock = threading.RLock()
        self._cond = threading.Condition(self._lock)
        self._heap: List[tuple] = []        # (-priority, deadline_t, seq, id)
        self._seq = itertools.count()
        self._queued = 0
        self._running: Dict[str, JobContext] = {}
        self._draining = False
        self._stop = threading.Event()
        self._started = False
        self.arrivals = 0
        self.completed = 0
        self.t_start = time.time()
        self._listener: Optional[socket.socket] = None
        self._threads: List[threading.Thread] = []
        self._conns: List[socket.socket] = []
        self.monitor = monitor or RuntimeMonitor(
            self.scheduler, interval_s=monitor_interval_s)
        # Gauges the monitor samples; installed whether or not the monitor
        # was supplied by the caller.
        self.monitor.scheduler = self.scheduler
        self.monitor.queue_depth_fn = lambda: self._queued
        self.monitor.running_fn = lambda: len(self._running)
        self.monitor.arrivals_fn = lambda: self.arrivals

    # ------------------------------------------------------------------
    # Lifecycle of the server itself
    # ------------------------------------------------------------------
    def start(self) -> "DaemonServer":
        if self._started:
            return self
        self._started = True
        requeued, failed = self.store.recover()
        with self._cond:
            for job in requeued:
                self._push_locked(job)
            self._cond.notify_all()
        if failed:
            self.completed += len(failed)
        if os.path.exists(self.socket_path):
            os.unlink(self.socket_path)     # stale socket from a dead daemon
        os.makedirs(os.path.dirname(self.socket_path) or ".", exist_ok=True)
        self._listener = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
        self._listener.bind(self.socket_path)
        self._listener.listen(64)
        self._listener.settimeout(0.2)
        for i in range(self.workers):
            t = threading.Thread(target=self._dispatch_loop,
                                 name=f"repro-daemon-dispatch-{i}",
                                 daemon=True)
            t.start()
            self._threads.append(t)
        t = threading.Thread(target=self._accept_loop,
                             name="repro-daemon-accept", daemon=True)
        t.start()
        self._threads.append(t)
        self.monitor.start()
        return self

    def serve_forever(self) -> None:
        self.start()
        try:
            while not self._stop.wait(0.2):
                pass
        except KeyboardInterrupt:
            pass
        finally:
            self.stop()

    def stop(self, *, drain: bool = True) -> None:
        """Graceful shutdown: stop admitting, optionally finish running
        jobs, persist the store (compacted) and close the scheduler."""
        if self._stop.is_set():
            return
        with self._cond:
            self._draining = True
            self._cond.notify_all()
        if drain:
            self.wait_idle(timeout=30.0, queue_too=False)
        self._stop.set()
        with self._cond:
            self._cond.notify_all()
        listener, self._listener = self._listener, None
        if listener is not None:
            listener.close()
        for c in list(self._conns):
            try:
                c.shutdown(socket.SHUT_RDWR)
            except OSError:
                pass
            try:
                c.close()
            except OSError:
                pass
        for t in self._threads:
            t.join(timeout=5.0)
        self._threads.clear()
        self.monitor.stop()
        self.store.close(compact=True)
        if os.path.exists(self.socket_path):
            try:
                os.unlink(self.socket_path)
            except OSError:
                pass
        if self._owns_scheduler:
            self.scheduler.close()

    def wait_idle(self, timeout: float = 30.0, *,
                  queue_too: bool = True) -> bool:
        """Block until no job is running (and, with ``queue_too``, none is
        queued).  Returns False on timeout."""
        deadline = time.monotonic() + timeout
        with self._cond:
            while self._running or (queue_too and self._queued):
                left = deadline - time.monotonic()
                if left <= 0:
                    return False
                self._cond.wait(timeout=min(left, 0.2))
        return True

    # ------------------------------------------------------------------
    # Queue internals (callers hold self._cond)
    # ------------------------------------------------------------------
    def _push_locked(self, job: JobRecord) -> None:
        deadline_t = (job.submit_t + job.deadline_s
                      if job.deadline_s is not None else float("inf"))
        heapq.heappush(self._heap,
                       (-job.priority, deadline_t, next(self._seq),
                        job.job_id))
        self._queued += 1

    def _pop_locked(self) -> Optional[JobRecord]:
        while self._heap:
            _, _, _, job_id = heapq.heappop(self._heap)
            self._queued -= 1
            job = self.store.get(job_id)
            if job is not None and job.state is JobState.QUEUED:
                return job
            # Cancelled while queued (or unknown): drop silently.
        return None

    def _snap(self):
        # Background monitor running: its latest sample is fresh enough.
        # No background thread (deterministic tests): sample synchronously.
        if self.monitor.interval_s is not None and not self._stop.is_set():
            snap = self.monitor.last
            if snap is not None and time.monotonic() - snap.t \
                    <= 4 * self.monitor.interval_s:
                return snap
        return self.monitor.sample_once()

    def _transition(self, job: JobRecord, dst: JobState, *,
                    reason: str = "") -> None:
        with self._cond:
            job.transition(dst, reason=reason)
            self.store.update(job)
            if job.terminal:
                self.completed += 1
            self._cond.notify_all()

    # ------------------------------------------------------------------
    # Dispatchers
    # ------------------------------------------------------------------
    def _claim_next(self, timeout: float) -> Optional[JobRecord]:
        with self._cond:
            if self._stop.is_set() or self._draining or not self._queued:
                self._cond.wait(timeout=timeout)
                return None
            return self._pop_locked()

    def _dispatch_loop(self) -> None:
        while not self._stop.is_set():
            job = self._claim_next(timeout=0.05)
            if job is None:
                continue
            snap = self._snap()
            decision = self.policy.dispatch(job, snap)
            if not decision.admitted:
                with self._cond:
                    # Keep its queue position; retry after the backoff.
                    self._push_locked(job)
                self._stop.wait(self.policy.defer_backoff_s)
                continue
            self._run_job(job)

    def _run_job(self, job: JobRecord) -> None:
        ctx = JobContext(self.scheduler, job.job_id, tenant=job.tenant,
                         priority=job.priority, deadline_s=job.deadline_s)
        ctx.on_pause = lambda: self._transition(job, JobState.PAUSED,
                                                reason="paused")
        ctx.on_resume = lambda: self._transition(job, JobState.RUNNING,
                                                 reason="resumed")
        with self._cond:
            if job.state is not JobState.QUEUED:   # cancel raced the claim
                return
            job.transition(JobState.ADMITTED)
            self.store.update(job)
            self._running[job.job_id] = ctx
        try:
            self._transition(job, JobState.RUNNING)
            result = jobs_mod.run_job(self.scheduler, job.kind, job.params,
                                      ctx=ctx)
            with self._cond:
                job.result = result
            self._transition(job, JobState.FINISHED)
        except JobCancelled:
            self._transition(job, JobState.CANCELLED,
                             reason=job.reason or "cancelled")
        except Exception as exc:
            self._transition(job, JobState.FAILED,
                             reason=f"{type(exc).__name__}: {exc}\n"
                                    f"{traceback.format_exc(limit=4)}")
        finally:
            with self._cond:
                self._running.pop(job.job_id, None)
                self._cond.notify_all()

    # ------------------------------------------------------------------
    # Request handling
    # ------------------------------------------------------------------
    def _accept_loop(self) -> None:
        while not self._stop.is_set():
            listener = self._listener
            if listener is None:
                return
            try:
                conn, _ = listener.accept()
            except socket.timeout:
                continue
            except OSError:
                return
            self._conns.append(conn)
            t = threading.Thread(target=self._serve_conn, args=(conn,),
                                 name="repro-daemon-conn", daemon=True)
            t.start()

    def _serve_conn(self, conn: socket.socket) -> None:
        try:
            while not self._stop.is_set():
                try:
                    req = recv_msg(conn)
                except (OSError, ValueError):
                    break
                if req is None:
                    break
                try:
                    resp = self.handle(req)
                except Exception as exc:
                    resp = {"ok": False,
                            "error": f"{type(exc).__name__}: {exc}"}
                # A shutdown op must not close this connection before its
                # reply is on the wire: run the trigger after send_msg.
                after = resp.pop("_after", None) \
                    if isinstance(resp, dict) else None
                try:
                    send_msg(conn, resp)
                except OSError:
                    break
                if after is not None:
                    after()
        finally:
            try:
                conn.close()
            except OSError:
                pass
            if conn in self._conns:
                self._conns.remove(conn)

    # -- ops -------------------------------------------------------------
    def handle(self, req: dict) -> dict:
        op = req.get("op")
        fn = getattr(self, f"_op_{op}", None)
        if fn is None:
            return {"ok": False, "error": f"unknown op {op!r}"}
        return fn(req)

    def _op_ping(self, req: dict) -> dict:
        return {"ok": True, "pid": os.getpid(),
                "uptime_s": time.time() - self.t_start}

    def _op_submit(self, req: dict) -> dict:
        spec = req.get("job") or {}
        kind = spec.get("kind")
        if kind not in jobs_mod.REGISTRY:
            return {"ok": False, "error": f"unknown job kind {kind!r}; "
                    f"registered: {sorted(jobs_mod.REGISTRY)}"}
        if self._draining:
            return {"ok": False, "error": "daemon is draining",
                    "draining": True}
        with self._cond:
            self.arrivals += 1
        job = JobRecord(
            job_id=f"j-{uuid.uuid4().hex[:12]}", kind=kind,
            params=dict(spec.get("params") or {}),
            tenant=str(spec.get("tenant", "default")),
            priority=int(spec.get("priority", 0)),
            deadline_s=spec.get("deadline_s"),
            submit_t=time.time())
        snap = self._snap()
        decision = self.policy.admit(job, snap)
        with self._cond:
            self.store.put(job)             # journal: born QUEUED
            if decision.admitted:
                self._push_locked(job)
                self._cond.notify_all()
        if not decision.admitted:           # shed: QUEUED -> CANCELLED
            self._transition(job, JobState.CANCELLED, reason=decision.reason)
            return {"ok": False, "shed": True, "job_id": job.job_id,
                    "reason": decision.reason}
        return {"ok": True, "job_id": job.job_id, "state": job.state.value}

    def _require_job(self, req: dict) -> JobRecord:
        job = self.store.get(str(req.get("job_id")))
        if job is None:
            raise KeyError(f"unknown job_id {req.get('job_id')!r}")
        return job

    def _op_status(self, req: dict) -> dict:
        job = self._require_job(req)
        return {"ok": True, "job": job.to_json()}

    def _op_wait(self, req: dict) -> dict:
        job = self._require_job(req)
        deadline = time.monotonic() + float(req.get("timeout", 60.0))
        with self._cond:
            while job.state not in TERMINAL_STATES:
                left = deadline - time.monotonic()
                if left <= 0:
                    return {"ok": False, "timed_out": True,
                            "job": job.to_json()}
                self._cond.wait(timeout=min(left, 0.2))
        return {"ok": True, "job": job.to_json()}

    def _op_cancel(self, req: dict) -> dict:
        job = self._require_job(req)
        with self._cond:
            if job.state is JobState.QUEUED:
                job.transition(JobState.CANCELLED, reason="client cancel")
                self.store.update(job)
                self.completed += 1
                self._cond.notify_all()
                return {"ok": True, "job": job.to_json()}
            ctx = self._running.get(job.job_id)
            if ctx is not None:
                job.reason = "client cancel"
                ctx.cancel_requested = True
                ctx.pause_event.set()       # wake a paused job so it can die
                return {"ok": True, "cancelling": True,
                        "job": job.to_json()}
        return {"ok": job.terminal, "job": job.to_json(),
                "error": None if job.terminal else "not cancellable"}

    def _op_pause(self, req: dict) -> dict:
        job = self._require_job(req)
        with self._cond:
            ctx = self._running.get(job.job_id)
            if ctx is None:
                return {"ok": False, "error": "job is not running",
                        "job": job.to_json()}
            ctx.pause_event.clear()
        return {"ok": True, "job": job.to_json()}

    def _op_resume(self, req: dict) -> dict:
        job = self._require_job(req)
        with self._cond:
            ctx = self._running.get(job.job_id)
            if ctx is None:
                return {"ok": False, "error": "job is not running",
                        "job": job.to_json()}
            ctx.pause_event.set()
        return {"ok": True, "job": job.to_json()}

    def _op_jobs(self, req: dict) -> dict:
        rows = [{"job_id": j.job_id, "kind": j.kind, "tenant": j.tenant,
                 "priority": j.priority, "state": j.state.value,
                 "reason": j.reason}
                for j in self.store.jobs()]
        return {"ok": True, "jobs": rows}

    def _op_stats(self, req: dict) -> dict:
        with self._cond:
            server = {
                "uptime_s": time.time() - self.t_start,
                "arrivals": self.arrivals,
                "queued": self._queued,
                "running": len(self._running),
                "completed": self.completed,
                "draining": self._draining,
                "workers": self.workers,
            }
        out = {"ok": True, "server": server,
               "policy": self.policy.stats(),
               "monitor": self.monitor.stats(),
               "store": self.store.stats(),
               "job_tenant_stats": self.job_tenant_stats()}
        if req.get("scheduler", True):
            out["scheduler"] = _json_safe(self.scheduler.stats())
            out["tenant_stats"] = _json_safe(self.scheduler.tenant_stats())
        return out

    def _op_drain(self, req: dict) -> dict:
        with self._cond:
            self._draining = True
            self._cond.notify_all()
        idle = self.wait_idle(timeout=float(req.get("timeout", 30.0)),
                              queue_too=False)
        with self._cond:
            return {"ok": idle, "drained": idle, "queued": self._queued,
                    "running": len(self._running)}

    def _op_resume_admission(self, req: dict) -> dict:
        with self._cond:
            self._draining = False
            self._cond.notify_all()
        return {"ok": True}

    def _op_shutdown(self, req: dict) -> dict:
        drain = bool(req.get("drain", True))

        def trigger() -> None:
            threading.Thread(target=self.stop, kwargs={"drain": drain},
                             name="repro-daemon-stop", daemon=True).start()

        return {"ok": True, "stopping": True, "_after": trigger}

    # ------------------------------------------------------------------
    def job_tenant_stats(self) -> dict:
        """Per-tenant job accounting from the lifecycle timestamps:
        queue delay (QUEUED -> first RUNNING), service time (first RUNNING
        -> terminal) and terminal-state counts, including sheds."""
        per: Dict[str, dict] = {}
        for job in self.store.jobs():
            d = per.setdefault(job.tenant, {
                "jobs": 0, "finished": 0, "failed": 0, "cancelled": 0,
                "shed": 0, "queue_delays": [], "service_times": []})
            d["jobs"] += 1
            if job.state in TERMINAL_STATES:
                d[job.state.value] += 1
                if job.reason.startswith("shed:"):
                    d["shed"] += 1
            run_t = job.transition_time(JobState.RUNNING)
            if run_t is not None:
                d["queue_delays"].append(run_t - job.submit_t)
                if job.terminal:
                    d["service_times"].append(
                        job.transitions[-1][2] - run_t)
        out = {}
        for tenant, d in per.items():
            qd, st = d.pop("queue_delays"), d.pop("service_times")
            d["queue_delay_mean_s"] = sum(qd) / len(qd) if qd else 0.0
            d["queue_delay_max_s"] = max(qd) if qd else 0.0
            d["service_mean_s"] = sum(st) / len(st) if st else 0.0
            out[tenant] = d
        return out
