"""Monitoring loop: EWMAs, spike detection, cooldowns, residency drift.

The monitor is the daemon's sensory system.  It samples queue depth,
arrival rate, lane utilization and memory-pool occupancy as exponentially
weighted moving averages, detects spikes (an observation far above the
moving baseline) and opens a *cooldown window* during which the admission
policy defers or sheds instead of admitting blindly.

It is also the home of the physical-accounting reconciliation: the
MemoryManager's *logical* residency ledger is cross-checked against itself
(:meth:`MemoryManager.verify`) and — on the real executor — against the
bytes physically installed on devices.  Persistent drift raises an alarm
counter the policy and operators can see; transient in-flight skew (logical
bits flip at schedule time, physical values land at completion) is filtered
by requiring the drift to persist across consecutive quiescent samples.
"""
from __future__ import annotations

import collections
import threading
import time
from dataclasses import dataclass, field
from typing import Callable, List, Optional


class Ewma:
    """Exponentially weighted moving average; ``None`` until first update."""

    def __init__(self, alpha: float = 0.3) -> None:
        self.alpha = float(alpha)
        self.value: Optional[float] = None

    def update(self, x: float) -> float:
        x = float(x)
        if self.value is None:
            self.value = x
        else:
            self.value = self.alpha * x + (1.0 - self.alpha) * self.value
        return self.value

    def get(self, default: float = 0.0) -> float:
        return default if self.value is None else self.value


class SpikeDetector:
    """Spike = observation > ``factor`` x max(EWMA baseline, ``floor``).

    A detected spike opens (or extends) a cooldown window of
    ``cooldown_s``; :meth:`active` reports whether the window is open.
    The observation is folded into the baseline *after* the comparison, so
    a step change is seen as a spike before the average absorbs it."""

    def __init__(self, *, factor: float = 3.0, floor: float = 2.0,
                 cooldown_s: float = 0.5, alpha: float = 0.3,
                 warmup: int = 0) -> None:
        self.factor = float(factor)
        self.floor = float(floor)
        self.cooldown_s = float(cooldown_s)
        self.ewma = Ewma(alpha)
        self.spikes = 0
        self.cooldown_until = 0.0
        # Observations absorbed before the detector may signal: the first
        # sample of a busy-but-healthy workload would otherwise compare a
        # real rate against the cold floor and read steady state as a spike.
        self.warmup = max(0, int(warmup))
        self._seen = 0

    def observe(self, x: float, now: Optional[float] = None) -> bool:
        now = time.monotonic() if now is None else now
        baseline = max(self.ewma.get(self.floor), self.floor)
        self._seen += 1
        spiking = (self._seen > self.warmup
                   and float(x) > self.factor * baseline)
        if spiking:
            self.spikes += 1
            self.cooldown_until = max(self.cooldown_until,
                                      now + self.cooldown_s)
        self.ewma.update(x)
        return spiking

    def active(self, now: Optional[float] = None) -> bool:
        now = time.monotonic() if now is None else now
        return now < self.cooldown_until


@dataclass
class MonitorSnapshot:
    """One consistent sample the admission policy decides from."""

    t: float = 0.0
    queue_depth: int = 0
    running: int = 0
    queue_depth_ewma: float = 0.0
    arrival_rate_ewma: float = 0.0          # submits/second
    utilization: float = 0.0                # device-busy fraction, EWMA
    mem_occupancy: float = 0.0              # bounded-pool resident/budget
    spiking: bool = False                   # inside a cooldown window
    cooldown_remaining_s: float = 0.0
    drift_alarms: int = 0
    drift_problems: List[str] = field(default_factory=list)


class RuntimeMonitor:
    """Background sampler over one scheduler + the server's queue gauges.

    ``queue_depth_fn``/``running_fn``/``arrivals_fn`` are cheap gauges the
    server installs; the scheduler is read through its (now lock-consistent)
    ``stats()`` snapshot.  ``interval_s=None`` disables the background
    thread — callers then drive :meth:`sample_once` explicitly, which is
    what the deterministic tests do."""

    def __init__(self, scheduler=None, *, interval_s: Optional[float] = 0.05,
                 spike_factor: float = 3.0, spike_floor: float = 4.0,
                 rate_floor: Optional[float] = None,
                 cooldown_s: float = 0.5, alpha: float = 0.3,
                 spike_warmup: int = 2,
                 drift_grace: int = 2, rate_window_s: float = 0.25,
                 queue_depth_fn: Optional[Callable[[], int]] = None,
                 running_fn: Optional[Callable[[], int]] = None,
                 arrivals_fn: Optional[Callable[[], int]] = None) -> None:
        self.scheduler = scheduler
        self.interval_s = interval_s
        self.queue_depth_fn = queue_depth_fn or (lambda: 0)
        self.running_fn = running_fn or (lambda: 0)
        self.arrivals_fn = arrivals_fn or (lambda: 0)
        self.depth_spikes = SpikeDetector(factor=spike_factor,
                                          floor=spike_floor,
                                          cooldown_s=cooldown_s, alpha=alpha,
                                          warmup=spike_warmup)
        # Queue depth (jobs) and arrival rate (jobs/second) live on very
        # different scales; ``rate_floor`` keeps a healthy high-throughput
        # trickle from reading as a rate spike (default: 4x the depth floor
        # per second).
        self.rate_spikes = SpikeDetector(
            factor=spike_factor,
            floor=4.0 * spike_floor if rate_floor is None else rate_floor,
            cooldown_s=cooldown_s, alpha=alpha, warmup=spike_warmup)
        self.util_ewma = Ewma(alpha)
        self.occupancy_ewma = Ewma(alpha)
        self.drift_grace = max(1, int(drift_grace))
        self.samples = 0
        self.drift_alarms = 0
        self._drift_streak = 0
        self._drift_problems: List[str] = []
        self._drift_report = None       # latest DriftReport from _reconcile
        self._last_t: Optional[float] = None
        # Arrival rate is measured over a sliding window, not one sample
        # interval: at a 20 ms cadence a single submit would read as an
        # instantaneous 50 jobs/s "spike".  The window keeps the gauge in
        # genuine jobs-per-second regardless of the sampling period.
        self.rate_window_s = float(rate_window_s)
        self._arrival_hist: "collections.deque" = collections.deque()
        self._busy_idx = 0                  # timeline cursor for busy delta
        self._lock = threading.Lock()
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self.last: Optional[MonitorSnapshot] = None

    # ------------------------------------------------------------------
    def start(self) -> None:
        if self.interval_s is None or self._thread is not None:
            return
        self._stop.clear()
        self._thread = threading.Thread(target=self._loop,
                                        name="repro-daemon-monitor",
                                        daemon=True)
        self._thread.start()

    def stop(self) -> None:
        self._stop.set()
        t, self._thread = self._thread, None
        if t is not None:
            t.join(timeout=5.0)

    def _loop(self) -> None:
        while not self._stop.wait(self.interval_s):
            try:
                self.sample_once()
            except Exception:               # pragma: no cover - never die
                pass

    # ------------------------------------------------------------------
    def _lane_utilization(self, now: float) -> float:
        """Device-busy seconds accrued since the previous sample, divided
        by wall-interval x lanes — a coarse utilization gauge."""
        sched = self.scheduler
        if sched is None or self._last_t is None:
            return 0.0
        interval = max(1e-9, now - self._last_t)
        tl = sched.timeline
        self._busy_idx, busy = tl.device_busy_since(self._busy_idx)
        lanes = max(1, sched.streams.lanes_created)
        return min(1.0, busy / (interval * lanes))

    def _reconcile(self, quiescent: bool) -> List[str]:
        """Logical-ledger self-check + logical-vs-physical accounting.

        Physical accounting only means something on a real executor at a
        quiescent point: the simulator installs no physical values, and a
        mid-flight real run legitimately has logical bits ahead of the
        device (flipped at schedule time).  The sampler must never unwind
        on drift — it records the structured :class:`DriftReport` for the
        alarm path instead of raising."""
        sched = self.scheduler
        if sched is None:
            return []
        physical = (quiescent
                    and type(sched.executor).__name__ == "ThreadLaneExecutor")
        report = sched.memory.verify(raise_on_drift=False, physical=physical)
        self._drift_report = report
        return list(report.problems)

    def sample_once(self, now: Optional[float] = None) -> MonitorSnapshot:
        with self._lock:
            now = time.monotonic() if now is None else now
            depth = int(self.queue_depth_fn())
            running = int(self.running_fn())
            arrivals = int(self.arrivals_fn())
            self.depth_spikes.observe(depth, now)
            hist = self._arrival_hist
            hist.append((now, arrivals))
            while len(hist) > 1 and hist[0][0] < now - self.rate_window_s:
                hist.popleft()
            dt = now - hist[0][0]
            if dt > 0:
                rate = (arrivals - hist[0][1]) / dt
                self.rate_spikes.observe(rate, now)
            util = self._lane_utilization(now)
            self.util_ewma.update(util)
            occ = 0.0
            if self.scheduler is not None:
                occ = float(self.scheduler.stats().get("mem_occupancy", 0.0))
            self.occupancy_ewma.update(occ)
            problems = self._reconcile(quiescent=(running == 0 and depth == 0))
            if problems:
                self._drift_streak += 1
                if self._drift_streak == self.drift_grace:
                    self.drift_alarms += 1
                    self._drift_problems = problems
            else:
                self._drift_streak = 0
            self._last_t = now
            self.samples += 1
            spiking = (self.depth_spikes.active(now)
                       or self.rate_spikes.active(now))
            cooldown_until = max(self.depth_spikes.cooldown_until,
                                 self.rate_spikes.cooldown_until)
            snap = MonitorSnapshot(
                t=now, queue_depth=depth, running=running,
                queue_depth_ewma=self.depth_spikes.ewma.get(),
                arrival_rate_ewma=self.rate_spikes.ewma.get(),
                utilization=self.util_ewma.get(),
                mem_occupancy=self.occupancy_ewma.get(),
                spiking=spiking,
                cooldown_remaining_s=max(0.0, cooldown_until - now),
                drift_alarms=self.drift_alarms,
                drift_problems=list(self._drift_problems))
            self.last = snap
            return snap

    def snapshot(self) -> MonitorSnapshot:
        """Latest sample (fresh one if none has been taken yet)."""
        snap = self.last
        return snap if snap is not None else self.sample_once()

    def stats(self) -> dict:
        with self._lock:
            return {
                "monitor_samples": self.samples,
                "monitor_spikes": (self.depth_spikes.spikes
                                   + self.rate_spikes.spikes),
                "monitor_in_cooldown": (self.depth_spikes.active()
                                        or self.rate_spikes.active()),
                "monitor_queue_depth_ewma": self.depth_spikes.ewma.get(),
                "monitor_arrival_rate_ewma": self.rate_spikes.ewma.get(),
                "monitor_utilization_ewma": self.util_ewma.get(),
                "monitor_mem_occupancy_ewma": self.occupancy_ewma.get(),
                "monitor_drift_alarms": self.drift_alarms,
                "monitor_drift_problems": list(self._drift_problems),
                "monitor_drift_report": (self._drift_report.to_json()
                                         if self._drift_report is not None
                                         else None),
            }
