"""DaemonClient — thin, thread-safe handle to a running daemon socket.

One persistent connection, lazily opened, with a lock serializing
request/response pairs (the wire protocol is strictly one-in one-out per
connection).  Raises :class:`DaemonError` on server-reported errors so
callers don't have to inspect ``ok`` flags.
"""
from __future__ import annotations

import socket
import threading
import time
from typing import Optional

from .wire import recv_msg, send_msg


class DaemonError(RuntimeError):
    """Server-side failure, connection loss, or shed/timeout the caller
    asked to treat as an error."""

    def __init__(self, message: str, response: Optional[dict] = None) -> None:
        super().__init__(message)
        self.response = response or {}


class DaemonClient:
    def __init__(self, socket_path: str, *,
                 connect_timeout: float = 5.0) -> None:
        self.socket_path = socket_path
        self.connect_timeout = float(connect_timeout)
        self._sock: Optional[socket.socket] = None
        self._lock = threading.Lock()

    # ------------------------------------------------------------------
    def _connect(self) -> socket.socket:
        deadline = time.monotonic() + self.connect_timeout
        last: Optional[OSError] = None
        while time.monotonic() < deadline:
            s = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
            try:
                s.connect(self.socket_path)
                return s
            except OSError as exc:      # daemon still binding, or gone
                last = exc
                s.close()
                time.sleep(0.05)
        raise DaemonError(f"cannot connect to daemon at "
                          f"{self.socket_path!r}: {last}")

    def request(self, op: str, **kw) -> dict:
        """One request/response round trip; raises on ``ok: false``."""
        req = {"op": op, **kw}
        with self._lock:
            if self._sock is None:
                self._sock = self._connect()
            try:
                send_msg(self._sock, req)
                resp = recv_msg(self._sock)
            except OSError as exc:
                self.close()
                raise DaemonError(f"daemon connection lost: {exc}") from exc
            if resp is None:
                self.close()
                raise DaemonError("daemon closed the connection")
        if not resp.get("ok", False) and not resp.get("shed"):
            raise DaemonError(resp.get("error") or f"op {op!r} failed",
                              response=resp)
        return resp

    def close(self) -> None:
        s, self._sock = self._sock, None
        if s is not None:
            try:
                s.close()
            except OSError:
                pass

    def __enter__(self) -> "DaemonClient":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # ------------------------------------------------------------------
    # Convenience ops
    # ------------------------------------------------------------------
    def ping(self) -> dict:
        return self.request("ping")

    def submit(self, kind: str, params: Optional[dict] = None, *,
               tenant: str = "default", priority: int = 0,
               deadline_s: Optional[float] = None,
               error_on_shed: bool = False) -> dict:
        """Submit a job; returns the server response (check ``shed``)."""
        resp = self.request("submit", job={
            "kind": kind, "params": params or {}, "tenant": tenant,
            "priority": priority, "deadline_s": deadline_s})
        if resp.get("shed") and error_on_shed:
            raise DaemonError(resp.get("reason", "job shed"), response=resp)
        return resp

    def status(self, job_id: str) -> dict:
        return self.request("status", job_id=job_id)["job"]

    def wait(self, job_id: str, timeout: float = 60.0) -> dict:
        """Block until the job is terminal; returns the final job record.
        Raises :class:`DaemonError` on timeout."""
        resp = self.request("wait", job_id=job_id, timeout=timeout)
        if resp.get("timed_out"):
            raise DaemonError(f"timed out waiting for {job_id}",
                              response=resp)
        return resp["job"]

    def result(self, job_id: str, timeout: float = 60.0) -> dict:
        """Wait, then return the FINISHED job's result; raises if the job
        ended FAILED or CANCELLED."""
        job = self.wait(job_id, timeout=timeout)
        if job["state"] != "finished":
            raise DaemonError(f"job {job_id} ended {job['state']}: "
                              f"{job.get('reason', '')}", response=job)
        return job["result"]

    def cancel(self, job_id: str) -> dict:
        return self.request("cancel", job_id=job_id)

    def pause(self, job_id: str) -> dict:
        return self.request("pause", job_id=job_id)

    def resume(self, job_id: str) -> dict:
        return self.request("resume", job_id=job_id)

    def jobs(self) -> list:
        return self.request("jobs")["jobs"]

    def stats(self, *, scheduler: bool = True) -> dict:
        return self.request("stats", scheduler=scheduler)

    def drain(self, timeout: float = 30.0) -> dict:
        return self.request("drain", timeout=timeout)

    def resume_admission(self) -> dict:
        return self.request("resume_admission")

    def shutdown(self, *, drain: bool = True) -> dict:
        return self.request("shutdown", drain=drain)
