"""Job lifecycle state machine for the runtime daemon.

A job is the daemon's unit of admission and accounting — one client request
that expands into one or more scheduler launches.  Its lifecycle is a strict
state machine::

    QUEUED ---> ADMITTED ---> RUNNING ---> FINISHED
      |            |          |    ^
      |            |          v    |
      |            |        PAUSED-+
      |            |          |
      +------------+----------+---> CANCELLED
                   +----------+---> FAILED

Every transition is validated against :data:`LEGAL_TRANSITIONS` and recorded
with a timestamp; an illegal transition raises
:class:`IllegalTransitionError` *before* any state is mutated, so a bug in
the daemon can never journal an impossible history.  The per-transition
timestamps are what the daemon's ``tenant_stats`` are computed from
(queue delay = QUEUED->RUNNING, service time = RUNNING->terminal).
"""
from __future__ import annotations

import enum
import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple


class JobState(enum.Enum):
    QUEUED = "queued"        # accepted into the persistent queue
    ADMITTED = "admitted"    # claimed by a dispatcher, about to run
    RUNNING = "running"      # handler executing on the shared scheduler
    PAUSED = "paused"        # cooperatively paused at a checkpoint
    FINISHED = "finished"    # handler returned a result
    FAILED = "failed"        # handler raised / daemon restarted mid-run
    CANCELLED = "cancelled"  # client cancel or admission-control shed


#: The only edges the daemon may ever take.  Everything else raises.
LEGAL_TRANSITIONS: Dict[JobState, frozenset] = {
    JobState.QUEUED: frozenset({JobState.ADMITTED, JobState.CANCELLED}),
    JobState.ADMITTED: frozenset({JobState.RUNNING, JobState.CANCELLED,
                                  JobState.FAILED}),
    JobState.RUNNING: frozenset({JobState.PAUSED, JobState.FINISHED,
                                 JobState.FAILED, JobState.CANCELLED}),
    JobState.PAUSED: frozenset({JobState.RUNNING, JobState.CANCELLED,
                                JobState.FAILED}),
    JobState.FINISHED: frozenset(),
    JobState.FAILED: frozenset(),
    JobState.CANCELLED: frozenset(),
}

TERMINAL_STATES = frozenset({JobState.FINISHED, JobState.FAILED,
                             JobState.CANCELLED})


class IllegalTransitionError(RuntimeError):
    """An edge outside :data:`LEGAL_TRANSITIONS` was attempted."""

    def __init__(self, job_id: str, src: JobState, dst: JobState) -> None:
        super().__init__(
            f"job {job_id}: illegal transition {src.value} -> {dst.value}; "
            f"legal from {src.value}: "
            f"{sorted(s.value for s in LEGAL_TRANSITIONS[src]) or 'none'}")
        self.src, self.dst = src, dst


@dataclass
class JobRecord:
    """One job's durable state: spec + lifecycle history + result.

    ``transitions`` is the append-only list of
    ``(from_state, to_state, wall_timestamp)`` triples, in order; the last
    entry's destination always equals ``state``.
    """

    job_id: str
    kind: str
    params: Dict[str, Any] = field(default_factory=dict)
    tenant: str = "default"
    priority: int = 0
    deadline_s: Optional[float] = None
    submit_t: float = 0.0
    state: JobState = JobState.QUEUED
    reason: str = ""                       # why FAILED/CANCELLED/deferred
    result: Any = None                     # JSON-serializable handler result
    attempts: int = 0                      # times a dispatcher admitted it
    transitions: List[Tuple[str, str, float]] = field(default_factory=list)

    # ------------------------------------------------------------------
    def transition(self, dst: JobState, *, reason: str = "",
                   t: Optional[float] = None) -> None:
        """Take one validated edge, recording its timestamp.

        Raises :class:`IllegalTransitionError` (and changes nothing) when
        the edge is not in the legal table."""
        if dst not in LEGAL_TRANSITIONS[self.state]:
            raise IllegalTransitionError(self.job_id, self.state, dst)
        when = time.time() if t is None else t
        self.transitions.append((self.state.value, dst.value, when))
        self.state = dst
        if reason:
            self.reason = reason
        if dst is JobState.ADMITTED:
            self.attempts += 1

    @property
    def terminal(self) -> bool:
        return self.state in TERMINAL_STATES

    def transition_time(self, dst: JobState) -> Optional[float]:
        """Timestamp of the *first* transition into ``dst`` (None if the
        job never entered it)."""
        for _src, to, when in self.transitions:
            if to == dst.value:
                return when
        return None

    # -- serialization (journal records / wire status replies) ----------
    def to_json(self) -> dict:
        return {
            "job_id": self.job_id, "kind": self.kind, "params": self.params,
            "tenant": self.tenant, "priority": self.priority,
            "deadline_s": self.deadline_s, "submit_t": self.submit_t,
            "state": self.state.value, "reason": self.reason,
            "result": self.result, "attempts": self.attempts,
            "transitions": [list(tr) for tr in self.transitions],
        }

    @classmethod
    def from_json(cls, d: dict) -> "JobRecord":
        return cls(
            job_id=d["job_id"], kind=d["kind"], params=dict(d["params"]),
            tenant=d.get("tenant", "default"),
            priority=int(d.get("priority", 0)),
            deadline_s=d.get("deadline_s"),
            submit_t=float(d.get("submit_t", 0.0)),
            state=JobState(d["state"]), reason=d.get("reason", ""),
            result=d.get("result"), attempts=int(d.get("attempts", 0)),
            transitions=[tuple(tr) for tr in d.get("transitions", [])])


def validate_history(transitions: List[Tuple[str, str, float]], *,
                     check_times: bool = False) -> List[str]:
    """Audit a recorded transition history against the legal table.

    Returns a list of violation strings (empty = clean): illegal edges,
    broken chaining (an edge starting from a state the previous edge did
    not land in), transitions out of a terminal state, or a non-QUEUED
    start.  ``check_times=True`` additionally requires non-decreasing
    timestamps (the journal auditor's wall-clock sanity check; the
    recovery tests keep it off since fake clocks need not be monotone).
    Used by the recovery tests to prove no journal ever records an
    impossible history."""
    problems: List[str] = []
    prev_dst: Optional[str] = None
    prev_t: Optional[float] = None
    for i, (src, dst, t) in enumerate(transitions):
        if check_times:
            try:
                tf = float(t)
            except (TypeError, ValueError):
                problems.append(f"edge {i}: non-numeric timestamp {t!r}")
            else:
                if prev_t is not None and tf < prev_t:
                    problems.append(
                        f"edge {i}: timestamp {tf} precedes previous "
                        f"edge's {prev_t} — history is not append-ordered")
                prev_t = tf
        try:
            s, d = JobState(src), JobState(dst)
        except ValueError:
            problems.append(f"edge {i}: unknown state in {src!r}->{dst!r}")
            continue
        if i == 0 and s is not JobState.QUEUED:
            problems.append(f"edge 0 starts from {src!r}, not 'queued'")
        if prev_dst is not None and src != prev_dst:
            problems.append(f"edge {i}: starts from {src!r} but previous "
                            f"edge landed in {prev_dst!r}")
        if d not in LEGAL_TRANSITIONS[s]:
            problems.append(f"edge {i}: illegal {src!r}->{dst!r}")
        prev_dst = dst
    return problems
