"""``python -m repro.daemon`` -> the repro-daemon CLI."""
from .cli import main

if __name__ == "__main__":
    raise SystemExit(main())
