"""Job registry — the named programs an out-of-process client may run.

Clients cannot ship Python callables over a socket; they name a registered
*job kind* plus JSON parameters, and the daemon executes the handler against
the shared scheduler through the ordinary ambient-runtime frontend.  Every
handler must be deterministic given its params (the end-to-end tests compare
daemon results bit-identically against in-process execution) and must return
a JSON-serializable result.

Handlers receive a :class:`JobContext` and should call
:meth:`JobContext.checkpoint` at element boundaries: that is where
cooperative pause (RUNNING -> PAUSED -> RUNNING) and cancellation
(-> CANCELLED) take effect — the daemon never interrupts a handler
mid-kernel, mirroring the scheduler's element-boundary preemption.
"""
from __future__ import annotations

import hashlib
import threading
import time
from typing import Any, Callable, Dict, Optional

import numpy as np

from ..core.frontend import function, runtime

REGISTRY: Dict[str, Callable] = {}


class JobCancelled(Exception):
    """Raised inside a handler when its job was cancelled at a checkpoint."""


class JobContext:
    """What a handler sees: the shared scheduler + cooperative control.

    ``pause_event`` set = run freely; cleared = pause at next checkpoint.
    The daemon's pause/resume ops (and, optionally, the admission policy on
    a spike) drive it; ``on_pause``/``on_resume`` are server callbacks that
    journal the RUNNING<->PAUSED transitions."""

    def __init__(self, scheduler, job_id: str = "", *,
                 tenant: str = "default", priority: int = 0,
                 deadline_s: Optional[float] = None) -> None:
        self.scheduler = scheduler
        self.job_id = job_id
        self.tenant = tenant
        self.priority = priority
        self.deadline_s = deadline_s
        self.pause_event = threading.Event()
        self.pause_event.set()
        self.cancel_requested = False
        self.checkpoints = 0
        self.paused_times = 0
        self.on_pause: Optional[Callable[[], None]] = None
        self.on_resume: Optional[Callable[[], None]] = None

    def checkpoint(self) -> None:
        """Cooperative yield point between scheduler launches."""
        self.checkpoints += 1
        if self.cancel_requested:
            raise JobCancelled(self.job_id)
        if not self.pause_event.is_set():
            self.paused_times += 1
            if self.on_pause is not None:
                self.on_pause()
            self.pause_event.wait()
            if self.on_resume is not None:
                self.on_resume()
            if self.cancel_requested:
                raise JobCancelled(self.job_id)

    def options(self) -> dict:
        """QoS tags every launch issued on behalf of this job carries."""
        out: dict = {"tenant": self.tenant, "priority": self.priority}
        if self.deadline_s is not None:
            out["deadline_s"] = self.deadline_s
        return out


def job_handler(name: str):
    """Register ``fn(ctx, **params) -> json`` as job kind ``name``."""
    def deco(fn: Callable) -> Callable:
        REGISTRY[name] = fn
        return fn
    return deco


def run_job(scheduler, kind: str, params: Optional[dict] = None, *,
            ctx: Optional[JobContext] = None) -> Any:
    """Execute one job kind against ``scheduler`` (daemon and in-process
    paths share this entry point, which is what makes the bit-identical
    comparison meaningful)."""
    try:
        handler = REGISTRY[kind]
    except KeyError:
        raise ValueError(f"unknown job kind {kind!r}; registered: "
                         f"{sorted(REGISTRY)}") from None
    if ctx is None:
        ctx = JobContext(scheduler)
    return handler(ctx, **(params or {}))


# ======================================================================
# Built-in job kinds
# ======================================================================

def _jax_chain_fns():
    """Declared-once GrFunctions for the chain job (lazy: keeps the daemon
    importable, and startable, without pulling in jax)."""
    global _CHAIN_STEP, _CHAIN_RED
    try:
        return _CHAIN_STEP, _CHAIN_RED
    except NameError:
        pass
    import jax
    import jax.numpy as jnp
    _CHAIN_STEP = function(
        jax.jit(lambda x, _o: x * x * 0.5 + 0.25 * x + 0.125),
        modes=("const", "out"), outputs=0, name="daemon_chain_step")
    _CHAIN_RED = function(
        jax.jit(lambda x, _o: jnp.stack([x.sum(), jnp.abs(x).max()])),
        modes=("const", "out"), outputs=((2,), np.float32),
        name="daemon_chain_red")
    return _CHAIN_STEP, _CHAIN_RED


@job_handler("chain")
def chain_job(ctx: JobContext, *, n: int = 4, size: int = 8192,
              seed: int = 0, digest: bool = False) -> dict:
    """``n`` dependent kernels over a seeded random vector.

    Deterministic: input from ``default_rng(seed)``, jitted CPU ops.
    Returns the reduction pair plus either the full value list (small
    sizes) or a sha256 digest — both compare bit-identically across
    daemon/in-process runs."""
    step, red = _jax_chain_fns()
    opts = ctx.options()
    x = np.random.default_rng(int(seed)).standard_normal(
        int(size)).astype(np.float32)
    with runtime(scheduler=ctx.scheduler):
        a = ctx.scheduler.array(x, name=f"chain_{ctx.job_id or seed}")
        for _ in range(int(n)):
            a = step(a, **opts)
            ctx.checkpoint()
        r = red(a, **opts)
        values = np.asarray(a)          # host read syncs only this chain
        summary = np.asarray(r)
    out = {"sum": float(summary[0]), "absmax": float(summary[1])}
    if digest or int(size) > 4096:
        out["sha256"] = hashlib.sha256(values.tobytes()).hexdigest()
    else:
        out["values"] = [float(v) for v in values]
    return out


@job_handler("sleep")
def sleep_job(ctx: JobContext, *, total_s: float = 0.05,
              steps: int = 5) -> dict:
    """Pure host work in ``steps`` checkpointed slices — the test/bench
    workhorse for queueing, pause/resume, cancel and crash recovery (no
    jax import, so a freshly spawned daemon runs it instantly)."""
    steps = max(1, int(steps))
    for _ in range(steps):
        time.sleep(float(total_s) / steps)
        ctx.checkpoint()
    return {"slept_s": float(total_s), "checkpoints": ctx.checkpoints}


@job_handler("noop")
def noop_job(ctx: JobContext, **params) -> dict:
    """Echo job: the socket round-trip smoke test."""
    return {"echo": params}


@job_handler("serve_lm")
def serve_lm_job(ctx: JobContext, *, arch: str = "qwen2_moe_a2_7b",
                 requests: int = 4, prompt_len: int = 16,
                 new_tokens: int = 4, batch_size: int = 2,
                 seed: int = 0) -> dict:
    """Daemon-backed serving: run a reduced LM ServingEngine *inside* the
    resident runtime and pump ``requests`` greedy generations through it.

    This is the out-of-process submit path for ``runtime/serving.py`` — a
    client process gets batched, capture-replayed inference from the shared
    daemon scheduler without linking jax or the model itself."""
    import jax
    from ..configs import get_config
    from ..models import init_lm
    from ..runtime.serving import ServingEngine

    cfg = get_config(arch, reduced=True)
    params = init_lm(jax.random.PRNGKey(int(seed)), cfg)
    rng = np.random.RandomState(int(seed))
    with ServingEngine(cfg, params, batch_size=int(batch_size),
                       max_new_tokens=int(new_tokens),
                       scheduler=ctx.scheduler) as eng:
        reqs = [eng.submit(rng.randint(0, cfg.vocab, int(prompt_len)),
                           tenant=ctx.tenant, priority=ctx.priority,
                           deadline_s=ctx.deadline_s)
                for _ in range(int(requests))]
        eng.flush(force=True)
        done = eng.collect()
        ctx.checkpoint()
    assert len(done) == len(reqs)
    return {"generations": [[int(t) for t in r.result] for r in reqs],
            "tenant_stats": eng.tenant_stats().get(ctx.tenant, {})}
