"""Admission control: admit / defer / shed, driven by monitor snapshots.

Two decision points, mirroring the job lifecycle:

* :meth:`AdmissionPolicy.admit` — at **submission** (QUEUED or not at all).
  A full queue or a spike-with-cooldown sheds low-priority work outright
  (the job is journaled QUEUED -> CANCELLED with a ``shed:`` reason, so
  the client gets an immediate, honest answer instead of an unbounded
  queue), subject to ``shed_below_priority``.
* :meth:`AdmissionPolicy.dispatch` — at **claim time** (QUEUED -> ADMITTED
  or stay QUEUED).  Running-slot limits, memory-occupancy watermarks and
  open cooldown windows *defer* the job: it keeps its queue position and
  is retried after ``defer_backoff_s``.

Deferring is deliberately separate from shedding: deferral trades latency
for completeness, shedding trades completeness for stability.  Counters for
every decision feed ``stats`` (and the daemon benchmark's gates).
"""
from __future__ import annotations

import threading
from dataclasses import dataclass

from .lifecycle import JobRecord
from .monitor import MonitorSnapshot

ADMIT = "admit"
DEFER = "defer"
SHED = "shed"


@dataclass(frozen=True)
class Decision:
    action: str                 # ADMIT | DEFER | SHED
    reason: str = ""

    @property
    def admitted(self) -> bool:
        return self.action == ADMIT


class AdmissionPolicy:
    """Threshold policy over :class:`MonitorSnapshot` gauges.

    Knobs (all per-instance, all surfaced on the CLI):

    * ``max_queue_depth`` — hard bound on QUEUED jobs; beyond it, shed.
    * ``spike_shed_depth`` — during a spike cooldown, shed jobs with
      ``priority <= shed_below_priority`` once the queue is this deep
      (high-priority work is still admitted: a spike must not lock out
      the latency tenant).
    * ``max_running`` — dispatch-side concurrency bound; defer above it.
    * ``mem_high_watermark`` — defer dispatch while the memory-occupancy
      EWMA is above this fraction of budget.
    * ``defer_in_cooldown`` — hold dispatch of sub-priority work while a
      cooldown window is open (the queue drains at the rate running work
      completes, which is the point of the window).
    """

    def __init__(self, *, max_queue_depth: int = 64,
                 spike_shed_depth: int = 8,
                 shed_below_priority: int = 1,
                 max_running: int = 8,
                 mem_high_watermark: float = 0.97,
                 defer_in_cooldown: bool = True,
                 defer_backoff_s: float = 0.01) -> None:
        self.max_queue_depth = int(max_queue_depth)
        self.spike_shed_depth = int(spike_shed_depth)
        self.shed_below_priority = int(shed_below_priority)
        self.max_running = int(max_running)
        self.mem_high_watermark = float(mem_high_watermark)
        self.defer_in_cooldown = bool(defer_in_cooldown)
        self.defer_backoff_s = float(defer_backoff_s)
        self._lock = threading.Lock()
        self.admitted = 0
        self.shed = 0
        self.deferred_jobs = 0              # distinct jobs ever deferred
        self.defer_events = 0               # total defer decisions
        self._deferred_seen = set()

    # ------------------------------------------------------------------
    def admit(self, job: JobRecord, snap: MonitorSnapshot) -> Decision:
        """Submission-time gate: queue the job, or shed it now."""
        with self._lock:
            if snap.queue_depth >= self.max_queue_depth:
                self.shed += 1
                return Decision(SHED, f"shed:queue_full "
                                      f"(depth {snap.queue_depth} >= "
                                      f"{self.max_queue_depth})")
            if (snap.spiking
                    and job.priority <= self.shed_below_priority
                    and snap.queue_depth >= self.spike_shed_depth):
                self.shed += 1
                return Decision(SHED, f"shed:spike "
                                      f"(depth {snap.queue_depth}, cooldown "
                                      f"{snap.cooldown_remaining_s:.3f}s)")
            self.admitted += 1
            return Decision(ADMIT)

    # ------------------------------------------------------------------
    def dispatch(self, job: JobRecord, snap: MonitorSnapshot) -> Decision:
        """Claim-time gate: run now, or keep queued and retry later."""
        with self._lock:
            decision = None
            if snap.running >= self.max_running:
                decision = Decision(DEFER, f"defer:running_slots "
                                           f"({snap.running} >= "
                                           f"{self.max_running})")
            elif snap.mem_occupancy > self.mem_high_watermark:
                decision = Decision(DEFER, f"defer:mem_pressure "
                                           f"({snap.mem_occupancy:.2f} > "
                                           f"{self.mem_high_watermark})")
            elif (self.defer_in_cooldown and snap.spiking
                    and job.priority <= self.shed_below_priority):
                decision = Decision(DEFER, "defer:cooldown")
            if decision is None:
                return Decision(ADMIT)
            self.defer_events += 1
            if job.job_id not in self._deferred_seen:
                self._deferred_seen.add(job.job_id)
                self.deferred_jobs += 1
            return decision

    # ------------------------------------------------------------------
    def stats(self) -> dict:
        with self._lock:
            return {"policy_admitted": self.admitted,
                    "policy_shed": self.shed,
                    "policy_deferred_jobs": self.deferred_jobs,
                    "policy_defer_events": self.defer_events,
                    "policy_max_queue_depth": self.max_queue_depth,
                    "policy_max_running": self.max_running}
