"""Deterministic synthetic token pipeline.

Host-side batch generation is deliberately a *host computation* so the
GrJAX trainer can overlap it (and its H2D transfer) with the previous
step's device compute — the paper's transfer/compute overlap applied to the
training loop (DESIGN.md §3).
"""
from __future__ import annotations

from typing import Dict

import numpy as np

from ..models.config import ArchConfig


class SyntheticTokenStream:
    """Reproducible stream: batch(step) is a pure function of (seed, step) —
    this is what makes checkpoint-restart exactly resumable."""

    def __init__(self, cfg: ArchConfig, seq_len: int, global_batch: int,
                 accum: int = 1, seed: int = 0,
                 host_latency_s: float = 0.0) -> None:
        assert global_batch % accum == 0
        self.cfg = cfg
        self.seq = seq_len
        self.micro = global_batch // accum
        self.accum = accum
        self.seed = seed
        self.host_latency_s = host_latency_s

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        if self.host_latency_s:
            import time
            time.sleep(self.host_latency_s)
        rng = np.random.RandomState((self.seed * 1_000_003 + step) % 2**31)
        cfg = self.cfg
        shape = (self.accum, self.micro, self.seq + 1)
        toks = rng.randint(0, cfg.vocab, size=shape).astype(np.int32)
        out = {"tokens": toks[..., :-1], "labels": toks[..., 1:]}
        if cfg.n_encoder_layers:
            out["frames"] = rng.randn(self.accum, self.micro, self.seq // 4,
                                      cfg.d_model).astype(np.float32)
        if cfg.frontend == "vision":
            out["patches"] = rng.randn(self.accum, self.micro,
                                       cfg.n_frontend_tokens,
                                       cfg.d_model).astype(np.float32) * 0.02
        return out

    def nbytes(self) -> int:
        b = self.batch(0)
        return sum(v.nbytes for v in b.values())


def batch_specs(cfg: ArchConfig, seq_len: int, global_batch: int,
                accum: int = 1):
    """ShapeDtypeStructs for one training batch (used by the dry-run)."""
    import jax
    micro = global_batch // accum
    specs = {
        "tokens": jax.ShapeDtypeStruct((accum, micro, seq_len), np.int32),
        "labels": jax.ShapeDtypeStruct((accum, micro, seq_len), np.int32),
    }
    if cfg.n_encoder_layers:
        specs["frames"] = jax.ShapeDtypeStruct(
            (accum, micro, seq_len // 4, cfg.d_model), np.float32)
    if cfg.frontend == "vision":
        specs["patches"] = jax.ShapeDtypeStruct(
            (accum, micro, cfg.n_frontend_tokens, cfg.d_model), np.float32)
    return specs
