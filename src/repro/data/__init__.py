from .pipeline import SyntheticTokenStream, batch_specs

__all__ = ["SyntheticTokenStream", "batch_specs"]
