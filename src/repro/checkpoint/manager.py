"""Fault-tolerant checkpointing.

* atomic: writes into ``step_XXXX.tmp`` then ``os.rename`` — a crash
  mid-save never corrupts the latest checkpoint;
* asynchronous: device→host snapshot happens synchronously (cheap, and
  consistent), file I/O runs on a background thread off the training
  critical path (the GrJAX scheduler treats it as a host element);
* sharded-ready: each process writes only its addressable shard data
  (single-process here, but the layout is per-leaf files keyed by tree
  path, which is what a multi-host writer needs);
* bounded: keeps the newest ``keep`` checkpoints.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np

_STEP_RE = re.compile(r"^step_(\d+)$")


def _path_str(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        elif hasattr(k, "name"):
            parts.append(str(k.name))
        else:
            parts.append(str(k))
    return "/".join(parts)


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3,
                 async_save: bool = True) -> None:
        self.dir = directory
        self.keep = keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        # Snapshot-through-spill accounting (save_managed): blocks whose
        # bytes were referenced from a spill tier instead of copied again.
        self.spill_links = 0
        self.spill_link_bytes = 0
        self.tier_reads = 0
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------------
    def save(self, step: int, state: Any, extra: Optional[dict] = None) -> None:
        """Persist ``state`` (and JSON-serializable ``extra`` metadata — RNG
        seeds, data-stream position, anything else exact resume consumes)."""
        # 1. consistent host snapshot (D2H) — synchronous
        leaves_with_paths, treedef = jax.tree_util.tree_flatten_with_path(state)
        snapshot = [(_path_str(p), np.asarray(v)) for p, v in leaves_with_paths]
        self.wait()                          # one in-flight save at a time

        def write():
            tmp = os.path.join(self.dir, f"step_{step}.tmp")
            final = os.path.join(self.dir, f"step_{step}")
            shutil.rmtree(tmp, ignore_errors=True)
            os.makedirs(tmp)
            manifest = {}
            for name, arr in snapshot:
                fn = name.replace("/", "__") + ".npy"
                np.save(os.path.join(tmp, fn), arr)
                manifest[name] = {"file": fn, "dtype": str(arr.dtype),
                                  "shape": list(arr.shape)}
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump({"step": step, "leaves": manifest,
                           "extra": extra or {}}, f)
            shutil.rmtree(final, ignore_errors=True)
            os.rename(tmp, final)            # atomic publish
            self._gc()

        if self.async_save:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()
        else:
            write()

    # ------------------------------------------------------------------
    def save_managed(self, step: int, arrays, extra: Optional[dict] = None,
                     ) -> dict:
        """Persist a ``{name: ManagedArray}`` mapping with
        **snapshot-through-spill**: a block the memory subsystem has already
        written to a backing tier is *referenced* instead of copied again.

        * Disk-tier blocks: the published spool file (tiers.py writes it
          tmp+rename, so the inode is immutable — a later re-spill replaces
          the file, never rewrites it) is **hard-linked** into the
          checkpoint, a metadata-only operation.
        * Compressed-tier blocks: the payload is decoded host-side through
          ``tier.peek`` — no device hop, and the spill stays resident.
        * Host-valid blocks are snapshotted from the host buffer; dirty
          device-resident blocks take the ordinary synchronized D2H first.

        Returns per-save reuse stats (also accumulated on the manager).
        File layout and manifest match :meth:`save`, so
        :meth:`restore_managed` / :meth:`latest_step` / ``keep``-GC all
        apply unchanged."""
        self.wait()                          # one in-flight save at a time
        tmp = os.path.join(self.dir, f"step_{step}.tmp")
        final = os.path.join(self.dir, f"step_{step}")
        shutil.rmtree(tmp, ignore_errors=True)
        os.makedirs(tmp)
        stats = {"leaves": 0, "spill_links": 0, "spill_link_bytes": 0,
                 "tier_reads": 0, "copied": 0}
        manifest = {}
        pending = []                         # (file, np.ndarray) to np.save
        for name, ma in dict(arrays).items():
            fn = name.replace("/", "__") + ".npy"
            stats["leaves"] += 1
            entry = {"file": fn, "dtype": str(ma.dtype),
                     "shape": list(ma.shape)}
            tier = self._tier_of(ma)
            linked = False
            path = None
            if tier is not None and hasattr(tier, "path_for"):
                from ..core.element import dep_key
                path = tier.path_for(dep_key(ma))
            if path is not None:
                try:
                    # Hard-link the published spool payload: copy-on-write
                    # snapshot, zero data movement.  Links are taken
                    # synchronously — an async deferral could race the
                    # block's reload (which removes the spool file).
                    os.link(path, os.path.join(tmp, fn))
                    linked = True
                    stats["spill_links"] += 1
                    stats["spill_link_bytes"] += ma.nbytes
                    entry["via"] = "spill-link"
                except OSError:              # cross-device link etc.
                    shutil.copyfile(path, os.path.join(tmp, fn))
                    linked = True
                    stats["tier_reads"] += 1
                    entry["via"] = "spill-copy"
            if not linked:
                if tier is not None:
                    val = tier.peek(ma)
                    if val is not None:
                        stats["tier_reads"] += 1
                        entry["via"] = "tier-read"
                        pending.append((fn, np.array(val)))
                        manifest[name] = entry
                        continue
                # Ordinary path: synchronized host snapshot (D2H if the
                # device copy is the only valid one).
                if not getattr(ma, "host_valid", True):
                    ma.read()
                stats["copied"] += 1
                pending.append((fn, np.array(ma.host)))
            manifest[name] = entry
        self.spill_links += stats["spill_links"]
        self.spill_link_bytes += stats["spill_link_bytes"]
        self.tier_reads += stats["tier_reads"]

        def write():
            for fn, arr in pending:
                np.save(os.path.join(tmp, fn), arr)
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump({"step": step, "leaves": manifest,
                           "extra": extra or {}}, f)
            shutil.rmtree(final, ignore_errors=True)
            os.rename(tmp, final)            # atomic publish
            self._gc()

        if self.async_save:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()
        else:
            write()
        return stats

    @staticmethod
    def _tier_of(ma) -> Optional[Any]:
        tname = getattr(ma, "backing_tier", None)
        if tname is None:
            return None
        sched = getattr(ma, "_scheduler", None)
        mem = getattr(sched, "memory", None)
        return mem.tier_named(tname) if mem is not None else None

    def restore_managed(self, arrays, step: Optional[int] = None) -> None:
        """Load a checkpoint written by :meth:`save_managed` back into a
        ``{name: ManagedArray}`` mapping (host writes through the managed
        API, so location bits and DAG ordering stay correct)."""
        self.wait()
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {self.dir}")
        d = os.path.join(self.dir, f"step_{step}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)["leaves"]
        for name, ma in dict(arrays).items():
            ma.write(np.load(os.path.join(d, manifest[name]["file"])))

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    # ------------------------------------------------------------------
    def latest_step(self) -> Optional[int]:
        # Exact-resume correctness: an async save still in flight must be
        # visible to the caller deciding which step to resume from.  Without
        # this wait, latest_step() could answer N while restore() (which
        # waits internally) restores N+k — a resumed run that silently
        # re-trains steps with a future state (the ~1e-3 loss drift bug).
        self.wait()
        steps = []
        for d in os.listdir(self.dir):
            m = _STEP_RE.match(d)
            if m and os.path.exists(os.path.join(self.dir, d, "manifest.json")):
                steps.append(int(m.group(1)))
        return max(steps) if steps else None

    def restore(self, like: Any, step: Optional[int] = None) -> Any:
        """Restore into the structure (and shardings) of ``like``.

        Callers resuming training should pin ``step`` to the value they got
        from :meth:`latest_step` so the loop counter and the restored state
        can never disagree (see :meth:`restore_latest`).
        """
        self.wait()
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {self.dir}")
        d = os.path.join(self.dir, f"step_{step}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)["leaves"]

        leaves_with_paths, treedef = jax.tree_util.tree_flatten_with_path(like)
        out = []
        for path, ref in leaves_with_paths:
            name = _path_str(path)
            arr = np.load(os.path.join(d, manifest[name]["file"]))
            val = jax.device_put(arr, getattr(ref, "sharding", None)) \
                if hasattr(ref, "sharding") else arr
            out.append(val)
        return jax.tree_util.tree_unflatten(treedef, out)

    def restore_latest(self, like: Any):
        """Atomically resolve (step, state, extra) for exact resume.

        Returns ``(None, like, {})`` when no checkpoint exists.  The returned
        step is the one actually restored — callers must resume the loop from
        it rather than re-deriving it with a second ``latest_step()`` call.
        """
        step = self.latest_step()
        if step is None:
            return None, like, {}
        return step, self.restore(like, step=step), self._read_extra(step)

    def load_extra(self, step: Optional[int] = None) -> dict:
        """The ``extra`` metadata dict saved alongside ``step`` (``{}`` for
        checkpoints written before this field existed)."""
        self.wait()
        if step is None:
            step = self.latest_step()
            if step is None:
                raise FileNotFoundError(f"no checkpoint in {self.dir}")
        return self._read_extra(step)

    def _read_extra(self, step: int) -> dict:
        with open(os.path.join(self.dir, f"step_{step}",
                               "manifest.json")) as f:
            return json.load(f).get("extra", {})

    def _gc(self) -> None:
        steps = []
        for d in os.listdir(self.dir):
            m = _STEP_RE.match(d)
            if m:
                steps.append(int(m.group(1)))
        steps.sort()
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{s}"),
                          ignore_errors=True)
