"""Fault-tolerant checkpointing.

* atomic: writes into ``step_XXXX.tmp`` then ``os.rename`` — a crash
  mid-save never corrupts the latest checkpoint;
* asynchronous: device→host snapshot happens synchronously (cheap, and
  consistent), file I/O runs on a background thread off the training
  critical path (the GrJAX scheduler treats it as a host element);
* sharded-ready: each process writes only its addressable shard data
  (single-process here, but the layout is per-leaf files keyed by tree
  path, which is what a multi-host writer needs);
* bounded: keeps the newest ``keep`` checkpoints.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np

_STEP_RE = re.compile(r"^step_(\d+)$")


def _path_str(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        elif hasattr(k, "name"):
            parts.append(str(k.name))
        else:
            parts.append(str(k))
    return "/".join(parts)


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3,
                 async_save: bool = True) -> None:
        self.dir = directory
        self.keep = keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------------
    def save(self, step: int, state: Any, extra: Optional[dict] = None) -> None:
        """Persist ``state`` (and JSON-serializable ``extra`` metadata — RNG
        seeds, data-stream position, anything else exact resume consumes)."""
        # 1. consistent host snapshot (D2H) — synchronous
        leaves_with_paths, treedef = jax.tree_util.tree_flatten_with_path(state)
        snapshot = [(_path_str(p), np.asarray(v)) for p, v in leaves_with_paths]
        self.wait()                          # one in-flight save at a time

        def write():
            tmp = os.path.join(self.dir, f"step_{step}.tmp")
            final = os.path.join(self.dir, f"step_{step}")
            shutil.rmtree(tmp, ignore_errors=True)
            os.makedirs(tmp)
            manifest = {}
            for name, arr in snapshot:
                fn = name.replace("/", "__") + ".npy"
                np.save(os.path.join(tmp, fn), arr)
                manifest[name] = {"file": fn, "dtype": str(arr.dtype),
                                  "shape": list(arr.shape)}
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump({"step": step, "leaves": manifest,
                           "extra": extra or {}}, f)
            shutil.rmtree(final, ignore_errors=True)
            os.rename(tmp, final)            # atomic publish
            self._gc()

        if self.async_save:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()
        else:
            write()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    # ------------------------------------------------------------------
    def latest_step(self) -> Optional[int]:
        # Exact-resume correctness: an async save still in flight must be
        # visible to the caller deciding which step to resume from.  Without
        # this wait, latest_step() could answer N while restore() (which
        # waits internally) restores N+k — a resumed run that silently
        # re-trains steps with a future state (the ~1e-3 loss drift bug).
        self.wait()
        steps = []
        for d in os.listdir(self.dir):
            m = _STEP_RE.match(d)
            if m and os.path.exists(os.path.join(self.dir, d, "manifest.json")):
                steps.append(int(m.group(1)))
        return max(steps) if steps else None

    def restore(self, like: Any, step: Optional[int] = None) -> Any:
        """Restore into the structure (and shardings) of ``like``.

        Callers resuming training should pin ``step`` to the value they got
        from :meth:`latest_step` so the loop counter and the restored state
        can never disagree (see :meth:`restore_latest`).
        """
        self.wait()
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {self.dir}")
        d = os.path.join(self.dir, f"step_{step}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)["leaves"]

        leaves_with_paths, treedef = jax.tree_util.tree_flatten_with_path(like)
        out = []
        for path, ref in leaves_with_paths:
            name = _path_str(path)
            arr = np.load(os.path.join(d, manifest[name]["file"]))
            val = jax.device_put(arr, getattr(ref, "sharding", None)) \
                if hasattr(ref, "sharding") else arr
            out.append(val)
        return jax.tree_util.tree_unflatten(treedef, out)

    def restore_latest(self, like: Any):
        """Atomically resolve (step, state, extra) for exact resume.

        Returns ``(None, like, {})`` when no checkpoint exists.  The returned
        step is the one actually restored — callers must resume the loop from
        it rather than re-deriving it with a second ``latest_step()`` call.
        """
        step = self.latest_step()
        if step is None:
            return None, like, {}
        return step, self.restore(like, step=step), self._read_extra(step)

    def load_extra(self, step: Optional[int] = None) -> dict:
        """The ``extra`` metadata dict saved alongside ``step`` (``{}`` for
        checkpoints written before this field existed)."""
        self.wait()
        if step is None:
            step = self.latest_step()
            if step is None:
                raise FileNotFoundError(f"no checkpoint in {self.dir}")
        return self._read_extra(step)

    def _read_extra(self, step: int) -> dict:
        with open(os.path.join(self.dir, f"step_{step}",
                               "manifest.json")) as f:
            return json.load(f).get("extra", {})

    def _gc(self) -> None:
        steps = []
        for d in os.listdir(self.dir):
            m = _STEP_RE.match(d)
            if m:
                steps.append(int(m.group(1)))
        steps.sort()
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{s}"),
                          ignore_errors=True)
