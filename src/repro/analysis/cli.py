"""``python -m repro.analysis`` — lint | verify-plan | audit-journal.

* ``lint`` imports the in-repo declaration sites (benchsuite kernels,
  daemon job kernels, runtime serving/trainer, plus any ``--file`` —
  e.g. the examples) and runs the access-mode checker over every
  registered ``GrFunction``.  Exit 1 on any under/over-declaration.
* ``verify-plan`` drives the benchsuite scenarios on the simulator —
  eager live windows, capture/replay plans, planopt-rewritten plans,
  budgeted out-of-core plans — and runs the happens-before verifier over
  every live DAG window and cached plan.  Exit 1 on any violation.
* ``audit-journal PATH...`` replays daemon JSONL journals through the
  lifecycle state machine.  Exit 1 on any illegal history.
"""
from __future__ import annotations

import argparse
import importlib
import importlib.util
import json
import sys
import time
from typing import List

_LINT_MODULES = (
    "repro.benchsuite.kernels",
    "repro.benchsuite.multitenant",
    "repro.benchsuite.multidevice",
    "repro.benchsuite.outofcore",
    "repro.benchsuite.slo",
    "repro.daemon.jobs",
    "repro.runtime.serving",
    "repro.runtime.trainer",
)


def _import_file(path: str, idx: int) -> None:
    name = f"_repro_lint_target_{idx}"
    spec = importlib.util.spec_from_file_location(name, path)
    if spec is None or spec.loader is None:
        raise ImportError(f"cannot load {path}")
    mod = importlib.util.module_from_spec(spec)
    sys.modules[name] = mod
    spec.loader.exec_module(mod)


def cmd_lint(args) -> int:
    from .modes import lint_functions

    for mod in list(_LINT_MODULES) + list(args.module or []):
        importlib.import_module(mod)
    for i, path in enumerate(args.file or []):
        _import_file(path, i)
    # Daemon job kernels are declared lazily inside the handler; poke it.
    try:
        from repro.daemon import jobs as _jobs
        _jobs._jax_chain_fns()
    except Exception:
        pass

    reports = lint_functions()
    issues = [i for r in reports for i in r.issues]
    if args.json:
        json.dump({"functions": len(reports),
                   "issues": len(issues),
                   "reports": [r.to_json() for r in reports]},
                  sys.stdout, indent=2, sort_keys=True)
        sys.stdout.write("\n")
    else:
        for r in sorted(reports, key=lambda r: r.function):
            if r.skipped:
                status = f"SKIP ({r.skipped})"
            elif r.ok:
                status = "OK"
            else:
                status = "ISSUES"
            print(f"lint: {r.function:<24} modes={','.join(r.modes):<40} "
                  f"{status}")
            for issue in r.issues:
                print(f"    {issue}")
        print(f"lint: {len(reports)} declaration(s), "
              f"{len(issues)} issue(s)")
    return 1 if issues else 0


# ----------------------------------------------------------------------
def _verify_and_report(sched, label: str, out: List[str]) -> None:
    from .verifier import verify_scheduler
    for v in verify_scheduler(sched):
        out.append(f"{label}: {v}")


def cmd_verify_plan(args) -> int:
    import numpy as np

    from repro.benchsuite import (BENCHMARKS, build_locality_heavy,
                                  build_outofcore, build_slo_workload,
                                  build_task_parallel, working_set_bytes)
    from repro.benchsuite.costmodel import P100, sim_hardware
    from repro.benchsuite.multitenant import build_contention
    from repro.core import make_scheduler

    violations: List[str] = []
    scale = args.scale

    # Paper benchmarks: eager + capture/replay episodes on the simulator.
    for bname, bench in sorted(BENCHMARKS.items()):
        s = make_scheduler("parallel", simulate=True,
                           hw=sim_hardware(P100, "parallel", True))
        try:
            data = bench.make_data(scale)
            for ep in range(2):
                with s.capture(f"verify_{bname}"):
                    bench.build(s, data, gpu=P100, iters=2)
                _verify_and_report(s, f"bench {bname} ep{ep}", violations)
                s.sync()
        finally:
            s.shutdown()
        print(f"verify-plan: {bname}: "
              f"{'OK' if not violations else 'VIOLATIONS'}")

    # Multi-device scenarios, with the plan-time optimizer on (verifies a
    # planopt-rewritten plan, not just the greedy recording).
    for name, builder, kw in (
            ("task_parallel", build_task_parallel,
             dict(branches=3, chain=3, n=1 << 10)),
            ("locality_heavy", build_locality_heavy,
             dict(groups=2, iters=3, n=1 << 10))):
        s = make_scheduler("parallel", simulate=True, num_devices=2,
                           placement="round-robin", plan_optimize=True)
        try:
            for ep in range(2):
                with s.capture(f"verify_{name}"):
                    builder(s, **kw)
                _verify_and_report(s, f"scenario {name} ep{ep}", violations)
                s.sync()
        finally:
            s.shutdown()
        print(f"verify-plan: {name}: "
              f"{'OK' if not violations else 'VIOLATIONS'}")

    # Budgeted out-of-core: EVICT/RELOAD liveness on a memory-scheduled
    # plan (planopt Belady path) and on the greedy recording.
    chunks, n = 6, 1 << 10
    for opt in (False, True):
        s = make_scheduler("parallel", simulate=True,
                           memory_budget=working_set_bytes(chunks, n) // 2,
                           plan_optimize=opt)
        try:
            for ep in range(2):
                with s.capture("verify_ooc"):
                    build_outofcore(s, chunks=chunks, n=n)
                _verify_and_report(
                    s, f"scenario ooc(opt={opt}) ep{ep}", violations)
                s.sync()
        finally:
            s.shutdown()
    print(f"verify-plan: outofcore: "
          f"{'OK' if not violations else 'VIOLATIONS'}")

    # Multi-tenant contention + SLO workloads (live windows, no capture).
    s = make_scheduler("parallel", simulate=True)
    try:
        build_contention(s, bulk_kernels=3, latency_streams=2, per_stream=3,
                         n=1 << 10)
        _verify_and_report(s, "scenario contention", violations)
        s.sync()
    finally:
        s.shutdown()
    s = make_scheduler("parallel", simulate=True)
    try:
        build_slo_workload(s, bulk_units=6, latency_chains=2, per_chain=2)
        _verify_and_report(s, "scenario slo", violations)
        s.sync()
    finally:
        s.shutdown()
    print(f"verify-plan: contention+slo: "
          f"{'OK' if not violations else 'VIOLATIONS'}")

    # A tiny real-executor episode keeps the non-sim path honest.
    s = make_scheduler("parallel")
    try:
        from repro.benchsuite import kernels as K
        x = s.array(np.linspace(0.5, 1.5, 256, dtype=np.float32), name="vx")
        y = s.array(shape=(256,), dtype=np.float32, name="vy")
        z = s.array(shape=(1,), dtype=np.float32, name="vz")
        K.SQUARE.with_options(scheduler=s)(x, y)
        K.L2_NORM.with_options(scheduler=s)(y, z)
        float(z[0])
        _verify_and_report(s, "scenario real-executor", violations)
        s.sync()
    finally:
        s.shutdown()

    for v in violations:
        print(f"verify-plan: VIOLATION {v}", file=sys.stderr)
    print(f"verify-plan: {len(violations)} violation(s)")
    return 1 if violations else 0


# ----------------------------------------------------------------------
def cmd_audit_journal(args) -> int:
    from .journal import audit_journal

    bad = 0
    for path in args.paths:
        audit = audit_journal(path)
        if args.json:
            json.dump(audit.to_json(), sys.stdout, indent=2, sort_keys=True)
            sys.stdout.write("\n")
        else:
            print(f"audit: {path}: {audit.records} record(s), "
                  f"{audit.jobs} job(s), "
                  f"{'torn tail, ' if audit.torn_tail else ''}"
                  f"{'OK' if audit.ok else 'PROBLEMS'}")
            for note in audit.notes:
                print(f"    note: {note}")
            for p in audit.problems:
                print(f"    problem: {p}")
        bad += 0 if audit.ok else 1
    return 1 if bad else 0


# ----------------------------------------------------------------------
def build_parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(
        prog="repro-analysis",
        description="Static analysis for the GrJAX runtime: access-mode "
                    "lint, DAG/plan race verification, journal audits.")
    sub = p.add_subparsers(dest="cmd", required=True)

    lint = sub.add_parser("lint", help="check declared access modes "
                                       "against actual kernel behavior")
    lint.add_argument("--module", action="append",
                      help="extra module to import for declarations")
    lint.add_argument("--file", action="append",
                      help="extra python file to import (e.g. an example)")
    lint.add_argument("--json", action="store_true")

    vp = sub.add_parser("verify-plan",
                        help="verify live DAGs and captured plans over "
                             "the benchsuite scenarios")
    vp.add_argument("--scale", type=float, default=0.001,
                    help="benchsuite problem scale (default tiny)")

    aj = sub.add_parser("audit-journal",
                        help="audit daemon JSONL job journals")
    aj.add_argument("paths", nargs="+", help="journal file(s)")
    aj.add_argument("--json", action="store_true")
    return p


def main(argv=None) -> int:
    args = build_parser().parse_args(argv)
    t0 = time.perf_counter()
    if args.cmd == "lint":
        rc = cmd_lint(args)
    elif args.cmd == "verify-plan":
        rc = cmd_verify_plan(args)
    else:
        rc = cmd_audit_journal(args)
    print(f"repro-analysis: {args.cmd} finished in "
          f"{time.perf_counter() - t0:.2f}s (exit {rc})")
    return rc


if __name__ == "__main__":       # pragma: no cover
    raise SystemExit(main())
