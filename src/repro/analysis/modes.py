"""Access-mode checker: infer what a kernel *actually* reads and writes.

Every ``GrFunction`` declares per-argument access modes (``const`` /
``out`` / ``inout``) and the scheduler builds the dependency DAG from
nothing else.  The contract (paper §IV-D + the executor's install
convention) is:

* a kernel is a pure function of the device values of its arguments, in
  declared order, *including* output placeholders;
* it returns the new values of its writable (``out``/``inout``) arguments,
  in declared order — the executor installs them;
* ``const`` operands are never written, ``out`` operands' *prior values*
  are never read (their shape/dtype may be used — that is static).

The checker abstractly executes the kernel and compares behavior against
the declaration:

* **under-declaration** (correctness): the kernel returns more outputs
  than there are writable args (a computed value has no declared
  destination → the write drops DAG edges), a declared-``out`` operand's
  input *value* flows to an output (replay would read stale device
  contents), or the kernel mutates a ``const`` numpy operand in place;
* **over-declaration** (performance): the kernel returns fewer outputs
  than there are writable args (a declared write that never happens
  serializes every later reader), or a declared-``inout`` operand is never
  read (forces a spurious H2D prefetch/reload of dead data).

Inference is jaxpr-based: the kernel is traced with
:func:`jax.make_jaxpr` on shadow ``ShapeDtypeStruct`` operands and the
read-set is the backward reachability of the output variables through the
equations (recursing into sub-jaxprs, conservative where operand alignment
is unclear — conservatism can only *suppress* a report, never fabricate
one).  A concrete dual pass with read-only numpy operands catches in-place
mutation through ``const``.  Kernels that cannot be traced (``fn=None``
sim-only declarations, shape-sensitive kernels without
``lint_shapes`` hints) are reported as *skipped*, never as errors.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Iterable, List, Optional, Sequence, Tuple

import numpy as np

from ..core.element import AccessMode

try:  # pragma: no cover - exercised indirectly everywhere
    import jax
    from jax import core as _jcore
except Exception:  # pragma: no cover - jax is a hard dep of the runtime
    jax = None
    _jcore = None


@dataclass(frozen=True)
class ModeIssue:
    """One mismatch between a declaration and observed kernel behavior."""

    function: str
    kind: str                   # "under" (correctness) | "over" (performance)
    message: str
    arg: Optional[int] = None   # argument position, when attributable
    declared: Optional[str] = None

    def __str__(self) -> str:
        where = f" arg {self.arg}" if self.arg is not None else ""
        return f"[{self.kind}] {self.function}{where}: {self.message}"


@dataclass
class ModeReport:
    """Result of analyzing one declared ``GrFunction``."""

    function: str
    modes: Tuple[str, ...]
    issues: List[ModeIssue] = field(default_factory=list)
    reads: Optional[Tuple[bool, ...]] = None   # inferred value-read per arg
    n_outputs: Optional[int] = None            # values the kernel returns
    skipped: Optional[str] = None              # reason when unanalyzable

    @property
    def ok(self) -> bool:
        return not self.issues

    def to_json(self) -> dict:
        return {
            "function": self.function,
            "modes": list(self.modes),
            "reads": list(self.reads) if self.reads is not None else None,
            "n_outputs": self.n_outputs,
            "skipped": self.skipped,
            "issues": [{"kind": i.kind, "arg": i.arg,
                        "declared": i.declared, "message": i.message}
                       for i in self.issues],
        }


# ----------------------------------------------------------------------
# jaxpr read-set inference
# ----------------------------------------------------------------------

def _is_literal(v: Any) -> bool:
    return _jcore is not None and isinstance(v, _jcore.Literal)


def _sub_jaxprs(eqn) -> List[Any]:
    """Collect inner (Closed)Jaxprs from an equation's params."""
    subs = []
    for v in eqn.params.values():
        vals = v if isinstance(v, (tuple, list)) else (v,)
        for item in vals:
            if _jcore is not None and isinstance(
                    item, (_jcore.Jaxpr, _jcore.ClosedJaxpr)):
                subs.append(item)
    return subs


def _inner_jaxpr(obj):
    return obj.jaxpr if hasattr(obj, "jaxpr") else obj


def _value_read_positions(jaxpr) -> set:
    """Positions of ``jaxpr.invars`` whose *value* can reach an output.

    Backward reachability from the outvars.  Call-like primitives with a
    single sub-jaxpr whose invars align 1:1 with the equation's invars
    (pjit, remat, custom_* wrappers) are recursed into so an operand that
    is dead *inside* the call does not count as read; anything whose
    operand alignment is unclear (scan/while/cond consts splitting) keeps
    every operand — conservative in the direction that only suppresses
    over-declaration reports.
    """
    live = {id(v) for v in jaxpr.outvars if not _is_literal(v)}
    for eqn in reversed(jaxpr.eqns):
        if not any(id(v) in live for v in eqn.outvars):
            continue
        used: Iterable[Any] = eqn.invars
        subs = _sub_jaxprs(eqn)
        if len(subs) == 1:
            inner = _inner_jaxpr(subs[0])
            if len(inner.invars) == len(eqn.invars):
                inner_reads = _value_read_positions(inner)
                used = [eqn.invars[i] for i in inner_reads]
        for v in used:
            if not _is_literal(v):
                live.add(id(v))
    return {i for i, v in enumerate(jaxpr.invars) if id(v) in live}


# ----------------------------------------------------------------------
# shadow operands
# ----------------------------------------------------------------------

_DEFAULT_SHAPE_CANDIDATES: Tuple[Tuple[Tuple[int, ...], Any], ...] = (
    ((8, 8), np.float32),
    ((8,), np.float32),
)


def _candidate_spec_sets(gf, n_args: int,
                         shapes: Optional[Sequence] = None):
    """Yield lists of (shape, dtype) pairs to trace with.

    Order of preference: explicit ``shapes`` argument, the declaration's
    ``lint_shapes`` hint, then generic fallbacks (all-2D f32, all-1D f32).
    """
    hint = shapes if shapes is not None else getattr(gf, "lint_shapes", None)
    if hint is not None:
        yield [(tuple(s), np.dtype(d)) for s, d in hint]
        return
    for shape, dtype in _DEFAULT_SHAPE_CANDIDATES:
        yield [(shape, np.dtype(dtype))] * n_args


def _concrete_fill(shape, dtype, salt: int) -> np.ndarray:
    n = int(np.prod(shape)) if shape else 1
    if np.issubdtype(dtype, np.integer):
        vals = (np.arange(n) + salt) % 7
    elif np.issubdtype(dtype, np.bool_):
        vals = (np.arange(n) + salt) % 2
    else:
        vals = (np.arange(n) + salt) * 0.125 + 0.5
    return np.asarray(vals, dtype=dtype).reshape(shape)


def _check_inplace_const(fn, specs, modes) -> Optional[int]:
    """Run the kernel on read-only numpy operands for every ``const`` arg;
    an in-place write through one raises ``ValueError: ... read-only``.
    Returns the offending arg position, or None."""
    arrs = []
    for i, (shape, dtype) in enumerate(specs):
        a = _concrete_fill(shape, dtype, salt=3 * i + 1)
        if not modes[i].writes:
            a.setflags(write=False)
        arrs.append(a)
    try:
        fn(*arrs)
    except ValueError as exc:
        msg = str(exc).lower()
        if "read-only" in msg or "not writeable" in msg:
            # Re-run flipping one const arg writable at a time to attribute.
            for i in range(len(arrs)):
                if modes[i].writes:
                    continue
                probe = [np.array(a) for a in arrs]
                for j in range(len(probe)):
                    if not modes[j].writes and j != i:
                        probe[j].setflags(write=False)
                try:
                    fn(*probe)
                except ValueError:
                    continue
                except Exception:
                    return None
                return i
            return -1  # some const arg, position unknown
    except Exception:
        pass        # concrete pass is best-effort; tracing is the oracle
    return None


# ----------------------------------------------------------------------
# the checker
# ----------------------------------------------------------------------

def analyze_function(gf, shapes: Optional[Sequence] = None) -> ModeReport:
    """Infer read/write behavior of one declared ``GrFunction`` and diff it
    against the declared access modes.  Never raises for unanalyzable
    kernels — those come back with ``report.skipped`` set."""
    modes: Tuple[AccessMode, ...] = tuple(gf.modes)
    mode_names = tuple(m.value for m in modes)
    name = getattr(gf, "name", None) or getattr(gf.fn, "__name__", "<fn>")
    report = ModeReport(function=name, modes=mode_names)
    fn = gf.fn
    if fn is None:
        report.skipped = "no kernel callable (sim-only declaration)"
        return report
    if jax is None:  # pragma: no cover - jax always present in this repo
        report.skipped = "jax unavailable"
        return report

    closed = None
    n_out = None
    last_error: Optional[str] = None
    chosen_specs = None
    for specs in _candidate_spec_sets(gf, len(modes), shapes):
        if len(specs) != len(modes):
            last_error = (f"lint_shapes has {len(specs)} entries for "
                          f"{len(modes)} declared args")
            continue
        sds = [jax.ShapeDtypeStruct(s, d) for s, d in specs]
        try:
            closed = jax.make_jaxpr(fn)(*sds)
            out_tree = jax.eval_shape(fn, *sds)
        except Exception as exc:
            last_error = f"{type(exc).__name__}: {exc}"
            continue
        n_out = (len(out_tree) if isinstance(out_tree, (tuple, list))
                 else 1)
        chosen_specs = specs
        break
    if closed is None:
        report.skipped = f"trace failed: {last_error}"
        return report

    read_positions = _value_read_positions(closed.jaxpr)
    report.reads = tuple(i in read_positions for i in range(len(modes)))
    report.n_outputs = n_out

    writable = [i for i, m in enumerate(modes) if m.writes]
    if n_out > len(writable):
        report.issues.append(ModeIssue(
            function=name, kind="under",
            message=(f"kernel returns {n_out} outputs but only "
                     f"{len(writable)} args are declared writable — a "
                     f"computed value has no declared destination, so its "
                     f"write carries no DAG edges (and the executor would "
                     f"reject the launch)")))
    elif n_out < len(writable):
        report.issues.append(ModeIssue(
            function=name, kind="over",
            message=(f"declares {len(writable)} writable (out/inout) args "
                     f"but the kernel returns {n_out} outputs — the phantom "
                     f"write serializes every later reader of that operand "
                     f"behind a store that never happens")))

    for i, m in enumerate(modes):
        is_read = i in read_positions
        if m is AccessMode.OUT and is_read:
            report.issues.append(ModeIssue(
                function=name, kind="under", arg=i, declared=m.value,
                message=("declared 'out' but the operand's input value "
                         "flows to an output — the runtime skips the H2D "
                         "refresh for pure outputs, so the kernel reads "
                         "stale device contents; declare 'inout'")))
        elif m is AccessMode.INOUT and not is_read:
            report.issues.append(ModeIssue(
                function=name, kind="over", arg=i, declared=m.value,
                message=("declared 'inout' but the operand's prior value "
                         "is never read — forces a spurious host→device "
                         "prefetch/reload of dead data; declare 'out'")))

    if chosen_specs is not None:
        bad = _check_inplace_const(fn, chosen_specs, modes)
        if bad is not None:
            report.issues.append(ModeIssue(
                function=name, kind="under",
                arg=bad if bad >= 0 else None, declared="const",
                message=("kernel mutates a 'const' operand in place — the "
                         "write is invisible to the DAG (no WAR/WAW edges) "
                         "and races every concurrent reader; declare "
                         "'inout'")))
    return report


def lint_functions(fns: Optional[Iterable] = None) -> List[ModeReport]:
    """Analyze every declared ``GrFunction`` (default: the process-wide
    declaration registry) and return one report per declaration."""
    if fns is None:
        from ..core.frontend import declared_functions
        fns = declared_functions()
    reports = []
    seen = set()
    for gf in fns:
        fid = getattr(gf, "fid", None)
        if fid is not None:
            if fid in seen:
                continue
            seen.add(fid)
        reports.append(analyze_function(gf))
    return reports
