"""Static analysis and runtime sanitization for the GrJAX runtime.

The scheduler infers the whole dependency DAG from declared access modes
(paper §IV-D) — which makes a wrong annotation invisible at runtime: a
``const`` on a written operand silently drops an edge and races kernels, an
``inout`` on a read-only operand serializes work the space-sharing
scheduler should overlap.  This package is the correctness tooling:

* :mod:`~repro.analysis.modes` — abstract execution of declared
  ``GrFunction`` kernels to infer actual read/write behavior vs modes;
* :mod:`~repro.analysis.verifier` — happens-before verification of live
  DAGs and captured/optimized :class:`ExecutionPlan` objects;
* :mod:`~repro.analysis.sanitizer` — runtime shadow tracking
  (``GrScheduler(sanitize=True)``) raising on observed races and
  writes-through-const;
* :mod:`~repro.analysis.journal` — offline audits of the daemon's JSONL
  job journal against the lifecycle state machine.

CLI: ``python -m repro.analysis lint|verify-plan|audit-journal``.
"""
from .journal import JournalAudit, audit_journal
from .modes import ModeIssue, ModeReport, analyze_function, lint_functions
from .sanitizer import Sanitizer, SanitizerError
from .verifier import (PlanVerificationError, Violation, verify_elements,
                       verify_plan, verify_scheduler)

__all__ = [
    "ModeIssue", "ModeReport", "analyze_function", "lint_functions",
    "Violation", "PlanVerificationError", "verify_plan", "verify_elements",
    "verify_scheduler", "Sanitizer", "SanitizerError",
    "JournalAudit", "audit_journal",
]
