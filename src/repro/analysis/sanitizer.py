"""Runtime sanitizer: shadow version-vectors + const checksum canaries.

``GrScheduler(sanitize=True)`` installs a :class:`Sanitizer` on the
executor's element-boundary hooks (``pre_exec``/``post_exec``, called
around every element body on both executors).  It shadow-tracks, per
``dep_key``:

* the **in-flight access set** — which elements currently hold the array
  for reading/writing.  A write beginning while another access is in
  flight, or a read beginning while a write is in flight, is an observed
  race (a conflicting pair the DAG failed to order) and raises
  :class:`SanitizerError` immediately, attributing both elements;
* a **version counter**, bumped at each write completion.  Readers record
  the version at element start and re-check it at completion;
* on the real executor, a **checksum canary** over ``const`` operands:
  the operand's bytes are hashed before and after the kernel body, so a
  kernel that mutates a const-declared operand in place (a write the DAG
  cannot see) is caught at the element boundary.

The tracking is purely observational: it never blocks, reorders or
copies, so ``sanitize=False`` (the default — no hooks installed) is
bit-identical, and sim-executor timelines are unchanged even when it is
on (the hooks run outside the simulated clock).
"""
from __future__ import annotations

import threading
import zlib
from typing import Dict, List, Optional, Set, Tuple

import numpy as np

from ..core.element import AccessMode, ComputationalElement


class SanitizerError(RuntimeError):
    """An observed race or write-through-const at an element boundary."""


class _KeyState:
    __slots__ = ("version", "writer", "writer_name", "readers")

    def __init__(self) -> None:
        self.version = 0
        self.writer: Optional[int] = None       # uid of in-flight writer
        self.writer_name = ""
        self.readers: Dict[int, str] = {}       # uid -> name, in-flight reads


def _array_name(e: ComputationalElement, key) -> str:
    for a in e.args:
        if a.key == key:
            return getattr(a.array, "name", None) or str(key)
    return str(key)


def _const_bytes(e: ComputationalElement, key) -> Optional[bytes]:
    """Current value bytes of the operand behind ``key`` (device copy if
    valid, else host copy); None when no concrete value exists (sim)."""
    for a in e.args:
        if a.key != key:
            continue
        ma = a.array
        try:
            if getattr(ma, "device_valid", False) and \
                    getattr(ma, "device", None) is not None:
                return np.asarray(ma.device).tobytes()
            if getattr(ma, "host_valid", False) and \
                    getattr(ma, "host", None) is not None:
                return np.asarray(ma.host).tobytes()
        except Exception:
            return None
    return None


class Sanitizer:
    """Thread-safe shadow tracker; see the module docstring."""

    def __init__(self, checksums: bool = False) -> None:
        self.checksums = bool(checksums)
        self._lock = threading.Lock()
        self._state: Dict[object, _KeyState] = {}
        self._modes: Dict[int, Dict[object, AccessMode]] = {}
        self._observed: Dict[int, List[Tuple[object, int]]] = {}
        self._canaries: Dict[int, List[Tuple[object, int]]] = {}
        self.elements_checked = 0
        self.races_detected = 0

    # ------------------------------------------------------------------
    def on_schedule(self, e: ComputationalElement) -> None:
        """Snapshot the declared access set at submission time (args can
        be rebound later on replay paths; the declaration is the claim
        being audited)."""
        with self._lock:
            self._modes[e.uid] = dict(e.arg_modes())

    def _modes_of(self, e: ComputationalElement) -> Dict[object, AccessMode]:
        return self._modes.get(e.uid) or dict(e.arg_modes())

    # ------------------------------------------------------------------
    def pre_exec(self, e: ComputationalElement) -> None:
        """Element body is about to run: claim its declared accesses and
        raise on any conflicting in-flight access."""
        with self._lock:
            modes = self._modes_of(e)
            observed: List[Tuple[object, int]] = []
            canaries: List[Tuple[object, int]] = []
            for key, mode in modes.items():
                st = self._state.setdefault(key, _KeyState())
                aname = _array_name(e, key)
                if mode.writes:
                    if st.writer is not None and st.writer != e.uid:
                        self.races_detected += 1
                        raise SanitizerError(
                            f"write-write race on array {aname!r}: "
                            f"{e.name}(uid {e.uid}) began while "
                            f"{st.writer_name}(uid {st.writer}) is still "
                            f"writing — the DAG never ordered this WAW "
                            f"pair")
                    if st.readers:
                        ruid, rname = next(iter(st.readers.items()))
                        self.races_detected += 1
                        raise SanitizerError(
                            f"read-write race on array {aname!r}: writer "
                            f"{e.name}(uid {e.uid}) began while "
                            f"{rname}(uid {ruid}) is still reading — the "
                            f"DAG never ordered this WAR pair")
                    st.writer, st.writer_name = e.uid, e.name
                else:
                    if st.writer is not None:
                        self.races_detected += 1
                        raise SanitizerError(
                            f"write-read race on array {aname!r}: reader "
                            f"{e.name}(uid {e.uid}) began while "
                            f"{st.writer_name}(uid {st.writer}) is still "
                            f"writing — the DAG never ordered this RAW "
                            f"pair")
                    st.readers[e.uid] = e.name
                    observed.append((key, st.version))
                    if self.checksums and mode is AccessMode.CONST:
                        data = _const_bytes(e, key)
                        if data is not None:
                            canaries.append((key, zlib.crc32(data)))
            if observed:
                self._observed[e.uid] = observed
            if canaries:
                self._canaries[e.uid] = canaries

    def post_exec(self, e: ComputationalElement) -> None:
        """Element body finished: release claims, bump write versions,
        re-check read versions and const checksums."""
        with self._lock:
            modes = self._modes_of(e)
            observed = dict(self._observed.pop(e.uid, ()))
            canaries = dict(self._canaries.pop(e.uid, ()))
            self._modes.pop(e.uid, None)
            self.elements_checked += 1
            for key, mode in modes.items():
                st = self._state.get(key)
                if st is None:
                    continue
                aname = _array_name(e, key)
                if mode.writes:
                    if st.writer == e.uid:
                        st.writer, st.writer_name = None, ""
                    st.version += 1
                else:
                    st.readers.pop(e.uid, None)
                    v0 = observed.get(key)
                    if v0 is not None and st.version != v0:
                        self.races_detected += 1
                        raise SanitizerError(
                            f"torn read on array {aname!r}: {e.name}"
                            f"(uid {e.uid}) observed version {v0} at start "
                            f"but {st.version} at completion — a writer "
                            f"ran mid-read without a DAG edge")
                    crc0 = canaries.get(key)
                    if crc0 is not None:
                        data = _const_bytes(e, key)
                        if data is not None and zlib.crc32(data) != crc0:
                            self.races_detected += 1
                            raise SanitizerError(
                                f"write through const on array {aname!r}: "
                                f"checksum changed across {e.name}"
                                f"(uid {e.uid}) — the kernel (or a "
                                f"concurrent element) mutated a "
                                f"const-declared operand in place")
                if st.writer is None and not st.readers and st.version == 0:
                    self._state.pop(key, None)

    # ------------------------------------------------------------------
    def in_flight(self) -> Set[int]:
        with self._lock:
            uids: Set[int] = set()
            for st in self._state.values():
                if st.writer is not None:
                    uids.add(st.writer)
                uids.update(st.readers)
            return uids

    def stats(self) -> dict:
        with self._lock:
            return {"sanitizer_elements_checked": self.elements_checked,
                    "sanitizer_races_detected": self.races_detected,
                    "sanitizer_tracked_keys": len(self._state)}
