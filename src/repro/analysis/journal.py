"""Offline auditor for the daemon's JSONL job journal.

The daemon journals every job mutation as one JSON line (last record
wins) and replays the file on restart; :mod:`repro.daemon.lifecycle`
defines the legal state machine.  The auditor replays a journal *without
mutating it* and flags:

* **torn records** anywhere but the tail (a torn tail is the legal crash
  frontier — the store truncates it on recovery — but a torn record with
  valid records after it means lost history / concurrent writers);
* **illegal transition histories** per job, via
  :func:`lifecycle.validate_history` (unknown states, illegal edges,
  broken chaining, transitions out of terminal states) plus timestamp
  monotonicity;
* **non-append-only rewrites**: each journal snapshot of a job must
  extend the previous snapshot's transition list — a snapshot whose
  history is *not* an extension means the record was mutated, not
  appended;
* **state/history divergence**: the record's ``state`` field must equal
  the destination of its last transition.
"""
from __future__ import annotations

import json
from dataclasses import dataclass, field
from typing import Dict, List

from ..daemon.lifecycle import JobState, validate_history


@dataclass
class JournalAudit:
    """Result of auditing one journal file."""

    path: str
    records: int = 0
    jobs: int = 0
    torn_tail: bool = False
    problems: List[str] = field(default_factory=list)
    notes: List[str] = field(default_factory=list)

    @property
    def ok(self) -> bool:
        return not self.problems

    def to_json(self) -> dict:
        return {"path": self.path, "records": self.records,
                "jobs": self.jobs, "torn_tail": self.torn_tail,
                "ok": self.ok, "problems": list(self.problems),
                "notes": list(self.notes)}


def _as_triples(transitions) -> List[tuple]:
    return [tuple(t) for t in (transitions or [])]


def audit_journal(path: str) -> JournalAudit:
    """Audit one JSONL journal; never modifies the file."""
    audit = JournalAudit(path=str(path))
    try:
        with open(path) as fh:
            raw_lines = fh.read().splitlines()
    except OSError as exc:
        audit.problems.append(f"unreadable journal: {exc}")
        return audit

    parsed: List[tuple] = []        # (line_no, record dict)
    torn: List[int] = []
    for no, line in enumerate(raw_lines, start=1):
        if not line.strip():
            continue
        try:
            rec = json.loads(line)
            if not isinstance(rec, dict) or "job" not in rec:
                raise ValueError("record is not a {'t', 'job'} object")
        except ValueError:
            torn.append(no)
            continue
        parsed.append((no, rec))
    audit.records = len(parsed)
    for no in torn:
        if parsed and no > parsed[-1][0]:
            # Beyond the last valid record: the legal crash frontier.
            audit.torn_tail = True
            audit.notes.append(
                f"torn tail record at line {no} (truncated on recovery)")
        else:
            audit.problems.append(
                f"torn record at line {no} with valid records after it — "
                f"lost history or concurrent writers")

    histories: Dict[str, List[tuple]] = {}
    last_record: Dict[str, dict] = {}
    for no, rec in parsed:
        job = rec.get("job") or {}
        jid = job.get("job_id")
        if not jid:
            audit.problems.append(f"line {no}: record without a job_id")
            continue
        trans = _as_triples(job.get("transitions"))
        prev = histories.get(jid)
        if prev is not None and trans[:len(prev)] != prev:
            audit.problems.append(
                f"job {jid}: snapshot at line {no} does not extend the "
                f"previous transition history — journal was rewritten, "
                f"not appended")
        if prev is None or len(trans) >= len(prev):
            histories[jid] = trans
        last_record[jid] = job

    audit.jobs = len(last_record)
    valid_states = {s.value for s in JobState}
    for jid, job in sorted(last_record.items()):
        trans = _as_triples(job.get("transitions"))
        for msg in validate_history(trans, check_times=True):
            audit.problems.append(f"job {jid}: {msg}")
        state = job.get("state")
        if state not in valid_states:
            audit.problems.append(f"job {jid}: unknown state {state!r}")
        elif trans and trans[-1][1] != state:
            audit.problems.append(
                f"job {jid}: recorded state {state!r} != last transition "
                f"destination {trans[-1][1]!r}")
        elif not trans and state != JobState.QUEUED.value:
            audit.problems.append(
                f"job {jid}: state {state!r} with an empty transition "
                f"history (jobs are born {JobState.QUEUED.value!r})")
    return audit
