"""Happens-before verification of live DAGs and captured execution plans.

Two conflicting accesses to the same array (same ``dep_key``/slot, at
least one write) must be *ordered*: RAW, WAR and WAW pairs all need a path
in the transitive closure of the ordering edges.  What counts as an
ordering edge differs by artifact:

* **Captured plans** replay through lane FIFOs plus recorded cross-lane
  ``wait_events`` — so the execution closure is lane-order ∪ wait_events,
  and the recorded ``parents`` are *claims* checked against that closure
  (a parent not enforced by lane order or an event is a lane/event
  inconsistency even before it loses a race).
* **Live DAGs** are ordered by the inferred parent edges themselves
  (that is precisely what the verifier audits: a dropped edge on a
  conflicting pair is a race even if today's lane assignment happens to
  serialize it), plus host-access barriers: a host read/write blocks the
  submitting thread until its frontier completes, so it orders before
  everything submitted after it returned.

Plans additionally get an evict/reload liveness check: after an EVICT of
a slot, no kernel may read that slot until a TRANSFER/RELOAD/D2D places
it back (a pure ``out`` write also re-materializes it).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

from ..core.element import (AccessMode, ComputationalElement, ElementKind)

_COMPUTE_KINDS = (ElementKind.KERNEL, ElementKind.LIBRARY)
_PLACING_KINDS = (ElementKind.TRANSFER, ElementKind.RELOAD, ElementKind.D2D)


@dataclass(frozen=True)
class Violation:
    """One verified ordering/consistency defect."""

    kind: str        # "race" | "parent-order" | "liveness" | "structure"
    message: str
    elements: Tuple[int, ...] = ()   # uids (live) or plan indices

    def __str__(self) -> str:
        return f"[{self.kind}] {self.message}"


class PlanVerificationError(RuntimeError):
    """Raised (under ``sanitize=True``) when a plan fails verification."""

    def __init__(self, name: str, violations: Sequence[Violation]) -> None:
        self.violations = list(violations)
        lines = "\n  ".join(str(v) for v in self.violations)
        super().__init__(
            f"plan {name!r} failed verification "
            f"({len(self.violations)} violation(s)):\n  {lines}")


def _race_kind(m1: AccessMode, m2: AccessMode) -> str:
    if m1.writes and m2.writes:
        return "WAW"
    return "RAW" if m1.writes else "WAR"


# ======================================================================
# Captured plans
# ======================================================================

def verify_plan(plan) -> List[Violation]:
    """Check one :class:`ExecutionPlan` (greedy-recorded or
    planopt-rewritten) for unordered conflicts, lane/event inconsistency
    and evict/reload liveness.  Returns violations; empty list = green."""
    out: List[Violation] = []
    elements = list(plan.elements)
    n = len(elements)
    lane_dev: Dict[int, Optional[int]] = dict(plan.lane_devices)

    # -- structure: indices must be 0..n-1 in topological (record) order.
    for pos, pe in enumerate(elements):
        if pe.index != pos:
            out.append(Violation(
                "structure",
                f"element #{pos} carries index {pe.index}", (pos,)))
            return out      # positional reasoning is unsound beyond this

    # -- execution happens-before closure: lane FIFO ∪ wait_events.
    hb = [0] * n
    last_on_lane: Dict[int, int] = {}
    for i, pe in enumerate(elements):
        preds = list(pe.wait_events)
        if pe.lane in last_on_lane:
            preds.append(last_on_lane[pe.lane])
        mask = 0
        for p in preds:
            if not 0 <= p < i:
                out.append(Violation(
                    "structure",
                    f"{pe.name}#{i} waits on non-preceding index {p}",
                    (i,)))
                continue
            mask |= hb[p] | (1 << p)
        hb[i] = mask
        last_on_lane[pe.lane] = i

        # -- recorded parents must be enforced by lane order or events.
        for p in pe.parents:
            if not 0 <= p < i or not (mask >> p) & 1:
                pname = elements[p].name if 0 <= p < n else "?"
                out.append(Violation(
                    "parent-order",
                    f"{pe.name}#{i} declares parent {pname}#{p} but no "
                    f"lane-FIFO/event path enforces it at replay",
                    (p, i)))

        # -- lane/device consistency.
        expect = lane_dev.get(pe.lane)
        if (pe.device is not None and expect is not None
                and pe.device != expect):
            out.append(Violation(
                "structure",
                f"{pe.name}#{i} targets device {pe.device} but lane "
                f"{pe.lane} is bound to device {expect}", (i,)))

    # -- merged per-slot access modes per element (write wins).
    def merged(pe) -> Dict[int, AccessMode]:
        acc: Dict[int, AccessMode] = {}
        for slot, mode in pe.arg_slots:
            prev = acc.get(slot)
            if prev is None or (mode.writes and not prev.writes):
                acc[slot] = mode
            elif prev.writes and mode.reads and not prev.reads:
                acc[slot] = AccessMode.INOUT
        return acc

    accesses: Dict[int, List[Tuple[int, AccessMode]]] = {}
    for i, pe in enumerate(elements):
        for slot, mode in merged(pe).items():
            accesses.setdefault(slot, []).append((i, mode))

    # -- every conflicting pair must be ordered in the execution closure.
    for slot, acc in accesses.items():
        sname = plan.slots[slot].name if slot < len(plan.slots) else slot
        for a in range(len(acc)):
            i, mi = acc[a]
            for b in range(a + 1, len(acc)):
                j, mj = acc[b]
                if not mi.conflicts_with(mj):
                    continue
                if not (hb[j] >> i) & 1:
                    out.append(Violation(
                        "race",
                        f"unordered {_race_kind(mi, mj)} on slot "
                        f"{sname!r}: {elements[i].name}#{i} "
                        f"({mi.value}) vs {elements[j].name}#{j} "
                        f"({mj.value})", (i, j)))

    # -- evict/reload liveness (plan order is record order).
    evicted: Dict[int, int] = {}            # slot -> evicting index
    for i, pe in enumerate(elements):
        slots_here = merged(pe)
        if pe.kind is ElementKind.EVICT:
            for slot in slots_here:
                evicted[slot] = i
        elif pe.kind in _PLACING_KINDS:
            for slot in slots_here:
                evicted.pop(slot, None)
        elif pe.kind in _COMPUTE_KINDS:
            for slot, mode in slots_here.items():
                if slot in evicted and mode.reads:
                    sname = (plan.slots[slot].name
                             if slot < len(plan.slots) else slot)
                    out.append(Violation(
                        "liveness",
                        f"{pe.name}#{i} reads slot {sname!r} between its "
                        f"EVICT (#{evicted[slot]}) and the next reload",
                        (evicted[slot], i)))
                elif slot in evicted and mode.writes:
                    evicted.pop(slot, None)   # pure write re-materializes
    return out


# ======================================================================
# Live DAGs
# ======================================================================

def verify_elements(elements: Sequence[ComputationalElement],
                    host_log: Sequence[Tuple[int, ComputationalElement]] = (),
                    total_order: bool = False) -> List[Violation]:
    """Check a submission-ordered element window (``sched._elements``
    since the last full sync) for conflicting pairs not covered by the
    transitive closure of the inferred parent edges.

    ``host_log`` holds ``(position, host_element)`` entries: the host
    element's frontier wait completed before ``elements[position:]`` were
    submitted, so it bridges ordering across retired elements.
    ``total_order=True`` (serial policy: every launch is host-blocking)
    declares the whole window ordered."""
    out: List[Violation] = []
    if total_order:
        return out
    n = len(elements)
    pos = {e.uid: i for i, e in enumerate(elements)}

    def closure_of(parents) -> int:
        mask = 0
        for p in parents:
            k = pos.get(p.uid)
            if k is not None:
                mask |= hb[k] | (1 << k)
        return mask

    hb = [0] * n
    hosts = sorted(((at, h) for at, h in host_log), key=lambda t: t[0])
    host_mask = 0
    hi = 0
    for i, e in enumerate(elements):
        while hi < len(hosts) and hosts[hi][0] <= i:
            host_mask |= closure_of(hosts[hi][1].parents)
            hi += 1
        hb[i] = closure_of(e.parents) | host_mask

    accesses: Dict[object, List[Tuple[int, AccessMode]]] = {}
    names: Dict[object, str] = {}
    for i, e in enumerate(elements):
        for key, mode in e.arg_modes():
            accesses.setdefault(key, []).append((i, mode))
    for e in elements:
        for a in e.args:
            names.setdefault(a.key, getattr(a.array, "name", str(a.key)))

    for key, acc in accesses.items():
        aname = names.get(key, str(key))
        for a in range(len(acc)):
            i, mi = acc[a]
            for b in range(a + 1, len(acc)):
                j, mj = acc[b]
                if not mi.conflicts_with(mj):
                    continue
                if not (hb[j] >> i) & 1:
                    out.append(Violation(
                        "race",
                        f"unordered {_race_kind(mi, mj)} on array "
                        f"{aname!r}: {elements[i].name}"
                        f"(uid {elements[i].uid}, {mi.value}) vs "
                        f"{elements[j].name}(uid {elements[j].uid}, "
                        f"{mj.value}) — no happens-before path",
                        (elements[i].uid, elements[j].uid)))
    return out


def verify_scheduler(sched, plans: bool = True) -> List[Violation]:
    """Verify a scheduler's live window, its DAG bookkeeping invariants,
    and (optionally) every cached execution plan."""
    with sched.pipeline:
        window = list(sched._elements)
        host_log = list(getattr(sched, "_host_log", ()))
        out = verify_elements(window, host_log,
                              total_order=(sched.policy == "serial"))
        out += [Violation("structure", msg)
                for msg in sched.dag.validate()]
        if plans:
            for plan in sched.plan_cache.all_plans():
                for v in verify_plan(plan):
                    out.append(Violation(
                        v.kind, f"plan {plan.name!r} ({plan.key}): "
                        f"{v.message}", v.elements))
    return out
