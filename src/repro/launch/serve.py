"""Production serving launcher: prefill + batched greedy decode on a
sharded mesh (bf16 weights, sharded KV cache).

    PYTHONPATH=src python -m repro.launch.serve --arch gemma3_12b --reduced \
        --batch 2 --prompt-len 32 --new-tokens 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.launch.mesh import make_mesh_for
from repro.models import init_cache, init_lm
from repro.runtime.steps import make_decode_step, make_prefill_step
from repro.sharding.context import sharding_rules
from repro.sharding.rules import cache_sharding, param_sharding


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3_12b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--model-parallel", type=int, default=2)
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=args.reduced)
    mesh = make_mesh_for(len(jax.devices()), args.model_parallel)
    params = init_lm(jax.random.PRNGKey(0), cfg, dtype=jnp.bfloat16)
    params = jax.device_put(params, param_sharding(params, mesh))
    max_len = args.prompt_len + args.new_tokens
    cross = args.prompt_len // 4 if cfg.n_encoder_layers else 0
    cache = init_cache(cfg, args.batch, max_len, cross_len=cross)
    cache = jax.device_put(cache, cache_sharding(cache, mesh))

    def wrap(fn):
        def inner(*a):
            with sharding_rules(mesh):
                return fn(*a)
        return inner

    rng = np.random.RandomState(0)
    batch = {"tokens": rng.randint(
        0, cfg.vocab, (args.batch, args.prompt_len)).astype(np.int32)}
    if cfg.n_encoder_layers:
        batch["frames"] = rng.randn(args.batch, cross,
                                    cfg.d_model).astype(np.float32)
    if cfg.frontend == "vision":
        batch["patches"] = rng.randn(args.batch, cfg.n_frontend_tokens,
                                     cfg.d_model).astype(np.float32) * 0.02

    with mesh:
        prefill = jax.jit(wrap(make_prefill_step(cfg)), donate_argnums=(2,))
        decode = jax.jit(wrap(make_decode_step(cfg)), donate_argnums=(2,))
        t0 = time.time()
        logits, cache = prefill(params, batch, cache)
        nxt = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        generated = [np.asarray(nxt)]
        for i in range(args.new_tokens - 1):
            nxt, _, cache = decode(params, nxt, cache,
                                   jnp.int32(args.prompt_len + i))
            generated.append(np.asarray(nxt))
        dt = time.time() - t0
    toks = args.batch * args.new_tokens
    gen = np.concatenate(generated, axis=1)
    print(f"mesh {dict(mesh.shape)} | generated {toks} tokens in {dt:.2f}s "
          f"({toks/dt:.1f} tok/s)")
    print("sample:", gen[0][:12])


if __name__ == "__main__":
    main()
