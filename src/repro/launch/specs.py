"""ShapeDtypeStruct stand-ins + shardings for every (arch x shape) cell.

Nothing here allocates: model/optimizer/cache shapes come from
``jax.eval_shape`` over the real init functions, so the dry-run lowers the
exact production step against the exact production state.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from ..data.pipeline import batch_specs
from ..models import init_cache, init_lm
from ..models.config import ArchConfig, ShapeCell
from ..optim import AdamW, Q8State
from ..runtime.steps import TrainState, make_decode_step, make_prefill_step, \
    make_train_step
from ..sharding.context import sharding_rules
from ..sharding.rules import batch_spec, cache_sharding, dp_axes, fit_spec, \
    param_sharding


def _with_rules(fn, mesh):
    """Activate use-site sharding constraints during tracing."""
    def wrapped(*args):
        with sharding_rules(mesh):
            return fn(*args)
    return wrapped


MICRO_TOKENS_BUDGET = 1 << 16     # ~64k tokens per microbatch (grad accum)


def plan_accum(cell: ShapeCell, mesh) -> Tuple[int, int]:
    """(accum, micro_batch): micro divisible by dp, tokens/micro bounded."""
    dp = 1
    for a in ("pod", "data"):
        if a in mesh.shape:
            dp *= mesh.shape[a]
    micro = min(cell.global_batch, max(dp, MICRO_TOKENS_BUDGET // cell.seq_len))
    micro -= micro % dp
    micro = max(micro, min(dp, cell.global_batch))
    while cell.global_batch % micro:
        micro -= dp
    accum = cell.global_batch // micro
    return accum, micro


def eval_shapes(fn, *args, **kw):
    return jax.eval_shape(fn, *args, **kw)


# ----------------------------------------------------------------------
def state_shapes(cfg: ArchConfig, optimizer: AdamW) -> TrainState:
    def init():
        params = init_lm(jax.random.PRNGKey(0), cfg)
        return TrainState(params, optimizer.init(params))
    return jax.eval_shape(init)


def opt_leaf_sharding(mesh, param_shard):
    """m/v moments mirror the parameter sharding; Q8 blocks keep the
    parameter's leading-axis sharding and leave the (nb, BLOCK) trailing
    axes unsharded."""

    def _axis_len(ax):
        if ax is None:
            return 1
        axes = ax if isinstance(ax, tuple) else (ax,)
        n = 1
        for a in axes:
            n *= mesh.shape.get(a, 1)
        return n

    def one(leaf, ps):
        if isinstance(leaf, Q8State):
            base = tuple(ps.spec) if ps is not None else ()
            lead = base[:-1] if base else ()
            last = base[-1] if base else None
            nb = leaf.codes.shape[-2]
            # ladder: full dp tuple -> each sub-axis -> unsharded, so a
            # non-dividing nb (e.g. 144 vs pod*data=32) still gets the
            # largest usable ZeRO degree instead of replication
            if isinstance(last, tuple):
                subs = sorted(last, key=_axis_len, reverse=True)
                candidates = [last] + subs
            else:
                candidates = [last]
            pick = None
            for c in candidates:
                if c is not None and nb % _axis_len(c) == 0:
                    pick = c
                    break
            codes = NamedSharding(mesh, fit_spec(lead + (pick, None),
                                                 leaf.codes.shape, mesh))
            scales = NamedSharding(mesh, fit_spec(lead + (pick,),
                                                  leaf.scales.shape, mesh))
            return Q8State(codes, scales)
        return ps

    return one


def train_state_sharding(mesh, cfg: ArchConfig, st_shapes: TrainState):
    ps = param_sharding(st_shapes.params, mesh)
    one = opt_leaf_sharding(mesh, None)
    is_q8 = lambda x: isinstance(x, Q8State)
    m_sh = jax.tree_util.tree_map(lambda leaf, p: one(leaf, p),
                                  st_shapes.opt.m, ps, is_leaf=is_q8)
    v_sh = jax.tree_util.tree_map(lambda leaf, p: one(leaf, p),
                                  st_shapes.opt.v, ps, is_leaf=is_q8)
    step_sh = NamedSharding(mesh, P())
    OptState = type(st_shapes.opt)
    return TrainState(ps, OptState(step=step_sh, m=m_sh, v=v_sh))


def replicated(mesh, tree):
    return jax.tree_util.tree_map(
        lambda _: NamedSharding(mesh, P()), tree)


# ----------------------------------------------------------------------
class CellLowering(NamedTuple):
    fn: Any                        # the jittable step function
    arg_specs: tuple               # ShapeDtypeStructs
    in_shardings: tuple
    out_shardings: Any
    donate_argnums: tuple
    meta: dict


def make_optimizer(cfg: ArchConfig) -> AdamW:
    # 8-bit optimizer state for the very large dense configs (DESIGN.md §5):
    # fp32 moments for <=200B params fit the pod; 340B needs int8 moments.
    quantized = cfg.param_count() > 200e9
    return AdamW(lr=3e-4, quantized=quantized)


def build_cell(cfg: ArchConfig, cell: ShapeCell, mesh, *,
               use_flash: bool = False, remat: bool = True,
               seq_shard: Optional[bool] = None) -> CellLowering:
    """Lowerable artifact for one (arch x shape x mesh) cell."""
    optimizer = make_optimizer(cfg)
    dp = dp_axes(mesh)
    if seq_shard is None:
        # Megatron-SP by default for training — except recurrent families,
        # whose token-shift ops slice the sequence dim every layer
        seq_shard = (cell.kind == "train"
                     and cfg.family not in ("ssm", "hybrid"))

    if cell.kind == "train":
        accum, micro = plan_accum(cell, mesh)
        st = state_shapes(cfg, optimizer)
        st_sh = train_state_sharding(mesh, cfg, st)
        b_specs = batch_specs(cfg, cell.seq_len, micro * accum, accum)
        bs = batch_spec(mesh, seq_shard=False)
        b_sh = {k: NamedSharding(mesh,
                                 P(*((None,) + tuple(bs[k]))))
                for k in b_specs}
        step = _with_rules(make_train_step(cfg, optimizer,
                                           use_flash=use_flash,
                                           remat=remat,
                                           seq_shard=seq_shard), mesh)
        metrics_shapes = jax.eval_shape(step, st, b_specs)[1]
        out_sh = (st_sh, replicated(mesh, metrics_shapes))
        return CellLowering(step, (st, b_specs), (st_sh, b_sh), out_sh,
                            donate_argnums=(0,),
                            meta={"accum": accum, "micro": micro,
                                  "quantized_opt": optimizer.quantized})

    # inference cells: bf16 weights (serving precision) -------------------
    import jax.numpy as jnp
    params = jax.eval_shape(
        lambda: init_lm(jax.random.PRNGKey(0), cfg, dtype=jnp.bfloat16))
    p_sh = param_sharding(params, mesh)
    B, S = cell.global_batch, cell.seq_len
    cross = S // 4 if cfg.n_encoder_layers else 0
    cache = jax.eval_shape(lambda: init_cache(cfg, B, S, cross_len=cross))
    c_sh = cache_sharding(cache, mesh)

    if cell.kind == "prefill":
        batch = {"tokens": jax.ShapeDtypeStruct((B, S), np.int32)}
        b_sh = {"tokens": NamedSharding(mesh, P(dp, "model" if seq_shard
                                                else None))}
        if cfg.n_encoder_layers:
            batch["frames"] = jax.ShapeDtypeStruct((B, cross, cfg.d_model),
                                                   np.float32)
            b_sh["frames"] = NamedSharding(mesh, P(dp, None, None))
        if cfg.frontend == "vision":
            batch["patches"] = jax.ShapeDtypeStruct(
                (B, cfg.n_frontend_tokens, cfg.d_model), np.float32)
            b_sh["patches"] = NamedSharding(mesh, P(dp, None, None))
        step = _with_rules(make_prefill_step(cfg, use_flash=use_flash), mesh)
        logits_sh = NamedSharding(mesh, fit_spec((dp, "model"),
                                                 (B, cfg.vocab), mesh))
        return CellLowering(step, (params, batch, cache),
                            (p_sh, b_sh, c_sh), (logits_sh, c_sh),
                            donate_argnums=(2,), meta={})

    # decode -------------------------------------------------------------
    tokens = jax.ShapeDtypeStruct((B, 1), np.int32)
    pos = jax.ShapeDtypeStruct((), np.int32)
    t_sh = NamedSharding(mesh, fit_spec((dp, None), (B, 1), mesh))
    pos_sh = NamedSharding(mesh, P())
    step = _with_rules(make_decode_step(cfg), mesh)
    logits_sh = NamedSharding(mesh, fit_spec((dp, "model"),
                                             (B, cfg.vocab), mesh))
    return CellLowering(step, (params, tokens, cache, pos),
                        (p_sh, t_sh, c_sh, pos_sh),
                        (t_sh, logits_sh, c_sh),
                        donate_argnums=(2,), meta={})
