"""HLO-text cost analyzer for the dry-run roofline.

``compiled.cost_analysis()`` counts while-loop bodies ONCE (verified on this
jaxlib), which silently undercounts a scanned 96-layer model by ~96x.  This
module parses ``compiled.as_text()`` instead:

* builds the computation call graph (entry → while bodies / fusions / calls)
  with **trip-count multipliers** extracted from each while condition's
  comparison constant;
* FLOPs from ``dot`` / ``convolution`` ops (2 x numel(result) x contracted
  extent) plus a 1-flop/elem charge for arithmetic fusions;
* HBM traffic ~= sum over materialized ops (fusion parameters + results) —
  fusions internalize their intermediates, which is exactly XLA's VMEM/HBM
  boundary model;
* collective bytes per category (all-gather / all-reduce / reduce-scatter /
  all-to-all / collective-permute), with wire-byte factors applied in the
  roofline layer.

All numbers are whole-module totals (sum over devices is NOT taken: SPMD
modules are per-device programs, so totals are already per-device).
"""
from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*)$")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->\s*.+\{\s*$")
_CALLED_RE = re.compile(r"(?:calls=|to_apply=|body=|condition=)%?([\w.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")


def _parse_shape(s: str) -> Tuple[Optional[str], List[int]]:
    m = _SHAPE_RE.match(s.strip())
    if not m:
        return None, []
    dt, dims = m.group(1), m.group(2)
    if dt not in _DTYPE_BYTES:
        return None, []
    return dt, [int(d) for d in dims.split(",")] if dims else []


def _numel(dims: List[int]) -> int:
    n = 1
    for d in dims:
        n *= d
    return n


def _nbytes(dt: str, dims: List[int]) -> int:
    return _DTYPE_BYTES[dt] * _numel(dims)


@dataclass
class OpInfo:
    name: str
    dtype: Optional[str]
    dims: List[int]
    kind: str
    line: str
    operands: List[str]


@dataclass
class HLOStats:
    flops: float = 0.0
    memory_bytes: float = 0.0
    wire_bytes: float = 0.0        # estimated per-device ICI traffic
    collective_bytes: Dict[str, float] = field(
        default_factory=lambda: defaultdict(float))
    collective_counts: Dict[str, int] = field(
        default_factory=lambda: defaultdict(int))
    trip_counts: Dict[str, int] = field(default_factory=dict)

    @property
    def total_collective_bytes(self) -> float:
        return sum(self.collective_bytes.values())

    def as_dict(self) -> dict:
        return {"flops": self.flops, "memory_bytes": self.memory_bytes,
                "wire_bytes": self.wire_bytes,
                "collective_bytes": dict(self.collective_bytes),
                "collective_counts": dict(self.collective_counts),
                "total_collective_bytes": self.total_collective_bytes}


_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def _group_size(line: str) -> int:
    """Participants per replica group (v2 format [ngroups,gsize]<=[total],
    else literal {{0,1,...},...})."""
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]<=", line)
    if m:
        return max(1, int(m.group(2)))
    m = re.search(r"replica_groups=\{\{([\d,]+)\}", line)
    if m:
        return max(1, m.group(1).count(",") + 1)
    return 1


def _wire_bytes(kind: str, nbytes: float, g: int) -> float:
    """Per-device link traffic for one collective op (ring algorithms)."""
    if g <= 1:
        return 0.0
    if kind == "all-gather":          # result = full gather
        return nbytes * (g - 1) / g
    if kind == "all-reduce":          # reduce-scatter + all-gather
        return 2.0 * nbytes * (g - 1) / g
    if kind == "reduce-scatter":      # result = one shard
        return nbytes * (g - 1)
    if kind == "all-to-all":
        return nbytes * (g - 1) / g
    return nbytes                     # collective-permute


def _op_kind(rest: str) -> str:
    # rest looks like "f32[1,2]{1,0} opname(...)" or "(tuple...) while(...)"
    m = re.search(r"\}?\s([a-z][\w\-]*)\(", rest)
    return m.group(1) if m else ""


class _Module:
    def __init__(self, text: str):
        self.comps: Dict[str, List[OpInfo]] = {}
        self.shape_tab: Dict[str, Tuple[Optional[str], List[int]]] = {}
        self.local_shapes: Dict[str, Dict[str, Tuple[Optional[str], List[int]]]] = {}
        self._parse(text)

    def lookup(self, comp: str, name: str):
        loc = self.local_shapes.get(comp, {})
        if name in loc:
            return loc[name]
        return self.shape_tab.get(name)

    def root_op(self, comp: str) -> Optional["OpInfo"]:
        ops = self.comps.get(comp)
        return ops[-1] if ops else None

    def operand_read_bytes(self, called: str, operand_idx: int,
                           full_bytes: float) -> float:
        """HBM bytes a fusion actually reads from operand ``operand_idx``:
        if every consumer of the corresponding parameter is a slicing op
        (dynamic-slice / gather / slice), only the slices are read."""
        ops = self.comps.get(called)
        if not ops:
            return full_bytes
        pname = None
        for op in ops:
            if op.kind == "parameter":
                m = re.search(r"parameter\((\d+)\)", op.line)
                if m and int(m.group(1)) == operand_idx:
                    pname = op.name
                    break
        if pname is None:
            return full_bytes
        sliced = 0.0
        for op in ops:
            if pname not in op.operands:
                continue
            if op.kind in ("dynamic-slice", "gather", "slice") \
                    and op.operands and op.operands[0] == pname:
                if op.dtype:
                    sliced += _nbytes(op.dtype, op.dims)
            elif op.kind in ("bitcast", "reshape"):
                return full_bytes   # passthrough: give up, charge full
            else:
                return full_bytes   # consumed wholesale
        return sliced if sliced > 0 else full_bytes

    def _parse(self, text: str) -> None:
        cur: Optional[str] = None
        for raw in text.splitlines():
            line = raw.rstrip()
            hdr = _COMP_HDR_RE.match(line.strip())
            if hdr and ("->" in line) and line.strip().endswith("{"):
                cur = hdr.group(1)
                self.comps[cur] = []
                continue
            if line.strip() == "}":
                cur = None
                continue
            if cur is None:
                continue
            m = _DEF_RE.match(line)
            if not m:
                continue
            name, rest = m.group(1), m.group(2)
            dt, dims = _parse_shape(rest)
            kind = _op_kind(rest)
            ops = re.findall(r"%([\w.\-]+)", rest.split("(", 1)[-1]) \
                if "(" in rest else []
            info = OpInfo(name, dt, dims, kind, rest, ops)
            self.comps[cur].append(info)
            self.shape_tab[name] = (dt, dims)
            self.local_shapes.setdefault(cur, {})[name] = (dt, dims)

    # -- trip counts -----------------------------------------------------
    def trip_count(self, cond_comp: str) -> int:
        best = 1
        for op in self.comps.get(cond_comp, []):
            cm = re.search(r"constant\((\d+)\)", op.line)
            if cm and op.dtype in ("s32", "u32", "s64", "u64"):
                best = max(best, int(cm.group(1)))
        return best

    # -- multipliers via call graph ---------------------------------------
    def multipliers(self, entry: str) -> Dict[str, float]:
        mult: Dict[str, float] = defaultdict(float)
        mult[entry] = 1.0
        order = [entry]
        seen = {entry}
        # BFS through call sites, accumulating multipliers.
        i = 0
        while i < len(order):
            comp = order[i]
            i += 1
            for op in self.comps.get(comp, []):
                called: List[Tuple[str, float]] = []
                if op.kind == "while":
                    names = dict(re.findall(r"(body|condition)=%?([\w.\-]+)",
                                            op.line))
                    # XLA annotates known trip counts in backend_config
                    tm = re.search(r'"known_trip_count":\{"n":"(\d+)"', op.line)
                    if tm:
                        trip = int(tm.group(1))
                    else:
                        trip = self.trip_count(names.get("condition", ""))
                    if "body" in names:
                        called.append((names["body"], float(trip)))
                    if "condition" in names:
                        called.append((names["condition"], float(trip + 1)))
                elif op.kind == "conditional":
                    bm = _BRANCHES_RE.search(op.line)
                    if bm:
                        for b in re.findall(r"%?([\w.\-]+)", bm.group(1)):
                            called.append((b, 1.0))
                else:
                    for c in _CALLED_RE.findall(op.line):
                        called.append((c, 1.0))
                for cname, factor in called:
                    if cname not in self.comps:
                        continue
                    mult[cname] += mult[comp] * factor
                    if cname not in seen:
                        seen.add(cname)
                        order.append(cname)
        return dict(mult)

    def entry(self) -> str:
        # ENTRY computation: the one declared with 'ENTRY' — re-find it.
        return self._entry_name

    def set_entry(self, text: str) -> None:
        m = re.search(r"^ENTRY\s+%?([\w.\-]+)", text, re.M)
        self._entry_name = m.group(1) if m else next(iter(self.comps))


def _dot_flops(mod: _Module, op: OpInfo) -> float:
    if not op.dims:
        return 0.0
    out = _numel(op.dims)
    cm = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.line)
    contract = 1
    if cm and op.operands:
        lhs = mod.shape_tab.get(op.operands[0])
        if lhs and lhs[1]:
            for d in cm.group(1).split(","):
                if d and int(d) < len(lhs[1]):
                    contract *= lhs[1][int(d)]
    return 2.0 * out * contract


def _conv_flops(mod: _Module, op: OpInfo) -> float:
    if not op.dims or len(op.operands) < 2:
        return 0.0
    out = _numel(op.dims)
    rhs = mod.shape_tab.get(op.operands[1])
    if rhs and rhs[1]:
        # kernel: O,I,*spatial in some layout; flops = 2*out*prod(kernel)/O
        k = _numel(rhs[1])
        o = max(op.dims) if op.dims else 1
        return 2.0 * out * k / max(1, min(rhs[1]))
    return 2.0 * out


def analyze_hlo(text: str) -> HLOStats:
    mod = _Module(text)
    mod.set_entry(text)
    mults = mod.multipliers(mod.entry())
    stats = HLOStats()
    stats.trip_counts = {c: int(m) for c, m in mults.items() if m > 1}

    for comp, ops in mod.comps.items():
        mult = mults.get(comp, 0.0)
        if mult <= 0:
            continue
        for op in ops:
            k = op.kind
            if k == "dot":
                stats.flops += mult * _dot_flops(mod, op)
            elif k == "convolution":
                stats.flops += mult * _conv_flops(mod, op)
            elif k in ("add", "multiply", "subtract", "divide", "exponential",
                       "tanh", "rsqrt", "sqrt", "maximum", "minimum",
                       "log", "power", "negate", "compare", "select") \
                    and op.dims:
                stats.flops += mult * _numel(op.dims)
            for cname in _COLLECTIVES:
                if k == cname:
                    b = 0.0
                    if op.dtype:
                        b = _nbytes(op.dtype, op.dims)
                    else:
                        # tuple-shaped collective: sum operand sizes
                        for o in op.operands:
                            sh = mod.shape_tab.get(o)
                            if sh and sh[0]:
                                b += _nbytes(sh[0], sh[1])
                    stats.collective_bytes[cname] += mult * b
                    stats.collective_counts[cname] += int(mult)
                    stats.wire_bytes += mult * _wire_bytes(
                        cname, b, _group_size(op.line))
            # memory traffic: materialized ops (fusions internalize their
            # intermediates).  In-place update ops (dynamic-update-slice and
            # fusions rooted at one) are charged for the *update slice*, not
            # the whole aliased buffer — XLA updates these in place.
            if k in ("fusion", "dot", "convolution", "copy",
                     "dynamic-update-slice", "dynamic-slice", "scatter",
                     "gather", "reduce") or k in _COLLECTIVES:
                # sliced reads/writes touch only the slice, not the operand
                if k in ("gather", "dynamic-slice"):
                    if op.dtype:
                        stats.memory_bytes += mult * 2 * _nbytes(op.dtype,
                                                                 op.dims)
                    continue
                if k == "scatter":
                    upd = (mod.lookup(comp, op.operands[2])
                           if len(op.operands) > 2 else None)
                    if upd and upd[0]:
                        stats.memory_bytes += mult * 2 * _nbytes(upd[0],
                                                                 upd[1])
                    continue
                dus_root = None
                if k == "dynamic-update-slice":
                    dus_root = (comp, op)
                elif k == "fusion":
                    called = _CALLED_RE.findall(op.line)
                    if called:
                        r = mod.root_op(called[0])
                        if r is not None and r.kind == "dynamic-update-slice":
                            dus_root = (called[0], r)
                if dus_root is not None:
                    ccomp, r = dus_root
                    upd = (mod.lookup(ccomp, r.operands[1])
                           if len(r.operands) > 1 else None)
                    if upd and upd[0]:
                        stats.memory_bytes += mult * 2 * _nbytes(upd[0], upd[1])
                    continue
                if op.dtype:
                    stats.memory_bytes += mult * _nbytes(op.dtype, op.dims)
                called = (_CALLED_RE.findall(op.line)
                          if op.kind == "fusion" else [])
                for idx, o in enumerate(op.operands):
                    sh = mod.lookup(comp, o)
                    if sh and sh[0]:
                        b = _nbytes(sh[0], sh[1])
                        if called:
                            b = mod.operand_read_bytes(called[0], idx, b)
                        stats.memory_bytes += mult * b
    return stats
