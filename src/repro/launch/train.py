"""Production training launcher.

Builds the device mesh (all local devices, or the production 16x16 /
2x16x16 meshes on a real pod), shards the train state per sharding/rules,
and drives the step loop with fault-tolerant checkpointing and exact
resume.  On this CPU container use ``--reduced`` (the full configs only
lower via dryrun.py).

    PYTHONPATH=src python -m repro.launch.train --arch qwen3_32b --reduced \
        --steps 20 --ckpt /tmp/ckpt
"""
from __future__ import annotations

import argparse
import time

import jax

from repro.checkpoint import CheckpointManager
from repro.configs import get_config
from repro.data import SyntheticTokenStream
from repro.launch.mesh import make_mesh_for, make_production_mesh
from repro.models import init_lm
from repro.optim import AdamW
from repro.runtime.steps import TrainState, make_train_step
from repro.sharding.context import sharding_rules
from repro.sharding.rules import batch_spec, param_sharding
from jax.sharding import NamedSharding, PartitionSpec as P


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3_32b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--production-mesh", action="store_true",
                    help="16x16 (or 2x16x16 with --multi-pod) mesh")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--model-parallel", type=int, default=2)
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=args.reduced)
    if args.production_mesh:
        mesh = make_production_mesh(multi_pod=args.multi_pod)
    else:
        mesh = make_mesh_for(len(jax.devices()), args.model_parallel)
    print(f"mesh: {dict(mesh.shape)} | params(full-cfg) "
          f"{cfg.param_count()/1e6:.1f}M")

    optimizer = AdamW(lr=3e-4, warmup=20, total_steps=max(100, args.steps))
    params = init_lm(jax.random.PRNGKey(0), cfg)
    state = TrainState(params, optimizer.init(params))
    p_sh = param_sharding(params, mesh)
    state = TrainState(jax.device_put(params, p_sh), state.opt)

    stream = SyntheticTokenStream(cfg, args.seq, args.global_batch,
                                  accum=args.accum)
    bspec = batch_spec(mesh)
    sample = stream.batch(0)
    b_sh = {k: NamedSharding(mesh, P(*((None,) + tuple(bspec.get(
        k, P(None, None))))))
            for k in sample}

    def wrapped(st, batch):
        with sharding_rules(mesh):
            return make_train_step(cfg, optimizer)(st, batch)

    with mesh:
        step_fn = jax.jit(wrapped, in_shardings=(None, b_sh),
                          donate_argnums=(0,))

        ckpt = CheckpointManager(args.ckpt) if args.ckpt else None
        start = 0
        if ckpt:
            # (step, state) resolved atomically: resuming the loop from a
            # different step than the restored state breaks exact resume.
            ck_step, ck_state, extra = ckpt.restore_latest(like=state)
            if ck_step is not None:
                saved_seed = extra.get("stream_seed")
                if saved_seed is not None and saved_seed != stream.seed:
                    raise ValueError(
                        f"checkpoint was trained with stream seed "
                        f"{saved_seed}, this run has {stream.seed}: "
                        f"resume would not be exact")
                start, state = ck_step, ck_state
                print(f"resumed from step {start}")

        t0 = time.time()
        for step in range(start, args.steps):
            batch = {k: jax.device_put(v, b_sh[k])
                     for k, v in stream.batch(step).items()}
            state, metrics = step_fn(state, batch)
            if (step + 1) % 5 == 0 or step == args.steps - 1:
                print(f"step {step+1}: loss={float(metrics['loss']):.4f} "
                      f"gnorm={float(metrics['grad_norm']):.3f} "
                      f"lr={float(metrics['lr']):.2e}")
            if ckpt and (step + 1) % args.ckpt_every == 0:
                ckpt.save(step + 1, state,
                          extra={"stream_seed": stream.seed})
        if ckpt:
            ckpt.wait()
        dt = time.time() - t0
        toks = (args.steps - start) * args.global_batch * args.seq
        print(f"done: {dt:.1f}s, {toks/dt:.0f} tok/s")


if __name__ == "__main__":
    main()
