import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any other import (jax locks the device
# count at first initialization).
import argparse
import json
import sys
import time
import traceback

import jax
import numpy as np

from repro.configs import ARCH_IDS, cells, get_config
from repro.launch.hlostats import analyze_hlo
from repro.launch.mesh import (HBM_BW, HBM_BYTES, ICI_BW, PEAK_BF16_FLOPS,
                               make_production_mesh)
from repro.launch.specs import build_cell
from repro.models.config import SHAPES


def roofline_terms(stats, mem, chips: int, cfg, cell) -> dict:
    """Three-term roofline (§Roofline).  HLO stats are per-device (SPMD
    modules are per-device programs)."""
    compute_s = stats.flops / PEAK_BF16_FLOPS
    memory_s = stats.memory_bytes / HBM_BW
    collective_s = stats.wire_bytes / ICI_BW
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": collective_s}
    dominant = max(terms, key=terms.get)
    # MODEL_FLOPS: 6*N*D for training, 2*N*D forward-only (per device)
    n_params = cfg.param_count(active_only=True)
    tokens = cell.seq_len * cell.global_batch if cell.kind != "decode" \
        else cell.global_batch
    factor = 6.0 if cell.kind == "train" else 2.0
    model_flops = factor * n_params * tokens / chips
    return {
        **terms,
        "dominant": dominant,
        "model_flops_per_device": model_flops,
        "hlo_flops_per_device": stats.flops,
        "useful_flops_ratio": model_flops / stats.flops if stats.flops else 0,
        "step_time_bound_s": max(terms.values()),
        "roofline_fraction": (compute_s / max(terms.values())
                              if max(terms.values()) > 0 else 0.0),
    }


def run_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
             use_flash: bool = False, seq_shard=None,
             remat: bool = True, verbose: bool = True) -> dict:
    cfg = get_config(arch)
    cell = SHAPES[shape_name]
    for c, skip in cells(arch):
        if c.name == shape_name and skip:
            return {"arch": arch, "shape": shape_name, "skipped": skip}

    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = int(np.prod(list(mesh.shape.values())))
    t0 = time.time()
    lowering = build_cell(cfg, cell, mesh, use_flash=use_flash,
                          remat=remat, seq_shard=seq_shard)
    with mesh:
        jitted = jax.jit(lowering.fn,
                         in_shardings=lowering.in_shardings,
                         out_shardings=lowering.out_shardings,
                         donate_argnums=lowering.donate_argnums)
        lowered = jitted.lower(*lowering.arg_specs)
        compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    stats = analyze_hlo(compiled.as_text())

    arg_b = getattr(mem, "argument_size_in_bytes", 0)
    out_b = getattr(mem, "output_size_in_bytes", 0)
    tmp_b = getattr(mem, "temp_size_in_bytes", 0)
    alias_b = getattr(mem, "alias_size_in_bytes", 0)
    peak_bytes = arg_b + out_b + tmp_b - alias_b
    result = {
        "arch": arch, "shape": shape_name,
        "mesh": dict(mesh.shape), "chips": chips,
        "compile_s": round(t_compile, 1),
        "memory": {"argument_bytes": arg_b, "output_bytes": out_b,
                   "temp_bytes": tmp_b, "alias_bytes": alias_b,
                   "peak_bytes_per_device": peak_bytes,
                   "fits_hbm": bool(peak_bytes <= HBM_BYTES),
                   "hbm_fraction": peak_bytes / HBM_BYTES},
        "xla_cost_analysis": {
            "flops_once": float(ca.get("flops", 0.0)),
            "bytes_once": float(ca.get("bytes accessed", 0.0))},
        "hlo": stats.as_dict(),
        "roofline": roofline_terms(stats, mem, chips, cfg, cell),
        "meta": lowering.meta,
    }
    if verbose:
        m = result["memory"]
        r = result["roofline"]
        print(f"[{arch} x {shape_name} x {'2x16x16' if multi_pod else '16x16'}]"
              f" compile {t_compile:.0f}s | "
              f"mem/device {m['peak_bytes_per_device']/2**30:.2f} GiB "
              f"({'fits' if m['fits_hbm'] else 'OVER'}) | "
              f"compute {r['compute_s']*1e3:.2f} ms, "
              f"memory {r['memory_s']*1e3:.2f} ms, "
              f"collective {r['collective_s']*1e3:.2f} ms -> "
              f"{r['dominant']} bound, roofline {r['roofline_fraction']:.2f}")
        print(f"  memory_analysis: {mem}")
        print(f"  cost_analysis(once): flops={ca.get('flops', 0):.3e} "
              f"bytes={ca.get('bytes accessed', 0):.3e}")
        print(f"  collectives: { {k: f'{v/2**30:.2f} GiB' for k, v in stats.collective_bytes.items()} }")
    return result


def main() -> None:
    ap = argparse.ArgumentParser(description="multi-pod dry run")
    ap.add_argument("--arch", default=None, help="arch id (default: all)")
    ap.add_argument("--shape", default=None, help="shape cell (default: all)")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--use-flash", action="store_true")
    ap.add_argument("--seq-shard", action="store_true", default=None)
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    args = ap.parse_args()

    archs = [args.arch] if args.arch else ARCH_IDS
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    os.makedirs(args.out, exist_ok=True)
    failures = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                tag = f"{arch}__{shape}__{'multi' if mp else 'single'}"
                try:
                    res = run_cell(arch, shape, multi_pod=mp,
                                   use_flash=args.use_flash,
                                   seq_shard=args.seq_shard,
                                   remat=not args.no_remat)
                except Exception as e:
                    traceback.print_exc()
                    failures.append(tag)
                    res = {"arch": arch, "shape": shape, "multi_pod": mp,
                           "error": repr(e)}
                with open(os.path.join(args.out, tag + ".json"), "w") as f:
                    json.dump(res, f, indent=1)
    if failures:
        print("FAILURES:", failures, file=sys.stderr)
        sys.exit(1)
    print("dry-run complete")


if __name__ == "__main__":
    main()
